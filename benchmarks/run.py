"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time per
benchmark unit; derived = the table's headline quantity reproduced) and
writes the same rows machine-readably to ``BENCH_paper.json`` so the
paper-table benchmarks feed the ``BENCH_*`` perf trajectory alongside
``BENCH_serve.json`` (compare the file across PRs).

  table1_pipeline      — Table I: data-pipeline stages as parallel jobs
  table3_detection     — Table III: 30-model detection campaign accounting
  table4_ba_models     — Table IV: U-Net family comparison (reduced, real)
  table5_totals        — Table V: 234-model / 4,040-hour campaign totals
  roofline_summary     — §Roofline figure: dominant terms from the dry-run
  kernel_micro         — kernel-path microbenchmarks (CPU, jnp paths)
  resume_overhead      — durable-checkpoint cost on the training hot path
                         (async cadence saves; contract: <5% steps/s)
"""
from __future__ import annotations

import json
import pathlib
import tempfile
import time

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]
ROWS = []


def row(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


# ---------------------------------------------------------------- Table I
def table1_pipeline():
    """Paper Table I: Download/Norm/Label/Chip stages, #jobs and GB."""
    from repro.core import JobSpec, Orchestrator, PersistentVolume, Resources
    from repro.data.chipping import dedup_chips, make_chips
    from repro.data.normalize import percentile_stretch
    from repro.data.rasters import synth_raster

    n_scenes = 6
    t0 = time.time()
    with tempfile.TemporaryDirectory() as td:
        pvc = PersistentVolume(td)
        orch = Orchestrator(pvc)
        stage_bytes = {"download": 0, "norm": 0, "label": 0, "chip": 0}
        chips_all = []

        def dl(i="0", **kw):
            s = synth_raster(f"bench-{i}", 256, 256, seed=int(i))
            stage_bytes["download"] += s.raster.nbytes
            return s

        scenes = []
        for i in range(n_scenes):
            orch.submit(JobSpec(name=f"download-{i}", payload=dl,
                                env={"i": str(i)},
                                resources=Resources(gpus=0, cpus=2,
                                                    memory_gb=8)))
        orch.run_local()
        scenes = [r.result for r in orch.records.values()]

        for s in scenes:
            norm = percentile_stretch(s.raster)
            stage_bytes["norm"] += norm.nbytes
            stage_bytes["label"] += s.mask.nbytes
            cs = make_chips(norm[..., :3], s.mask, s.scene_id,
                            chip=64, overlap=0.25)
            chips_all.extend(cs)
            stage_bytes["chip"] += sum(c.image.nbytes for c in cs)
        chips_all = dedup_chips(chips_all)
    wall = time.time() - t0
    total_mb = sum(stage_bytes.values()) / 1e6
    row("table1_pipeline", wall * 1e6 / n_scenes,
        f"stages=4 jobs={n_scenes + 3 * n_scenes} data_mb={total_mb:.1f} "
        f"chips={len(chips_all)} (paper: 174 jobs / 992.6 GB / 5762 chips)")


# --------------------------------------------------------------- Table III
def table3_detection():
    """Paper Table III: 10 networks x 3 datasets, 4 GPUs each; reproduce the
    campaign's cluster accounting (1,402 GPU-h of training)."""
    from repro.core import ClusterSim
    from repro.launch.submit import build_campaign

    jobs = build_campaign("detection")
    t0 = time.time()
    res = ClusterSim().run(jobs)
    wall = time.time() - t0
    row("table3_detection", wall * 1e6 / len(jobs),
        f"models=30 gpu_hours={res.total_gpu_hours:.0f} "
        f"makespan_h={res.makespan_h:.1f} "
        f"(paper: 30 models / {4 * (241.2 + 580.4 + 580.6):.0f} GPU-h)")


# --------------------------------------------------------------- Table IV
def table4_ba_models():
    """Paper Table IV: U-Net vs U-Net++ vs DeepLabV3 vs DeepLabV3+ with the
    best hyperparameters — real (reduced) training on the synthetic BA set."""
    import jax
    import jax.numpy as jnp
    from repro.data.chipping import make_chips
    from repro.data.normalize import percentile_stretch
    from repro.data.rasters import synth_raster
    from repro.models.segmentation import (SEG_MODELS, seg_apply, seg_init,
                                           seg_loss, seg_metrics)
    from repro.optim import get_optimizer

    chips = []
    for i in range(3):
        s = synth_raster(f"t4-{i}", 192, 192, seed=i)
        img = percentile_stretch(s.raster)[..., :3]
        chips.extend(make_chips(img, s.mask, s.scene_id, chip=64,
                                overlap=0.25, min_frac=0.08))
    x = jnp.asarray(np.stack([c.image for c in chips]))
    m = jnp.asarray(np.stack([c.mask for c in chips]), jnp.int32)
    xtr, mtr, xte, mte = x[:-4], m[:-4], x[-4:], m[-4:]

    results = {}
    for name in sorted(SEG_MODELS):
        t0 = time.time()
        params = seg_init(name, jax.random.PRNGKey(0), width=8)
        opt = get_optimizer("lamb")   # paper's winning optimizer
        st = opt.init(params)

        @jax.jit
        def step(p, s, i):
            l, g = jax.value_and_grad(
                lambda p: seg_loss(name, p, xtr, mtr))(p)
            return *opt.update(g, s, p, i, 1e-2), l

        for i in range(25):
            params, st, loss = step(params, st, jnp.asarray(i))
        f1 = float(seg_metrics(seg_apply(name, params, xte), mte)["f1"])
        iou = float(seg_metrics(seg_apply(name, params, xte), mte)["iou"])
        wall = time.time() - t0
        results[name] = (f1, iou, wall)
        row(f"table4_{name}", wall * 1e6 / 25,
            f"f1={f1:.3f} iou={iou:.3f} "
            f"(paper full-scale: f1 0.82-0.84, iou 0.69-0.72)")
    best = max(results, key=lambda n: results[n][0])
    row("table4_best_model", 0.0,
        f"best={best} (paper: DeepLabV3 best IoU, DeepLabV3+ best Prec)")


# ---------------------------------------------------------------- Table V
def table5_totals():
    """Paper Table V: all three campaigns, 234 models / 4,040 h total."""
    from repro.core import ClusterSim
    from repro.launch.submit import build_campaign

    jobs = []
    for c in ("detection", "burned_area", "deforestation"):
        jobs.extend(build_campaign(c))
    t0 = time.time()
    res = ClusterSim().run(jobs)
    wall = time.time() - t0
    months_serial = res.total_wall_hours / (24 * 30)
    row("table5_totals", wall * 1e6 / len(jobs),
        f"models={len(jobs)} wall_hours={res.total_wall_hours:.0f} "
        f"makespan_h={res.makespan_h:.1f} serial_months={months_serial:.1f} "
        f"speedup={res.speedup_vs_serial():.0f}x "
        f"(paper: 234 models / 4040 h / '5.5+ months serial')")


# ----------------------------------------------------------- §Roofline
def _generate_dryrun_artifacts(d: pathlib.Path) -> bool:
    """Produce the dry-run records the roofline row aggregates.  Runs in a
    subprocess: the dryrun runner needs its 512-host-device XLA trick set
    *before* jax initializes, which is long gone in this process (table4
    already trained models)."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "repro.launch", "run", "dryrun",
           "--arch", "stablelm-1.6b", "--shape", "train_4k",
           "--out", str(d)]
    try:
        proc = subprocess.run(cmd, env=env, cwd=ROOT, capture_output=True,
                              text=True, timeout=1800)
    except (OSError, subprocess.TimeoutExpired) as e:
        print(f"# dryrun generation failed: {e}")
        return False
    if proc.returncode != 0:
        print(f"# dryrun generation failed:\n{proc.stderr[-2000:]}")
    return proc.returncode == 0


def roofline_summary():
    d = ROOT / "experiments" / "dryrun"
    if not (d.exists() and any(d.glob("*.json"))):
        # no committed sweep: generate a single-cell sweep into a scratch
        # dir (NOT experiments/dryrun — that dir, when present, must hold
        # the complete sweep; tests/test_system.py enforces it)
        d = ROOT / "experiments" / "roofline_dryrun"
        have_scratch = d.exists() and any(d.glob("*.json"))
        if not have_scratch and not _generate_dryrun_artifacts(d):
            row("roofline_summary", 0.0,
                "dry-run artifacts missing and generation failed")
            return
    recs = [json.loads(p.read_text()) for p in sorted(d.glob("*.json"))]
    ok = [r for r in recs if r.get("status") == "ok" and "roofline" in r]
    if not ok:
        row("roofline_summary", 0.0,
            f"cells={len(recs)} ok=0 (no usable dry-run records)")
        return
    doms = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(
            r["roofline"]["dominant"], 0) + 1
    mean_compile = float(np.mean([r["compile_s"] for r in ok]))
    row("roofline_summary", float(np.mean([r["total_s"] for r in ok])) * 1e6,
        f"cells={len(recs)} ok={len(ok)} dominant={doms} "
        f"mean_compile_s={mean_compile:.1f}")


# ---------------------------------------------------------- kernel micro
def kernel_micro():
    import jax
    import jax.numpy as jnp
    from repro.models.layers import flash_attention_jnp, naive_attention

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, hd = 1, 1024, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, 2, hd))
    v = jax.random.normal(ks[2], (B, S, 2, hd))

    naive = jax.jit(lambda q, k, v: naive_attention(q, k, v, causal=True,
                                                    window=None))
    flash = jax.jit(lambda q, k, v: flash_attention_jnp(
        q, k, v, causal=True, window=None, q_chunk=256, k_chunk=256))

    for name, fn in [("attn_naive_1k", naive), ("attn_flash_jnp_1k", flash)]:
        fn(q, k, v).block_until_ready()
        t0 = time.time()
        n = 5
        for _ in range(n):
            fn(q, k, v).block_until_ready()
        row(f"kernel_{name}", (time.time() - t0) / n * 1e6,
            f"B{B}xS{S}xH{H}xhd{hd}")

    # MoE dispatch: argsort ranking vs (TK,E) cumsum ranking
    T, E, K = 8192, 64, 4
    eids = jax.random.randint(ks[0], (T, K), 0, E)

    @jax.jit
    def rank_argsort(eids):
        ef = eids.reshape(-1)
        order = jnp.argsort(ef, stable=True)
        se = ef[order]
        start = jnp.searchsorted(se, jnp.arange(E))
        rk = jnp.arange(T * K) - start[se]
        return jnp.zeros((T * K,), jnp.int32).at[order].set(
            rk.astype(jnp.int32))

    @jax.jit
    def rank_cumsum(eids):
        oh = jax.nn.one_hot(eids.reshape(-1), E, dtype=jnp.int32)
        ranks = jnp.cumsum(oh, axis=0) - oh
        return (ranks * oh).sum(-1)

    for name, fn in [("moe_rank_argsort", rank_argsort),
                     ("moe_rank_cumsum", rank_cumsum)]:
        out1 = fn(eids)
        out1.block_until_ready()
        t0 = time.time()
        n = 10
        for _ in range(n):
            fn(eids).block_until_ready()
        row(f"kernel_{name}", (time.time() - t0) / n * 1e6,
            f"T{T}xE{E}xK{K}")
    assert bool(jnp.all(rank_argsort(eids) == rank_cumsum(eids)))


# ------------------------------------------------------- resume overhead
def resume_overhead():
    """Cost of durable checkpointing on the training hot path: the same
    reduced run with and without cadence checkpoints (async saves).  The
    subsystem's contract is < 5% steps/s regression — saves happen on a
    background thread, the loop only pays the host snapshot.

    Conditions run interleaved (base, ckpt, base, ckpt) and each takes
    its best repetition: single-shot wall comparisons on a shared host
    drift more than the effect being measured (the hot-path blocked
    time, reported separately, is the ground truth).  On hosts with
    fewer cores than compute threads + 1 the wall delta also includes
    the background writer competing for cores — a cost the async design
    trades for durability, amortized by the save cadence (every 8 steps
    here; preemption-test runs use stress cadences instead)."""
    import tempfile

    from repro.launch.train import train_main

    steps = 32
    kw = dict(steps=steps, batch=4, seq=64, log_every=0, seed=0)
    base_runs, ck_runs = [], []
    with tempfile.TemporaryDirectory() as td:
        for rep in range(2):
            base_runs.append(train_main("stablelm-1.6b", **kw))
            ck_runs.append(train_main("stablelm-1.6b",
                                      checkpoint_dir=f"{td}/rep{rep}",
                                      checkpoint_every=8, **kw))
    base = max(base_runs, key=lambda r: r["steps_per_s"])
    ck = max(ck_runs, key=lambda r: r["steps_per_s"])
    regression = 1.0 - ck["steps_per_s"] / base["steps_per_s"]
    st = ck["checkpoint"]
    row("resume_overhead", ck["wall_s"] * 1e6 / steps,
        f"steps_per_s base={base['steps_per_s']:.2f} "
        f"ckpt={ck['steps_per_s']:.2f} regression={regression * 100:.1f}% "
        f"saves={st['saves']} save_s={st['save_s']:.2f} "
        f"hot_path_blocked_s={st['blocked_s']:.3f} "
        f"overhead_frac={st['overhead_frac']:.4f} (contract: <5%)")


def write_json(path=None) -> dict:
    """name -> {us_per_call, derived} for every row emitted so far."""
    path = path or ROOT / "BENCH_paper.json"
    report = {
        "schema": 1,
        "bench": "paper_tables",
        "rows": {name: {"us_per_call": round(us, 1), "derived": derived}
                 for name, us, derived in ROWS},
    }
    pathlib.Path(path).write_text(json.dumps(report, indent=1) + "\n")
    return report


def main() -> None:
    print("name,us_per_call,derived")
    table1_pipeline()
    table3_detection()
    table4_ba_models()
    table5_totals()
    roofline_summary()
    kernel_micro()
    resume_overhead()
    write_json()
    print(f"# {len(ROWS)} benchmark rows -> {ROOT / 'BENCH_paper.json'}")


if __name__ == "__main__":
    main()
