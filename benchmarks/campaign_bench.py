"""Campaign execution benchmark -> BENCH_campaign.json.

Reproduces the paper's parallel-campaign accounting on *real processes*:
a tiny-config train campaign (default 12 runs) executed through
``Orchestrator.run_cluster`` at workers ∈ {1, 2, 4}, measuring the real
wall-clock makespan (the paper's "five and a half months on a single
server" vs cluster-parallel argument, at laptop scale), queue-wait
p50/p95, and — with injected SIGKILL preemption — goodput and the steps
salvaged by checkpoint resume.

Every subprocess is pinned to one XLA host thread (see
``SINGLE_THREAD_ENV``) so workers scale across cores instead of fighting
over them; that makes the workers=N sweep an honest strong-scaling
measurement on any core count.

    PYTHONPATH=src python benchmarks/campaign_bench.py \
        [--runs 12] [--steps 4] [--workers 1,2,4] [--kill 2] \
        [--workdir DIR] [--out BENCH_campaign.json]

Exits nonzero if any campaign run fails to complete — CI uses that as
the completion assertion for its preempt-one-run smoke.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.api import RunSpec                                  # noqa: E402
from repro.core import ChaosSpec, JobState, Orchestrator, \
    PersistentVolume, Resources                                # noqa: E402

# One XLA/BLAS thread per worker subprocess (including LLVM codegen,
# which XLA otherwise parallelizes): the sweep then measures scheduling,
# not intra-op thread contention.
SINGLE_THREAD_ENV = {
    "XLA_FLAGS": ("--xla_cpu_multi_thread_eigen=false "
                  "intra_op_parallelism_threads=1 "
                  "--xla_cpu_parallel_codegen_split_count=1"),
    "OMP_NUM_THREADS": "1",
    "OPENBLAS_NUM_THREADS": "1",
}

ARCH = "stablelm-1.6b"


# NOTE: the jax persistent compilation cache is deliberately NOT used:
# with jaxlib 0.4.37 on CPU, cache-hitting resumed runs segfault
# (native heap corruption) after a campaign SIGKILL — found by this
# bench's chaos leg.  Until the cache is crash-safe, campaign workers
# pay their own compiles.


def build_runs(n: int, steps: int, batch: int, seq: int,
               ckpt_root: Path):
    # checkpoint_async=False: durable synchronous saves (fsynced before
    # the step continues) — the strict-durability regime, and the real
    # disk I/O that concurrent workers overlap with other runs' compute.
    # cpus=1 + run_cluster(pin_cpus=True) turns the request into a real
    # affinity limit (k8s CPU-limit semantics), so workers=1 means one
    # core and the sweep measures scheduling, not thread contention.
    return [RunSpec(kind="train", arch=ARCH, seed=i, name=f"run{i:02d}",
                    resources=Resources(gpus=0, cpus=1, memory_gb=4),
                    overrides={"steps": steps, "batch": batch, "seq": seq,
                               "log_every": 0,
                               "checkpoint_dir": str(ckpt_root / f"ck{i:02d}"),
                               "checkpoint_every": 1,
                               "checkpoint_async": False})
            for i in range(n)]


def run_campaign(workdir: Path, tag: str, runs, workers: int,
                 chaos=None) -> dict:
    pvc = PersistentVolume(workdir / tag)
    orch = Orchestrator(pvc)
    orch.submit_runs(runs)
    t0 = time.time()
    recs = orch.run_cluster(workers=workers, chaos=chaos,
                            worker_env=SINGLE_THREAD_ENV, pin_cpus=True,
                            attempt_timeout_s=600)
    wall = time.time() - t0
    summary = orch.last_campaign_summary
    ok = all(r.state == JobState.SUCCEEDED for r in recs.values())
    return {"tag": tag, "ok": ok, "wall_s": round(wall, 2), **summary}


# Two calibration burns: ALU-bound, and memory-streaming — training
# steps/compiles are memory-bound, so the memory burn is the ceiling
# that actually binds a train campaign.
_BURNS = {
    "alu": "x=0\nfor i in range(20_000_000): x += i",
    "mem": "b = bytes(60_000_000)\nn = 0\nfor _ in range(10): n += b.count(0)",
}


def host_parallel_ceiling(nproc: int = 4) -> dict:
    """Calibrate what concurrent-process speedup this host can
    physically deliver (cloud containers are often oversubscribed
    and/or memory-bandwidth-bound: this repo's 2-vCPU dev container
    measures ~1.2-1.4x for memory-streaming work, which is what caps a
    concurrent train campaign).  The campaign speedup is reported
    alongside these ceilings so the number is interpretable on any
    host."""
    def burn(src, n):
        t0 = time.time()
        ps = [subprocess.Popen([sys.executable, "-c", src])
              for _ in range(n)]
        for p in ps:
            p.wait()
        return time.time() - t0

    out = {"cpus_visible": len(os.sched_getaffinity(0))
           if hasattr(os, "sched_getaffinity") else os.cpu_count(),
           "procs": nproc}
    for name, src in _BURNS.items():
        burn(src, 1)                           # warm the interpreter path
        serial = burn(src, 1)
        t_par = burn(src, nproc)
        out[name] = {"serial_s": round(serial, 2),
                     "parallel_s": round(t_par, 2),
                     "speedup_ceiling":
                         round(nproc * serial / t_par, 3) if t_par else 0.0}
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=12)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--workers", default="1,2,4",
                    help="comma-separated worker counts to sweep")
    ap.add_argument("--kill", type=int, default=2,
                    help="runs to SIGKILL (after their first checkpoint) "
                         "in the chaos campaign; 0 disables")
    ap.add_argument("--chaos-workers", type=int, default=2)
    ap.add_argument("--workdir", default=None,
                    help="campaign work root (default: a temp dir); CI "
                         "passes an explicit dir to upload the event log")
    ap.add_argument("--out", default="BENCH_campaign.json")
    args = ap.parse_args(argv)

    workdir = Path(args.workdir or tempfile.mkdtemp(prefix="campbench-"))
    workdir.mkdir(parents=True, exist_ok=True)
    worker_counts = [int(w) for w in args.workers.split(",") if w]

    host = host_parallel_ceiling()
    print(f"host ceilings: alu={host['alu']['speedup_ceiling']}x "
          f"mem={host['mem']['speedup_ceiling']}x over "
          f"{host['cpus_visible']} visible cpus", flush=True)

    # warm the OS page cache (interpreter + jax imports) so the first
    # sweep isn't penalized with cold disk reads the others skip
    warm = build_runs(1, args.steps, args.batch, args.seq,
                      workdir / "ckpt-warm")
    run_campaign(workdir, "warmup", warm, 1)
    print("warmup done", flush=True)

    rows = []
    for w in worker_counts:
        runs = build_runs(args.runs, args.steps, args.batch, args.seq,
                          workdir / f"ckpt-w{w}")
        row = run_campaign(workdir, f"workers{w}", runs, w)
        rows.append(row)
        print(f"workers={w}: makespan={row['makespan_s']}s "
              f"goodput={row['wall_goodput']} "
              f"queue_p50={row['queue_wait_s']['p50']}s "
              f"p95={row['queue_wait_s']['p95']}s ok={row['ok']}",
              flush=True)

    base = next((r for r in rows if r["workers"] == 1), rows[0])
    if base["workers"] != 1:
        print(f"note: --workers omits 1; speedups are vs the "
              f"workers={base['workers']} row", file=sys.stderr)
    for row in rows:
        row["speedup_vs_baseline"] = round(
            base["makespan_s"] / row["makespan_s"], 3) \
            if row["makespan_s"] else 0.0

    chaos_row = None
    if args.kill > 0:
        runs = build_runs(args.runs, args.steps, args.batch, args.seq,
                          workdir / "ckpt-chaos")
        names = [r.run_name for r in runs]
        chaos = ChaosSpec.sample(names, fraction=args.kill / len(names),
                                 seed=7, after_checkpoints=1)
        chaos_row = run_campaign(workdir, "chaos", runs,
                                 args.chaos_workers, chaos=chaos)
        chaos_row["killed_jobs"] = list(chaos.kill_jobs)
        ref = next((r for r in rows
                    if r["workers"] == args.chaos_workers), None)
        if ref:
            chaos_row["makespan_overhead_vs_no_chaos"] = round(
                chaos_row["makespan_s"] / ref["makespan_s"], 3)
        print(f"chaos(workers={args.chaos_workers}, "
              f"kill={len(chaos.kill_jobs)}): "
              f"makespan={chaos_row['makespan_s']}s "
              f"preemptions={chaos_row['preemptions']} "
              f"goodput={chaos_row['wall_goodput']} "
              f"salvaged_steps={chaos_row['steps_salvaged_by_resume']} "
              f"ok={chaos_row['ok']}", flush=True)

    fastest = min(rows, key=lambda r: r["makespan_s"])
    ceiling = host["mem"]["speedup_ceiling"]
    out = {
        "benchmark": "campaign_exec",
        "config": {"runs": args.runs, "steps": args.steps,
                   "batch": args.batch, "seq": args.seq, "arch": ARCH,
                   "worker_env": SINGLE_THREAD_ENV, "pin_cpus": True},
        "host": host,
        "rows": rows,
        "chaos": chaos_row,
        "headline": {
            "baseline_workers": base["workers"],
            "best_speedup_vs_baseline": fastest["speedup_vs_baseline"],
            "best_workers": fastest["workers"],
            "baseline_makespan_s": base["makespan_s"],
            # fraction of the host's physically-available concurrency
            # (memory-streaming ceiling — what binds a train campaign)
            # the executor converts into makespan reduction; >= 2x
            # absolute speedup is expected wherever the host's own
            # ceiling exceeds 2x (e.g. 4-core CI runners), while
            # oversubscribed 2-vCPU dev boxes measure a ceiling well
            # under 2
            "speedup_vs_host_ceiling":
                round(fastest["speedup_vs_baseline"] / ceiling, 3)
                if ceiling else None,
            "goodput_under_preemption":
                chaos_row["wall_goodput"] if chaos_row else None,
        },
    }
    Path(args.out).write_text(json.dumps(out, indent=1, sort_keys=True)
                              + "\n")
    print(f"wrote {args.out}: best speedup "
          f"{out['headline']['best_speedup_vs_baseline']}x at "
          f"workers={out['headline']['best_workers']}")
    failed = [r["tag"] for r in rows + ([chaos_row] if chaos_row else [])
              if not r["ok"]]
    if failed:
        print(f"FAILED campaigns: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
