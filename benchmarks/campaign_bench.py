"""Campaign execution benchmark -> BENCH_campaign.json.

Reproduces the paper's parallel-campaign accounting on *real processes*:
a tiny-config train campaign (default 12 runs) executed through
``Orchestrator.run_cluster`` at workers ∈ {1, 2, 4}, measuring the real
wall-clock makespan (the paper's "five and a half months on a single
server" vs cluster-parallel argument, at laptop scale), queue-wait
p50/p95, and — with injected SIGKILL preemption — goodput and the steps
salvaged by checkpoint resume.

Every subprocess is pinned to one XLA host thread (see
``SINGLE_THREAD_ENV``) so workers scale across cores instead of fighting
over them; that makes the workers=N sweep an honest strong-scaling
measurement on any core count.

    PYTHONPATH=src python benchmarks/campaign_bench.py \
        [--runs 12] [--steps 4] [--workers 1,2,4] [--kill 2] \
        [--evict-runs 2] [--workdir DIR] [--out BENCH_campaign.json]

Exits nonzero if any campaign run fails to complete — CI uses that as
the completion assertion for its preempt-one-run smoke.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.api import RunSpec                                  # noqa: E402
from repro.core import ChaosSpec, JobState, NodeSpec, Orchestrator, \
    PersistentVolume, Resources                                # noqa: E402

# One XLA/BLAS thread per worker subprocess (including LLVM codegen,
# which XLA otherwise parallelizes): the sweep then measures scheduling,
# not intra-op thread contention.
SINGLE_THREAD_ENV = {
    "XLA_FLAGS": ("--xla_cpu_multi_thread_eigen=false "
                  "intra_op_parallelism_threads=1 "
                  "--xla_cpu_parallel_codegen_split_count=1"),
    "OMP_NUM_THREADS": "1",
    "OPENBLAS_NUM_THREADS": "1",
}

ARCH = "stablelm-1.6b"


# NOTE: the jax persistent compilation cache is deliberately NOT used:
# with jaxlib 0.4.37 on CPU, cache-hitting resumed runs segfault
# (native heap corruption) after a campaign SIGKILL — found by this
# bench's chaos leg.  Until the cache is crash-safe, campaign workers
# pay their own compiles.


def build_runs(n: int, steps: int, batch: int, seq: int,
               ckpt_root: Path, ckpt_every: int = 1):
    # checkpoint_async=False: durable synchronous saves (fsynced before
    # the step continues) — the strict-durability regime, and the real
    # disk I/O that concurrent workers overlap with other runs' compute.
    # cpus=1 + run_cluster(pin_cpus=True) turns the request into a real
    # affinity limit (k8s CPU-limit semantics), so workers=1 means one
    # core and the sweep measures scheduling, not thread contention.
    return [RunSpec(kind="train", arch=ARCH, seed=i, name=f"run{i:02d}",
                    resources=Resources(gpus=0, cpus=1, memory_gb=4),
                    overrides={"steps": steps, "batch": batch, "seq": seq,
                               "log_every": 0,
                               "checkpoint_dir": str(ckpt_root / f"ck{i:02d}"),
                               "checkpoint_every": ckpt_every,
                               "checkpoint_async": False})
            for i in range(n)]


def run_campaign(workdir: Path, tag: str, runs, workers: int,
                 chaos=None, **exec_kw) -> dict:
    pvc = PersistentVolume(workdir / tag)
    orch = Orchestrator(pvc)
    orch.submit_runs(runs)
    t0 = time.time()
    recs = orch.run_cluster(workers=workers, chaos=chaos,
                            worker_env=SINGLE_THREAD_ENV, pin_cpus=True,
                            attempt_timeout_s=600, **exec_kw)
    wall = time.time() - t0
    summary = orch.last_campaign_summary
    ok = all(r.state == JobState.SUCCEEDED for r in recs.values())
    return {"tag": tag, "ok": ok, "wall_s": round(wall, 2), **summary}


def _final_tree(ckpt_dir: Path):
    from repro.checkpoint import list_checkpoints, load_checkpoint
    ckpts = list_checkpoints(ckpt_dir)
    if not ckpts:
        return None, None
    tree, step = load_checkpoint(ckpts[-1][1])
    return tree, int(step)


def straggler_leg(workdir: Path, args) -> dict:
    """One victim run stalled REPRO_STEP_DELAY_S per step (wall-only:
    the math is untouched).  The same campaign runs FIFO and with
    ``speculate`` — the duplicate races the victim at full speed and
    first-finisher-wins; the victim's final checkpoint must be bitwise
    identical across both legs."""
    import numpy as np
    legs = {}
    for tag, speculate in (("straggler_fifo", False),
                           ("straggler_spec", True)):
        runs = build_runs(args.straggler_runs, args.steps, args.batch,
                          args.seq, workdir / f"ckpt-{tag}")
        legs[tag] = run_campaign(
            workdir, tag, runs, args.straggler_workers,
            speculate=speculate,
            straggler_env={"run00": {"REPRO_STEP_DELAY_S":
                                     str(args.straggler_delay_s)}})
        print(f"{tag}: makespan={legs[tag]['makespan_s']}s "
              f"speculation={legs[tag]['speculation']} "
              f"ok={legs[tag]['ok']}", flush=True)

    a, step_a = _final_tree(workdir / "ckpt-straggler_fifo" / "ck00")
    b, step_b = _final_tree(workdir / "ckpt-straggler_spec" / "ck00")
    bitwise = (a is not None and b is not None and step_a == step_b
               and set(a) == set(b)
               and all(np.array_equal(a[k], b[k]) for k in a))
    fifo, spec = legs["straggler_fifo"], legs["straggler_spec"]
    return {
        "victim": "run00",
        "step_delay_s": args.straggler_delay_s,
        "runs": args.straggler_runs,
        "workers": args.straggler_workers,
        "ok": fifo["ok"] and spec["ok"] and bitwise,
        "fifo_makespan_s": fifo["makespan_s"],
        "speculate_makespan_s": spec["makespan_s"],
        "makespan_improvement": round(
            fifo["makespan_s"] / spec["makespan_s"], 3)
        if spec["makespan_s"] else None,
        "speculation": spec["speculation"],   # launches/wins/losses/wall
        "victim_bitwise_identical": bool(bitwise),
    }


def sched_kill_leg(workdir: Path, args) -> dict:
    """SIGKILL the *scheduler process* mid-campaign (the driver is
    ``python -m repro.launch campaign run``), restart it with
    ``--resume-campaign``, and account recovery: completed jobs are
    never re-executed, live orphans are adopted or re-queued, and the
    campaign finishes."""
    root = workdir / "schedkill"
    root.mkdir(parents=True, exist_ok=True)
    runs = build_runs(args.sched_kill_runs, args.steps, args.batch,
                      args.seq, root / "ckpt")
    jobs_file = root / "jobs.json"
    jobs_file.write_text(json.dumps([r.to_dict() for r in runs]))
    src = str(Path(__file__).resolve().parents[1] / "src")
    env = {**os.environ, **SINGLE_THREAD_ENV, "PYTHONPATH": src}
    argv = [sys.executable, "-m", "repro.launch", "campaign", "run",
            "--jobs", str(jobs_file), "--workdir", str(root),
            "--workers", "2", "--retry-backoff-base", "0.2"]
    events_path = root / "repro-data" / "campaign" / "events.jsonl"

    def succeeded_jobs():
        try:
            lines = events_path.read_text(errors="replace").splitlines()
        except OSError:
            return set()
        out = set()
        for ln in lines:
            try:
                e = json.loads(ln)
            except ValueError:
                continue
            if e.get("event") == "succeeded":
                out.add(e["job"])
        return out

    with open(root / "sched1.log", "wb") as log:
        proc = subprocess.Popen(argv, env=env, stdout=log, stderr=log)
    deadline = time.time() + 600
    done_before = set()
    while time.time() < deadline and proc.poll() is None:
        done_before = succeeded_jobs()
        if len(done_before) >= 2:
            break
        time.sleep(0.5)
    proc.kill()
    proc.wait()

    t0 = time.time()
    res = subprocess.run(argv + ["--resume-campaign"], env=env,
                         capture_output=True, timeout=1200)
    resume_wall = time.time() - t0
    lines = events_path.read_text(errors="replace").splitlines()
    events = []
    for ln in lines:
        try:
            events.append(json.loads(ln))
        except ValueError:
            pass
    resume_idx = max((i for i, e in enumerate(events)
                      if e.get("event") == "campaign_resume"), default=0)
    re_executed = sorted({e["job"] for e in events[resume_idx:]
                          if e.get("event") == "started"
                          and e.get("job") in done_before})
    succeeded = succeeded_jobs()
    from repro.core import replay_events
    state = replay_events(lines)
    ok = (res.returncode == 0 and len(succeeded) == len(runs)
          and not re_executed and state["consistent"])
    row = {
        "runs": args.sched_kill_runs,
        "killed_scheduler_after_done": len(done_before),
        "resume_wall_s": round(resume_wall, 2),
        "re_executed_completed_jobs": re_executed,
        "orphans_adopted": sum(1 for e in events
                               if e.get("event") == "adopted"),
        "orphans_requeued": sum(1 for e in events
                                if e.get("event") == "orphan_requeued"),
        "succeeded": len(succeeded),
        "replay_consistent": state["consistent"],
        "ok": ok,
    }
    if not ok:
        sys.stderr.write(res.stdout.decode(errors="replace")[-2000:])
        sys.stderr.write(res.stderr.decode(errors="replace")[-2000:])
    print(f"schedkill: killed after {row['killed_scheduler_after_done']} "
          f"done, resume adopted={row['orphans_adopted']} "
          f"requeued={row['orphans_requeued']} "
          f"re_executed={re_executed} ok={ok}", flush=True)
    return row


def placement_leg(workdir: Path, args) -> dict:
    """The same job set executed once per placement policy on the same
    heterogeneous two-node inventory, reporting each policy's makespan
    and the event-log-derived utilization ledger (busy vs goodput AUC
    per node) — the BENCH surface for `campaign run --placement`.

    Each policy gets a fresh checkpoint root: a shared one would let a
    later policy resume the earlier policy's checkpoints and measure
    nothing."""
    inventory = [
        NodeSpec("small", gpus=0, gpu_memory_gb=0.0, cpus=2,
                 memory_gb=8.0),
        NodeSpec("big", gpus=0, gpu_memory_gb=0.0, cpus=4,
                 memory_gb=16.0),
    ]
    policies = [p for p in args.placement_sweep.split(",") if p]
    legs = {}
    for pol in policies:
        runs = build_runs(args.placement_runs, args.steps, args.batch,
                          args.seq, workdir / f"ckpt-place-{pol}")
        row = run_campaign(workdir, f"placement_{pol}", runs,
                           args.placement_workers, inventory=inventory,
                           placement=pol)
        util = (row.get("utilization") or {}).get("cluster") or {}
        legs[pol] = {
            "ok": row["ok"],
            "makespan_s": row["makespan_s"],
            "queue_wait_s": row["queue_wait_s"],
            "utilization": row.get("utilization"),
        }
        print(f"placement={pol}: makespan={row['makespan_s']}s "
              f"cpu_busy_util={util.get('busy_cpu_util')} "
              f"cpu_goodput_util={util.get('goodput_cpu_util')} "
              f"ok={row['ok']}", flush=True)
    return {
        "runs": args.placement_runs,
        "workers": args.placement_workers,
        "inventory": [n.to_dict() for n in inventory],
        "policies": legs,
        "ok": all(l["ok"] for l in legs.values()) if legs else False,
    }


def evict_leg(workdir: Path, args) -> dict:
    """Graceful vs hard preemption: the same chaos campaign run twice,
    once with SIGKILL victims (lose everything since the last cadence
    checkpoint) and once with SIGTERM victims (the in-process handler
    salvages a final checkpoint inside the grace window, so the resume
    restarts from the exact preempted step).  Reports the steps each
    signal class salvaged — the measured value of the SIGTERM
    contract."""
    import signal as _sig
    legs = {}
    for tag, sig in (("evict_sigkill", _sig.SIGKILL),
                     ("evict_sigterm", _sig.SIGTERM)):
        runs = build_runs(args.evict_runs, args.steps, args.batch,
                          args.seq, workdir / f"ckpt-{tag}",
                          ckpt_every=args.evict_ckpt_every)
        names = [r.run_name for r in runs]
        chaos = ChaosSpec.sample(names, fraction=1.0, seed=7,
                                 after_checkpoints=1, signal=int(sig))
        legs[tag] = run_campaign(workdir, tag, runs, args.evict_workers,
                                 chaos=chaos, grace_s=60.0)
        print(f"{tag}: salvaged="
              f"{legs[tag]['steps_salvaged_by_resume']} "
              f"preemptions={legs[tag]['preemptions']} "
              f"goodput={legs[tag]['wall_goodput']} "
              f"ok={legs[tag]['ok']}", flush=True)
    kill, term = legs["evict_sigkill"], legs["evict_sigterm"]
    return {
        "runs": args.evict_runs,
        "workers": args.evict_workers,
        "checkpoint_every": args.evict_ckpt_every,
        "ok": kill["ok"] and term["ok"],
        "sigkill_salvaged_steps": kill["steps_salvaged_by_resume"],
        "sigterm_salvaged_steps": term["steps_salvaged_by_resume"],
        "sigterm_extra_steps_salvaged":
            term["steps_salvaged_by_resume"]
            - kill["steps_salvaged_by_resume"],
        "sigkill_goodput": kill["wall_goodput"],
        "sigterm_goodput": term["wall_goodput"],
    }


# Two calibration burns: ALU-bound, and memory-streaming — training
# steps/compiles are memory-bound, so the memory burn is the ceiling
# that actually binds a train campaign.
_BURNS = {
    "alu": "x=0\nfor i in range(20_000_000): x += i",
    "mem": "b = bytes(60_000_000)\nn = 0\nfor _ in range(10): n += b.count(0)",
}


def host_parallel_ceiling(nproc: int = 4) -> dict:
    """Calibrate what concurrent-process speedup this host can
    physically deliver (cloud containers are often oversubscribed
    and/or memory-bandwidth-bound: this repo's 2-vCPU dev container
    measures ~1.2-1.4x for memory-streaming work, which is what caps a
    concurrent train campaign).  The campaign speedup is reported
    alongside these ceilings so the number is interpretable on any
    host."""
    def burn(src, n):
        t0 = time.time()
        ps = [subprocess.Popen([sys.executable, "-c", src])
              for _ in range(n)]
        for p in ps:
            p.wait()
        return time.time() - t0

    out = {"cpus_visible": len(os.sched_getaffinity(0))
           if hasattr(os, "sched_getaffinity") else os.cpu_count(),
           "procs": nproc}
    for name, src in _BURNS.items():
        burn(src, 1)                           # warm the interpreter path
        serial = burn(src, 1)
        t_par = burn(src, nproc)
        out[name] = {"serial_s": round(serial, 2),
                     "parallel_s": round(t_par, 2),
                     "speedup_ceiling":
                         round(nproc * serial / t_par, 3) if t_par else 0.0}
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=12)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--workers", default="1,2,4",
                    help="comma-separated worker counts to sweep")
    ap.add_argument("--kill", type=int, default=2,
                    help="runs to SIGKILL (after their first checkpoint) "
                         "in the chaos campaign; 0 disables")
    ap.add_argument("--chaos-workers", type=int, default=2)
    ap.add_argument("--straggler-runs", type=int, default=0,
                    help="straggler leg: campaign size (0 disables); one "
                         "victim is stalled per step and raced FIFO vs "
                         "--speculate")
    ap.add_argument("--straggler-delay-s", type=float, default=5.0)
    ap.add_argument("--straggler-workers", type=int, default=3)
    ap.add_argument("--sched-kill-runs", type=int, default=0,
                    help="scheduler-kill leg: campaign size (0 disables); "
                         "SIGKILLs the 'campaign run' scheduler process "
                         "and recovers with --resume-campaign")
    ap.add_argument("--evict-runs", type=int, default=0,
                    help="eviction leg: campaign size (0 disables); runs "
                         "the same chaos campaign under SIGKILL and "
                         "SIGTERM and reports the steps each salvaged")
    ap.add_argument("--evict-workers", type=int, default=2)
    ap.add_argument("--placement-sweep", default="",
                    help="comma-separated placement policies (e.g. "
                         "best_fit,worst_fit,pack) to race on the same "
                         "job set + heterogeneous inventory; empty "
                         "disables the leg")
    ap.add_argument("--placement-runs", type=int, default=6)
    ap.add_argument("--placement-workers", type=int, default=4)
    ap.add_argument("--evict-ckpt-every", type=int, default=3,
                    help="cadence for the eviction leg (sparser than "
                         "the sweep's 1, so the SIGTERM salvage has "
                         "steps to save)")
    ap.add_argument("--workdir", default=None,
                    help="campaign work root (default: a temp dir); CI "
                         "passes an explicit dir to upload the event log")
    ap.add_argument("--out", default="BENCH_campaign.json")
    args = ap.parse_args(argv)

    workdir = Path(args.workdir or tempfile.mkdtemp(prefix="campbench-"))
    workdir.mkdir(parents=True, exist_ok=True)
    worker_counts = [int(w) for w in args.workers.split(",") if w]

    host = host_parallel_ceiling()
    print(f"host ceilings: alu={host['alu']['speedup_ceiling']}x "
          f"mem={host['mem']['speedup_ceiling']}x over "
          f"{host['cpus_visible']} visible cpus", flush=True)

    # warm the OS page cache (interpreter + jax imports) so the first
    # sweep isn't penalized with cold disk reads the others skip
    warm = build_runs(1, args.steps, args.batch, args.seq,
                      workdir / "ckpt-warm")
    run_campaign(workdir, "warmup", warm, 1)
    print("warmup done", flush=True)

    rows = []
    for w in worker_counts:
        runs = build_runs(args.runs, args.steps, args.batch, args.seq,
                          workdir / f"ckpt-w{w}")
        row = run_campaign(workdir, f"workers{w}", runs, w)
        rows.append(row)
        print(f"workers={w}: makespan={row['makespan_s']}s "
              f"goodput={row['wall_goodput']} "
              f"queue_p50={row['queue_wait_s']['p50']}s "
              f"p95={row['queue_wait_s']['p95']}s ok={row['ok']}",
              flush=True)

    base = next((r for r in rows if r["workers"] == 1), rows[0])
    if base["workers"] != 1:
        print(f"note: --workers omits 1; speedups are vs the "
              f"workers={base['workers']} row", file=sys.stderr)
    for row in rows:
        row["speedup_vs_baseline"] = round(
            base["makespan_s"] / row["makespan_s"], 3) \
            if row["makespan_s"] else 0.0

    chaos_row = None
    if args.kill > 0:
        runs = build_runs(args.runs, args.steps, args.batch, args.seq,
                          workdir / "ckpt-chaos")
        names = [r.run_name for r in runs]
        chaos = ChaosSpec.sample(names, fraction=args.kill / len(names),
                                 seed=7, after_checkpoints=1)
        chaos_row = run_campaign(workdir, "chaos", runs,
                                 args.chaos_workers, chaos=chaos)
        chaos_row["killed_jobs"] = list(chaos.kill_jobs)
        ref = next((r for r in rows
                    if r["workers"] == args.chaos_workers), None)
        if ref:
            chaos_row["makespan_overhead_vs_no_chaos"] = round(
                chaos_row["makespan_s"] / ref["makespan_s"], 3)
        print(f"chaos(workers={args.chaos_workers}, "
              f"kill={len(chaos.kill_jobs)}): "
              f"makespan={chaos_row['makespan_s']}s "
              f"preemptions={chaos_row['preemptions']} "
              f"goodput={chaos_row['wall_goodput']} "
              f"salvaged_steps={chaos_row['steps_salvaged_by_resume']} "
              f"ok={chaos_row['ok']}", flush=True)

    straggler_row = (straggler_leg(workdir, args)
                     if args.straggler_runs > 0 else None)
    sched_kill_row = (sched_kill_leg(workdir, args)
                      if args.sched_kill_runs > 0 else None)
    evict_row = evict_leg(workdir, args) if args.evict_runs > 0 else None
    placement_row = (placement_leg(workdir, args)
                     if args.placement_sweep else None)

    fastest = min(rows, key=lambda r: r["makespan_s"])
    ceiling = host["mem"]["speedup_ceiling"]
    out = {
        "benchmark": "campaign_exec",
        "config": {"runs": args.runs, "steps": args.steps,
                   "batch": args.batch, "seq": args.seq, "arch": ARCH,
                   "worker_env": SINGLE_THREAD_ENV, "pin_cpus": True},
        "host": host,
        "rows": rows,
        "chaos": chaos_row,
        "straggler": straggler_row,
        "sched_kill": sched_kill_row,
        "evict_signal": evict_row,
        "placement": placement_row,
        "headline": {
            "baseline_workers": base["workers"],
            "best_speedup_vs_baseline": fastest["speedup_vs_baseline"],
            "best_workers": fastest["workers"],
            "baseline_makespan_s": base["makespan_s"],
            # fraction of the host's physically-available concurrency
            # (memory-streaming ceiling — what binds a train campaign)
            # the executor converts into makespan reduction; >= 2x
            # absolute speedup is expected wherever the host's own
            # ceiling exceeds 2x (e.g. 4-core CI runners), while
            # oversubscribed 2-vCPU dev boxes measure a ceiling well
            # under 2
            "speedup_vs_host_ceiling":
                round(fastest["speedup_vs_baseline"] / ceiling, 3)
                if ceiling else None,
            "goodput_under_preemption":
                chaos_row["wall_goodput"] if chaos_row else None,
        },
    }
    Path(args.out).write_text(json.dumps(out, indent=1, sort_keys=True)
                              + "\n")
    print(f"wrote {args.out}: best speedup "
          f"{out['headline']['best_speedup_vs_baseline']}x at "
          f"workers={out['headline']['best_workers']}")
    extra = [("straggler", straggler_row), ("sched_kill", sched_kill_row),
             ("evict_signal", evict_row), ("placement", placement_row)]
    failed = [r["tag"] for r in rows + ([chaos_row] if chaos_row else [])
              if not r["ok"]]
    failed += [tag for tag, r in extra if r is not None and not r["ok"]]
    if failed:
        print(f"FAILED campaigns: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
