"""Training-step benchmark: the compiled hot path, variant by variant,
on an identical CPU-sized workload (the training-side sibling of
``serve_bench.py``).

Variants
--------
  baseline   — the seed hot path: ``jax.jit`` around the step with **no**
               donation, f32 everywhere, jnp kernel backends.
  donated    — the step jitted inside ``make_train_step`` with the
               ``TrainState`` donated (params/optimizer state updated in
               place) and the grad-norm/clip sharing one global
               reduction.
  bf16       — donated + the ``bf16`` mixed-precision policy (bf16
               backbone compute, f32 master params / optimizer state /
               loss / embedding+head matmuls).
  pallas     — donated + the Pallas flash-attention / SSD kernel
               backends (custom-VJP, so the backward also runs the
               kernels).  Off TPU this executes in interpret mode — a
               *validation* row, not a runtime path (``auto`` resolves
               to jnp on CPU for exactly that reason); the row also
               records gradient equivalence vs the jnp backend.

Per variant it reports steps/s and tokens/s (from the median step),
p50/p95 step latency, the jit cache size (compile count), XLA's compiled
memory analysis (argument/output/temp/alias bytes — donation shows up as
aliased bytes), and live-array bytes after a step.

Timing is **interleaved**: after per-variant compile+warmup, variants
execute round-robin in small blocks so slow drift of the host (shared CI
boxes) hits every variant equally instead of whichever ran last.

    PYTHONPATH=src python benchmarks/train_bench.py --out BENCH_train.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]

VARIANTS = ("baseline", "donated", "bf16", "pallas")


def bench_config(arch: str, d_model: int, vocab: int, n_layers: int):
    """The bench workload: a reduced config boosted to the update-bound
    regime (params large relative to the per-step token budget) — the
    regime where in-place state updates matter most, and the one a
    many-small-models campaign (the paper's 234) actually runs in."""
    from repro.configs import get_reduced
    cfg = get_reduced(arch)
    changes = {"vocab": vocab, "d_model": d_model, "n_layers": n_layers}
    if cfg.n_heads:
        changes["n_heads"] = max(4, cfg.n_heads)
        changes["n_kv_heads"] = max(2, cfg.n_kv_heads)
    if cfg.d_ff:
        changes["d_ff"] = 2 * d_model
    return dataclasses.replace(cfg, **changes)


def make_variant(cfg, variant: str, steps: int, lr: float = 3e-4):
    from repro.optim import get_optimizer, warmup_cosine
    from repro.train import make_train_step

    opt = get_optimizer("adamw")
    sched = warmup_cosine(lr, steps, warmup_steps=max(steps // 10, 1))
    if variant == "baseline":
        # seed semantics: bare step wrapped in an un-donated outer jit
        return jax.jit(make_train_step(cfg, opt, lr_schedule=sched,
                                       jit_compile=False))
    if variant == "donated":
        return make_train_step(cfg, opt, lr_schedule=sched)
    if variant == "bf16":
        return make_train_step(cfg, opt, lr_schedule=sched, precision="bf16")
    if variant == "pallas":
        pcfg = dataclasses.replace(cfg, attention_backend="pallas",
                                   mixer_backend="pallas")
        return make_train_step(pcfg, opt, lr_schedule=sched)
    raise ValueError(variant)


def make_batch(cfg, batch: int, seq: int, seed: int):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (batch, seq),
                              0, cfg.vocab)
    return {"tokens": toks, "labels": toks}


def memory_analysis(compiled) -> dict:
    try:
        mem = compiled.memory_analysis()
    except Exception:
        return {}
    if mem is None:
        return {}
    return {k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes")
            if hasattr(mem, k)}


def grad_equivalence(cfg, batch) -> dict:
    """Max |grad_pallas - grad_jnp| over all params, f32, plus the jnp
    grad scale for context.  This is the bench-level record of the
    kernel-equivalence contract (tests/test_kernels.py is the sweep)."""
    from repro.models import init_params, train_loss
    params = init_params(jax.random.PRNGKey(7), cfg)
    grads = {}
    for be in ("jnp", "pallas"):
        c = dataclasses.replace(cfg, attention_backend=be, mixer_backend=be)
        grads[be] = jax.grad(
            lambda p: train_loss(p, c, batch, remat=False))(params)
    diffs = [float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
             for a, b in zip(jax.tree.leaves(grads["jnp"]),
                             jax.tree.leaves(grads["pallas"]))]
    scale = max(float(jnp.abs(g.astype(jnp.float32)).max())
                for g in jax.tree.leaves(grads["jnp"]))
    return {"grad_max_abs_diff": max(diffs), "grad_max_abs": scale}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--vocab", type=int, default=32768)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=8,
                    help="interleaved timing rounds")
    ap.add_argument("--block", type=int, default=4,
                    help="steps per variant per round")
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-pallas", action="store_true",
                    help="skip the interpret-mode Pallas row (CI smoke)")
    ap.add_argument("--out", default=str(ROOT / "BENCH_train.json"))
    args = ap.parse_args(argv)

    from repro.train import init_train_state
    from repro.optim import get_optimizer

    cfg = bench_config(args.arch, args.d_model, args.vocab, args.n_layers)
    batch = make_batch(cfg, args.batch, args.seq, args.seed)
    total_steps = args.rounds * args.block + args.warmup + 1
    variants = [v for v in VARIANTS
                if not (v == "pallas" and args.skip_pallas)]

    fns, states, walls, rows = {}, {}, {v: [] for v in variants}, {}
    for v in variants:
        states[v] = init_train_state(jax.random.PRNGKey(args.seed), cfg,
                                     get_optimizer("adamw"))
        # AOT-compile once; the executable serves the memory analysis AND
        # the timed loop, and makes silent recompilation impossible (a
        # shape change would raise instead) — so compile_count is 1 by
        # construction
        t0 = time.perf_counter()
        fns[v] = make_variant(cfg, v, total_steps).lower(
            states[v], batch).compile()
        rows[v] = {"memory": memory_analysis(fns[v])}
        states[v], m = fns[v](states[v], batch)       # 1st step
        jax.block_until_ready(m["loss"])
        rows[v]["compile_plus_first_step_s"] = round(
            time.perf_counter() - t0, 3)
        for _ in range(args.warmup):
            states[v], m = fns[v](states[v], batch)
            jax.block_until_ready(m["loss"])
        rows[v]["state_bytes"] = sum(
            x.nbytes for x in jax.tree.leaves(states[v]))
        stats = jax.devices()[0].memory_stats()   # None on CPU
        if stats and "peak_bytes_in_use" in stats:
            rows[v]["device_peak_bytes"] = int(stats["peak_bytes_in_use"])
        print(f"{v:9s} compiled "
              f"({rows[v]['compile_plus_first_step_s']}s)", flush=True)

    # interleaved timing: drift hits every variant equally
    for _ in range(args.rounds):
        for v in variants:
            for _ in range(args.block):
                t0 = time.perf_counter()
                states[v], m = fns[v](states[v], batch)
                jax.block_until_ready(m["loss"])
                walls[v].append(time.perf_counter() - t0)

    tokens = args.batch * args.seq
    for v in variants:
        ms = 1e3 * np.asarray(walls[v])
        p50 = float(np.percentile(ms, 50))
        rows[v].update({
            "steps_timed": len(walls[v]),
            "p50_step_ms": round(p50, 2),
            "p95_step_ms": round(float(np.percentile(ms, 95)), 2),
            "steps_per_s": round(1e3 / p50, 3),
            "tokens_per_s": round(tokens * 1e3 / p50, 1),
            "compile_count": 1,      # AOT executable: recompiles raise
        })
        print(f"{v:9s} {json.dumps(rows[v])}", flush=True)

    if "pallas" in variants:
        eq_cfg = bench_config(args.arch, 128, 512, 2)
        rows["pallas"]["equivalence"] = grad_equivalence(
            eq_cfg, make_batch(eq_cfg, 2, 64, args.seed))

    report = {
        "schema": 1,
        "bench": "train",
        "arch": cfg.name,
        "backend": jax.default_backend(),
        "config": {k: getattr(args, k.replace("-", "_")) for k in
                   ("arch", "d_model", "vocab", "n_layers", "batch", "seq",
                    "rounds", "block", "seed")},
        "params": cfg.param_count(),
        "optimizer": "adamw",
        "variants": rows,
        "speedup_donated": round(
            rows["donated"]["steps_per_s"]
            / rows["baseline"]["steps_per_s"], 3),
        "speedup_optimized": round(
            rows["bf16"]["steps_per_s"]
            / rows["baseline"]["steps_per_s"], 3),
    }
    pathlib.Path(args.out).write_text(json.dumps(report, indent=1) + "\n")
    print(f"# donated {report['speedup_donated']}x, optimized "
          f"(donated+fused+bf16) {report['speedup_optimized']}x steps/s "
          f"vs baseline -> {args.out}")
    return report


if __name__ == "__main__":
    main()
