"""Serving benchmark: device-resident engine vs the legacy (pre-change)
engine on an identical CPU-sized workload.

Per engine it reports
  * tokens_per_s       — end-to-end throughput (includes prefill + every
                         jit compile the engine triggers: for the legacy
                         engine that is one prefill program per distinct
                         prompt length, for the new engine one per
                         power-of-two bucket)
  * decode_tokens_per_s— steady-state decode throughput over pure-decode
                         steps only (steps in which no admission — and
                         hence no prefill execution or compile — ran)
  * p50/p95 per-step latency (one step = one token per active slot)
  * prefill_compiles   — distinct prefill programs traced
  * host_transfer_bytes— per-token device→host traffic (measured for the
                         new engine; analytic slots*vocab*4 logits per
                         step + prefill logits per admit for the legacy)

and writes everything to BENCH_serve.json so later PRs have a perf
trajectory to compare against:

    PYTHONPATH=src python benchmarks/serve_bench.py --out BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]


def make_requests(cfg, n, min_plen, max_plen, max_tokens, seed):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    # walk the [min_plen, max_plen] range so the legacy engine sees many
    # distinct prompt lengths (the serving reality this bench models)
    plens = (min_plen + rng.permutation(n) * max(1, (max_plen - min_plen))
             // max(1, n - 1)) if n > 1 else np.array([min_plen])
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=int(p)),
                    max_tokens=max_tokens)
            for i, p in enumerate(plens)]


def bench_engine(engine, requests) -> dict:
    for r in requests:
        r.generated, r.done = [], False
        engine.submit(r)
    step_walls = []
    decode_wall, decode_tokens, decode_steps = 0.0, 0, 0
    t0 = time.perf_counter()
    while engine.queue or any(s is not None for s in engine.active):
        queued = len(engine.queue)
        completed = len(engine.completed)
        t1 = time.perf_counter()
        engine.step()
        dt = time.perf_counter() - t1
        step_walls.append(dt)
        if len(engine.queue) == queued and len(step_walls) > 1:
            # pure decode: no admission ran, so no prefill exec/compile in
            # this step (each active or just-retired slot emitted 1 token)
            decode_wall += dt
            decode_steps += 1
            decode_tokens += (sum(s is not None for s in engine.active)
                              + len(engine.completed) - completed)
        if len(step_walls) > 100_000:
            raise RuntimeError("engine failed to drain")
    wall = time.perf_counter() - t0
    assert len(engine.completed) == len(requests)
    tokens = sum(len(r.generated) for r in requests)
    ms = 1e3 * np.asarray(step_walls)
    return {
        "requests": len(requests),
        "tokens": tokens,
        "wall_s": round(wall, 3),
        "tokens_per_s": round(tokens / wall, 2),
        "decode_tokens_per_s": round(decode_tokens / max(decode_wall, 1e-9),
                                     2),
        "decode_steps_timed": decode_steps,
        "p50_step_ms": round(float(np.percentile(ms, 50)), 3),
        "p95_step_ms": round(float(np.percentile(ms, 95)), 3),
        "steps": len(step_walls),
        "prefill_compiles": len(engine._prefill_cache),
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-tokens", type=int, default=24)
    ap.add_argument("--min-plen", type=int, default=4)
    ap.add_argument("--max-plen", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=str(ROOT / "BENCH_serve.json"))
    args = ap.parse_args(argv)

    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serve import LegacyServeEngine, ServeEngine

    cfg = get_reduced(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    kw = dict(slots=args.slots, cache_len=args.cache_len)

    results = {}
    for name, eng in [
        ("device_resident", ServeEngine(cfg, params, seed=args.seed, **kw)),
        ("legacy", LegacyServeEngine(cfg, params, seed=args.seed, **kw)),
    ]:
        reqs = make_requests(cfg, args.requests, args.min_plen,
                             args.max_plen, args.max_tokens, args.seed)
        r = bench_engine(eng, reqs)
        if name == "device_resident":
            r["host_transfer_bytes"] = eng.stats["host_transfer_bytes"]
        else:  # analytic: per-step logits pull + per-admit prefill logits
            r["host_transfer_bytes"] = (
                r["steps"] * args.slots * cfg.vocab * 4
                + len(reqs) * cfg.vocab * 4)
        results[name] = r
        print(f"{name:16s} {json.dumps(r)}")

    report = {
        "schema": 1,
        "bench": "serve",
        "arch": cfg.name,
        "backend": jax.default_backend(),
        "config": {k: getattr(args, k) for k in
                   ("requests", "slots", "cache_len", "max_tokens",
                    "min_plen", "max_plen", "seed")},
        "engines": results,
        "speedup_tokens_per_s": round(
            results["device_resident"]["tokens_per_s"]
            / results["legacy"]["tokens_per_s"], 2),
        "host_transfer_reduction": round(
            results["legacy"]["host_transfer_bytes"]
            / max(1, results["device_resident"]["host_transfer_bytes"]), 1),
    }
    pathlib.Path(args.out).write_text(json.dumps(report, indent=1) + "\n")
    print(f"# speedup {report['speedup_tokens_per_s']}x tokens/s, "
          f"{report['host_transfer_reduction']}x less host traffic "
          f"-> {args.out}")
    return report


if __name__ == "__main__":
    main()
