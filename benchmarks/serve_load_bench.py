"""Open-loop serving load benchmark: continuous-batching scheduler vs
the static-slot engine under live arrival traffic.

The bench models the millions-of-users regime the ROADMAP targets: an
*open-loop* load generator (arrivals follow the trace clock whether or
not the server keeps up) drives both

  * ``scheduler`` — :class:`repro.serve.ServeScheduler`: continuous
    admission into freed slots mid-decode, priority/SLO shedding, paged
    KV pool with LRU eviction; and
  * ``static``    — :class:`repro.serve.ServeEngine`: PR 2's slot engine
    with a plain FIFO queue (no shedding, no eviction), requests
    released at the same arrival instants;

over Poisson and bursty traces at several offered-QPS points derived
from a calibration run (so the sweep lands below / near / far above the
host's measured capacity on any machine).  Per (trace, rate, engine) it
reports goodput (SLO-met completions and their tokens per second),
TTFT / TPOT / queue-wait p50/p99, shed/eviction counts, and compile
counts; the decode program must never retrace after warmup
(``decode_compiles`` flat across every trace — hard assert), every
admitted request must end ``done`` (or ``shed``, scheduler only — hard
assert), and the headline records the scheduler/static goodput ratio at
the highest offered rate.

    PYTHONPATH=src python benchmarks/serve_load_bench.py \
        --out BENCH_serve_load.json
"""
from __future__ import annotations

import argparse
import json
import pathlib

import jax
import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]


def pctl_ms(vals, q):
    vals = [v for v in vals if v is not None]
    if not vals:
        return None
    return round(float(np.percentile(np.asarray(vals) * 1e3, q)), 2)


def trace_metrics(reqs, deadline_ms, wall_s) -> dict:
    """Per-trace service metrics computed from the request objects
    themselves (engines are reused across traces, so engine-level
    counters span runs)."""
    done = [r for r in reqs if r.status == "done"]
    shed = [r for r in reqs if r.status == "shed"]
    met = [r for r in done
           if r.ttft_s is not None and r.ttft_s * 1e3 <= deadline_ms]
    slo_tokens = sum(len(r.generated) for r in met)
    return {
        "offered": len(reqs),
        "completed": len(done),
        "shed": len(shed),
        "slo_met": len(met),
        "evictions": sum(r.evictions for r in reqs),
        "wall_s": round(wall_s, 3),
        "goodput_req_s": round(len(met) / max(wall_s, 1e-9), 3),
        "goodput_tok_s": round(slo_tokens / max(wall_s, 1e-9), 2),
        "tokens": sum(len(r.generated) for r in done),
        "ttft_p50_ms": pctl_ms([r.ttft_s for r in done], 50),
        "ttft_p99_ms": pctl_ms([r.ttft_s for r in done], 99),
        "tpot_p50_ms": pctl_ms([r.tpot_s for r in done], 50),
        "tpot_p99_ms": pctl_ms([r.tpot_s for r in done], 99),
        "queue_wait_p50_ms": pctl_ms([r.queue_wait_s for r in done], 50),
        "queue_wait_p99_ms": pctl_ms([r.queue_wait_s for r in done], 99),
    }


def run_scheduler_trace(sched, items) -> float:
    t0 = sched.clock.now()
    sched.submit_trace([(t0 + t, req) for t, req in items])
    sched.run()
    return sched.clock.now() - t0


def run_static_trace(engine, items) -> float:
    """Open-loop replay against the static engine: requests are released
    into its FIFO queue at their arrival instants; nothing is shed."""
    clock = engine.clock
    t0 = clock.now()
    timed = [(t0 + t, req) for t, req in items]
    i = 0
    while True:
        now = clock.now()
        while i < len(timed) and timed[i][0] <= now:
            t_arr, req = timed[i]
            req.t_submit = t_arr          # TTFT counts from arrival
            engine.submit(req)
            i += 1
        busy = engine.step()
        if (not busy and not engine.queue
                and all(s is None for s in engine.active)):
            if i >= len(timed):
                break
            clock.sleep_until(timed[i][0])
    return clock.now() - t0


def calibrate(sched, vocab, slots, max_tokens, seed) -> dict:
    """Closed-loop warmup then a single unloaded wave: the warmup batch
    compiles every program (prefill buckets + decode); the measured wave
    fills each slot exactly once, so its TTFT is pure prefill latency
    and its drain time is the per-wave service time — the numbers the
    offered-rate grid and the default SLO deadline derive from."""
    from repro.serve import Request

    def batch(n, rid_base):
        rng = np.random.default_rng(seed + rid_base)
        return [Request(rid=rid_base + i,
                        prompt=rng.integers(0, vocab,
                                            size=int(rng.integers(4, 24))),
                        max_tokens=max_tokens)
                for i in range(n)]

    for r in batch(2 * slots, 10_000_000):    # warmup: compile everything
        sched.submit(r)
    sched.run()

    wave = batch(slots, 10_000_100)           # one wave, every slot busy
    t0 = sched.clock.now()
    for r in wave:
        sched.submit(r)
    sched.run()
    wall = sched.clock.now() - t0
    tokens = sum(len(r.generated) for r in wave)
    ttft0 = float(np.median([r.ttft_s for r in wave if r.ttft_s]))
    tpot0 = float(np.median([r.tpot_s for r in wave if r.tpot_s]))
    return {
        "capacity_tok_s": round(tokens / max(wall, 1e-9), 2),
        "capacity_req_s": round(slots / max(wall, 1e-9), 3),
        "unloaded_ttft_ms": round(ttft0 * 1e3, 2),
        "unloaded_tpot_ms": round(tpot0 * 1e3, 3),
        "unloaded_service_s": round(wall, 3),
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=16,
                    help="arrivals per (trace, rate) run")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--max-tokens", type=int, default=12)
    ap.add_argument("--rate-multipliers", default="0.5,1.5,3.0",
                    help="offered QPS as multiples of calibrated capacity")
    ap.add_argument("--traces", default="poisson,bursty")
    ap.add_argument("--slo-deadline-ms", type=float, default=0.0,
                    help="TTFT SLO (0 = derive from calibration)")
    ap.add_argument("--max-kv-blocks", type=int, default=0,
                    help="paged pool size (0 = slots*cache_len worth)")
    ap.add_argument("--kv-block-size", type=int, default=16)
    ap.add_argument("--paged-pool-frac", type=float, default=0.5,
                    help="extra demo run with a KV pool this fraction of "
                         "slots*cache_len (0 = skip)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--strict", action="store_true",
                    help="fail unless the scheduler beats the static "
                         "baseline on goodput at the top offered rate")
    ap.add_argument("--out", default=str(ROOT / "BENCH_serve_load.json"))
    args = ap.parse_args(argv)

    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serve import ServeEngine, ServeScheduler, make_trace

    cfg = get_reduced(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    sched = ServeScheduler(
        cfg, params, slots=args.slots, cache_len=args.cache_len,
        seed=args.seed, max_kv_blocks=args.max_kv_blocks or None,
        kv_block_size=args.kv_block_size)
    static = ServeEngine(cfg, params, slots=args.slots,
                         cache_len=args.cache_len, seed=args.seed)

    calib = calibrate(sched, cfg.vocab, args.slots, args.max_tokens,
                      args.seed)
    # warm the static engine's jit cache too (it traces its own programs)
    run_static_trace(static, make_trace(
        "poisson", cfg.vocab, 2 * args.slots, 100.0, seed=args.seed,
        max_tokens=args.max_tokens, rid_base=20_000_000))

    # TTFT SLO: 4x unloaded prefill latency plus one decode-wave of
    # queueing slack — trivially met unloaded, blown once the backlog
    # exceeds about one wave of work
    deadline_ms = args.slo_deadline_ms or round(
        4 * calib["unloaded_ttft_ms"]
        + args.max_tokens * calib["unloaded_tpot_ms"], 1)
    multipliers = [float(x) for x in args.rate_multipliers.split(",")]
    rates = [round(m * calib["capacity_req_s"], 3) for m in multipliers]
    kinds = [k.strip() for k in args.traces.split(",") if k.strip()]

    # compile counts frozen after warmup: continuous admission must never
    # retrace the decode program
    dc0 = {"scheduler": sched.decode_compiles,
           "static": static.decode_compiles}
    compile_log = []

    rid_base, results = 0, []
    for kind in kinds:
        for rate in rates:
            row = {"trace": kind, "offered_qps": rate,
                   "deadline_ms": deadline_ms}
            for name, engine, runner in [
                    ("scheduler", sched, run_scheduler_trace),
                    ("static", static, run_static_trace)]:
                items = make_trace(
                    kind, cfg.vocab, args.requests, rate, seed=args.seed,
                    max_tokens=args.max_tokens, rid_base=rid_base,
                    deadline_ms=(deadline_ms if name == "scheduler"
                                 else None))
                rid_base += args.requests
                wall = runner(engine, items)
                reqs = [r for _, r in items]
                # hard invariant: every arrival reached a terminal state
                bad = [r.rid for r in reqs
                       if r.status not in ("done", "shed")]
                assert not bad, f"{name} left requests {bad} unterminated"
                row[name] = trace_metrics(reqs, deadline_ms, wall)
                compile_log.append(
                    {"trace": kind, "offered_qps": rate, "engine": name,
                     "decode_compiles": engine.decode_compiles,
                     "prefill_compiles": engine.prefill_compiles})
            results.append(row)
            print(f"{kind:8s} @ {rate:7.3f} qps  "
                  f"sched goodput {row['scheduler']['goodput_req_s']:6.3f} "
                  f"(shed {row['scheduler']['shed']}, "
                  f"evict {row['scheduler']['evictions']})  "
                  f"static {row['static']['goodput_req_s']:6.3f} req/s")

    # decode program flat after warmup, prefill cache bucket-bounded
    assert sched.decode_compiles == dc0["scheduler"], \
        "scheduler retraced its decode program mid-trace"
    assert static.decode_compiles == dc0["static"], \
        "static engine retraced its decode program mid-trace"
    assert sched.prefill_compiles <= sched.n_buckets()

    # ---- paged-pool demo: same mid-rate trace against a scheduler whose
    # KV pool is a fraction of slots*cache_len — admission is budgeted by
    # blocks, LRU eviction recycles them, and every request still lands
    paged = None
    if args.paged_pool_frac > 0:
        pool_blocks = max(
            -(-args.cache_len // args.kv_block_size),
            int(args.paged_pool_frac * args.slots * args.cache_len
                / args.kv_block_size))
        paged_sched = ServeScheduler(
            cfg, params, slots=args.slots, cache_len=args.cache_len,
            seed=args.seed, max_kv_blocks=pool_blocks,
            kv_block_size=args.kv_block_size)
        calibrate(paged_sched, cfg.vocab, args.slots, args.max_tokens,
                  args.seed + 7)            # warm its jit caches
        mid = rates[len(rates) // 2]
        # no deadline and longer generations: every slot stays busy and
        # grows past the halved pool, so block recycling + LRU eviction
        # (not shedding) is what keeps the trace moving
        items = make_trace(
            "poisson", cfg.vocab, args.requests, mid, seed=args.seed + 1,
            max_tokens=min(2 * args.max_tokens, args.cache_len // 2),
            rid_base=rid_base,
            plen_range=(4, min(24, args.cache_len // 2)))
        rid_base += args.requests
        wall = run_scheduler_trace(paged_sched, items)
        reqs = [r for _, r in items]
        assert all(r.status in ("done", "shed") for r in reqs), \
            "paged run left requests unterminated"
        paged = {"offered_qps": mid, "pool_blocks": pool_blocks,
                 "pool_frac": args.paged_pool_frac,
                 **trace_metrics(reqs, deadline_ms, wall),
                 "kv": paged_sched.kv.snapshot()}
        print(f"paged    @ {mid:7.3f} qps  pool {pool_blocks} blocks  "
              f"goodput {paged['goodput_req_s']:6.3f} req/s, "
              f"evictions {paged['evictions']}")

    def sustainable(name):
        """Highest offered rate at which >= 90% of the finite trace's
        arrivals still met their TTFT SLO."""
        ok = [r["offered_qps"] for r in results
              if r[name]["slo_met"] >= 0.9 * r[name]["offered"]]
        return max(ok) if ok else 0.0

    top = max(rates)
    top_rows = [r for r in results if r["offered_qps"] == top]
    ratio = min(
        (r["scheduler"]["goodput_req_s"]
         / max(r["static"]["goodput_req_s"], 1e-9) for r in top_rows),
        default=1.0)
    report = {
        "schema": 1,
        "bench": "serve_load",
        "arch": cfg.name,
        "backend": jax.default_backend(),
        "config": {k: getattr(args, k.replace("-", "_")) for k in
                   ("requests", "slots", "cache_len", "max_tokens",
                    "kv_block_size", "seed")},
        "calibration": calib,
        "deadline_ms": deadline_ms,
        "kv_pool": sched.kv.snapshot(),
        "rates": results,
        "paged_pool": paged,
        "max_sustainable_qps": {"scheduler": sustainable("scheduler"),
                                "static": sustainable("static")},
        "goodput_ratio_at_top_rate": round(ratio, 2),
        "compile_counts": {
            "decode_after_warmup": dc0,
            "decode_final": {"scheduler": sched.decode_compiles,
                             "static": static.decode_compiles},
            "prefill": {"scheduler": sched.prefill_compiles,
                        "static": static.prefill_compiles},
            "flat_after_warmup": True,
            "trajectory": compile_log,
        },
    }
    pathlib.Path(args.out).write_text(json.dumps(report, indent=1) + "\n")
    print(f"# max sustainable qps: scheduler "
          f"{report['max_sustainable_qps']['scheduler']} vs static "
          f"{report['max_sustainable_qps']['static']}; goodput ratio at "
          f"{top} qps offered: {report['goodput_ratio_at_top_rate']}x "
          f"-> {args.out}")
    if args.strict and ratio <= 1.0:
        raise SystemExit(
            f"strict check failed: scheduler goodput ratio {ratio} <= 1 "
            f"at offered {top} qps")
    return report


if __name__ == "__main__":
    main()
