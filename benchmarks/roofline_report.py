"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
the committed dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.roofline_report [--layout fsdp_tp]
"""
from __future__ import annotations

import argparse
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]


def load(layout: str):
    d = ROOT / "experiments" / "dryrun"
    recs = []
    for p in sorted(d.glob(f"*_{layout}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def dryrun_table(recs, mesh: str):
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [f"| arch | shape | kind | status | compile_s | temp GB/chip | args GB/chip |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['kind']} | "
                       f"SKIP ({r['reason']}) | – | – | – |")
            continue
        mem = r.get("memory_analysis", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | {r['status']} | "
            f"{r.get('compile_s', 0)} | "
            f"{mem.get('temp_size_in_bytes', 0) / 1e9:.1f} | "
            f"{mem.get('argument_size_in_bytes', 0) / 1e9:.2f} |")
    return "\n".join(out)


def roofline_table(recs):
    rows = [r for r in recs if r["mesh"] == "16x16" and r["status"] == "ok"]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| useful_flops_ratio | MFU-UB | what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{rl['compute_s']:.4f} | {rl['memory_s']:.4f} | "
            f"{rl['collective_s']:.4f} | {rl['dominant'].replace('_s','')} | "
            f"{rl['useful_flops_ratio']:.2f} | {rl['mfu_upper_bound']:.3f} | "
            f"{_advice(r)} |")
    return "\n".join(out)


def _advice(r):
    rl = r["roofline"]
    dom = rl["dominant"]
    kind = r["kind"]
    if dom == "collective_s":
        bd = rl.get("collective_breakdown", {})
        top = max(bd, key=bd.get) if bd else "tp_allreduce"
        return {"tp_allreduce": "sequence-parallel boundaries (fsdp_sp)",
                "fsdp_allgather": "larger per-gather granularity / overlap",
                "moe_alltoall": "grouped local-capacity dispatch",
                "grad_reducescatter": "overlap grad RS with backward",
                "pod_gradsync": "overlap DCN sync with compute",
                }.get(top, "resharding-free activation layout")
    if dom == "memory_s":
        if kind == "decode":
            return "irreducible cache read; batch more requests per step"
        return "fuse elementwise chains; larger microbatch"
    return "already compute-bound: kernel-level (Pallas) tuning"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--layout", default="fsdp_tp",
                    choices=["fsdp_tp", "fsdp_sp"])
    args = ap.parse_args()
    recs = load(args.layout)
    if not recs:
        print(f"no artifacts for layout {args.layout}")
        return
    print(f"### Dry-run — single pod 16x16 ({args.layout})\n")
    print(dryrun_table(recs, "16x16"))
    print(f"\n### Dry-run — multi-pod 2x16x16 ({args.layout})\n")
    print(dryrun_table(recs, "2x16x16"))
    print(f"\n### Roofline (single pod, {args.layout})\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
