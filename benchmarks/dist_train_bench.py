"""Data-parallel scaling benchmark: the distributed subsystem's
contract row (the training-side sibling of ``campaign_bench.py``'s
host-ceiling methodology).

For each world size N (default 1,2,4) it runs the REAL gang path —
``repro.distributed.gang.run_gang_local`` spawning N rank processes
with a ``jax.distributed`` coordinator, exactly what ``repro.launch run
train --world_size N`` does — at a fixed GLOBAL batch, and reports:

* steps/s and global tokens/s, measured by a **two-leg delta**: each
  world runs once at ``--steps A`` and once at ``--steps A+M``; the
  throughput is ``M / (pure_step_s_long - pure_step_s_short)``, so
  compile time and first-step warmup cancel instead of polluting the
  small-step runs CI can afford;
* speedup vs world=1 and parallel efficiency (ideal = N at fixed global
  batch: each rank computes ``G/N`` rows);
* the analytic ring all-reduce traffic per step and rank
  (``2(N-1)/N x grad_bytes`` — the FireCaffe reduction model), read
  back from the trainer's own ``dist`` report section;
* an estimated communication fraction: ``(t_N - t_local) / t_N`` where
  ``t_local`` is a single process timed at the same LOCAL batch
  ``G/N`` (same per-rank compute, zero communication);
* the host's measured memory-streaming parallel ceiling (from
  ``campaign_bench.host_parallel_ceiling``) — on an oversubscribed
  CPU container the ceiling, not the algorithm, usually binds, and the
  ceiling-relative efficiency is the number treated as the contract.

Results extend ``BENCH_train.json`` under a ``"distributed"`` key (the
single-process variant rows are left untouched), so CI uploads one
training-performance artifact.

    PYTHONPATH=src python benchmarks/dist_train_bench.py \
        --worlds 1,2 --batch 8 --steps 3 --extra-steps 6
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT / "benchmarks"))


def _gang_report(arch: str, world: int, batch: int, seq: int,
                 steps: int, seed: int, workdir: pathlib.Path) -> dict:
    """One gang run (world=1 still goes through the dist rank path, so
    every row pays identical per-process overheads)."""
    from repro.api.spec import RunSpec
    from repro.distributed.gang import run_gang_local

    spec = RunSpec(
        kind="train", arch=arch, seed=seed,
        name=f"distbench-w{world}-b{batch}-s{steps}",
        overrides={"steps": steps, "batch": batch, "seq": seq,
                   "world_size": world, "log_every": 0})
    return run_gang_local(spec, world,
                          log_dir=str(workdir / f"w{world}-s{steps}"))


def _throughput(arch: str, world: int, batch: int, seq: int,
                steps_a: int, steps_b: int, seed: int,
                workdir: pathlib.Path) -> dict:
    """Two-leg delta throughput for one (world, global batch) point."""
    short = _gang_report(arch, world, batch, seq, steps_a, seed, workdir)
    long_ = _gang_report(arch, world, batch, seq, steps_b, seed, workdir)
    d_steps = steps_b - steps_a
    d_wall = long_["pure_step_s"] - short["pure_step_s"]
    steps_per_s = d_steps / d_wall if d_wall > 0 else 0.0
    return {
        "report": long_,
        "steps_per_s": round(steps_per_s, 3),
        "tokens_per_s": round(steps_per_s * batch * seq, 1),
        "step_ms": round(1e3 / steps_per_s, 2) if steps_per_s else None,
        "legs": {"steps": [steps_a, steps_b],
                 "pure_step_s": [short["pure_step_s"],
                                 long_["pure_step_s"]]},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--worlds", default="1,2,4",
                    help="comma-separated world sizes to sweep")
    ap.add_argument("--batch", type=int, default=8,
                    help="GLOBAL batch, fixed across the sweep (must "
                         "divide by every world size)")
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--steps", type=int, default=3,
                    help="short-leg step count")
    ap.add_argument("--extra-steps", type=int, default=9,
                    help="long leg runs --steps + this many more")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-host-ceiling", action="store_true")
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--out", default=str(ROOT / "BENCH_train.json"))
    args = ap.parse_args(argv)

    import tempfile
    workdir = pathlib.Path(args.workdir or
                           tempfile.mkdtemp(prefix="distbench-"))
    worlds = [int(w) for w in args.worlds.split(",") if w]
    for w in worlds:
        if args.batch % w:
            ap.error(f"--batch {args.batch} not divisible by world {w}")
    steps_a, steps_b = args.steps, args.steps + args.extra_steps

    host = None
    if not args.skip_host_ceiling:
        from campaign_bench import host_parallel_ceiling
        host = host_parallel_ceiling(nproc=max(worlds))
        print(f"host ceilings over {host['cpus_visible']} visible cpus: "
              f"alu={host['alu']['speedup_ceiling']}x "
              f"mem={host['mem']['speedup_ceiling']}x", flush=True)

    rows = []
    base_steps_per_s = None
    for world in worlds:
        point = _throughput(args.arch, world, args.batch, args.seq,
                            steps_a, steps_b, args.seed, workdir)
        rep = point.pop("report")
        dist = rep.get("dist") or {}
        row = {
            "world_size": world,
            "global_batch": args.batch,
            "local_batch": args.batch // world,
            "steps_per_s": point["steps_per_s"],
            "tokens_per_s": point["tokens_per_s"],
            "step_ms": point["step_ms"],
            "legs": point["legs"],
            "grad_bytes": dist.get("grad_bytes"),
            "allreduce_bytes_per_step":
                dist.get("allreduce_bytes_per_step"),
            "final_loss": rep.get("final_loss"),
        }
        if base_steps_per_s is None:
            base_steps_per_s = row["steps_per_s"] or 1e-9
        speedup = row["steps_per_s"] / base_steps_per_s
        row["speedup_vs_world1"] = round(speedup, 3)
        row["efficiency"] = round(speedup / world, 3)
        if world > 1 and row["steps_per_s"]:
            # same per-rank compute, zero communication: one process at
            # the LOCAL batch isolates the all-reduce + sync cost
            local = _throughput(args.arch, 1, args.batch // world,
                                args.seq, steps_a, steps_b, args.seed,
                                workdir)
            t_n = 1.0 / row["steps_per_s"]
            t_local = (1.0 / local["steps_per_s"]
                       if local["steps_per_s"] else t_n)
            frac = max(0.0, (t_n - t_local) / t_n)
            row["local_ref_steps_per_s"] = local["steps_per_s"]
            row["comm_fraction_est"] = round(frac, 4)
            if row["allreduce_bytes_per_step"]:
                row["allreduce_mb_per_s_est"] = round(
                    row["allreduce_bytes_per_step"] / 1e6
                    / max(t_n - t_local, 1e-9), 1)
        if host is not None and world > 1:
            ceiling = min(world, host["mem"]["speedup_ceiling"] or world)
            row["host_ceiling_speedup"] = ceiling
            row["efficiency_vs_host_ceiling"] = round(speedup / ceiling,
                                                      3)
        rows.append(row)
        print(f"world={world}: {row['steps_per_s']} steps/s "
              f"({row['tokens_per_s']} tok/s) speedup={speedup:.2f}x "
              f"eff={row['efficiency']}"
              + (f" comm_frac={row.get('comm_fraction_est')}"
                 if world > 1 else ""), flush=True)

    payload = {
        "workload": {"arch": args.arch, "global_batch": args.batch,
                     "seq": args.seq,
                     "legs_steps": [steps_a, steps_b]},
        "host": host,
        "scaling": rows,
    }
    out = pathlib.Path(args.out)
    doc = {}
    if out.exists():
        try:
            doc = json.loads(out.read_text())
        except ValueError:
            doc = {}
    doc["distributed"] = payload
    out.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
