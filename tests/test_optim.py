"""Optimizer correctness against closed-form references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adam, adamw, get_optimizer, lamb, sgd, sgdm
from repro.optim.schedules import (constant, cosine, step_decay,
                                   warmup_cosine)


def _quad_setup():
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.5, -1.0, 2.0])}
    return params, grads


def test_sgd_step():
    p, g = _quad_setup()
    opt = sgd()
    s = opt.init(p)
    new_p, _ = opt.update(g, s, p, jnp.array(0), 0.1)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.asarray(p["w"] - 0.1 * g["w"]), rtol=1e-6)


def test_sgdm_accumulates_momentum():
    p, g = _quad_setup()
    opt = sgdm(momentum=0.9)
    s = opt.init(p)
    p1, s = opt.update(g, s, p, jnp.array(0), 0.1)
    p2, s = opt.update(g, s, p1, jnp.array(1), 0.1)
    # second step uses m = 0.9*g + g = 1.9 g
    expect = p1["w"] - 0.1 * 1.9 * g["w"]
    np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(expect),
                               rtol=1e-6)


def test_adam_matches_reference_formula():
    p, g = _quad_setup()
    b1, b2, eps, lr = 0.9, 0.999, 1e-8, 0.01
    opt = adam(b1=b1, b2=b2, eps=eps)
    s = opt.init(p)
    new_p, s = opt.update(g, s, p, jnp.array(0), lr)
    m = (1 - b1) * g["w"]
    v = (1 - b2) * g["w"] ** 2
    mhat = m / (1 - b1)
    vhat = v / (1 - b2)
    expect = p["w"] - lr * mhat / (jnp.sqrt(vhat) + eps)
    np.testing.assert_allclose(np.asarray(new_p["w"]), np.asarray(expect),
                               rtol=1e-6)


def test_adamw_decoupled_weight_decay():
    p, g = _quad_setup()
    wd = 0.1
    no_wd, _ = adamw(weight_decay=0.0).update(
        g, adamw().init(p), p, jnp.array(0), 0.01)
    with_wd, _ = adamw(weight_decay=wd).update(
        g, adamw().init(p), p, jnp.array(0), 0.01)
    np.testing.assert_allclose(
        np.asarray(no_wd["w"] - with_wd["w"]),
        np.asarray(0.01 * wd * p["w"]), rtol=1e-5, atol=1e-7)


def test_lamb_trust_ratio_scales_update():
    """LAMB update direction equals AdamW's but scaled per-leaf by
    ||p|| / ||u||."""
    p, g = _quad_setup()
    lr = 0.01
    a_opt = adamw(weight_decay=0.01, eps=1e-6)
    l_opt = lamb(weight_decay=0.01, eps=1e-6)
    pa, _ = a_opt.update(g, a_opt.init(p), p, jnp.array(0), lr)
    pl, _ = l_opt.update(g, l_opt.init(p), p, jnp.array(0), lr)
    u_adam = (p["w"] - pa["w"]) / lr
    u_lamb = (p["w"] - pl["w"]) / lr
    ratio = jnp.linalg.norm(p["w"]) / jnp.linalg.norm(u_adam)
    np.testing.assert_allclose(np.asarray(u_lamb),
                               np.asarray(ratio * u_adam), rtol=1e-5)


def test_optimizers_converge_on_quadratic():
    """All four optimizers reduce f(w) = ||w - w*||^2."""
    target = jnp.array([1.0, -1.0, 0.5, 2.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for name, lr in [("sgd", 0.1), ("sgdm", 0.05), ("adam", 0.1),
                     ("adamw", 0.1), ("lamb", 0.1)]:
        opt = get_optimizer(name)
        p = {"w": jnp.zeros(4)}
        s = opt.init(p)
        l0 = float(loss(p))
        for i in range(100):
            g = jax.grad(loss)(p)
            p, s = opt.update(g, s, p, jnp.array(i), lr)
        assert float(loss(p)) < 0.05 * l0, name


def test_bf16_state_dtype():
    opt = adam(state_dtype=jnp.bfloat16)
    s = opt.init({"w": jnp.zeros(4, jnp.bfloat16)})
    assert s["m"]["w"].dtype == jnp.bfloat16


def test_schedules():
    assert float(constant(1e-3)(jnp.array(100))) == pytest.approx(1e-3)
    sd = step_decay(1.0, 0.5, every=50)
    assert float(sd(jnp.array(0))) == pytest.approx(1.0)
    assert float(sd(jnp.array(50))) == pytest.approx(0.5)
    assert float(sd(jnp.array(100))) == pytest.approx(0.25)
    wc = warmup_cosine(1.0, total_steps=1000, warmup_steps=100)
    assert float(wc(jnp.array(0))) == pytest.approx(0.0)
    assert float(wc(jnp.array(100))) == pytest.approx(1.0, rel=1e-2)
    assert float(wc(jnp.array(1000))) == pytest.approx(0.1, rel=1e-2)
    cs = cosine(1.0, 100)
    assert float(cs(jnp.array(0))) == pytest.approx(1.0)
