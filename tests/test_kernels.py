"""Pallas kernel validation: shape/dtype sweeps, assert_allclose against
the pure-jnp oracles (interpret=True executes kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.percentile_norm.ops import percentile_normalize
from repro.kernels.percentile_norm.ref import percentile_normalize_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

KEY = jax.random.PRNGKey(42)


# ------------------------------------------------------------ flash attn
FLASH_CASES = [
    # B, Sq, Sk, H, Kh, hd, causal, window, bq, bk
    (2, 128, 128, 4, 2, 64, True, None, 64, 64),
    (1, 256, 256, 8, 8, 32, True, 64, 128, 64),
    (2, 100, 100, 4, 1, 64, False, None, 32, 32),
    (1, 512, 512, 4, 2, 128, True, None, 256, 256),
    (1, 64, 192, 2, 2, 16, False, None, 64, 64),   # cross-length
    (3, 80, 80, 6, 3, 48, True, 32, 16, 16),       # odd sizes + window
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, Sq, Sk, H, Kh, hd, causal, window, bq, bk = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Kh, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Kh, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


FLASH_GRAD_CASES = [
    # B, Sq, Sk, H, Kh, hd, causal, window, bq, bk
    (2, 128, 128, 4, 2, 64, True, None, 64, 64),
    (1, 100, 100, 4, 1, 32, False, None, 32, 32),   # padding path
    (3, 80, 80, 6, 3, 48, True, 32, 16, 16),        # window + GQA
    (1, 64, 192, 2, 2, 16, False, None, 64, 64),    # cross-length
]


@pytest.mark.parametrize("case", FLASH_GRAD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_grads_match_ref(case, dtype):
    """The custom-VJP backward kernels agree with autodiff through the
    jnp oracle — the contract that lets training run the Pallas path."""
    B, Sq, Sk, H, Kh, hd, causal, window, bq, bk = case
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Kh, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Kh, hd), dtype)
    co = jax.random.normal(ks[3], (B, Sq, H, hd), jnp.float32)

    def f(q, k, v):
        out = flash_attention(q, k, v, causal=causal, window=window,
                              block_q=bq, block_k=bk)
        return jnp.sum(out.astype(jnp.float32) * co)

    def f_ref(q, k, v):
        out = attention_ref(q, k, v, causal=causal, window=window)
        return jnp.sum(out.astype(jnp.float32) * co)

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    for a, b, name in zip(g, g_ref, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=tol, rtol=tol, err_msg=name)


# ------------------------------------------------------------- ssd scan
SSD_CASES = [
    # Bs, S, nh, hp, g, N, chunk, head_block
    (2, 64, 4, 16, 1, 16, 16, 4),
    (1, 96, 8, 32, 2, 32, 32, 4),
    (2, 130, 4, 16, 4, 8, 32, 2),    # padding path
    (1, 128, 2, 64, 1, 64, 64, 2),
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_ref(case, dtype):
    Bs, S, nh, hp, g, N, chunk, hb = case
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bs, S, nh, hp), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bs, S, nh))).astype(
        jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B = jax.random.normal(ks[3], (Bs, S, g, N), dtype)
    C = jax.random.normal(ks[4], (Bs, S, g, N), dtype)
    y = ssd_scan(x, dt, A, B, C, chunk=chunk, head_block=hb)
    yr, _ = ssd_ref(x, dt, A, B, C)
    tol = 5e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=tol, rtol=tol)


SSD_GRAD_CASES = [
    # Bs, S, nh, hp, g, N, chunk, head_block
    (2, 64, 4, 16, 1, 16, 16, 4),
    (2, 130, 4, 16, 4, 8, 32, 2),    # padding path
    (1, 96, 8, 32, 2, 32, 32, 4),
]


@pytest.mark.parametrize("case", SSD_GRAD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_grads_match_ref(case, dtype):
    """jax.grad through the Pallas SSD op (custom VJP) agrees with
    autodiff through the sequential-recurrence oracle."""
    Bs, S, nh, hp, g, N, chunk, hb = case
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (Bs, S, nh, hp), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bs, S, nh))).astype(
        jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B = jax.random.normal(ks[3], (Bs, S, g, N), dtype)
    C = jax.random.normal(ks[4], (Bs, S, g, N), dtype)
    co = jax.random.normal(ks[5], (Bs, S, nh, hp), jnp.float32)

    def f(x, dt, A, B, C):
        y = ssd_scan(x, dt, A, B, C, chunk=chunk, head_block=hb)
        return jnp.sum(y.astype(jnp.float32) * co)

    def f_ref(x, dt, A, B, C):
        y, _ = ssd_ref(x, dt, A, B, C)
        return jnp.sum(y.astype(jnp.float32) * co)

    grads = jax.grad(f, argnums=(0, 1, 2, 3, 4))(x, dt, A, B, C)
    grads_ref = jax.grad(f_ref, argnums=(0, 1, 2, 3, 4))(x, dt, A, B, C)
    tol = 2e-3 if dtype == jnp.float32 else 2e-1
    for a, b, name in zip(grads, grads_ref, ("dx", "ddt", "dA", "dB", "dC")):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=tol, rtol=tol, err_msg=name)


def test_ssd_scan_return_state_matches_ref():
    """return_state=True yields the kernel's carried final state, and
    grads flow through the state output too."""
    Bs, S, nh, hp, N = 2, 64, 4, 16, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bs, S, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bs, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B = jax.random.normal(ks[3], (Bs, S, 1, N))
    C = jax.random.normal(ks[4], (Bs, S, 1, N))
    y, h = ssd_scan(x, dt, A, B, C, chunk=16, return_state=True)
    yr, hr = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=5e-4, rtol=5e-4)
    gh = jax.grad(lambda x: jnp.sum(
        ssd_scan(x, dt, A, B, C, chunk=16, return_state=True)[1]))(x)
    gh_ref = jax.grad(lambda x: jnp.sum(ssd_ref(x, dt, A, B, C)[1]))(x)
    np.testing.assert_allclose(np.asarray(gh), np.asarray(gh_ref),
                               atol=5e-4, rtol=5e-4)


def test_ssd_scan_state_continuity():
    """Scanning two halves with carried state == scanning the whole."""
    from repro.models.ssm import ssd_chunked
    from repro.configs.base import SSMConfig
    cfg = SSMConfig(d_state=16, head_dim=16, n_groups=1, chunk=16)
    ks = jax.random.split(KEY, 5)
    Bs, S, nh, hp, N = 2, 64, 4, 16, 16
    x = jax.random.normal(ks[0], (Bs, S, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bs, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B = jax.random.normal(ks[3], (Bs, S, 1, N))
    C = jax.random.normal(ks[4], (Bs, S, 1, N))
    y_full, h_full = ssd_chunked(x, dt, A, B, C, cfg)
    y1, h1 = ssd_chunked(x[:, :32], dt[:, :32], A, B[:, :32], C[:, :32], cfg)
    y2, h2 = ssd_chunked(x[:, 32:], dt[:, 32:], A, B[:, 32:], C[:, 32:],
                         cfg, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               atol=1e-4, rtol=1e-4)


# ------------------------------------------------------- percentile norm
@pytest.mark.parametrize("shape", [(64, 64, 3), (100, 37, 13), (257, 3),
                                   (31, 31, 1)])
@pytest.mark.parametrize("block_rows", [32, 128])
def test_percentile_norm_matches_ref(shape, block_rows):
    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.gamma(2.0, 500.0, size=shape).astype(np.float32))
    out = percentile_normalize(img, block_rows=block_rows)
    ref = percentile_normalize_ref(img)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    assert float(out.min()) >= 0.0 and float(out.max()) <= 1.0


def test_percentile_norm_constant_band_safe():
    img = jnp.ones((64, 64, 2))
    out = percentile_normalize(img)
    assert bool(jnp.isfinite(out).all())


PCT_GRAD_SHAPES = [(257, 5), (64, 64, 3), (100, 37, 13)]


@pytest.mark.parametrize("shape", PCT_GRAD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_percentile_norm_grads_match_ref(shape, dtype):
    """jax.grad through the Pallas stretch (custom VJP) agrees with
    autodiff through the pure-jnp oracle — including the percentile
    bounds' interpolation gradients, which stay outside the custom-VJP
    boundary.  Completes the per-dtype fwd+grad contract the other two
    kernels got in PR 4."""
    ks = jax.random.split(KEY, 2)
    x = (jax.random.normal(ks[0], shape) * 3.0).astype(dtype)
    co = jax.random.normal(ks[1], shape, jnp.float32)

    def f(v):
        return jnp.sum(percentile_normalize(v, block_rows=64) * co)

    def f_ref(v):
        return jnp.sum(percentile_normalize_ref(v) * co)

    g = jax.grad(f)(x)
    g_ref = jax.grad(f_ref)(x)
    assert g.shape == x.shape and g.dtype == x.dtype
    # f32 tolerance matches the SSD grad test: the percentile-neighbor
    # pixels carry the summed dlo/dhi term, where division-vs-reciprocal
    # rounding at the clip boundary costs a few 1e-4 relative
    tol = 2e-3 if dtype == jnp.float32 else 2e-1
    np.testing.assert_allclose(np.asarray(g, np.float32),
                               np.asarray(g_ref, np.float32),
                               atol=tol, rtol=tol)
    assert bool(jnp.isfinite(g.astype(jnp.float32)).all())


def test_percentile_norm_grad_zero_outside_stretch():
    """Pixels clipped at 0 or 1 contribute zero input gradient through
    the stretch path (clip subgradient), and a constant band (hi == lo)
    stays finite instead of emitting inf/nan."""
    x = jnp.asarray(np.linspace(-100.0, 100.0, 128,
                                dtype=np.float32)).reshape(-1, 1)
    g = jax.grad(lambda v: jnp.sum(percentile_normalize(v)))(x)
    gf = np.asarray(g)
    # extremes sit outside [p1, p99]: clipped, so only the percentile
    # interpolation term (exactly zero for non-neighbor ranks) remains
    assert gf[0, 0] == 0.0 and gf[-1, 0] == 0.0
    g_const = jax.grad(lambda v: jnp.sum(percentile_normalize(v)))(
        jnp.ones((64, 2)))
    assert bool(jnp.isfinite(g_const).all())


def test_ssd_seq_parallel_matches_chunked():
    """The sequence-parallel SSD decomposition (per-segment scan + state
    combine + local correction) is exact vs the plain chunked scan."""
    from repro.configs.base import SSMConfig
    from repro.models.ssm import ssd_chunked, ssd_seq_parallel
    cfg = SSMConfig(d_state=16, head_dim=16, n_groups=2, chunk=16)
    ks = jax.random.split(KEY, 5)
    Bs, S, nh, N = 2, 128, 4, 16
    x = jax.random.normal(ks[0], (Bs, S, nh, 16))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bs, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B = jax.random.normal(ks[3], (Bs, S, 2, N))
    C = jax.random.normal(ks[4], (Bs, S, 2, N))
    y0, h0 = ssd_chunked(x, dt, A, B, C, cfg)
    for n_seg in (2, 4, 8):
        y1, h1 = ssd_seq_parallel(x, dt, A, B, C, cfg, n_seg)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                                   atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h0),
                                   atol=2e-5, rtol=2e-5)
