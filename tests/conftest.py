"""Test fixtures.

Provides a minimal fallback implementation of the ``hypothesis`` API used
by this suite (``given``/``settings``/``strategies``) when the real
package is not installed — the container image ships without it.  The
fallback draws deterministic pseudo-random examples, so the property
tests still execute (with weaker shrinking/edge coverage than real
hypothesis).  When hypothesis is installed it is used untouched.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types


def _install_hypothesis_fallback():
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    def lists(elements, min_size=0, max_size=10, unique=False):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            out, seen, tries = [], set(), 0
            while len(out) < n and tries < 50 * (n + 1):
                tries += 1
                v = elements._draw(rng)
                if unique:
                    if v in seen:
                        continue
                    seen.add(v)
                out.append(v)
            return out
        return _Strategy(draw)

    def dictionaries(keys, values, min_size=0, max_size=10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            out, tries = {}, 0
            while len(out) < n and tries < 50 * (n + 1):
                tries += 1
                out[keys._draw(rng)] = values._draw(rng)
            return out
        return _Strategy(draw)

    def settings(**kwargs):
        def deco(fn):
            fn._fallback_settings = kwargs
            return fn
        return deco

    def given(**named):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                cfg = getattr(fn, "_fallback_settings", {})
                n = int(cfg.get("max_examples", 20))
                rng = random.Random(0)
                for _ in range(n):
                    draws = {k: s._draw(rng) for k, s in named.items()}
                    fn(*args, **{**kwargs, **draws})
            # hide the drawn parameters from pytest's fixture resolution
            # (real hypothesis rewrites the signature the same way)
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in named]
            wrapper.__wrapped__ = None
            del wrapper.__wrapped__
            wrapper.__signature__ = sig.replace(parameters=params)
            return wrapper
        return deco

    for name, fn in [("integers", integers), ("floats", floats),
                     ("sampled_from", sampled_from), ("lists", lists),
                     ("dictionaries", dictionaries)]:
        setattr(st, name, fn)
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401 — prefer the real package
except ModuleNotFoundError:
    _install_hypothesis_fallback()
