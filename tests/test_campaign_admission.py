"""Property-based executor admission invariants (hypothesis; the
conftest fallback runs the same properties when the real package is not
installed):

* the ResourcePool never oversubscribes a node, under any admit/release
  interleaving;
* the executor never runs more than ``workers`` processes at once;
* conservation: submitted = succeeded + failed (+ unschedulable, which
  is a failure) — no job is lost or double-terminated, and the event log
  replays consistently;
* no starvation under priorities: every admissible job is eventually
  admitted, in (-priority, submit-order) order on a serial pool.
"""
import json

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (JobSpec, JobState, NodeSpec, Orchestrator,
                        PersistentVolume, Resources, ResourcePool,
                        replay_events)
from repro.core.executor import EVENTS_REL

from test_campaign_exec import fake_spawn


# Seeds are cheap to draw with both real and fallback hypothesis; all
# structure (resources, priorities, outcomes) is derived from them.
seeds = st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=14)


def _resources(seed: int) -> Resources:
    return Resources(gpus=seed % 3, cpus=1 + (seed // 3) % 4,
                     memory_gb=float(4 + (seed // 12) % 3 * 10))


def _inventory(seed: int):
    return [
        NodeSpec("small", gpus=2, gpu_memory_gb=11, cpus=4, memory_gb=24,
                 count=1 + seed % 2),
        NodeSpec("big", gpus=4, gpu_memory_gb=48, cpus=8, memory_gb=64,
                 count=1 + (seed // 2) % 2),
    ]


@given(job_seeds=seeds, inv_seed=st.integers(0, 3))
@settings(max_examples=25, deadline=None)
def test_pool_never_oversubscribes(job_seeds, inv_seed):
    """Any admit/release interleaving keeps every node within capacity
    (the pool raises internally on violation; we also check directly)."""
    pool = ResourcePool(_inventory(inv_seed))
    caps = {n.name: n.spec for n in pool.nodes}
    admitted = []
    pending = [_resources(s) for s in job_seeds]
    rng_release = [s % 2 == 0 for s in job_seeds]
    step = 0
    while pending or admitted:
        progressed = False
        for res in list(pending):
            node = pool.admit(res)
            if node is not None:
                pending.remove(res)
                admitted.append((node, res))
                progressed = True
            for name, (g, c, m) in pool.in_use().items():
                spec = caps[name]
                assert 0 <= g <= spec.gpus
                assert 0 <= c <= spec.cpus
                assert 0 - 1e-9 <= m <= spec.memory_gb + 1e-9
        # release one (deterministically chosen) to make room
        if admitted and (not progressed or
                         rng_release[step % len(rng_release)]):
            node, res = admitted.pop(0)
            pool.release(node, res)
        step += 1
        if step > 10 * len(job_seeds) + 20:
            # remaining pending jobs simply never fit this inventory
            assert all(not pool.fits_when_empty(r) for r in pending)
            break


@given(job_seeds=seeds, workers=st.integers(1, 4), inv_seed=st.integers(0, 3))
@settings(max_examples=15, deadline=None)
def test_executor_conservation_and_worker_cap(tmp_path_factory, job_seeds,
                                              workers, inv_seed):
    """submitted = succeeded + failed; every record terminal; concurrent
    processes never exceed ``workers``; the event log replays clean."""
    tmp = tmp_path_factory.mktemp("adm")
    pvc = PersistentVolume(tmp)
    orch = Orchestrator(pvc)
    outcome_plan = {}
    for i, s in enumerate(job_seeds):
        name = f"job{i}"
        # ~1/4 of jobs fail once then succeed; ~1/8 fail permanently
        if s % 8 == 7:
            outcome_plan[name] = [1, 1, 1, 1]          # exhausts retries
        elif s % 4 == 2:
            outcome_plan[name] = [1, 0]
        orch.submit(JobSpec(name=name, resources=_resources(s),
                            priority=s % 5, retries=3,
                            env={"RUN_KIND": "train"}))
    tracker = {"active": 0, "max": 0}
    recs = orch.run_cluster(workers=workers, poll_s=0.0,
                            inventory=_inventory(inv_seed),
                            retry_backoff_base_s=0.0, telemetry=False,
                            spawn=fake_spawn(plan=outcome_plan,
                                             tracker=tracker))
    assert tracker["max"] <= workers
    states = [r.state for r in recs.values()]
    assert all(s in (JobState.SUCCEEDED, JobState.FAILED) for s in states)
    n_ok = sum(s == JobState.SUCCEEDED for s in states)
    n_fail = sum(s == JobState.FAILED for s in states)
    assert n_ok + n_fail == len(job_seeds)          # conservation
    state = replay_events(pvc.read_bytes(EVENTS_REL).decode().splitlines())
    assert state["ended"] and state["consistent"], state["violations"]
    assert state["counts"].get("Succeeded", 0) == n_ok
    assert state["counts"].get("Failed", 0) == n_fail


@given(prios=st.lists(st.integers(0, 9), min_size=2, max_size=10))
@settings(max_examples=15, deadline=None)
def test_no_starvation_and_priority_order(tmp_path_factory, prios):
    """On a serial pool every job is admitted exactly once, in
    (-priority, submit order) — FIFO within a class, so nothing
    starves."""
    tmp = tmp_path_factory.mktemp("prio")
    pvc = PersistentVolume(tmp)
    orch = Orchestrator(pvc)
    for i, p in enumerate(prios):
        orch.submit(JobSpec(name=f"p{i}", priority=p,
                            resources=Resources(gpus=1, cpus=1,
                                                memory_gb=1.0),
                            env={"RUN_KIND": "train"}))
    orch.run_cluster(workers=1, poll_s=0.0, retry_backoff_base_s=0.0,
                     telemetry=False, spawn=fake_spawn())
    events = [json.loads(ln) for ln
              in pvc.read_bytes(EVENTS_REL).decode().splitlines()]
    admitted = [e["job"] for e in events if e["event"] == "admitted"]
    assert sorted(admitted) == sorted(f"p{i}" for i in range(len(prios)))
    expected = [f"p{i}" for i in
                sorted(range(len(prios)), key=lambda i: (-prios[i], i))]
    assert admitted == expected


# --------------------------------------------------------------------------
# telemetry-fed admission (learned requests) + backfill invariants
# --------------------------------------------------------------------------
def _learned_from(obs_seeds, kind="train:"):
    from repro.core import LearnedRequests
    learned = LearnedRequests()
    for s in obs_seeds:
        # wild observed peaks: from near-zero to far above any declared
        learned.observe(kind, cpus=(s % 97) / 7.0,
                        memory_gb=(s % 1031) / 13.0)
    return learned


@given(obs_seeds=st.lists(st.integers(0, 2**31 - 1), min_size=0,
                          max_size=24),
       dec_seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_learned_requests_clamped_to_declared(obs_seeds, dec_seed):
    """The learned effective request can only *tighten* a declared one:
    componentwise ≤ declared, ≥ the safety floor, GPUs never touched —
    and below min_samples the declared request passes through verbatim.
    Admission therefore can never oversubscribe more than the declared
    requests already allowed."""
    learned = _learned_from(obs_seeds)
    declared = _resources(dec_seed)
    eff = learned.effective("train:", declared)
    assert eff.gpus == declared.gpus
    assert 1 <= eff.cpus <= declared.cpus
    assert 0 < eff.memory_gb <= declared.memory_gb
    if len(obs_seeds) < learned.min_samples:
        assert (eff.cpus, eff.memory_gb) == (declared.cpus,
                                             declared.memory_gb)
    # an unknown kind is never shrunk
    other = learned.effective("serve:", declared)
    assert (other.cpus, other.memory_gb) == (declared.cpus,
                                             declared.memory_gb)


@given(job_seeds=seeds,
       obs_seeds=st.lists(st.integers(0, 2**31 - 1), min_size=3,
                          max_size=20),
       workers=st.integers(1, 4), inv_seed=st.integers(0, 3))
@settings(max_examples=10, deadline=None)
def test_admission_with_learned_requests_stays_sound(
        tmp_path_factory, job_seeds, obs_seeds, workers, inv_seed):
    """A campaign admitted under arbitrary learned requests still
    conserves jobs, replays consistently, and every admitted attempt's
    effective request is within its declared envelope."""
    tmp = tmp_path_factory.mktemp("learned")
    pvc = PersistentVolume(tmp)
    orch = Orchestrator(pvc)
    declared = {}
    for i, s in enumerate(job_seeds):
        name = f"job{i}"
        declared[name] = _resources(s)
        orch.submit(JobSpec(name=name, resources=declared[name],
                            priority=s % 5, retries=3,
                            env={"RUN_KIND": "train"}))
    recs = orch.run_cluster(workers=workers, poll_s=0.0,
                            inventory=_inventory(inv_seed),
                            retry_backoff_base_s=0.0, telemetry=False,
                            learned=_learned_from(obs_seeds),
                            spawn=fake_spawn())
    assert all(r.state in (JobState.SUCCEEDED, JobState.FAILED)
               for r in recs.values())
    events = [json.loads(ln) for ln
              in pvc.read_bytes(EVENTS_REL).decode().splitlines()]
    for e in events:
        if e["event"] != "admitted" or not e.get("learned_request"):
            continue
        dec, eff = declared[e["job"]], e["learned_request"]
        assert eff["gpus"] == dec.gpus
        assert 1 <= eff["cpus"] <= dec.cpus
        assert 0 < eff["memory_gb"] <= dec.memory_gb
    state = replay_events(events)
    assert state["ended"] and state["consistent"], state["violations"]


def _bf_inventory():
    return [NodeSpec("small", gpus=2, gpu_memory_gb=11, cpus=4,
                     memory_gb=24, count=1),
            NodeSpec("big", gpus=4, gpu_memory_gb=48, cpus=8,
                     memory_gb=64, count=1)]


def _bf_submit(orch, holder_ticks=25):
    """holder occupies the big node; head needs the whole big node;
    little fits the small node the head can never use."""
    from test_campaign_exec import FakeProc

    def spawn(job, attempt, argv, env, out, err):
        ticks = {"holder": holder_ticks}.get(job.name, 2)
        return FakeProc(job, attempt, out, rc=0, ticks=ticks)

    orch.submit(JobSpec(name="holder", env={"RUN_KIND": "train"},
                        resources=Resources(gpus=3, cpus=2,
                                            memory_gb=8.0)))
    orch.submit(JobSpec(name="head", env={"RUN_KIND": "train"},
                        resources=Resources(gpus=4, cpus=4,
                                            memory_gb=16.0)))
    orch.submit(JobSpec(name="little", env={"RUN_KIND": "train"},
                        resources=Resources(gpus=1, cpus=1,
                                            memory_gb=2.0)))
    return spawn


def test_head_of_line_is_strict_without_backfill(tmp_path):
    """With backfill off, a blocked queue head blocks everything behind
    it — FIFO within a priority class is absolute."""
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    spawn = _bf_submit(orch)
    orch.run_cluster(workers=3, poll_s=0.001, inventory=_bf_inventory(),
                     retry_backoff_base_s=0.0, telemetry=False,
                     spawn=spawn)
    events = [json.loads(ln) for ln
              in pvc.read_bytes(EVENTS_REL).decode().splitlines()]
    admitted = [e["job"] for e in events if e["event"] == "admitted"]
    assert admitted == ["holder", "head", "little"]


def test_backfill_jumps_head_only_into_unusable_capacity(tmp_path):
    """With backfill on, ``little`` runs on the small node the blocked
    head could never occupy (node-disjoint rule) — and the head starts
    the moment the holder releases the big node, provably undelayed."""
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    spawn = _bf_submit(orch)
    orch.run_cluster(workers=3, poll_s=0.001, inventory=_bf_inventory(),
                     retry_backoff_base_s=0.0, telemetry=False,
                     backfill=True, spawn=spawn)
    events = [json.loads(ln) for ln
              in pvc.read_bytes(EVENTS_REL).decode().splitlines()]
    admits = {e["job"]: e for e in events if e["event"] == "admitted"}
    order = [e["job"] for e in events if e["event"] == "admitted"]
    assert order == ["holder", "little", "head"]
    bf = admits["little"]
    assert bf["backfill"] is True and bf["blocked_head"] == "head"
    assert bf["node"].startswith("small")
    assert admits["head"]["node"].startswith("big")
    # zero head delay: the head is admitted in the poll cycle right
    # after the holder exits, not after the backfiller finishes
    holder_exit = next(e for e in events if e["event"] == "exited"
                       and e["job"] == "holder")
    assert admits["head"]["t"] - holder_exit["t"] < 0.25
    state = replay_events(events)
    assert state["consistent"], state["violations"]
    assert state["jobs"]["little"]["backfills"] == 1


# --------------------------------------------------------------------------
# gang-scheduling invariants (PR 8): atomic placement, process-unit
# worker cap, deadlock freedom, preserved ordering
# --------------------------------------------------------------------------
def _gang_spec(name, gang, *, cpus=1, priority=0):
    return JobSpec(name=name, gang=gang, priority=priority, retries=2,
                   resources=Resources(gpus=0, cpus=cpus, memory_gb=1.0),
                   env={"RUN_KIND": "train"})


@given(job_seeds=seeds, workers=st.integers(2, 4))
@settings(max_examples=15, deadline=None)
def test_gang_placement_is_atomic_and_capped(tmp_path_factory, job_seeds,
                                             workers):
    """Mixed gangs and singletons under arbitrary interleavings: every
    started gang attempt has exactly ``gang`` ranks and ``gang``
    placements (no partial placement, ever), concurrent processes never
    exceed ``workers``, jobs are conserved, and the log replays clean.
    The pool's own internal capacity assertions run throughout."""
    tmp = tmp_path_factory.mktemp("gang")
    pvc = PersistentVolume(tmp)
    orch = Orchestrator(pvc)
    gangs = {}
    for i, s in enumerate(job_seeds):
        name = f"job{i}"
        gangs[name] = 1 + s % min(3, workers)   # gang sizes 1..min(3,w)
        orch.submit(_gang_spec(name, gangs[name], priority=s % 3))
    tracker = {"active": 0, "max": 0}
    recs = orch.run_cluster(workers=workers, poll_s=0.0,
                            telemetry=False, retry_backoff_base_s=0.0,
                            spawn=fake_spawn(tracker=tracker))
    assert tracker["max"] <= workers
    assert all(r.state == JobState.SUCCEEDED for r in recs.values())
    events = [json.loads(ln) for ln
              in pvc.read_bytes(EVENTS_REL).decode().splitlines()]
    for e in events:
        if e["event"] == "admitted" and e.get("gang"):
            assert len(e["placements"]) == e["gang"] == gangs[e["job"]]
        if e["event"] == "started" and e.get("ranks"):
            assert [r["rank"] for r in e["ranks"]] \
                == list(range(gangs[e["job"]]))
    state = replay_events(events)
    assert state["ended"] and state["consistent"], state["violations"]
    assert state["counts"] == {"Succeeded": len(job_seeds)}


def test_two_gangs_fit_alone_not_together_do_not_deadlock(tmp_path):
    """Two 2-rank gangs, each filling the whole 2-node inventory: they
    cannot run together, and because gang admission is atomic (no
    hold-and-wait on partial placements) one runs while the other
    queues whole — both complete, never overlapping."""
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    orch.submit(_gang_spec("gangA", 2, cpus=2))
    orch.submit(_gang_spec("gangB", 2, cpus=2))
    tracker = {"active": 0, "max": 0}
    inventory = [NodeSpec("node", gpus=0, gpu_memory_gb=0, cpus=2,
                          memory_gb=8.0, count=2)]
    recs = orch.run_cluster(workers=4, poll_s=0.0, telemetry=False,
                            retry_backoff_base_s=0.0,
                            inventory=inventory,
                            spawn=fake_spawn(tracker=tracker))
    assert all(r.state == JobState.SUCCEEDED for r in recs.values())
    assert tracker["max"] <= 2           # the gangs never coexisted


# --------------------------------------------------------------------------
# elastic-inventory invariants (PR 9): arbitrary grow/drain/remove/
# admit/release interleavings never oversubscribe and never lose capacity
# accounting
# --------------------------------------------------------------------------
@given(op_seeds=st.lists(st.integers(0, 2**31 - 1), min_size=4,
                         max_size=40),
       inv_seed=st.integers(0, 3))
@settings(max_examples=25, deadline=None)
def test_pool_resize_never_oversubscribes(op_seeds, inv_seed):
    """Interleave admissions/releases with node adds, drains and
    removals: at every step each node stays within capacity, nothing is
    ever admitted to a draining node, and a node is only removable once
    drained AND empty (live allocations are never stranded)."""
    pool = ResourcePool(_inventory(inv_seed))
    caps = {n.name: n.spec for n in pool.nodes}
    admitted = []           # (node, res) live allocations
    fresh = 0

    def check():
        draining = {n.name for n in pool.nodes if n.draining}
        for name, (g, c, m) in pool.in_use().items():
            spec = caps[name]
            assert 0 <= g <= spec.gpus
            assert 0 <= c <= spec.cpus
            assert 0 - 1e-9 <= m <= spec.memory_gb + 1e-9
        # every live allocation still has its node in the pool
        names = {n.name for n in pool.nodes}
        assert {node for node, _ in admitted} <= names
        return draining

    for s in op_seeds:
        op = s % 5
        if op == 0:                                   # grow
            spec = NodeSpec(f"elastic{fresh}", gpus=1 + s % 4,
                            gpu_memory_gb=16, cpus=2 + s % 6,
                            memory_gb=float(8 + s % 48))
            name = pool.add_node(spec)
            caps[name] = pool.node(name).spec
            fresh += 1
        elif op == 1 and pool.nodes:                  # drain one
            pool.drain(pool.nodes[s % len(pool.nodes)].name)
        elif op == 2:                                 # reap drained+empty
            for name in pool.drained_free():
                assert not any(n == name for n, _ in admitted)
                pool.remove_node(name)
        elif op == 3 and admitted:                    # release one
            node, res = admitted.pop(s % len(admitted))
            pool.release(node, res)
        else:                                         # admit one
            res = _resources(s)
            node = pool.admit(res)
            if node is not None:
                assert not pool.node(node).draining
                admitted.append((node, res))
        check()
    # drain everything, release everything: the pool must fully empty
    for n in list(pool.nodes):
        if not n.draining:
            pool.drain(n.name)
    for node, res in admitted:
        pool.release(node, res)
    admitted.clear()
    assert sorted(pool.drained_free()) == sorted(n.name
                                                 for n in pool.nodes)
    for name in pool.drained_free():
        pool.remove_node(name)
    assert not pool.nodes


@given(job_seeds=seeds, workers=st.integers(1, 4),
       resize_seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_executor_conservation_across_midcampaign_resize(
        tmp_path_factory, job_seeds, workers, resize_seed):
    """A campaign whose nodes.json is rewritten mid-flight (grow then
    shrink back, at arbitrary spawn points) still conserves jobs, never
    exceeds the worker cap, and its event log replays with zero
    allocation violations."""
    tmp = tmp_path_factory.mktemp("resize")
    pvc = PersistentVolume(tmp)
    nodes_file = pvc.path("campaign/nodes.json")
    nodes_file.parent.mkdir(parents=True, exist_ok=True)
    base = [{"name": "small", "gpus": 2, "gpu_memory_gb": 11,
             "cpus": 4, "memory_gb": 24},
            {"name": "big", "gpus": 4, "gpu_memory_gb": 48,
             "cpus": 8, "memory_gb": 64}]
    extra = {"name": "burst", "gpus": 4, "gpu_memory_gb": 48,
             "cpus": 8, "memory_gb": 64}
    nodes_file.write_text(json.dumps({"nodes": base}))
    orch = Orchestrator(pvc)
    for i, s in enumerate(job_seeds):
        orch.submit(JobSpec(name=f"job{i}", resources=_resources(s),
                            priority=s % 5, retries=3,
                            env={"RUN_KIND": "train"}))
    spawned = {"n": 0}
    grow_at = 1 + resize_seed % max(1, len(job_seeds))
    shrink_at = grow_at + 1 + (resize_seed // 7) % 3

    def resizing_spawn(job, attempt, argv, env, out, err):
        from test_campaign_exec import FakeProc
        spawned["n"] += 1
        if spawned["n"] == grow_at:
            nodes_file.write_text(json.dumps({"nodes": base + [extra]}))
        elif spawned["n"] == shrink_at:
            nodes_file.write_text(json.dumps({"nodes": base}))
        return FakeProc(job, attempt, out, tracker=tracker)

    tracker = {"active": 0, "max": 0}
    recs = orch.run_cluster(workers=workers, poll_s=0.0,
                            retry_backoff_base_s=0.0, telemetry=False,
                            spawn=resizing_spawn)
    assert tracker["max"] <= workers
    states = [r.state for r in recs.values()]
    assert all(s in (JobState.SUCCEEDED, JobState.FAILED) for s in states)
    assert len(states) == len(job_seeds)              # conservation
    events = [json.loads(ln) for ln
              in pvc.read_bytes(EVENTS_REL).decode().splitlines()]
    state = replay_events(events)
    assert state["ended"] and state["consistent"], state["violations"]
    # once the shrink drains the burst node, nothing lands on it again
    # (the campaign may finish before the rewrite is even observed, or
    # end while the node is still draining — both are fine; admitting
    # to a draining node is not, and replay would also flag it)
    drained_at = next((i for i, e in enumerate(events)
                       if e["event"] == "node_draining"
                       and e["node"].startswith("burst")), None)
    if drained_at is not None:
        assert not any(
            e["event"] == "admitted"
            and str(e.get("node", "")).startswith("burst")
            for e in events[drained_at:])


@given(prios=st.lists(st.integers(0, 5), min_size=2, max_size=6))
@settings(max_examples=15, deadline=None)
def test_gang_admission_preserves_priority_fifo(tmp_path_factory, prios):
    """All-gang queue on a pool that fits one gang at a time: admission
    order is exactly (-priority, submit order) — gangs don't jump the
    line and are never jumped (they neither backfill nor get backfilled
    past, by construction)."""
    tmp = tmp_path_factory.mktemp("gprio")
    pvc = PersistentVolume(tmp)
    orch = Orchestrator(pvc)
    for i, p in enumerate(prios):
        orch.submit(_gang_spec(f"g{i}", 2, priority=p))
    orch.run_cluster(workers=2, poll_s=0.0, telemetry=False,
                     retry_backoff_base_s=0.0, spawn=fake_spawn())
    events = [json.loads(ln) for ln
              in pvc.read_bytes(EVENTS_REL).decode().splitlines()]
    admitted = [e["job"] for e in events if e["event"] == "admitted"]
    expected = [f"g{i}" for i in
                sorted(range(len(prios)), key=lambda i: (-prios[i], i))]
    assert admitted == expected
