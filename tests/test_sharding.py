"""Sharding rules: every param/state spec must divide its array dims on
the production meshes, for every architecture; batch fallback handles
batch=1; the analytic roofline is internally consistent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.analytic import (analytic_roofline,
                                     collective_bytes_per_chip,
                                     flops_forward, mesh_dims)
from repro.analysis.hlo import collective_bytes, parse_shape_bytes
from repro.configs import get_config, get_reduced, list_archs
from repro.models import param_specs
from repro.models.model import init_decode_state
from repro.sharding import rules


def _fake_mesh(shape, axes):
    # an abstract mesh stand-in good enough for spec computation: rules only
    # use mesh.shape / axis_names / as constructor arg for NamedSharding.
    devs = np.array(jax.devices() * (int(np.prod(shape)) // len(jax.devices()) + 1))
    return jax.sharding.Mesh(devs[:int(np.prod(shape))].reshape(shape), axes)


MESH_1POD = _fake_mesh((16, 16), ("data", "model"))
MESH_2POD = _fake_mesh((2, 16, 16), ("pod", "data", "model"))


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mesh", [MESH_1POD, MESH_2POD],
                         ids=["16x16", "2x16x16"])
def test_param_shardings_divide(arch, mesh):
    cfg = get_config(arch)
    specs = param_specs(cfg)
    shardings = rules.param_shardings(specs, mesh, "fsdp_tp")

    def check(path, spec, sh):
        pspec = sh.spec
        sizes = dict(mesh.shape)
        for dim, names in zip(spec.shape, tuple(pspec) + (None,) * 10):
            if names is None:
                continue
            names = (names,) if isinstance(names, str) else names
            k = 1
            for n in names:
                k *= sizes[n]
            assert dim % k == 0, (arch, path, spec.shape, pspec)

    jax.tree_util.tree_map_with_path(check, specs, shardings)


@pytest.mark.parametrize("arch", ["glm4-9b", "jamba-1.5-large-398b",
                                  "mamba2-2.7b"])
def test_decode_state_shardings_divide(arch):
    cfg = get_config(arch)
    state = jax.eval_shape(lambda: init_decode_state(cfg, 128, 32768))
    sh = rules.decode_state_shardings(state, MESH_1POD, "fsdp_tp")
    sizes = dict(MESH_1POD.shape)

    def check(path, spec, s):
        for dim, names in zip(spec.shape, tuple(s.spec) + (None,) * 10):
            if names is None:
                continue
            names = (names,) if isinstance(names, str) else names
            k = 1
            for n in names:
                k *= sizes[n]
            assert dim % k == 0, (arch, path, spec.shape, s.spec)

    jax.tree_util.tree_map_with_path(check, state, sh)


def test_batch_sharding_fallback_batch1():
    sh = rules.batch_sharding(MESH_1POD, ndim=2, batch_dim=0, batch_size=1)
    assert sh.spec == jax.sharding.PartitionSpec(None, None)
    sh256 = rules.batch_sharding(MESH_1POD, ndim=2, batch_dim=0,
                                 batch_size=256)
    assert sh256.spec[0] == "data"


def test_dp_layout_replicates_everything():
    cfg = get_reduced("granite-3-2b")
    specs = param_specs(cfg)
    sh = rules.param_shardings(specs, MESH_1POD, "dp")
    for s in jax.tree.leaves(sh):
        assert all(a is None for a in s.spec) or len(s.spec) == 0


# ------------------------------------------------------------- analysis
def test_parse_shape_bytes():
    assert parse_shape_bytes("bf16[16,128]{1,0}") == 16 * 128 * 2
    assert parse_shape_bytes("f32[]") == 4
    assert parse_shape_bytes("(f32[8], s32[2])") == 32 + 8


def test_collective_bytes_parses_hlo():
    hlo = """
  %ag = bf16[32,1024]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce-start(%y)
  %ar.2 = f32[256]{0} all-reduce-done(%ar.1)
  %a2a = (f32[16,64]{1,0}) all-to-all(%z)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 32 * 1024 * 2
    assert out["all-to-all"] == 16 * 64 * 4
    assert out["_counts"]["all-gather"] == 1


def test_analytic_flops_sane_for_dense():
    """Forward flops ~ 2*N*D within 20% for a dense LM at short seq."""
    cfg = get_config("granite-3-2b")
    fwd = flops_forward(cfg, batch=8, seq=512, kind="train")
    approx = 2.0 * cfg.param_count() * 8 * 512
    assert 0.8 * approx <= fwd <= 1.3 * approx


def test_analytic_roofline_terms_positive():
    cfg = get_config("qwen3-moe-30b-a3b")
    r = analytic_roofline(cfg, 256, 4096, "train", MESH_1POD, "fsdp_tp")
    assert r["compute_s"] > 0 and r["memory_s"] > 0
    assert r["collective_s"] > 0
    assert 0 < r["useful_flops_ratio"] <= 1.5
    assert r["dominant"] in ("compute_s", "memory_s", "collective_s")


def test_dp_vs_fsdp_collectives_differ():
    cfg = get_config("glm4-9b")
    md = mesh_dims(MESH_1POD)
    dp = collective_bytes_per_chip(cfg, 256, 4096, "train", md, "dp")
    fs = collective_bytes_per_chip(cfg, 256, 4096, "train", md, "fsdp_tp")
    # paper-faithful DP all-reduces full grads; FSDP+TP trades that for
    # param gathers + activation all-reduces
    assert dp["grad_reducescatter"] == pytest.approx(
        2 * cfg.param_count() * 2)
    assert fs["fsdp_allgather"] > 0 and fs["tp_allreduce"] > 0
