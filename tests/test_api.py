"""The unified run layer: RunSpec round-trips (JSON / env / CLI / grid),
the runner registry, RunReport uniformity, and orchestrator integration
(submit_runs + registry payloads)."""
import json

import pytest

from repro.api import (FAILED, SUCCEEDED, RunReport, RunSpec, get_runner,
                       register_runner, run, runner_kinds)
from repro.core import (ExperimentGrid, JobState, Orchestrator,
                        PersistentVolume, Resources, S3Store)


# ---------------------------------------------------------------- RunSpec
def test_runspec_json_roundtrip():
    spec = RunSpec(kind="train", arch="glm4-9b", name="exp-1",
                   overrides={"steps": 20, "lr": 1e-4, "init": "imagenet"},
                   resources=Resources(gpus=2, cpus=8, memory_gb=48),
                   seed=7, duration_h=3.5, labels={"experiment": "t"})
    assert RunSpec.from_json(spec.to_json()) == spec


def test_runspec_env_roundtrip_full():
    spec = RunSpec(kind="serve", arch="granite-3-2b",
                   overrides={"requests": 4, "max_tokens": 2},
                   resources=Resources(gpus=4), seed=3, duration_h=2.0,
                   labels={"a": "b"})
    env = spec.to_env(full=True)
    assert all(isinstance(v, str) for v in env.values())
    assert RunSpec.from_env(env) == spec


def test_runspec_env_is_papers_bash_interface():
    """Overrides surface as uppercase env vars with typed values
    recoverable — the paper's bash automation contract."""
    spec = RunSpec(kind="train", arch="stablelm-1.6b",
                   overrides={"lr": 1e-5, "batch_size": 16,
                              "dataset": "norm_rgb"})
    env = spec.to_env()
    assert env["ARCH"] == "stablelm-1.6b"
    assert env["RUN_KIND"] == "train"
    assert env["LR"] == "1e-05" and env["BATCH_SIZE"] == "16"
    back = RunSpec.from_env(env)
    assert back.overrides == spec.overrides
    assert (back.kind, back.arch, back.seed) == ("train", spec.arch, 0)


def test_runspec_env_roundtrip_preserves_ambiguous_strings():
    """String overrides that look like JSON scalars ('8', 'true') must
    come back as strings, not get retyped."""
    spec = RunSpec(kind="train", overrides={"tag": "8", "note": "true",
                                            "dataset": "tci"})
    assert RunSpec.from_env(spec.to_env()).overrides == spec.overrides


def test_from_env_does_not_sweep_process_environment(monkeypatch):
    """Bare os.environ reconstruction must not absorb PATH/XLA_FLAGS/...
    as overrides (only keys declared in RUN_OVERRIDE_KEYS count)."""
    monkeypatch.setenv("RUN_KIND", "train")
    monkeypatch.setenv("XLA_FLAGS", "--some-flag")
    monkeypatch.setenv("STRAY_UPPER", "17")
    spec = RunSpec.from_env()
    assert spec.kind == "train" and spec.overrides == {}
    # a declared key is honored even from os.environ
    monkeypatch.setenv("RUN_OVERRIDE_KEYS", "steps")
    monkeypatch.setenv("STEPS", "5")
    assert RunSpec.from_env().overrides == {"steps": 5}
    # declaring a key without providing it is an error, not a silent drop
    monkeypatch.setenv("RUN_OVERRIDE_KEYS", "steps,missing_knob")
    with pytest.raises(ValueError, match="missing_knob"):
        RunSpec.from_env()


def test_runspec_from_args():
    spec = RunSpec.from_args(
        ["dryrun", "--arch", "glm4-9b", "--seed", "3",
         "--shape", "train_4k", "--mesh=both", "--multi-pod"])
    assert spec.kind == "dryrun" and spec.arch == "glm4-9b"
    assert spec.seed == 3
    assert spec.overrides == {"shape": "train_4k", "mesh": "both",
                              "multi_pod": True}


def test_runspec_rejects_bad_kind_and_reserved_overrides():
    with pytest.raises(ValueError):
        RunSpec(kind="")
    with pytest.raises(ValueError):
        RunSpec(kind="train", overrides={"arch": "x"})  # reserved env name


def test_runspec_experiment_roundtrip():
    grid = ExperimentGrid("ba", {"lr": [1e-4], "bs": [8]})
    espec = grid.expand()[0]
    spec = RunSpec.from_experiment(espec, kind="train", arch="unet")
    assert spec.run_name == espec.name
    assert spec.overrides == espec.params
    back = spec.to_experiment()
    assert back.name == espec.name and back.params == espec.params


def test_grid_to_runs():
    grid = ExperimentGrid("g", {"lr": [0.1, 0.2], "seed": [0, 1]})
    runs = grid.to_runs(kind="train", arch="unet",
                        resources=Resources(gpus=2), duration_h=2.5,
                        labels={"experiment": "g"})
    assert len(runs) == 4
    assert {r.run_name for r in runs} == {s.name for s in grid.expand()}
    assert all(r.resources.gpus == 2 and r.duration_h == 2.5 for r in runs)


def test_merged_overrides_rejects_unknown_keys():
    spec = RunSpec(kind="train", overrides={"stepz": 5})
    with pytest.raises(ValueError, match="stepz"):
        spec.merged_overrides({"steps": 100})


# --------------------------------------------------------------- registry
def test_register_and_run_custom_kind():
    @register_runner("echo-test")
    def _echo(spec):
        return RunReport(kind=spec.kind, name=spec.run_name,
                         metrics=dict(spec.overrides))

    assert "echo-test" in runner_kinds()
    report = run(RunSpec(kind="echo-test", overrides={"x": 1}))
    assert report.status == SUCCEEDED
    assert report.metrics == {"x": 1}
    assert report.spec["kind"] == "echo-test"   # provenance filled in
    assert report.wall_s >= 0


def test_run_converts_exception_to_failed_report():
    @register_runner("boom-test")
    def _boom(spec):
        raise RuntimeError("kaput")

    report = run(RunSpec(kind="boom-test"))
    assert report.status == FAILED and not report.ok
    assert "kaput" in report.error
    assert "RuntimeError" in report.metrics["traceback"]


def test_register_runner_declares_env_prerequisites(monkeypatch):
    import os

    @register_runner("env-test", env={"ENV_TEST_FLAG": "42"})
    def _env(spec):
        return RunReport(kind=spec.kind, name=spec.run_name,
                         metrics={"flag": os.environ["ENV_TEST_FLAG"]})

    monkeypatch.delenv("ENV_TEST_FLAG", raising=False)
    report = run(RunSpec(kind="env-test"))
    assert report.metrics["flag"] == "42"
    # setdefault semantics: an operator-set value wins
    monkeypatch.setenv("ENV_TEST_FLAG", "7")
    assert run(RunSpec(kind="env-test")).metrics["flag"] == "7"


def test_unknown_kind_raises():
    with pytest.raises(KeyError, match="no-such-kind"):
        get_runner("no-such-kind")


def test_builtin_kinds_registered():
    assert {"train", "serve", "dryrun", "perfprobe",
            "simulate"} <= set(runner_kinds())


# -------------------------------------------------------------- RunReport
def test_runreport_roundtrip_and_status_validation():
    rep = RunReport(kind="train", name="r", metrics={"loss": 0.5},
                    wall_s=1.5, artifacts=("ckpt/",))
    assert RunReport.from_json(rep.to_json()) == rep
    assert rep.ok
    with pytest.raises(ValueError):
        RunReport(kind="train", name="r", status="exploded")


# -------------------------------------------- end-to-end through the API
def test_train_kind_through_api():
    report = run(RunSpec(kind="train", arch="stablelm-1.6b",
                         overrides={"steps": 3, "batch": 2, "seq": 16,
                                    "log_every": 0}))
    assert report.status == SUCCEEDED, report.error
    assert report.metrics["steps"] == 3
    assert "final_loss" in report.metrics
    assert report.wall_s > 0


def test_train_kind_accepts_campaign_grid_vocabulary():
    """Burned-area grid overrides (batch_size/init/dataset/optimizer)
    must pass the typo guard: aliases map onto trainer knobs, metadata
    is carried in the report."""
    report = run(RunSpec(kind="train", arch="stablelm-1.6b",
                         overrides={"batch_size": 2, "init": "random",
                                    "dataset": "tci", "steps": 2,
                                    "seq": 16, "log_every": 0}))
    assert report.status == SUCCEEDED, report.error
    assert report.metrics["grid_params"] == {"init": "random",
                                             "dataset": "tci"}


def test_serve_kind_through_api():
    report = run(RunSpec(kind="serve", arch="granite-3-2b",
                         overrides={"requests": 2, "slots": 2,
                                    "cache_len": 32, "max_tokens": 2}))
    assert report.status == SUCCEEDED, report.error
    assert report.metrics["requests"] == 2
    assert report.metrics["tokens"] == 4


def test_simulate_kind_through_api(tmp_path):
    report = run(RunSpec(kind="simulate",
                         overrides={"campaign": "burned_area",
                                    "workdir": str(tmp_path)}))
    assert report.status == SUCCEEDED, report.error
    m = report.metrics
    assert m["jobs"] == 144 and m["manifests"] == 144
    assert m["total_wall_hours"] == pytest.approx(518.0)
    assert m["total_gpu_hours"] == pytest.approx(1036.0)
    assert m["cluster_makespan_h"] == pytest.approx(3.6, abs=0.05)


# --------------------------------------------- orchestrator integration
def test_submit_runs_executes_through_registry(tmp_path):
    @register_runner("toy-fit")
    def _toy(spec):
        lr = float(spec.overrides["lr"])
        return RunReport(kind=spec.kind, name=spec.run_name,
                         metrics={"final_loss": 1.0 / (1.0 + lr)})

    grid = ExperimentGrid("toy", {"lr": [0.1, 1.0, 10.0]})
    runs = grid.to_runs(kind="toy-fit", duration_h=2.0)
    pvc = PersistentVolume(tmp_path)
    s3 = S3Store(tmp_path)
    orch = Orchestrator(pvc, s3)
    orch.submit_runs(runs, attach_payload=True)
    assert len(pvc.listdir("manifests")) == 3
    orch.run_local()
    assert orch.summary()["states"] == {"Succeeded": 3}
    # RunReports serialized uniformly to both stores
    for key in s3.list("results/"):
        rec = json.loads(s3.get_bytes(key))
        assert rec["result"]["kind"] == "toy-fit"
        assert rec["result"]["status"] == "succeeded"
        assert "final_loss" in rec["result"]["metrics"]
    # cluster-sim accounting still works off the same records
    assert orch.simulate().makespan_h == pytest.approx(2.0)


def test_run_local_monotonic_states_and_attempt_history(tmp_path):
    from repro.core import JobSpec
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    calls = {"n": 0}

    def flaky(**kw):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("preempted")
        return "ok"

    orch.submit(JobSpec(name="flaky", payload=flaky, retries=5))
    recs = orch.run_local()
    rec = recs["flaky"]
    assert rec.state == JobState.SUCCEEDED and rec.attempts == 3
    assert len(pvc.listdir("logs")) == 2    # one log per failed attempt
    result = json.loads(pvc.read_bytes("results/flaky.json"))
    hist = result["attempt_history"]
    assert [h["outcome"] for h in hist] == ["failed", "failed", "succeeded"]
    assert result["state"] == "Succeeded"


def test_run_local_failed_job_reaches_final_state(tmp_path):
    from repro.core import JobSpec
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)

    def always_fails(**kw):
        raise ValueError("nope")

    orch.submit(JobSpec(name="doomed", payload=always_fails, retries=1))
    recs = orch.run_local()
    assert recs["doomed"].state == JobState.FAILED
    assert recs["doomed"].attempts == 2
    result = json.loads(pvc.read_bytes("results/doomed.json"))
    assert result["state"] == "Failed" and result["error"]


def test_run_local_parallelism_drives_lane_accounting(tmp_path):
    from repro.core import JobSpec
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    for i in range(6):
        orch.submit(JobSpec(name=f"j{i}", payload=lambda **kw: "ok"))
    with pytest.raises(ValueError):
        orch.run_local(parallelism=0)
    recs = orch.run_local(parallelism=3)
    lanes = {r.node for r in recs.values()}
    assert lanes <= {"lane0", "lane1", "lane2"} and len(lanes) == 3
    summary = json.loads(pvc.read_bytes("results/_local_run_summary.json"))
    assert summary["parallelism"] == 3 and summary["jobs"] == 6
    assert summary["simulated_makespan_s"] <= summary["serial_s"] + 1e-9
    assert len(summary["lane_busy_s"]) == 3


# ------------------------------------------------------- grid expand cache
def test_grid_expand_is_cached_but_mutation_safe():
    grid = ExperimentGrid("c", {"a": [1, 2, 3], "b": [4, 5]})
    first = grid.expand()
    second = grid.expand()
    assert second is not first                    # fresh list each call
    assert all(a is b for a, b in zip(first, second))  # cached elements
    first.pop()                                   # caller mutation...
    assert len(grid) == 6                         # ...doesn't corrupt grid
