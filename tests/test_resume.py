"""Preemption-resilient training subsystem: atomic CheckpointManager
(rotation, torn-checkpoint fallback), seekable data streams, TrainLoop
kill/resume bitwise determinism, orchestrator retry-resume semantics,
and checkpoint-aware ClusterSim preemption accounting."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointError, CheckpointManager,
                              list_checkpoints, load_checkpoint,
                              save_checkpoint)
from repro.core import ClusterSim, JobSpec, JobState, Orchestrator, \
    PersistentVolume, Resources
from repro.data.tokens import SeekableTokenBatches, lm_batch_iterator
from repro.data.inputs import SeekableSyntheticBatches
from repro.train import TrainLoop, TrainState
from repro.train.loop import Preemption


# A toy quadratic "trainer" so manager/loop mechanics are tested without
# model compile time: params -> scalar loss, SGD update.
def _toy_state(value=1.0):
    params = {"w": jnp.full((4,), value, jnp.float32)}
    return TrainState(params, (), jnp.zeros((), jnp.int32))


def _toy_step(state, batch):
    w = state.params["w"]
    new_w = w - 0.1 * (w - batch["target"])
    loss = jnp.mean((w - batch["target"]) ** 2)
    metrics = {"loss": loss, "lr": jnp.float32(0.1),
               "grad_norm": jnp.linalg.norm(w - batch["target"])}
    return TrainState({"w": new_w}, (), state.step + 1), metrics


class _ToyData:
    """Seekable deterministic stream: batch i is a pure function of i."""

    def __init__(self):
        self.step = 0

    def next_batch(self):
        b = {"target": jnp.full((4,), float(self.step % 3), jnp.float32)}
        self.step += 1
        return b

    def cursor(self):
        return {"step": self.step}

    def seek(self, cursor):
        self.step = int(cursor["step"])


# ------------------------------------------------------ CheckpointManager
def test_manager_atomic_layout_and_rotation(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck", keep_last=2, every_steps=1,
                            async_saves=False)
    state = _toy_state()
    for step in (1, 2, 3, 4):
        mgr.save(state, step, extra={"data_cursor": {"step": step}})
    steps = [s for s, _ in list_checkpoints(tmp_path / "ck")]
    assert steps == [3, 4]                       # keep-last-2 rotation
    # no tmp debris after publication
    assert not [p for p in (tmp_path / "ck").iterdir()
                if p.name.startswith(".tmp")]
    restored = mgr.restore_latest(like=state)
    assert restored is not None
    tree, step, extra = restored
    assert step == 4 and extra["data_cursor"] == {"step": 4}


def test_manager_async_saves_and_stats(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck", keep_last=3, every_steps=2,
                            async_saves=True)
    state = _toy_state()
    assert not mgr.maybe_save(state, 1)          # off-cadence
    assert mgr.maybe_save(state, 2)
    assert mgr.maybe_save(state, 4)
    mgr.wait()
    assert [s for s, _ in list_checkpoints(tmp_path / "ck")] == [2, 4]
    st = mgr.stats()
    assert st["saves"] == 2 and st["async"]
    mgr.close()


def test_manager_falls_back_past_torn_checkpoint(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck", keep_last=3, async_saves=False)
    state = _toy_state(1.0)
    mgr.save(state, 5)
    mgr.save(_toy_state(9.0), 10)
    # tear the newest: truncate its manifest mid-write
    newest = tmp_path / "ck" / "step_00000010" / "manifest.json"
    newest.write_text(newest.read_text()[: len(newest.read_text()) // 2])
    tree, step, _ = mgr.restore_latest(like=state)
    assert step == 5                              # fell back
    np.testing.assert_array_equal(np.asarray(tree.params["w"]),
                                  np.full((4,), 1.0, np.float32))
    assert mgr.restore_skipped and "step_00000010" in mgr.restore_skipped[0]


def test_manager_restore_latest_empty_dir(tmp_path):
    mgr = CheckpointManager(tmp_path / "nothing-here")
    assert mgr.restore_latest(like=_toy_state()) is None
    assert mgr.latest_step() is None


# ------------------------------------------------------------ io hardening
def test_load_checkpoint_casts_dtype_only_mismatch(tmp_path):
    params = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
    d = save_checkpoint(tmp_path / "ck", params, step=1)
    like = {"w": jnp.zeros((2, 3), jnp.float16)}
    tree, step = load_checkpoint(d, like=like)
    assert tree["w"].dtype == jnp.float16        # cast, not crash
    np.testing.assert_allclose(np.asarray(tree["w"], np.float32),
                               np.arange(6, dtype=np.float32).reshape(2, 3))


def test_load_checkpoint_missing_and_truncated_manifest(tmp_path):
    with pytest.raises(CheckpointError, match="no manifest.json"):
        load_checkpoint(tmp_path)                # empty dir
    params = {"w": jnp.ones((2,))}
    d = save_checkpoint(tmp_path / "ck", params, step=1)
    mpath = tmp_path / "ck" / "manifest.json"
    mpath.write_text('{"step": 1, "keys": {"w"')  # truncated json
    with pytest.raises(CheckpointError, match="truncated or corrupt"):
        load_checkpoint(d)


def test_load_checkpoint_torn_final_shard(tmp_path):
    params = {"w": jnp.ones((8,)), "b": jnp.zeros((3,))}
    d = save_checkpoint(tmp_path / "ck", params, step=2)
    shard = sorted((tmp_path / "ck").glob("shard_*.npz"))[-1]
    data = shard.read_bytes()
    shard.write_bytes(data[: len(data) // 2])    # torn mid-write
    with pytest.raises(CheckpointError, match="missing or torn"):
        load_checkpoint(d, like=params)
    shard.unlink()                               # shard gone entirely
    with pytest.raises(CheckpointError, match="missing or torn"):
        load_checkpoint(d, like=params)


# --------------------------------------------------------- seekable data
def test_seekable_token_batches_cursor_is_exact():
    a = SeekableTokenBatches(128, 4, 16, seed=3)
    for _ in range(5):
        a.next_batch()
    cur = json.loads(json.dumps(a.cursor()))     # survives JSON roundtrip
    want = [a.next_batch() for _ in range(3)]
    b = SeekableTokenBatches(128, 4, 16, seed=3)
    b.seek(cur)
    got = [b.next_batch() for _ in range(3)]
    for (t1, l1), (t2, l2) in zip(want, got):
        np.testing.assert_array_equal(t1, t2)
        np.testing.assert_array_equal(l1, l2)


def test_lm_batch_iterator_start_step_matches_skipping():
    it = lm_batch_iterator(64, 2, 8, seed=1)
    skipped = [next(it) for _ in range(4)][-1]
    fresh = next(lm_batch_iterator(64, 2, 8, seed=1, start_step=3))
    np.testing.assert_array_equal(skipped[0], fresh[0])
    np.testing.assert_array_equal(skipped[1], fresh[1])


def test_seekable_synthetic_batches_cursor():
    from repro.configs import get_reduced
    cfg = get_reduced("hubert-xlarge")           # audio family: make_batch
    a = SeekableSyntheticBatches(cfg, 2, 8, seed=0)
    for _ in range(3):
        a.next_batch()
    b = SeekableSyntheticBatches(cfg, 2, 8, seed=0)
    b.seek(a.cursor())
    x, y = a.next_batch(), b.next_batch()
    for k in x:
        np.testing.assert_array_equal(np.asarray(x[k]), np.asarray(y[k]))


# ------------------------------------------------- TrainLoop kill/resume
def test_trainloop_preempt_then_resume_bitwise_identical(tmp_path):
    def run(ckpt=None, preempt=None, resume=False):
        loop = TrainLoop(_toy_step, _toy_state(), _ToyData(),
                         checkpointer=ckpt, preempt_at_step=preempt,
                         log_every=0)
        if resume:
            assert loop.resume()
        return loop, loop.run(30)

    _, base = run()
    mgr = CheckpointManager(tmp_path / "ck", every_steps=4, async_saves=True)
    with pytest.raises(Preemption):
        run(ckpt=mgr, preempt=15)
    loop2, res = run(ckpt=CheckpointManager(tmp_path / "ck", every_steps=4),
                     resume=True)
    assert res["resumed_from_step"] == 12        # 15 rounded down to cadence
    assert res["steps"] == 30
    assert res["final_loss"] == base["final_loss"]   # bitwise on CPU
    np.testing.assert_array_equal(
        np.asarray(loop2.state.params["w"]), np.asarray(_run_ref(30)))


def _run_ref(steps):
    loop = TrainLoop(_toy_step, _toy_state(), _ToyData(), log_every=0)
    loop.run(steps)
    return loop.state.params["w"]


def test_trainloop_resumed_loss_curve_matches_uninterrupted_tail(tmp_path):
    base = TrainLoop(_toy_step, _toy_state(), _ToyData(), log_every=0)
    base.run(20)
    mgr = CheckpointManager(tmp_path / "ck", every_steps=5, async_saves=False)
    broken = TrainLoop(_toy_step, _toy_state(), _ToyData(),
                       checkpointer=mgr, preempt_at_step=13, log_every=0)
    with pytest.raises(Preemption):
        broken.run(20)
    resumed = TrainLoop(_toy_step, _toy_state(), _ToyData(),
                        checkpointer=CheckpointManager(tmp_path / "ck"),
                        log_every=0)
    assert resumed.resume()
    res = resumed.run(20)
    assert res["resumed_from_step"] == 10
    # every post-resume loss equals the uninterrupted curve, bitwise
    assert resumed.losses == base.losses[10:]


def test_trainloop_fault_hook_generalizes():
    seen = []

    class Boom(RuntimeError):
        pass

    def hook(i):
        seen.append(i)
        if i == 4:
            raise Boom()

    loop = TrainLoop(_toy_step, _toy_state(), _ToyData(), fault_hook=hook,
                     log_every=0)
    with pytest.raises(Boom):
        loop.run(10)
    assert seen == [0, 1, 2, 3, 4]


def test_real_training_kill_and_resume_bitwise(tmp_path):
    """Acceptance: a reduced-config run killed mid-flight via the fault
    hook and resumed produces the identical final loss and step count."""
    from repro.launch.train import train_main

    kw = dict(steps=10, batch=2, seq=16, log_every=0, seed=0)
    base = train_main("stablelm-1.6b", **kw)
    ck = str(tmp_path / "ck")
    with pytest.raises(Preemption):
        train_main("stablelm-1.6b", checkpoint_dir=ck, checkpoint_every=3,
                   preempt_at_step=7, **kw)
    res = train_main("stablelm-1.6b", checkpoint_dir=ck, checkpoint_every=3,
                     resume=True, **kw)
    assert res["resumed_from_step"] == 6
    assert res["steps"] == base["steps"] == 10
    assert res["final_loss"] == base["final_loss"]   # bitwise on CPU
    assert res["checkpoint"]["saves"] >= 2
    # the full TrainState (params + opt state + step) roundtrips: the
    # checkpoint contains optimizer moment keys, not just params
    from repro.checkpoint.io import read_manifest
    step_dirs = list_checkpoints(ck)
    manifest = read_manifest(step_dirs[-1][1])
    keys = manifest["keys"]
    assert any(k.startswith("opt_state/") for k in keys), list(keys)[:5]
    assert "step" in keys
    assert any(k.startswith("params/") for k in keys)


def test_bf16_policy_kill_and_resume_bitwise(tmp_path):
    """The bf16 mixed-precision policy keeps master params + optimizer
    state f32, so its checkpoints round-trip through CheckpointManager
    exactly like f32 runs: a bf16-computed run killed mid-flight and
    resumed ends bitwise-identical to the uninterrupted bf16 run."""
    from repro.launch.train import train_main

    kw = dict(steps=10, batch=2, seq=16, log_every=0, seed=0,
              precision="bf16")
    base = train_main("stablelm-1.6b", **kw)
    ck = str(tmp_path / "ck")
    with pytest.raises(Preemption):
        train_main("stablelm-1.6b", checkpoint_dir=ck, checkpoint_every=3,
                   preempt_at_step=7, **kw)
    res = train_main("stablelm-1.6b", checkpoint_dir=ck, checkpoint_every=3,
                     resume=True, **kw)
    assert res["resumed_from_step"] == 6
    assert res["final_loss"] == base["final_loss"]   # bitwise on CPU
    # the checkpointed state is the f32 master copy, not bf16 compute
    from repro.checkpoint.io import read_manifest
    manifest = read_manifest(list_checkpoints(ck)[-1][1])
    param_dtypes = {v["dtype"] for k, v in manifest["keys"].items()
                    if k.startswith(("params/", "opt_state/"))}
    assert param_dtypes == {"float32"}


def test_bf16_checkpoint_restores_into_f32_run(tmp_path):
    """Cross-policy restore: a checkpoint written by a bf16-policy run
    restores into an f32-policy run (dtype-cast-on-restore is a no-op —
    the master state is f32 either way) and training continues."""
    from repro.launch.train import train_main

    ck = str(tmp_path / "ck")
    train_main("stablelm-1.6b", steps=4, batch=2, seq=16, log_every=0,
               seed=0, precision="bf16", checkpoint_dir=ck,
               checkpoint_every=2)
    res = train_main("stablelm-1.6b", steps=8, batch=2, seq=16, log_every=0,
                     seed=0, precision="f32", checkpoint_dir=ck,
                     checkpoint_every=2, resume=True)
    assert res["resumed_from_step"] == 4
    assert res["steps"] == 8
    assert np.isfinite(res["final_loss"])


# ------------------------------------------- orchestrator resume semantics
def test_orchestrator_retry_resumes_from_checkpoint(tmp_path):
    """A payload that raises at step k then succeeds on retry must end at
    the full target step with attempt history recording
    resumed_from_step >= k - checkpoint_every."""
    from repro.api import RunSpec

    k, every, steps = 5, 2, 8
    ck = str(tmp_path / "ck")
    spec = RunSpec(kind="train", arch="stablelm-1.6b", name="resume-job",
                   overrides={"steps": steps, "batch": 2, "seq": 16,
                              "log_every": 0, "checkpoint_dir": ck,
                              "checkpoint_every": every,
                              "preempt_at_step": k})
    orch = Orchestrator(PersistentVolume(tmp_path))
    orch.submit_runs([spec], attach_payload=True)
    rec = orch.run_local()["resume-job"]
    assert rec.state == JobState.SUCCEEDED and rec.attempts == 2
    result = json.loads(orch.pvc.read_bytes("results/resume-job.json"))
    hist = result["attempt_history"]
    assert hist[0]["outcome"] == "failed" and "Preemption" in hist[0]["error"]
    assert hist[1]["outcome"] == "succeeded"
    assert hist[1]["resumed_from_step"] >= k - every
    assert result["result"]["metrics"]["steps"] == steps


def test_to_job_retry_env_only_for_resumable_kinds():
    from repro.api import RunSpec

    train = RunSpec(kind="train", overrides={"steps": 4}).to_job()
    assert train.retry_env.get("RESUME") == "true"
    assert "resume" in train.retry_env["RUN_OVERRIDE_KEYS"].split(",")
    assert "RESUME" not in train.env             # first attempt: fresh
    serve = RunSpec(kind="serve").to_job()
    assert serve.retry_env == {}


# ------------------------------------- checkpoint-aware cluster simulation
def test_clustersim_checkpointing_strictly_improves_makespan():
    jobs = [JobSpec(name=f"j{i}", duration_h=10.0, retries=10,
                    resources=Resources(gpus=1, cpus=1, memory_gb=4))
            for i in range(40)]
    for seed in (0, 1, 2):
        no = ClusterSim(seed=seed, preemption_rate=0.4).run(jobs)
        ck = ClusterSim(seed=seed, preemption_rate=0.4,
                        checkpoint_every_h=1.0).run(jobs)
        assert all(r.state == JobState.SUCCEEDED for r in ck.records)
        assert ck.makespan_h < no.makespan_h     # strictly lower
        assert ck.lost_gpu_hours < no.lost_gpu_hours
        assert ck.goodput > no.goodput
        # lost work bounded by one checkpoint interval per preemption
        assert ck.lost_gpu_hours <= ck.preemptions * 1.0 + 1e-9


def test_clustersim_no_preemption_unchanged_by_checkpointing():
    jobs = [JobSpec(name=f"j{i}", duration_h=2.0,
                    resources=Resources(gpus=1, cpus=1, memory_gb=4))
            for i in range(8)]
    res = ClusterSim(checkpoint_every_h=0.5).run(jobs)
    assert res.preemptions == 0 and res.lost_gpu_hours == 0.0
    assert res.goodput == 1.0
    assert res.makespan_h == pytest.approx(2.0)


def test_orchestrator_simulate_passes_checkpoint_knob(tmp_path):
    orch = Orchestrator(PersistentVolume(tmp_path))
    for i in range(20):
        orch.submit(JobSpec(name=f"j{i}", duration_h=5.0, retries=10,
                            resources=Resources(gpus=1, cpus=1,
                                                memory_gb=4)))
    no = orch.simulate(preemption_rate=0.5)
    ck = orch.simulate(preemption_rate=0.5, checkpoint_every_h=0.5)
    assert ck.makespan_h < no.makespan_h
