"""Serving-engine behaviour: continuous batching, greedy invariance to
slot count, EOS and max-token retirement, queue draining."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params
from repro.serve import Request, ServeEngine

CFG = get_reduced("granite-3-2b")


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _requests(n, seed=0, max_tokens=8):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab,
                                        size=int(rng.integers(4, 12))),
                    max_tokens=max_tokens)
            for i in range(n)]


def test_engine_drains_queue(params):
    eng = ServeEngine(CFG, params, slots=3, cache_len=64)
    for r in _requests(7):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 7
    assert all(r.done and len(r.generated) == 8 for r in done)


def test_greedy_decode_invariant_to_slot_count(params):
    """Continuous batching must not change greedy outputs — the KV slots
    are independent."""
    outs = {}
    for slots in (1, 2, 5):
        eng = ServeEngine(CFG, params, slots=slots, cache_len=64)
        for r in _requests(6, seed=3):
            eng.submit(r)
        done = eng.run()
        outs[slots] = {r.rid: tuple(r.generated) for r in done}
    assert outs[1] == outs[2] == outs[5]


def test_eos_stops_generation(params):
    # find the first greedily generated token, then use it as EOS
    eng = ServeEngine(CFG, params, slots=1, cache_len=64)
    probe = _requests(1, seed=5, max_tokens=4)[0]
    eng.submit(probe)
    eng.run()
    eos = probe.generated[1]

    eng2 = ServeEngine(CFG, params, slots=1, cache_len=64)
    req = _requests(1, seed=5, max_tokens=16)[0]
    req.eos_id = int(eos)
    eng2.submit(req)
    done = eng2.run()
    assert done[0].generated[-1] == eos
    assert len(done[0].generated) <= 16


def test_cache_len_bounds_generation(params):
    eng = ServeEngine(CFG, params, slots=1, cache_len=16)
    req = Request(rid=0, prompt=np.arange(8) % CFG.vocab, max_tokens=100)
    eng.submit(req)
    done = eng.run()
    # positions stop before overrunning the cache
    assert len(done[0].generated) <= 16 - 8 + 1


def test_mixed_families_one_engine():
    for arch in ("mamba2-2.7b", "jamba-1.5-large-398b"):
        cfg = get_reduced(arch)
        p = init_params(jax.random.PRNGKey(1), cfg)
        eng = ServeEngine(cfg, p, slots=2, cache_len=48)
        rng = np.random.default_rng(0)
        for i in range(3):
            eng.submit(Request(rid=i,
                               prompt=rng.integers(0, cfg.vocab, size=6),
                               max_tokens=5))
        done = eng.run()
        assert len(done) == 3, arch
