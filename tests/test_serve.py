"""Serving-engine behaviour: continuous batching, greedy invariance to
slot count, EOS and max-token retirement, queue draining."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params
from repro.serve import Request, ServeEngine

CFG = get_reduced("granite-3-2b")


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _requests(n, seed=0, max_tokens=8):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab,
                                        size=int(rng.integers(4, 12))),
                    max_tokens=max_tokens)
            for i in range(n)]


def test_engine_drains_queue(params):
    eng = ServeEngine(CFG, params, slots=3, cache_len=64)
    for r in _requests(7):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 7
    assert all(r.done and len(r.generated) == 8 for r in done)


def test_greedy_decode_invariant_to_slot_count(params):
    """Continuous batching must not change greedy outputs — the KV slots
    are independent."""
    outs = {}
    for slots in (1, 2, 5):
        eng = ServeEngine(CFG, params, slots=slots, cache_len=64)
        for r in _requests(6, seed=3):
            eng.submit(r)
        done = eng.run()
        outs[slots] = {r.rid: tuple(r.generated) for r in done}
    assert outs[1] == outs[2] == outs[5]


def test_eos_stops_generation(params):
    # find the first greedily generated token, then use it as EOS
    eng = ServeEngine(CFG, params, slots=1, cache_len=64)
    probe = _requests(1, seed=5, max_tokens=4)[0]
    eng.submit(probe)
    eng.run()
    eos = probe.generated[1]

    eng2 = ServeEngine(CFG, params, slots=1, cache_len=64)
    req = _requests(1, seed=5, max_tokens=16)[0]
    req.eos_id = int(eos)
    eng2.submit(req)
    done = eng2.run()
    assert done[0].generated[-1] == eos
    assert len(done[0].generated) <= 16


def test_cache_len_bounds_generation(params):
    eng = ServeEngine(CFG, params, slots=1, cache_len=16)
    req = Request(rid=0, prompt=np.arange(8) % CFG.vocab, max_tokens=100)
    eng.submit(req)
    done = eng.run()
    # positions stop before overrunning the cache
    assert len(done[0].generated) <= 16 - 8 + 1


def test_mixed_families_one_engine():
    for arch in ("mamba2-2.7b", "jamba-1.5-large-398b"):
        cfg = get_reduced(arch)
        p = init_params(jax.random.PRNGKey(1), cfg)
        eng = ServeEngine(cfg, p, slots=2, cache_len=48)
        rng = np.random.default_rng(0)
        for i in range(3):
            eng.submit(Request(rid=i,
                               prompt=rng.integers(0, cfg.vocab, size=6),
                               max_tokens=5))
        done = eng.run()
        assert len(done) == 3, arch


# ----------------------------------------------- device-resident hot path
def _run_engine(engine_cls, cfg, params, reqs, **kw):
    eng = engine_cls(cfg, params, **kw)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    return eng, {r.rid: tuple(r.generated) for r in done}


def test_refactored_matches_legacy_greedy(params):
    """Greedy decode on the device-resident engine is token-for-token
    identical to the seed engine (bucketed/padded prefill, fused on-device
    argmax, donated state must change nothing)."""
    from repro.serve import LegacyServeEngine

    _, new = _run_engine(ServeEngine, CFG, params, _requests(7, seed=11),
                         slots=3, cache_len=64)
    _, old = _run_engine(LegacyServeEngine, CFG, params,
                         _requests(7, seed=11), slots=3, cache_len=64)
    assert new == old


def test_refactored_matches_legacy_greedy_ssm():
    """Same equivalence through the SSM path: the frozen-state (dt=0)
    length masking of padded prefill must be exact."""
    from repro.serve import LegacyServeEngine

    cfg = get_reduced("mamba2-2.7b")
    p = init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(3, 14)))
               for _ in range(5)]
    reqs = lambda: [Request(rid=i, prompt=pr, max_tokens=6)
                    for i, pr in enumerate(prompts)]
    _, new = _run_engine(ServeEngine, cfg, p, reqs(), slots=2, cache_len=48)
    _, old = _run_engine(LegacyServeEngine, cfg, p, reqs(), slots=2,
                         cache_len=48)
    assert new == old


def test_prefill_jit_cache_bounded(params):
    """Many distinct prompt lengths must trace at most one prefill program
    per power-of-two bucket — not one per length like the seed engine."""
    eng = ServeEngine(CFG, params, slots=2, cache_len=64)
    for i, plen in enumerate(range(3, 45)):          # 42 distinct lengths
        eng.submit(Request(rid=i, prompt=(np.arange(plen) * 7) % CFG.vocab,
                           max_tokens=2))
    done = eng.run(max_steps=5000)
    assert len(done) == 42
    assert eng.prefill_compiles <= eng.n_buckets() <= 4  # 8/16/32/64


def test_decode_step_ships_only_token_ids(params):
    """The jitted decode step's non-state outputs are (slots,) token ids,
    positions and done-flags — the (slots, vocab) logits never appear in
    the traced signature, so they can never cross to host."""
    slots = 3
    eng = ServeEngine(CFG, params, slots=slots, cache_len=64)
    out = jax.eval_shape(
        lambda *a: eng._decode(*a, False),
        eng.params, eng.state, eng.last_token, eng.positions,
        eng._base_key, np.int32(1), eng._temps, eng._topks, eng._eos)
    state_shapes, tok, pos, done = out
    assert tok.shape == pos.shape == done.shape == (slots,)
    assert tok.dtype == np.int32 and done.dtype == np.bool_
    for leaf in (tok, pos, done):
        assert CFG.vocab not in leaf.shape
    # per-token host traffic is exactly the ids + flags
    for r in _requests(3, seed=1):
        eng.submit(r)
    eng.run()
    steps = eng.stats["decode_steps"]
    assert steps > 0
    assert eng.stats["host_transfer_bytes"] == steps * (slots * 4 + slots)


def test_top_k_one_equals_greedy(params):
    """top_k=1 with any temperature collapses the fused sampling head to
    argmax — must match greedy decode exactly."""
    mk = lambda: [Request(rid=i, prompt=r.prompt, max_tokens=8,
                          temperature=0.7, top_k=1)
                  for i, r in enumerate(_requests(4, seed=21))]
    _, sampled = _run_engine(ServeEngine, CFG, params, mk(), slots=2,
                             cache_len=64)
    _, greedy = _run_engine(ServeEngine, CFG, params, _requests(4, seed=21),
                            slots=2, cache_len=64)
    assert sampled == greedy


def test_sampled_decode_is_seeded_and_varied(params):
    """Non-greedy decode is reproducible per seed and actually samples."""
    mk = lambda: [Request(rid=0, prompt=np.arange(9) % CFG.vocab,
                          max_tokens=12, temperature=1.5)]
    _, a = _run_engine(ServeEngine, CFG, params, mk(), slots=1,
                       cache_len=64, seed=5)
    _, b = _run_engine(ServeEngine, CFG, params, mk(), slots=1,
                       cache_len=64, seed=5)
    _, c = _run_engine(ServeEngine, CFG, params, mk(), slots=1,
                       cache_len=64, seed=6)
    assert a == b
    assert a != c  # overwhelmingly likely at T=1.5 over 12 tokens


def test_window_crossing_prompt_matches_legacy(params):
    """Prompts longer than the sliding window but shorter than their pad
    bucket: the per-row ring layout in kv_to_cache must keep the last
    `window` *real* keys (pad positions never evict real tokens)."""
    from repro.serve import LegacyServeEngine

    assert CFG.sliding_window == 64
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, CFG.vocab, size=n) for n in (70, 90, 10)]
    mk = lambda: [Request(rid=i, prompt=p, max_tokens=6)
                  for i, p in enumerate(prompts)]
    _, new = _run_engine(ServeEngine, CFG, params, mk(), slots=2,
                         cache_len=128)
    _, old = _run_engine(LegacyServeEngine, CFG, params, mk(), slots=2,
                         cache_len=128)
    assert new == old
