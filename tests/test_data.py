"""Data-pipeline property tests (hypothesis) + pipeline behaviour."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.chipping import (Chip, augment_rotations, chip_positions,
                                 dedup_chips, make_chips, split_by_raster)
from repro.data.normalize import evi, ndvi, percentile_stretch
from repro.data.rasters import (rasterize_polygons, random_polygon,
                                synth_change_pair, synth_raster)
from repro.data.tokens import TokenStream, lm_batch_iterator


# ----------------------------------------------------------- chipping
@given(h=st.integers(32, 300), w=st.integers(32, 300),
       chip=st.sampled_from([16, 32, 64]),
       overlap=st.sampled_from([0.0, 0.25, 0.5]))
@settings(max_examples=40, deadline=None)
def test_chip_positions_cover_and_fit(h, w, chip, overlap):
    pos = chip_positions(h, w, chip, overlap)
    if h < chip or w < chip:
        return
    covered_y = np.zeros(h, bool)
    covered_x = np.zeros(w, bool)
    for y, x in pos:
        assert 0 <= y <= h - chip and 0 <= x <= w - chip
        covered_y[y:y + chip] = True
        covered_x[x:x + chip] = True
    assert covered_y.all() and covered_x.all()


@given(frac=st.floats(0.0, 1.0))
@settings(max_examples=30, deadline=None)
def test_chip_threshold_filter(frac):
    """Chips kept iff both classes >= 10% (paper's rule)."""
    mask = np.zeros((64, 64), np.uint8)
    n_on = int(round(frac * mask.size))
    mask.flat[:n_on] = 1
    raster = np.zeros((64, 64, 3), np.float32)
    chips = make_chips(raster, mask, "s", chip=64, overlap=0.0,
                       min_frac=0.10)
    keep = 0.10 <= mask.mean() <= 0.90
    assert (len(chips) == 1) == keep


def test_dedup_removes_exact_duplicates():
    raster = np.random.default_rng(0).normal(size=(64, 64, 3)).astype(
        np.float32)
    mask = (raster[..., 0] > 0).astype(np.uint8)
    c = make_chips(raster, mask, "a", chip=32, overlap=0.5, min_frac=0.0)
    doubled = c + [Chip(x.image.copy(), x.mask.copy(), "b", x.y, x.x)
                   for x in c]
    dd = dedup_chips(doubled)
    assert len(dd) == len(c)
    assert len(dedup_chips(dd)) == len(dd)  # idempotent


def test_split_by_raster_keeps_scenes_disjoint():
    rng = np.random.default_rng(1)
    chips = []
    for sid, n in [("a", 50), ("b", 30), ("c", 12), ("d", 5), ("e", 3)]:
        for i in range(n):
            img = rng.normal(size=(8, 8, 3)).astype(np.float32)
            chips.append(Chip(img, (img[..., 0] > 0).astype(np.uint8),
                              sid, 0, i))
    split = split_by_raster(chips)
    scenes = {k: {c.scene_id for c in v} for k, v in split.items()}
    assert not (scenes["train"] & scenes["val"])
    assert not (scenes["train"] & scenes["test"])
    assert not (scenes["val"] & scenes["test"])
    assert sum(len(v) for v in split.values()) == len(chips)
    # big rasters go to train (paper's rule)
    assert "a" in scenes["train"]


def test_rotation_augmentation_triples():
    img = np.arange(27, dtype=np.float32).reshape(3, 3, 3)
    c = [Chip(img, np.ones((3, 3), np.uint8), "s", 0, 0)]
    out = augment_rotations(c)
    assert len(out) == 3
    np.testing.assert_array_equal(out[1].image, np.rot90(img, 1))


# ------------------------------------------------------------ normalize
@given(seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_percentile_stretch_bounds_and_monotonic(seed):
    rng = np.random.default_rng(seed)
    img = rng.gamma(2.0, 300.0, size=(50, 50, 3)).astype(np.float32)
    out = percentile_stretch(img)
    assert out.min() >= 0.0 and out.max() <= 1.0
    # monotonic per band: order preserved where not clipped
    b = 0
    flat_in = img[..., b].ravel()
    flat_out = out[..., b].ravel()
    idx = np.argsort(flat_in)
    diffs = np.diff(flat_out[idx])
    assert (diffs >= -1e-6).all()


def test_spectral_indices_ranges():
    img = np.abs(np.random.default_rng(0).normal(
        2000, 500, size=(32, 32, 4))).astype(np.float32)
    nd = ndvi(img)
    assert (-1.0 <= nd).all() and (nd <= 1.0).all()
    ev = evi(img)
    assert np.isfinite(ev).all()


# -------------------------------------------------------------- rasters
def test_rasterize_square():
    sq = np.array([[2.0, 2.0], [10.0, 2.0], [10.0, 10.0], [2.0, 10.0]])
    m = rasterize_polygons([sq], 16, 16)
    assert m[5, 5] == 1 and m[0, 0] == 0 and m[12, 12] == 0
    assert m.sum() == 64  # 8x8 interior


def test_synth_raster_deterministic_and_two_class():
    s1 = synth_raster("sceneX", 128, 128, seed=3)
    s2 = synth_raster("sceneX", 128, 128, seed=3)
    np.testing.assert_array_equal(s1.raster, s2.raster)
    assert 0 < s1.mask.mean() < 1


def test_change_pair_mask_matches_difference():
    a, b, m = synth_change_pair("p1", 128, 128, seed=0)
    delta = np.abs(a - b).mean(axis=-1)
    inside = delta[m == 1].mean()
    outside = delta[m == 0].mean()
    assert inside > 3 * outside


# --------------------------------------------------------------- tokens
def test_token_stream_deterministic():
    a = TokenStream(100, seed=5).sample(1000)
    b = TokenStream(100, seed=5).sample(1000)
    np.testing.assert_array_equal(a, b)
    assert a.max() < 100 and a.min() >= 0


def test_lm_batch_iterator_shift():
    it = lm_batch_iterator(50, batch=2, seq=16, seed=0)
    toks, labels = next(it)
    assert toks.shape == (2, 16) and labels.shape == (2, 16)
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])


# --------------------------------------------------------------- loader
def test_prefetch_matches_plain_epoch():
    from repro.data.chipping import make_chips as mk
    from repro.data.loader import ChipLoader, prefetch

    s = synth_raster("pf", 128, 128, seed=0)
    chips = mk(s.raster[..., :3], s.mask, s.scene_id, chip=32, overlap=0.0,
               min_frac=0.0)
    plain = list(ChipLoader(chips, batch_size=4, seed=7).epoch())
    staged = list(prefetch(ChipLoader(chips, batch_size=4, seed=7), n=2))
    assert len(staged) == len(plain) and len(plain) > 1
    for (pi, pm), (si, sm) in zip(plain, staged):
        # device-resident (early device_put), same contents, same order
        assert hasattr(si, "devices")
        np.testing.assert_array_equal(pi, np.asarray(si))
        np.testing.assert_array_equal(pm, np.asarray(sm))


def test_prefetch_wraps_plain_iterables_and_raises():
    from repro.data.loader import prefetch

    batches = [np.arange(4) + i for i in range(5)]
    out = list(prefetch(iter(batches), n=3))
    for a, b in zip(batches, out):
        np.testing.assert_array_equal(a, np.asarray(b))

    def boom():
        yield np.zeros(2)
        raise RuntimeError("producer died")

    it = prefetch(boom(), n=2)
    next(it)
    with pytest.raises(RuntimeError, match="producer died"):
        list(it)


def test_prefetch_early_close_stops_producer():
    import itertools
    import time as _time

    from repro.data.loader import prefetch

    pulled = itertools.count()

    def infinite():
        for i in iter(lambda: next(pulled), None):
            yield np.full(2, i)

    it = prefetch(infinite(), n=2)
    next(it)
    it.close()                       # GeneratorExit -> stop event set
    _time.sleep(0.3)
    seen = next(pulled)
    _time.sleep(0.3)                 # producer must have stopped pulling
    assert next(pulled) == seen + 1
