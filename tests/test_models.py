"""Model-level behaviour: decode==prefill==forward consistency, flash vs
naive attention, sliding-window semantics, MoE routing properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import MoEConfig
from repro.models import (decode_step, forward, init_decode_state,
                          init_params, prefill)
from repro.models.layers import flash_attention_jnp, naive_attention
from repro.models import moe as MOE

KEY = jax.random.PRNGKey(7)


def _high_capacity(cfg):
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))


CONSISTENCY_ARCHS = ["granite-3-2b", "glm4-9b", "stablelm-1.6b",
                     "codeqwen1.5-7b", "mamba2-2.7b",
                     "jamba-1.5-large-398b", "qwen3-moe-30b-a3b",
                     "llama4-maverick-400b-a17b"]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_decode_matches_forward(arch):
    cfg = _high_capacity(get_reduced(arch))
    params = init_params(KEY, cfg)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = forward(params, cfg, {"tokens": toks}, remat=False)
    state = init_decode_state(cfg, B, S)
    step = jax.jit(lambda s, t, p: decode_step(params, cfg, s, t, p))
    outs = []
    for t in range(S):
        lg, state = step(state, toks[:, t:t + 1],
                         jnp.full((B,), t, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("arch", ["granite-3-2b", "jamba-1.5-large-398b"])
def test_prefill_then_decode_matches_forward(arch):
    cfg = _high_capacity(get_reduced(arch))
    params = init_params(KEY, cfg)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0, cfg.vocab)
    full, _ = forward(params, cfg, {"tokens": toks}, remat=False)
    last, state = prefill(params, cfg, {"tokens": toks[:, :S]},
                          cache_len=S + 4)
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(full[:, S - 1], np.float32),
                               atol=5e-4, rtol=5e-4)
    lg, _ = decode_step(params, cfg, state, toks[:, S:S + 1],
                        jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(full[:, S], np.float32),
                               atol=5e-4, rtol=5e-4)


def test_sliding_window_ring_buffer_decode():
    """With window W, decode beyond W positions must equal a fresh forward
    over the last-W context (dense arch, window smaller than sequence)."""
    cfg = dataclasses.replace(get_reduced("granite-3-2b"),
                              sliding_window=8, n_layers=2)
    params = init_params(KEY, cfg)
    B, S = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    # decode token-by-token through a ring buffer of exactly W slots
    state = init_decode_state(cfg, B, cache_len=S)  # clamps to window=8
    assert state["slot0"]["k"].shape[2] == 8
    step = jax.jit(lambda s, t, p: decode_step(params, cfg, s, t, p))
    for t in range(S):
        lg, state = step(state, toks[:, t:t + 1],
                         jnp.full((B,), t, jnp.int32))
    # reference: full forward with the same window
    full, _ = forward(params, cfg, {"tokens": toks}, remat=False)
    np.testing.assert_allclose(np.asarray(lg[0], np.float32),
                               np.asarray(full[0, -1], np.float32),
                               atol=5e-4, rtol=5e-4)


def test_flash_equals_naive_attention():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 200, 4, 32))
    k = jax.random.normal(ks[1], (2, 200, 2, 32))
    v = jax.random.normal(ks[2], (2, 200, 2, 32))
    for causal, window in [(True, None), (True, 50), (False, None)]:
        a = naive_attention(q, k, v, causal=causal, window=window)
        b = flash_attention_jnp(q, k, v, causal=causal, window=window,
                                q_chunk=64, k_chunk=48)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-6, rtol=2e-6)


# ----------------------------------------------------------------- MoE
def _moe_setup(E=8, K=2, T=64, d=16, cf=1.25):
    cfg = MoEConfig(n_experts=E, top_k=K, expert_d_ff=32,
                    capacity_factor=cf)
    params = MOE.moe_init(jax.random.PRNGKey(0), d, cfg, "silu",
                          jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, T, d))
    return cfg, params, x


def test_moe_output_finite_and_aux_positive():
    cfg, params, x = _moe_setup()
    y, aux = MOE.moe_apply(params, x, cfg, "silu")
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) >= 0


def test_moe_aux_loss_minimized_by_uniform_routing():
    """GShard aux loss lower bound is 1.0 at perfectly uniform routing."""
    E, T = 4, 1000
    probs = jnp.full((T, E), 1.0 / E)
    mask = jnp.tile(jnp.eye(E), (T // E + 1, 1))[:T]
    val = MOE.load_balance_loss(probs, mask)
    assert abs(float(val) - 1.0) < 1e-5
    # concentrated routing strictly worse
    probs_bad = jnp.concatenate(
        [jnp.full((T, 1), 0.97), jnp.full((T, E - 1), 0.01)], axis=1)
    mask_bad = jnp.concatenate(
        [jnp.ones((T, 1)), jnp.zeros((T, E - 1))], axis=1)
    assert float(MOE.load_balance_loss(probs_bad, mask_bad)) > 1.5


def test_moe_capacity_drops_vanish_with_large_factor():
    """With cf -> inf, capacity routing equals exact top-k mixture."""
    cfg, params, x = _moe_setup(cf=64.0)
    y_hi, _ = MOE.moe_apply(params, x, cfg, "silu")
    # exact dense reference: full softmax-topk mixture of expert MLPs
    probs, _ = MOE.router_probs(params, x.reshape(-1, x.shape[-1]))
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    xt = x.reshape(-1, x.shape[-1])
    up = jnp.einsum("td,edf->tef", xt, params["up"])
    gt = jnp.einsum("td,edf->tef", xt, params["gate"])
    dn = jnp.einsum("tef,efd->ted", jax.nn.silu(gt) * up, params["down"])
    ref = jnp.take_along_axis(dn, idx[..., None], axis=1)
    ref = (ref * gate[..., None]).sum(1).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y_hi), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_vlm_loss_ignores_patch_positions():
    cfg = get_reduced("llava-next-mistral-7b")
    from repro.models import train_loss
    from repro.data import make_batch
    params = init_params(KEY, cfg)
    batch = make_batch(cfg, 2, 32)
    loss = train_loss(params, cfg, batch, remat=False)
    assert bool(jnp.isfinite(loss))


def test_audio_masked_prediction_loss():
    cfg = get_reduced("hubert-xlarge")
    from repro.models import train_loss
    from repro.data import make_batch
    params = init_params(KEY, cfg)
    batch = make_batch(cfg, 2, 32)
    loss = train_loss(params, cfg, batch, remat=False)
    assert bool(jnp.isfinite(loss))
    # zero mask -> no supervised positions -> loss must still be finite
    batch["mask"] = jnp.zeros_like(batch["mask"])
    loss0 = train_loss(params, cfg, batch, remat=False)
    assert bool(jnp.isfinite(loss0))


def test_local_top_k_matches_lax():
    """Iterated-argmax top-k (shard-local under GSPMD) == lax.top_k."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32, 64))
    for k in (1, 2, 8):
        v0, i0 = jax.lax.top_k(x, k)
        v1, i1 = MOE._local_top_k(x, k)
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v0),
                                   atol=1e-6, rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))


def test_gqa_grouped_equals_repeated_attention():
    """GQA via grouped einsum (no K/V repeat) == explicit-repeat ref."""
    from repro.kernels.flash_attention.ref import attention_ref
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 64, 8, 32))
    k = jax.random.normal(ks[1], (2, 64, 2, 32))
    v = jax.random.normal(ks[2], (2, 64, 2, 32))
    a = naive_attention(q, k, v, causal=True, window=None)
    b = attention_ref(q, k, v, causal=True, window=None)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-6, rtol=2e-6)


def test_moe_token_mask_excludes_pads_from_capacity():
    """Serve prefill pads whole dummy rows into the MoE batch; without the
    router token mask their (identical, zero) tokens rank first and steal
    expert capacity from real tokens.  With the mask, real-token outputs
    are bit-identical to running the real row alone (capacities chosen
    equal: both floor at 4)."""
    from repro.configs.base import MoEConfig
    from repro.models import moe as MOE

    cfgm = MoEConfig(n_experts=4, top_k=1, expert_d_ff=16,
                     capacity_factor=0.5)
    d, S = 8, 16
    params = MOE.moe_init(jax.random.PRNGKey(0), d, cfgm, "silu",
                          jnp.float32)
    xr = jax.random.normal(jax.random.PRNGKey(1), (1, S, d), jnp.float32)
    xp = jnp.concatenate([jnp.zeros((1, S, d), jnp.float32), xr], axis=0)
    mask = jnp.stack([jnp.zeros(S, bool), jnp.ones(S, bool)])

    y_alone, _ = MOE.moe_apply(params, xr, cfgm, "silu")
    y_mask, _ = MOE.moe_apply(params, xp, cfgm, "silu", token_mask=mask)
    y_nomask, _ = MOE.moe_apply(params, xp, cfgm, "silu")

    np.testing.assert_array_equal(np.asarray(y_alone[0]),
                                  np.asarray(y_mask[1]))
    # counterfactual: unmasked dummy tokens visibly displace real ones
    assert not np.array_equal(np.asarray(y_mask[1]), np.asarray(y_nomask[1]))
