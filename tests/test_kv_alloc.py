"""Paged KV allocator: block accounting, no-partial-alloc growth, LRU
victim ordering.  Pure host logic — no JAX, no model."""
import pytest

from repro.serve import PagedKVAllocator


def test_admit_grow_release_accounting():
    kv = PagedKVAllocator(8, block_size=4)
    assert kv.blocks_for(1) == 1 and kv.blocks_for(4) == 1
    assert kv.blocks_for(5) == 2 and kv.blocks_for(0) == 1

    assert kv.admit(0, 6)                 # 2 blocks
    assert kv.used_blocks == 2 and kv.free_blocks == 6
    assert kv.grow(0, 8)                  # still 2 blocks (8 tokens fit)
    assert kv.used_blocks == 2
    assert kv.grow(0, 9)                  # crosses a boundary -> 3rd block
    assert kv.used_blocks == 3
    assert kv.table(0).n_tokens == 9

    assert kv.release(0) == 3
    assert kv.free_blocks == kv.total_blocks == 8
    assert kv.stats["allocated_blocks"] == 3
    assert kv.stats["freed_blocks"] == 3
    assert kv.stats["peak_blocks_in_use"] == 3


def test_admit_rejects_without_partial_allocation():
    kv = PagedKVAllocator(4, block_size=4)
    assert kv.admit(0, 12)                # 3 of 4 blocks
    assert not kv.admit(1, 8)             # needs 2, only 1 free
    assert kv.free_blocks == 1            # nothing leaked
    assert kv.table(1) is None
    assert kv.stats["failed_grows"] == 1


def test_grow_rejects_without_partial_allocation():
    kv = PagedKVAllocator(4, block_size=4)
    assert kv.admit(0, 4)
    assert kv.admit(1, 8)
    assert not kv.grow(0, 16)             # needs 3 more, only 1 free
    assert kv.table(0).n_tokens == 4      # untouched on failure
    assert len(kv.table(0).blocks) == 1
    assert kv.free_blocks == 1
    assert kv.stats["failed_grows"] == 1


def test_double_admit_raises():
    kv = PagedKVAllocator(4)
    assert kv.admit(7, 1)
    with pytest.raises(ValueError):
        kv.admit(7, 1)


def test_lru_victim_ordering():
    kv = PagedKVAllocator(16, block_size=4)
    kv.admit(0, 4, priority=0, tick=0)
    kv.admit(1, 4, priority=0, tick=0)
    kv.admit(2, 4, priority=0, tick=0)
    kv.grow(0, 5, tick=5)                 # rid 0 touched most recently
    # rids 1 and 2 are equally stale; the tie breaks toward the newer
    # admission (rid 2) so the older request keeps its accumulated work
    assert kv.lru_victim() == 2
    kv.grow(2, 5, tick=3)
    assert kv.lru_victim() == 1           # now strictly least recent
    # priority beats admission order among equally recent holders
    kv.admit(3, 4, priority=-1, tick=3)
    kv.grow(1, 5, tick=3)
    assert kv.lru_victim() == 3
    # exclusions and empty pool
    assert kv.lru_victim(exclude={0, 1, 2, 3}) is None


def test_snapshot_shape():
    kv = PagedKVAllocator(8, block_size=2)
    kv.admit(0, 3)
    snap = kv.snapshot()
    assert snap == {"total_blocks": 8, "block_size": 2, "used_blocks": 2,
                    "free_blocks": 6, "peak_blocks_in_use": 2,
                    "failed_grows": 0}


def test_invalid_pool_raises():
    with pytest.raises(ValueError):
        PagedKVAllocator(0)
    with pytest.raises(ValueError):
        PagedKVAllocator(4, block_size=0)
