"""Replay idempotence and scheduler-crash recovery, hermetically.

Property tests (hypothesis, with the conftest fallback when the real
package is absent) over a *recorded* event log rich in outcomes —
retries, preemptions, timeouts, speculation, and a multi-campaign
append:

* any prefix of the log — including a torn trailing line — replays to a
  consistent state, and the torn line contributes nothing;
* ``replay_events`` is an incremental fold: replaying a prefix, then the
  rest on top of it, equals the one-shot replay for every line-aligned
  split (crash-anywhere ≡ never-crashed).

Plus hermetic scheduler-crash recovery over a handcrafted log with real
orphan pids: a live orphan is re-adopted by pid + start-time identity, a
dead one re-queued, and completed work is never re-executed.
"""
import json
import subprocess
import sys
import time

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (JobState, Orchestrator, PersistentVolume,
                        SpeculationSpec, replay_events)
from repro.core.executor import EVENTS_REL, _pid_alive, _pid_start_time

from test_campaign_speculation import (FAST, FakeProc, _progress,
                                       _train_run, spec_spawn)


# --------------------------------------------------------------------------
# a recorded log rich in outcomes, reused by every property test
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def rich_lines(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("replay_log")

    # campaign A: a clean run, a crash+retry, a preemption, a timeout
    pvc_a = PersistentVolume(tmp / "a")
    orch_a = Orchestrator(pvc_a)
    orch_a.submit_runs([_train_run(n, seed=i, steps=4) for i, n in
                        enumerate(["plain", "flaky", "preempt", "hang"])])
    plans_a = {("flaky", 1): {"rc": 1, "ticks": 2},
               ("preempt", 1): {"rc": -9, "ticks": 2},
               ("hang", 1): {"ticks": 10_000}}     # killed by the timeout
    orch_a.run_cluster(workers=2, spawn=spec_spawn(plans_a),
                       attempt_timeout_s=0.08, **FAST)

    # campaign B: a straggler race with a speculation win + promotion
    pvc_b = PersistentVolume(tmp / "b")
    orch_b = Orchestrator(pvc_b)
    orch_b.submit_runs([
        _train_run("slow", steps=4, checkpoint_dir=str(tmp / "ck_slow")),
        _train_run("peer1", seed=1, steps=4),
        _train_run("peer2", seed=2, steps=4)])
    orch_b.run_cluster(
        workers=4, spawn=spec_spawn({("slow", 1): {"ticks": 10_000},
                                     ("slow", 2): {"ticks": 3}}),
        speculate=SpeculationSpec(min_runtime_s=0.0, grace=None,
                                  min_peers=1),
        progress_fn=_progress({"slow"}), **FAST)

    lines = (pvc_a.read_bytes(EVENTS_REL).decode().splitlines()
             + pvc_b.read_bytes(EVENTS_REL).decode().splitlines())
    # the recording must actually exercise every outcome family
    kinds = {json.loads(ln)["event"] for ln in lines}
    assert {"attempt_failed", "preempted", "attempt_timeout",
            "timeout_kill", "speculation_win", "speculation_loss",
            "speculation_promote", "campaign_start",
            "campaign_end"} <= kinds
    return lines


@settings(max_examples=60)
@given(k=st.integers(min_value=0, max_value=10_000))
def test_any_prefix_replays_consistent(rich_lines, k):
    k %= len(rich_lines) + 1
    state = replay_events(rich_lines[:k])
    assert state["consistent"], (k, state["violations"])


@settings(max_examples=60)
@given(k=st.integers(min_value=0, max_value=10_000),
       j=st.integers(min_value=0, max_value=500))
def test_torn_trailing_line_contributes_nothing(rich_lines, k, j):
    """A crash mid-append leaves a half-written last line: replay must
    treat it exactly as if the write never happened."""
    k %= len(rich_lines)
    line = rich_lines[k]
    j %= len(line)                      # strictly truncated
    torn_state = replay_events(rich_lines[:k] + [line[:j]])
    assert torn_state["consistent"], torn_state["violations"]
    assert torn_state == replay_events(rich_lines[:k])


@settings(max_examples=60)
@given(k=st.integers(min_value=0, max_value=10_000))
def test_incremental_fold_equals_one_shot(rich_lines, k):
    """replay(A+B) == replay(B, state=replay(A)) for any aligned split —
    the property ``--resume-campaign`` stands on."""
    k %= len(rich_lines) + 1
    prefix_state = replay_events(rich_lines[:k])
    folded = replay_events(rich_lines[k:], state=prefix_state)
    assert folded == replay_events(rich_lines)


def test_replay_then_append_then_replay(rich_lines):
    """Folding in three chunks (crash, resume, crash, resume) equals the
    one-shot replay, and the intermediate state is never mutated."""
    a, b = len(rich_lines) // 3, 2 * len(rich_lines) // 3
    s1 = replay_events(rich_lines[:a])
    s1_snapshot = json.loads(json.dumps(s1, default=str))
    s2 = replay_events(rich_lines[a:b], state=s1)
    s3 = replay_events(rich_lines[b:], state=s2)
    assert s3 == replay_events(rich_lines)
    assert json.loads(json.dumps(s1, default=str)) == s1_snapshot


# --------------------------------------------------------------------------
# crash recovery over a handcrafted log with real orphan pids
# --------------------------------------------------------------------------
def test_pid_identity_guards_against_reuse():
    import os
    pid = os.getpid()
    assert _pid_alive(pid, _pid_start_time(pid))
    assert not _pid_alive(pid, 1)          # right pid, wrong start time
    assert not _pid_alive(2 ** 22 + 11)    # beyond pid_max default


def _report_line(name):
    return json.dumps({"kind": "train", "name": name,
                       "status": "succeeded", "metrics": {}})


def test_resume_adopts_live_orphan_requeues_dead_never_reruns_done(
        tmp_path):
    """Handcrafted crash scene: one job already succeeded, one live
    orphan attempt (a real process that will print its RunReport), one
    orphan whose pid is gone.  ``resume=True`` must keep the first,
    adopt the second, re-queue the third — and re-execute nothing."""
    import dataclasses
    pvc = PersistentVolume(tmp_path / "pvc")
    orch = Orchestrator(pvc)
    orch.submit_runs([_train_run(n, seed=i, steps=4) for i, n in
                      enumerate(["done", "alive", "dead"])])
    res = dataclasses.asdict(orch.records["done"].spec.resources)

    # the live orphan: sleeps long enough to be adopted, then reports
    out_p = pvc.path("logs/alive.attempt1.out")
    out_p.parent.mkdir(parents=True, exist_ok=True)
    code = ("import time, sys; time.sleep(1.2); "
            f"print({_report_line('alive')!r})")
    with open(out_p, "wb") as fh:
        orphan = subprocess.Popen([sys.executable, "-c", code],
                                  stdout=fh)
    # the dead orphan: a pid that has already exited (reuse is caught by
    # the start-time identity check even if the OS recycles it)
    gone = subprocess.Popen([sys.executable, "-c", "pass"])
    gone.wait()

    t = time.time() - 5.0
    events = [
        {"event": "campaign_start", "workers": 2, "t": t},
        *({"event": "submitted", "job": n, "priority": 0,
           "kind": "train:stablelm-1.6b", "resources": res, "t": t}
          for n in ("done", "alive", "dead")),
        {"event": "admitted", "job": "done", "attempt": 1,
         "node": "local-0", "t": t},
        {"event": "started", "job": "done", "attempt": 1, "pid": 999,
         "pid_start": 1, "t": t, "ckpt_dir": None},
        {"event": "exited", "job": "done", "attempt": 1,
         "returncode": 0, "wall_s": 2.5, "t": t + 2.5},
        {"event": "succeeded", "job": "done", "attempt": 1,
         "resumed_from_step": None, "t": t + 2.5},
        {"event": "admitted", "job": "alive", "attempt": 1,
         "node": "local-1", "t": t},
        {"event": "started", "job": "alive", "attempt": 1,
         "pid": orphan.pid, "pid_start": _pid_start_time(orphan.pid),
         "t": t, "ckpt_dir": None},
        {"event": "admitted", "job": "dead", "attempt": 1,
         "node": "local-0", "t": t + 3},
        {"event": "started", "job": "dead", "attempt": 1,
         "pid": gone.pid, "pid_start": 12345, "t": t + 3,
         "ckpt_dir": None},
    ]
    ev_path = pvc.path(EVENTS_REL)
    ev_path.parent.mkdir(parents=True, exist_ok=True)
    ev_path.write_text(
        "".join(json.dumps(e) + "\n" for e in events), encoding="utf-8")
    done_result = {"loss": 1.23}
    pvc.stage_json("results/done.json", {
        "job": "done", "state": "Succeeded", "attempts": 1,
        "attempt_history": [{"attempt": 1, "outcome": "succeeded",
                             "wall_s": 2.5, "returncode": 0,
                             "speculative": False}],
        "result": {"status": "succeeded", "metrics": done_result}})

    spawn = spec_spawn({})               # every fresh attempt succeeds
    recs = orch.run_cluster(workers=2, spawn=spawn, resume=True, **FAST)

    assert {n: r.state for n, r in recs.items()} == {
        "done": JobState.SUCCEEDED, "alive": JobState.SUCCEEDED,
        "dead": JobState.SUCCEEDED}
    # completed work untouched, its staged result restored
    assert recs["done"].result["metrics"] == done_result
    spawned = [s["job"] for s in spawn.started]
    assert "done" not in spawned and "alive" not in spawned
    # the live orphan was adopted (attempt count unchanged), the dead
    # one re-ran as attempt 2
    assert recs["alive"].attempts == 1
    assert [s["attempt"] for s in spawn.started if s["job"] == "dead"] \
        == [2]

    lines = pvc.read_bytes(EVENTS_REL).decode().splitlines()
    state = replay_events(lines)
    assert state["consistent"], state["violations"]
    assert state["resumes"] == 1
    assert state["counts"] == {"Succeeded": 3}
    kinds = [json.loads(ln)["event"] for ln in lines]
    assert "adopted" in kinds and "orphan_requeued" in kinds
    # no started event for the completed job after the resume marker
    after = lines[kinds.index("campaign_resume"):]
    assert not any(json.loads(ln).get("job") == "done"
                   and json.loads(ln)["event"] == "started"
                   for ln in after)

    summary = json.loads(pvc.read_bytes("results/_campaign_summary.json"))
    assert summary["resumed"] is True
    assert summary["resumed_done"] == 1
    assert summary["orphans_adopted"] == 1
    assert summary["orphans_requeued"] == 1
    assert orphan.wait(timeout=10) == 0
