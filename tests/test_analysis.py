"""HLO analysis: computation splitting, while-trip-count scaling, and the
analytic model's layout sensitivity."""
import numpy as np
import pytest

from repro.analysis.hlo import (_split_computations, collective_bytes,
                                collective_bytes_scaled,
                                loop_trip_multipliers, parse_shape_bytes)
from repro.analysis.analytic import (MeshDims, analytic_roofline,
                                     collective_bytes_per_chip,
                                     decode_state_bytes, flops_forward)
from repro.configs import get_config

SYNTH_HLO = """
HloModule test

%body.1 (p: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %p = (s32[], f32[64,128]) parameter(0)
  %ag.1 = f32[64,128]{1,0} all-gather(%x), replica_groups={}
  ROOT %t = (s32[], f32[64,128]) tuple(%i, %ag.1)
}

%cond.1 (p2: (s32[], f32[64,128])) -> pred[] {
  %p2 = (s32[], f32[64,128]) parameter(0)
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i2, %c), direction=LT
}

ENTRY %main (a: f32[64,128]) -> f32[64,128] {
  %a = f32[64,128] parameter(0)
  %ar.0 = f32[32]{0} all-reduce(%z), replica_groups={}
  %w = (s32[], f32[64,128]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[64,128] get-tuple-element(%w), index=1
}
"""


def test_split_computations_handles_tuple_params():
    comps = _split_computations(SYNTH_HLO)
    assert {"body.1", "cond.1", "main"} <= set(comps)


def test_loop_trip_scaling():
    mult = loop_trip_multipliers(SYNTH_HLO)
    assert mult["body.1"] == 12
    raw = collective_bytes(SYNTH_HLO)
    scaled = collective_bytes_scaled(SYNTH_HLO)
    ag = 64 * 128 * 4
    assert raw["all-gather"] == ag
    assert scaled["all-gather"] == 12 * ag
    # the entry-level all-reduce is NOT scaled
    assert scaled["all-reduce"] == 32 * 4


def test_parse_shape_bytes_tuples_and_scalars():
    assert parse_shape_bytes("bf16[2,3]{1,0}") == 12
    assert parse_shape_bytes("(f32[4], bf16[4], pred[])") == 16 + 8 + 1
    assert parse_shape_bytes("s32[]") == 4


MD = MeshDims(pod=1, data=16, model=16)


def test_sp_layout_reduces_dense_attention_collectives():
    """For a GQA arch the fsdp_sp analytic collective term must be far
    below fsdp_tp (K/V-granular gathers vs per-layer activation ARs)."""
    cfg = get_config("glm4-9b")    # kv=2: extreme GQA
    tp = collective_bytes_per_chip(cfg, 256, 4096, "train", MD, "fsdp_tp")
    sp = collective_bytes_per_chip(cfg, 256, 4096, "train", MD, "fsdp_sp")
    assert sp["tp_allreduce"] < 0.1 * tp["tp_allreduce"]


def test_decode_state_bytes_window_clamps():
    import dataclasses
    cfg = dataclasses.replace(get_config("glm4-9b"), sliding_window=None)
    full = decode_state_bytes(cfg, 1, 524_288)
    win = decode_state_bytes(dataclasses.replace(cfg, sliding_window=8192),
                             1, 524_288)
    assert win < full / 32


def test_train_flops_exceed_prefill_exceed_decode():
    cfg = get_config("granite-3-2b")
    tr = flops_forward(cfg, 256, 4096, "train")
    pf = flops_forward(cfg, 256, 4096, "prefill")
    de = flops_forward(cfg, 256, 4096, "decode")
    assert tr == pf          # forward flops equal; train multiplies later
    assert de < pf / 1000


def test_roofline_decode_memory_dominant():
    cfg = get_config("glm4-9b")
    import jax
    mesh = jax.sharding.Mesh(
        np.array(jax.devices() * 256)[:256].reshape(16, 16),
        ("data", "model"))
    r = analytic_roofline(cfg, 128, 32768, "decode", mesh, "fsdp_tp")
    assert r["dominant"] == "memory_s"
