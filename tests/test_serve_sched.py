"""Continuous-batching scheduler: submit validation, greedy equivalence
with the legacy oracle, priority/SLO admission, paged-KV eviction with
token-identical resume, streaming, and service-timing stats."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params
from repro.serve import (LegacyServeEngine, Request, ServeEngine,
                         ServeScheduler, VirtualClock, poisson_trace)

CFG = get_reduced("granite-3-2b")


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _requests(n, seed=0, max_tokens=8, plo=4, phi=12):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab,
                                        size=int(rng.integers(plo, phi))),
                    max_tokens=max_tokens)
            for i in range(n)]


# ------------------------------------------------------------ validation
@pytest.mark.parametrize("make", [
    lambda p: ServeEngine(CFG, p, slots=1, cache_len=32),
    lambda p: LegacyServeEngine(CFG, p, slots=1, cache_len=32),
    lambda p: ServeScheduler(CFG, p, slots=1, cache_len=32),
])
def test_submit_rejects_invalid_prompts(params, make):
    eng = make(params)
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(rid=0, prompt=np.array([], np.int32)))
    with pytest.raises(ValueError, match="cache_len"):
        eng.submit(Request(rid=1, prompt=np.arange(32) % CFG.vocab))
    # the boundary case fits: cache_len - 1 prompt tokens + 1 generated
    eng.submit(Request(rid=2, prompt=np.arange(31) % CFG.vocab,
                       max_tokens=4))
    done = eng.run()
    assert len(done) == 1 and len(done[0].generated) >= 1


def test_submit_at_validates_before_queueing(params):
    sched = ServeScheduler(CFG, params, slots=1, cache_len=32)
    with pytest.raises(ValueError):
        sched.submit_at(Request(rid=0, prompt=np.array([], np.int32)), 0.0)
    assert sched.next_arrival() is None


def test_pool_too_small_for_one_request_raises(params):
    with pytest.raises(ValueError, match="deadlock"):
        ServeScheduler(CFG, params, slots=2, cache_len=64,
                       max_kv_blocks=2, kv_block_size=8)


# ------------------------------------------- greedy equivalence (oracle)
def test_scheduler_matches_legacy_on_fixed_trace(params):
    """Token-for-token: the continuous scheduler on a fixed arrival trace
    must generate exactly what the seed engine generates for the same
    prompts — admission plumbing must never change greedy decode."""
    trace = poisson_trace(CFG.vocab, 9, rate_qps=1e6, seed=13,
                          max_tokens=7)
    sched = ServeScheduler(CFG, params, slots=3, cache_len=64)
    sched.submit_trace(trace)
    sched.run()
    new = {r.rid: tuple(r.generated) for r in sched.completed}

    legacy = LegacyServeEngine(CFG, params, slots=3, cache_len=64)
    for _, r in trace:
        legacy.submit(Request(rid=r.rid, prompt=np.asarray(r.prompt),
                              max_tokens=r.max_tokens))
    old = {r.rid: tuple(r.generated) for r in legacy.run()}
    assert new == old
    assert sched.stats["shed"] == 0 and sched.stats["evictions"] == 0


def test_eviction_resume_is_token_identical(params):
    """Oversubscribed pool: LRU eviction + requeue + re-prefill of
    prompt+generated must resume greedy decode exactly where it left
    off — outputs identical to an unconstrained run."""
    mk = lambda: _requests(6, seed=23, max_tokens=20)
    ref = ServeScheduler(CFG, params, slots=3, cache_len=64)
    for r in mk():
        ref.submit(r)
    want = {r.rid: tuple(r.generated) for r in ref.run()}

    # pool of exactly cache_len tokens shared by 3 slots: ~3x oversubscribed
    tight = ServeScheduler(CFG, params, slots=3, cache_len=64,
                           max_kv_blocks=8, kv_block_size=8)
    for r in mk():
        tight.submit(r)
    got = {r.rid: tuple(r.generated) for r in tight.run()}
    assert got == want
    assert tight.stats["evictions"] > 0            # pressure was real
    assert tight.kv.stats["failed_grows"] > 0
    assert tight.kv.used_blocks == 0               # everything recycled


# --------------------------------------------------- priority / SLO / KV
def test_priority_orders_admission(params):
    sched = ServeScheduler(CFG, params, slots=1, cache_len=64)
    for r in _requests(3, seed=2, max_tokens=3):
        r.priority = r.rid                 # rid 2 most urgent
        sched.submit(r)
    sched.run()
    assert [r.rid for r in sched.completed] == [2, 1, 0]
    admits = [r.t_admit for r in sorted(sched.completed,
                                        key=lambda r: -r.priority)]
    assert admits == sorted(admits)


def test_slo_shedding_is_deterministic(params):
    """With a virtual clock (10ms per decode step) a queued request whose
    TTFT deadline lapses behind a long-running one is shed, not served."""
    clock = VirtualClock(dt_per_step=0.01)
    sched = ServeScheduler(CFG, params, slots=1, cache_len=64,
                           clock=clock, slo_deadline_ms=50.0)
    hog, victim = _requests(2, seed=4, max_tokens=20)
    hog.deadline_ms = None                  # the hog never expires
    events = []
    victim.on_token = lambda r, tok, fin: events.append((tok, fin))
    sched.submit(hog)
    sched.submit(victim)
    sched.run()
    assert victim.status == "shed"
    assert victim in sched.shed and victim.t_done is not None
    assert events == [(-1, True)]           # shed notification fired
    assert sched.stats["shed"] == 1
    assert len(hog.generated) == 20
    s = sched.stats()
    assert s["shed"] == 1 and s["completed"] == 1


def test_open_loop_arrivals_release_by_clock(params):
    clock = VirtualClock(dt_per_step=0.01)
    sched = ServeScheduler(CFG, params, slots=2, cache_len=64, clock=clock)
    a, b = _requests(2, seed=6, max_tokens=4)
    sched.submit_at(a, 0.0)
    sched.submit_at(b, 5.0)                 # far in the virtual future
    assert sched.next_arrival() == 0.0
    sched.run()                             # sleeps the clock forward to b
    assert len(sched.completed) == 2
    assert b.t_submit == 5.0 and b.t_admit >= 5.0
    assert a.t_done < b.t_admit             # b really arrived later


# -------------------------------------------------------------- streaming
def test_stream_yields_tokens_and_ttft(params):
    sched = ServeScheduler(CFG, params, slots=2, cache_len=64)
    background = _requests(1, seed=8, max_tokens=10)[0]
    sched.submit(background)
    star = _requests(2, seed=8, max_tokens=6)[1]
    star.rid = 99
    got = []
    for tok in sched.stream(star):
        got.append(tok)
        assert star.t_first is not None     # TTFT stamped by first yield
    assert got == star.generated and len(got) == 6
    sched.run()                             # drain the co-batched request
    assert background.done


def test_on_token_callback_sees_every_token(params):
    sched = ServeScheduler(CFG, params, slots=1, cache_len=64)
    req = _requests(1, seed=12, max_tokens=5)[0]
    seen = []
    req.on_token = lambda r, tok, fin: seen.append((tok, fin))
    sched.submit(req)
    sched.run()
    assert [t for t, _ in seen] == req.generated
    assert [f for _, f in seen] == [False] * 4 + [True]


# --------------------------------------------------- prefill bucket edges
def test_bucket_boundary_prompts(params):
    """Prompt lengths sitting exactly on bucket boundaries (8, 16), a
    single-token prompt, and the largest admissible prompt all decode
    and compile at most one prefill program per bucket."""
    sched = ServeScheduler(CFG, params, slots=2, cache_len=64)
    plens = [1, 8, 16, 63]                  # 63 == cache_len - 1
    for i, plen in enumerate(plens):
        sched.submit(Request(rid=i, prompt=(np.arange(plen) * 3) % CFG.vocab,
                             max_tokens=2))
    done = sched.run()
    assert len(done) == len(plens)
    assert all(len(r.generated) >= 1 for r in done)
    assert sched.prefill_compiles <= sched.n_buckets() <= 4   # 8/16/32/64


def test_prefill_cache_bounded_under_mixed_trace(params):
    """A scheduler workload mixing many prompt lengths, priorities and
    mid-decode admissions keeps the prefill jit cache bucket-bounded and
    never retraces decode."""
    rng = np.random.default_rng(31)
    sched = ServeScheduler(CFG, params, slots=3, cache_len=64)
    for i, plen in enumerate(rng.permutation(np.arange(2, 40))):
        sched.submit(Request(rid=i,
                             prompt=(np.arange(plen) * 5) % CFG.vocab,
                             max_tokens=3, priority=int(i % 3)))
    done = sched.run(max_steps=5000)
    assert len(done) == 38
    assert sched.prefill_compiles <= sched.n_buckets()
    assert sched.decode_compiles == 1


# ------------------------------------------------------------ stats wiring
def test_timing_stats_surface_in_summary(params):
    clock = VirtualClock(dt_per_step=0.01)
    sched = ServeScheduler(CFG, params, slots=2, cache_len=64, clock=clock)
    for r in _requests(4, seed=14, max_tokens=6):
        sched.submit(r)
    sched.run()
    s = sched.stats()
    for key in ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s",
                "queue_wait_p50_s", "queue_wait_p99_s"):
        assert s[key] is not None and s[key] >= 0.0, key
    assert s["ttft_p50_s"] <= s["ttft_p99_s"]
    assert s["kv"]["used_blocks"] == 0
    # mapping access (the pre-existing counter contract) still works
    assert sched.stats["decode_steps"] == s["decode_steps"]
    for r in sched.completed:
        assert r.tpot_s is not None and r.queue_wait_s is not None


def test_serve_runner_reports_continuous_metrics():
    """RunSpec -> RunReport round trip through the continuous path: the
    report must carry goodput and latency percentiles."""
    from repro.api import RunSpec, run

    report = run(RunSpec(kind="serve", arch="granite-3-2b", overrides={
        "requests": 4, "slots": 2, "cache_len": 32, "max_tokens": 4,
        "arrival_rate": 200.0, "trace": "bursty",
        "slo_deadline_ms": 60_000.0}))
    assert report.ok
    m = report.metrics
    assert m["mode"] == "continuous" and m["trace"] == "bursty"
    assert m["completed"] + m["shed"] == 4
    for key in ("goodput_req_s", "goodput_tok_s", "ttft_p50_s",
                "tpot_p50_s", "queue_wait_p99_s", "evictions", "kv"):
        assert key in m, key
    assert m["decode_compiles"] == 1
