"""Orchestration-core tests: grid expansion, templating, the cluster
scheduler simulation invariants (hypothesis), artifacts, autobatch."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import (ClusterSim, ExperimentGrid, JobSpec, JobState,
                        NodeSpec, Orchestrator, PersistentVolume, Resources,
                        S3Store, autobatch, render_job_manifest)
from repro.core.autobatch import MemoryBudget
from repro.core.experiment import paper_burned_area_grid
from repro.core.scheduler import NAUTILUS_INVENTORY
from repro.core.templating import render_template, to_yaml


# ------------------------------------------------------------ grids
def test_paper_grid_reproduces_experiment_counts():
    """Paper Sect. III-B: 72 experiments x 2 architectures = 144 models,
    288 YAML manifests (train + eval per model)."""
    grids = paper_burned_area_grid()
    assert set(grids) == {"unet", "deeplabv3"}
    per_arch = {k: len(v.expand()) for k, v in grids.items()}
    assert per_arch == {"unet": 72, "deeplabv3": 72}
    n_models = sum(per_arch.values())
    assert n_models == 144
    assert 2 * n_models == 288  # train + eval manifests


@given(axes=st.dictionaries(
    st.sampled_from(["lr", "bs", "opt", "init", "data", "seed"]),
    st.lists(st.integers(0, 9), min_size=1, max_size=4, unique=True),
    min_size=1, max_size=5))
@settings(max_examples=30, deadline=None)
def test_grid_size_is_product(axes):
    g = ExperimentGrid("t", axes)
    expect = 1
    for v in axes.values():
        expect *= len(v)
    specs = g.expand()
    assert len(specs) == expect
    assert len({s.name for s in specs}) == expect  # unique names


def test_experiment_config_json_roundtrip():
    import json
    g = ExperimentGrid("ba", {"lr": [1e-4], "bs": [8]})
    spec = g.expand()[0]
    cfg = json.loads(spec.config_json())
    assert cfg["lr"] == 1e-4 and cfg["bs"] == 8


# --------------------------------------------------------- templating
def test_render_template_types_preserved():
    out = render_template({"gpus": "{{ r.gpus }}", "msg": "use {{ r.gpus }} gpus"},
                          {"r": {"gpus": 4}})
    assert out["gpus"] == 4 and out["msg"] == "use 4 gpus"


def test_job_manifest_shape_and_yaml():
    m = render_job_manifest("train-unet-lr1e-4", env={"LR": "1e-4"},
                            gpus=2, cpus=4, memory_gb=24)
    assert m["kind"] == "Job"
    limits = m["spec"]["template"]["spec"]["containers"][0]["resources"]["limits"]
    assert limits["nvidia.com/gpu"] == 2
    assert limits["memory"] == "24Gi"
    y = to_yaml(m)
    assert "kind: Job" in y and "nvidia.com/gpu: 2" in y


# ---------------------------------------------------------- scheduler
@given(n_jobs=st.integers(1, 60),
       gpus=st.sampled_from([1, 2, 4]),
       dur=st.floats(0.5, 20.0),
       seed=st.integers(0, 3))
@settings(max_examples=25, deadline=None)
def test_scheduler_invariants(n_jobs, gpus, dur, seed):
    jobs = [JobSpec(name=f"j{i}", duration_h=dur,
                    resources=Resources(gpus=gpus, cpus=2, memory_gb=8))
            for i in range(n_jobs)]
    sim = ClusterSim(seed=seed)
    res = sim.run(jobs)
    # every job completed
    assert all(r.state == JobState.SUCCEEDED for r in res.records)
    # makespan bounds: at least one job's duration; at most serial time
    assert res.makespan_h >= dur - 1e-9
    assert res.makespan_h <= n_jobs * dur + 1e-6
    # gpu-hour accounting exact
    assert res.total_gpu_hours == pytest.approx(n_jobs * dur * gpus)
    # nodes released: all free counts restored
    for node in sim.nodes:
        assert node.gpus_free == node.spec.gpus
        assert node.cpus_free == node.spec.cpus


def test_scheduler_respects_vram_constraint():
    """A job demanding 40GB VRAM must land on A40/A100 only."""
    jobs = [JobSpec(name=f"big{i}", duration_h=1.0,
                    resources=Resources(gpus=1, cpus=1, memory_gb=4,
                                        gpu_memory_gb_min=40))
            for i in range(10)]
    res = ClusterSim().run(jobs)
    for r in res.records:
        assert r.node.startswith(("a40", "a100")), r.node


def test_scheduler_queues_when_cluster_full():
    inv = [NodeSpec("tiny", gpus=2, gpu_memory_gb=16, cpus=8,
                    memory_gb=32, count=1)]
    jobs = [JobSpec(name=f"j{i}", duration_h=1.0,
                    resources=Resources(gpus=2, cpus=2, memory_gb=8))
            for i in range(4)]
    res = ClusterSim(inv).run(jobs)
    assert res.makespan_h == pytest.approx(4.0)  # strictly serial
    assert res.queue_wait_h_mean > 0


def test_scheduler_preemption_retries_to_completion():
    jobs = [JobSpec(name=f"j{i}", duration_h=1.0, retries=10,
                    resources=Resources(gpus=1, cpus=1, memory_gb=4))
            for i in range(20)]
    res = ClusterSim(seed=1, preemption_rate=0.5).run(jobs)
    assert all(r.state == JobState.SUCCEEDED for r in res.records)
    assert any(r.attempts > 1 for r in res.records)


def test_nautilus_inventory_scale_matches_paper():
    gpus = sum(n.gpus * n.count for n in NAUTILUS_INVENTORY)
    cores = sum(n.cpus * n.count for n in NAUTILUS_INVENTORY)
    assert 1000 <= gpus <= 1400        # "over 1300 GPUs" era
    assert 15_000 <= cores <= 20_000   # "19,000 CPU cores"


# -------------------------------------------------------- orchestrator
def test_orchestrator_end_to_end(tmp_path):
    pvc = PersistentVolume(tmp_path, quota_gb=1)
    s3 = S3Store(tmp_path)
    orch = Orchestrator(pvc, s3)

    def payload(lr="0.1", **kw):
        return {"final_loss": 1.0 / (1 + float(lr))}

    jobs = [JobSpec(name=f"exp{i}", payload=payload,
                    env={"lr": str(0.1 * (i + 1))}, duration_h=2.0)
            for i in range(6)]
    orch.submit_many(jobs)
    # manifests staged before execution (paper autogenerates all YAML first)
    assert len(pvc.listdir("manifests")) == 6
    orch.run_local()
    assert orch.summary()["states"] == {"Succeeded": 6}
    assert len(s3.list("results/")) == 6
    sim = orch.simulate()
    assert sim.makespan_h == pytest.approx(2.0)  # all parallel


def test_orchestrator_retries_failures(tmp_path):
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    calls = {"n": 0}

    def flaky(**kw):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("preempted")
        return "ok"

    orch.submit(JobSpec(name="flaky", payload=flaky, retries=5))
    recs = orch.run_local()
    assert recs["flaky"].state == JobState.SUCCEEDED
    assert recs["flaky"].attempts == 3
    assert len(pvc.listdir("logs")) == 2  # two failure logs


def test_pvc_quota_enforced(tmp_path):
    pvc = PersistentVolume(tmp_path, quota_gb=1e-6)  # 1 KB
    with pytest.raises(IOError):
        pvc.stage_bytes("big.bin", b"x" * 10_000)


def test_s3_store_roundtrip(tmp_path):
    s3 = S3Store(tmp_path)
    etag = s3.put_bytes("models/a/weights.npz", b"abc")
    assert s3.get_bytes("models/a/weights.npz") == b"abc"
    assert s3.list("models/") == ["models/a/weights.npz"]
    assert len(etag) == 32


# ----------------------------------------------------------- autobatch
def test_autobatch_monotonic_in_memory():
    cfg = get_config("granite-3-2b")
    b_small = autobatch(cfg, 4096, budget=MemoryBudget(device_gb=16),
                        n_shards=256, act_shards=16)
    b_big = autobatch(cfg, 4096, budget=MemoryBudget(device_gb=80),
                      n_shards=256, act_shards=16)
    assert b_big >= b_small > 0
    # power of two
    assert b_small & (b_small - 1) == 0


def test_autobatch_reproduces_paper_motivation():
    """DP-only cannot fit the 400B arch on any single device (paper's
    future-work motivation); multi-pod FSDP can."""
    cfg = get_config("llama4-maverick-400b-a17b")
    assert autobatch(cfg, 4096, n_shards=1) == 0           # single GPU
    assert autobatch(cfg, 4096, budget=MemoryBudget(device_gb=80),
                     n_shards=1) == 0                      # even an A100
    assert autobatch(cfg, 4096, n_shards=512, act_shards=16) >= 1
