"""Training behaviour: loss decreases on learnable synthetic data;
microbatch gradient accumulation is exact; checkpoints roundtrip; the
compiled step donates its state; mixed precision and kernel backends
train correctly."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import export_to_s3, load_checkpoint, save_checkpoint
from repro.configs import get_reduced
from repro.core import S3Store
from repro.data.tokens import lm_batch_iterator
from repro.models import init_params, train_loss
from repro.optim import get_optimizer, warmup_cosine
from repro.train import (get_precision, init_train_state, make_eval_step,
                         make_train_step)


def test_loss_decreases_on_markov_tokens():
    cfg = dataclasses.replace(get_reduced("stablelm-1.6b"), vocab=128)
    state = init_train_state(jax.random.PRNGKey(0), cfg,
                             get_optimizer("adamw"))
    step_fn = make_train_step(
        cfg, get_optimizer("adamw"),
        lr_schedule=warmup_cosine(3e-3, 60, warmup_steps=10))
    it = lm_batch_iterator(cfg.vocab, batch=8, seq=64, seed=0)
    losses = []
    for i in range(60):
        toks, labels = next(it)
        state, metrics = step_fn(state, {"tokens": jnp.asarray(toks),
                                         "labels": jnp.asarray(labels)})
        losses.append(float(metrics["loss"]))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.2, (first, last)


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_reduced("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    batch = {"tokens": toks}

    g_full = jax.grad(lambda p: train_loss(p, cfg, batch, remat=False))(params)

    def acc_grads(n):
        total = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        for i in range(n):
            mb = {"tokens": toks[i * (8 // n):(i + 1) * (8 // n)]}
            g = jax.grad(lambda p: train_loss(p, cfg, mb, remat=False))(params)
            total = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 total, g)
        return jax.tree.map(lambda x: x / n, total)

    g_acc = acc_grads(4)
    flat_f = jnp.concatenate([x.ravel().astype(jnp.float32)
                              for x in jax.tree.leaves(g_full)])
    flat_a = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g_acc)])
    np.testing.assert_allclose(np.asarray(flat_a), np.asarray(flat_f),
                               atol=1e-5, rtol=1e-4)


def test_remat_does_not_change_loss_or_grads():
    cfg = get_reduced("glm4-9b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64),
                                          0, cfg.vocab)}
    l1, g1 = jax.value_and_grad(
        lambda p: train_loss(p, cfg, batch, remat=False))(params)
    l2, g2 = jax.value_and_grad(
        lambda p: train_loss(p, cfg, batch, remat=True))(params)
    assert float(jnp.abs(l1 - l2)) < 1e-5
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-4)


def _small_batch(cfg, batch=4, seq=32, seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (batch, seq),
                              0, cfg.vocab)
    return {"tokens": toks, "labels": toks}


def test_train_step_donates_state_buffers():
    """The jitted train step consumes its input TrainState: the donated
    buffers are deleted, so no second copy of params/opt state exists."""
    cfg = get_reduced("stablelm-1.6b")
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step_fn = make_train_step(cfg)
    new_state, metrics = step_fn(state, _small_batch(cfg))
    for leaf in jax.tree.leaves(state):
        assert leaf.is_deleted()
    for leaf in jax.tree.leaves(new_state):
        assert not leaf.is_deleted()
    # and the step is usable again with the new state
    new_state, _ = step_fn(new_state, _small_batch(cfg))
    assert int(new_state.step) == 2
    # opt-out keeps the input alive
    state2 = init_train_state(jax.random.PRNGKey(0), cfg)
    undonated = make_train_step(cfg, donate=False)
    undonated(state2, _small_batch(cfg))
    assert not any(leaf.is_deleted() for leaf in jax.tree.leaves(state2))


def test_eval_step_jit_identical_before_after_change():
    """Compiling the eval path must not change the loss.  The bitwise
    contract is jit-vs-jit: the seed's eval (bare function a caller
    would wrap in jax.jit) and the now-built-in jit produce the same
    program, hence bitwise-identical losses — and the jitted loss is
    deterministic across calls.  Eager (op-by-op) execution is only
    float-equal, not bitwise: XLA fusion reorders the reductions."""
    cfg = get_reduced("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _small_batch(cfg)
    seed_style = jax.jit(make_eval_step(cfg, jit_compile=False))
    new_style = make_eval_step(cfg)
    assert float(seed_style(params, batch)) == float(new_style(params, batch))
    assert float(new_style(params, batch)) == float(new_style(params, batch))
    eager = train_loss(params, cfg, batch, remat=False)
    np.testing.assert_allclose(float(new_style(params, batch)), float(eager),
                               rtol=1e-6)


def test_bf16_precision_policy_trains():
    """bf16 policy: master params and optimizer state stay f32 (the
    checkpointable state is unchanged), loss is f32 and close to the f32
    policy's, and the loss still decreases."""
    cfg = dataclasses.replace(get_reduced("stablelm-1.6b"), vocab=128)
    opt = get_optimizer("adamw")
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    f32_loss = float(train_loss(state.params, cfg, _small_batch(cfg)))
    bf16_loss = float(train_loss(state.params, cfg, _small_batch(cfg),
                                 compute_dtype="bfloat16"))
    assert bf16_loss == pytest.approx(f32_loss, rel=2e-2)

    step_fn = make_train_step(
        cfg, opt, precision="bf16",
        lr_schedule=warmup_cosine(3e-3, 40, warmup_steps=5))
    it = lm_batch_iterator(cfg.vocab, batch=8, seq=64, seed=0)
    losses = []
    for _ in range(40):
        toks, labels = next(it)
        state, metrics = step_fn(state, {"tokens": jnp.asarray(toks),
                                         "labels": jnp.asarray(labels)})
        losses.append(float(metrics["loss"]))
        assert metrics["loss"].dtype == jnp.float32
    for leaf in jax.tree.leaves(state.params) + jax.tree.leaves(
            state.opt_state):
        assert leaf.dtype == jnp.float32       # master state stays f32
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_precision_policy_resolution():
    p = get_precision("bf16")
    assert p.compute_dtype == "bfloat16" and p.param_dtype == "float32"
    assert p.grad_dtype == "float32" and p.casts_compute
    assert get_precision(None).name == "f32"
    assert get_precision(p) is p
    with pytest.raises(ValueError, match="unknown precision"):
        get_precision("fp8")


def test_grad_clip_fused_with_norm_metric():
    """grad_clip bounds the applied update without changing the reported
    grad_norm (the metric is the pre-clip norm from the same reduction)."""
    cfg = dataclasses.replace(get_reduced("stablelm-1.6b"), vocab=128)
    opt = get_optimizer("sgd")                  # update == -lr * grads
    batch = _small_batch(cfg)
    clip = 1e-3
    lr = 1.0

    unclipped = make_train_step(cfg, opt, lr_schedule=lambda s: lr,
                                donate=False)
    clipped = make_train_step(cfg, opt, lr_schedule=lambda s: lr,
                              grad_clip=clip, donate=False)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    _, m0 = unclipped(state, batch)
    new_state, m1 = clipped(state, batch)
    assert float(m0["grad_norm"]) == float(m1["grad_norm"])  # same reduction
    assert float(m1["grad_norm"]) > clip       # clip actually engaged
    upd = jnp.sqrt(sum(
        jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)))
        for a, b in zip(jax.tree.leaves(new_state.params),
                        jax.tree.leaves(state.params))))
    assert float(upd) <= lr * clip * (1 + 1e-4)


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "mamba2-2.7b"])
def test_pallas_backend_trains_equivalently(arch):
    """One full train step (value_and_grad + update) through the Pallas
    kernel backends matches the jnp backends within f32 tolerance."""
    cfg = get_reduced(arch)
    batch = _small_batch(cfg, batch=2, seq=64)
    states = {}
    for be in ("jnp", "pallas"):
        c = dataclasses.replace(cfg, attention_backend=be, mixer_backend=be)
        state = init_train_state(jax.random.PRNGKey(0), c)
        step_fn = make_train_step(c, donate=False)
        states[be] = step_fn(state, batch)
    (s_jnp, m_jnp), (s_pl, m_pl) = states["jnp"], states["pallas"]
    assert float(m_jnp["loss"]) == pytest.approx(float(m_pl["loss"]),
                                                 abs=1e-5)
    assert float(m_jnp["grad_norm"]) == pytest.approx(
        float(m_pl["grad_norm"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(s_jnp.params),
                    jax.tree.leaves(s_pl.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-4)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_reduced("mamba2-2.7b")
    params = init_params(jax.random.PRNGKey(3), cfg)
    d = save_checkpoint(tmp_path / "ck", params, step=17,
                        metadata={"arch": cfg.name})
    restored, step = load_checkpoint(d, like=params)
    assert step == 17
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # shape mismatch must raise
    bad = jax.tree.map(lambda x: x, params)
    bad["embed"]["w"] = jnp.zeros((3, 3))
    with pytest.raises((ValueError, KeyError)):
        load_checkpoint(d, like=bad)


def test_checkpoint_s3_export(tmp_path):
    cfg = get_reduced("stablelm-1.6b")
    params = init_params(jax.random.PRNGKey(3), cfg)
    d = save_checkpoint(tmp_path / "ck", params, step=1)
    s3 = S3Store(tmp_path)
    n = export_to_s3(d, s3, "models/stablelm-run0")
    assert n >= 2  # manifest + at least one shard
    assert s3.exists("models/stablelm-run0/manifest.json")
