"""Training behaviour: loss decreases on learnable synthetic data;
microbatch gradient accumulation is exact; checkpoints roundtrip."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import export_to_s3, load_checkpoint, save_checkpoint
from repro.configs import get_reduced
from repro.core import S3Store
from repro.data.tokens import lm_batch_iterator
from repro.models import init_params, train_loss
from repro.optim import get_optimizer, warmup_cosine
from repro.train import init_train_state, make_train_step


def test_loss_decreases_on_markov_tokens():
    cfg = dataclasses.replace(get_reduced("stablelm-1.6b"), vocab=128)
    state = init_train_state(jax.random.PRNGKey(0), cfg,
                             get_optimizer("adamw"))
    step_fn = jax.jit(make_train_step(
        cfg, get_optimizer("adamw"),
        lr_schedule=warmup_cosine(3e-3, 60, warmup_steps=10)))
    it = lm_batch_iterator(cfg.vocab, batch=8, seq=64, seed=0)
    losses = []
    for i in range(60):
        toks, labels = next(it)
        state, metrics = step_fn(state, {"tokens": jnp.asarray(toks),
                                         "labels": jnp.asarray(labels)})
        losses.append(float(metrics["loss"]))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.2, (first, last)


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_reduced("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    batch = {"tokens": toks}

    g_full = jax.grad(lambda p: train_loss(p, cfg, batch, remat=False))(params)

    def acc_grads(n):
        total = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        for i in range(n):
            mb = {"tokens": toks[i * (8 // n):(i + 1) * (8 // n)]}
            g = jax.grad(lambda p: train_loss(p, cfg, mb, remat=False))(params)
            total = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 total, g)
        return jax.tree.map(lambda x: x / n, total)

    g_acc = acc_grads(4)
    flat_f = jnp.concatenate([x.ravel().astype(jnp.float32)
                              for x in jax.tree.leaves(g_full)])
    flat_a = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g_acc)])
    np.testing.assert_allclose(np.asarray(flat_a), np.asarray(flat_f),
                               atol=1e-5, rtol=1e-4)


def test_remat_does_not_change_loss_or_grads():
    cfg = get_reduced("glm4-9b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64),
                                          0, cfg.vocab)}
    l1, g1 = jax.value_and_grad(
        lambda p: train_loss(p, cfg, batch, remat=False))(params)
    l2, g2 = jax.value_and_grad(
        lambda p: train_loss(p, cfg, batch, remat=True))(params)
    assert float(jnp.abs(l1 - l2)) < 1e-5
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-4)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_reduced("mamba2-2.7b")
    params = init_params(jax.random.PRNGKey(3), cfg)
    d = save_checkpoint(tmp_path / "ck", params, step=17,
                        metadata={"arch": cfg.name})
    restored, step = load_checkpoint(d, like=params)
    assert step == 17
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # shape mismatch must raise
    bad = jax.tree.map(lambda x: x, params)
    bad["embed"]["w"] = jnp.zeros((3, 3))
    with pytest.raises((ValueError, KeyError)):
        load_checkpoint(d, like=bad)


def test_checkpoint_s3_export(tmp_path):
    cfg = get_reduced("stablelm-1.6b")
    params = init_params(jax.random.PRNGKey(3), cfg)
    d = save_checkpoint(tmp_path / "ck", params, step=1)
    s3 = S3Store(tmp_path)
    n = export_to_s3(d, s3, "models/stablelm-run0")
    assert n >= 2  # manifest + at least one shard
    assert s3.exists("models/stablelm-run0/manifest.json")
