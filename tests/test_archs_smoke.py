"""Per-architecture smoke tests: a REDUCED variant of each assigned
architecture (2 layers, d_model<=512, <=4 experts) runs one forward and
one train step on CPU; output shapes and finiteness are asserted.  Decode
smoke runs for every decode-capable family."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced, list_archs
from repro.data import make_batch
from repro.data.inputs import make_decode_batch
from repro.models import (decode_step, forward, init_decode_state,
                          init_params, train_loss)
from repro.train import init_train_state, make_train_step

ARCHS = list_archs()


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_reduced(arch)
    params = init_params(rng, cfg)
    B, S = 2, 64
    batch = make_batch(cfg, B, S)
    logits, aux = jax.jit(
        lambda p, b: forward(p, cfg, b, remat=False))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    assert bool(jnp.isfinite(aux)), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, rng):
    cfg = get_reduced(arch)
    state = init_train_state(rng, cfg)
    # donate=False: the assertion below still reads the pre-step params
    step_fn = make_train_step(cfg, remat=True, donate=False)
    batch = make_batch(cfg, 2, 64)
    new_state, metrics = step_fn(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_state.step) == 1
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.params, new_state.params)
    assert max(jax.tree.leaves(moved)) > 0


DECODE_ARCHS = [a for a in ARCHS if get_reduced(a).causal]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_smoke(arch, rng):
    cfg = get_reduced(arch)
    params = init_params(rng, cfg)
    B, cache_len = 2, 32
    state = init_decode_state(cfg, B, cache_len)
    batch = make_decode_batch(cfg, B, position=5)
    logits, new_state = jax.jit(
        lambda p, s, t, pos: decode_step(p, cfg, s, t, pos))(
        params, state, batch["tokens"], batch["position"])
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    # state structure preserved
    assert jax.tree.structure(state) == jax.tree.structure(new_state)


def test_encoder_only_has_no_decode():
    cfg = get_reduced("hubert-xlarge")
    assert cfg.is_encoder_only
    from repro.launch.steps import build_decode
    from repro.launch.mesh import make_local_mesh
    with pytest.raises(ValueError):
        build_decode(cfg, make_local_mesh(), "dp", 2, 32)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_tree(arch, rng):
    """Analytic param accounting must match the real parameter tree."""
    cfg = get_reduced(arch)
    params = init_params(rng, cfg)
    actual = sum(x.size for x in jax.tree.leaves(params))
    assert actual == cfg.param_count(), arch


def test_full_configs_match_public_specs():
    """Full configs carry the assigned dimensions and plausible totals."""
    from repro.configs import get_config
    totals = {
        "llama4-maverick-400b-a17b": (350e9, 450e9),
        "jamba-1.5-large-398b": (350e9, 450e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "glm4-9b": (8e9, 10.5e9),
        "llava-next-mistral-7b": (6.5e9, 8e9),
        "codeqwen1.5-7b": (6.4e9, 8.3e9),  # MHA kv=32 per assignment
        "mamba2-2.7b": (2.4e9, 3.0e9),
        "granite-3-2b": (2.2e9, 2.9e9),
        "stablelm-1.6b": (1.4e9, 1.9e9),
        "hubert-xlarge": (0.9e9, 1.1e9),
    }
    for arch, (lo, hi) in totals.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
    # active params for the MoEs
    a17 = get_config("llama4-maverick-400b-a17b").active_param_count()
    assert 14e9 <= a17 <= 20e9, a17
    a3 = get_config("qwen3-moe-30b-a3b").active_param_count()
    assert 2.5e9 <= a3 <= 4e9, a3
