"""Real concurrent campaign execution: chaos system test (actual
subprocesses, actual SIGKILL, checkpoint resume, bitwise-identical final
params), plus hermetic executor tests over an injectable fake process
spawn (retry/resume argv semantics, unschedulable fail-fast, durable
event-log replay, the ``campaign status`` CLI, and the deprecated
``makespan_s`` alias)."""
import json
import os
import signal

import numpy as np
import pytest

from repro.core import (ChaosSpec, JobSpec, JobState, Orchestrator,
                        PersistentVolume, Resources, NodeSpec,
                        replay_events)
from repro.core.executor import (EVENTS_REL, job_run_argv,
                                 parse_trailing_report)


# --------------------------------------------------------------------------
# Fake process plumbing: exercise the executor loop without paying a jax
# import per job.
# --------------------------------------------------------------------------
class FakeProc:
    """Looks enough like subprocess.Popen for the executor: returns None
    from poll() for ``ticks`` calls, then writes a RunReport to stdout
    and exits with ``rc``."""

    def __init__(self, job, attempt, stdout_fh, *, rc=0, ticks=2,
                 tracker=None):
        self.job, self.attempt = job, attempt
        self.stdout_fh = stdout_fh
        self.rc, self.ticks = rc, ticks
        self.pid = 4242
        self.tracker = tracker
        if tracker is not None:
            tracker["active"] += 1
            tracker["max"] = max(tracker["max"], tracker["active"])

    def poll(self):
        self.ticks -= 1
        if self.ticks > 0:
            return None
        if self.rc == 0:
            report = {"kind": "train", "name": self.job.name,
                      "status": "succeeded",
                      "metrics": {"resumed_from_step":
                                  2 if self.attempt > 1 else None}}
            self.stdout_fh.write(json.dumps(report, indent=1).encode())
            self.stdout_fh.flush()
        if self.tracker is not None:
            self.tracker["active"] -= 1
            self.tracker = None
        return self.rc

    def send_signal(self, sig):
        self.rc, self.ticks = -sig, 1


def fake_spawn(plan=None, tracker=None):
    """plan: {job_name: [rc, rc, ...]} per attempt (default all 0)."""
    def spawn(job, attempt, argv, env, stdout_fh, stderr_fh):
        rcs = (plan or {}).get(job.name, [])
        rc = rcs[attempt - 1] if attempt <= len(rcs) else 0
        return FakeProc(job, attempt, stdout_fh, rc=rc, tracker=tracker)
    return spawn


def _train_run(name, seed=0, **overrides):
    from repro.api import RunSpec
    return RunSpec(kind="train", arch="stablelm-1.6b", seed=seed, name=name,
                   overrides=overrides)


# hermetic tests want no real retry sleeps and no /proc sampling of the
# fake pid — backoff/telemetry get their own dedicated tests below
FAST = dict(retry_backoff_base_s=0.0, telemetry=False)


# --------------------------------------------------------------------------
# Hermetic executor behaviour
# --------------------------------------------------------------------------
def test_retry_reenters_with_resume_argv(tmp_path):
    """A failed attempt is re-admitted with the retry_env overlay: the
    rebuilt argv carries --resume=true (train's RESUMABLE_KINDS
    contract), and the attempt history records the progression."""
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    orch.submit_runs([_train_run("flaky", steps=4)])
    seen_argv = []

    def spawn(job, attempt, argv, env, stdout_fh, stderr_fh):
        seen_argv.append(argv)
        return FakeProc(job, attempt, stdout_fh,
                        rc=1 if attempt == 1 else 0)

    recs = orch.run_cluster(workers=1, spawn=spawn, poll_s=0.001, **FAST)
    assert recs["flaky"].state == JobState.SUCCEEDED
    assert recs["flaky"].attempts == 2
    assert not any("--resume=true" in a for a in seen_argv[0])
    assert any(a == "--resume=true" for a in seen_argv[1])
    result = json.loads(pvc.read_bytes("results/flaky.json"))
    outcomes = [h["outcome"] for h in result["attempt_history"]]
    assert outcomes == ["failed", "succeeded"]
    assert result["attempt_history"][1]["resumed_from_step"] == 2


def test_sigkilled_attempt_is_preempted_and_requeued(tmp_path):
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    orch.submit_runs([_train_run("victim", steps=4)])
    recs = orch.run_cluster(
        workers=1, poll_s=0.001, **FAST,
        spawn=fake_spawn(plan={"victim": [-int(signal.SIGKILL), 0]}))
    assert recs["victim"].state == JobState.SUCCEEDED
    result = json.loads(pvc.read_bytes("results/victim.json"))
    assert [h["outcome"] for h in result["attempt_history"]] \
        == ["preempted", "succeeded"]
    summary = json.loads(pvc.read_bytes("results/_campaign_summary.json"))
    assert summary["preemptions"] == 1
    assert 0.0 < summary["wall_goodput"] < 1.0
    assert summary["steps_salvaged_by_resume"] == 2


def test_exhausted_retries_reach_failed(tmp_path):
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    run = _train_run("doomed", steps=4)
    job = run.to_job()
    job.retries = 1
    orch.submit(job)
    recs = orch.run_cluster(workers=1, poll_s=0.001, **FAST,
                            spawn=fake_spawn(plan={"doomed": [1, 1]}))
    assert recs["doomed"].state == JobState.FAILED
    assert recs["doomed"].attempts == 2
    state = replay_events(
        pvc.read_bytes(EVENTS_REL).decode().splitlines())
    assert state["jobs"]["doomed"]["state"] == "Failed"
    assert state["consistent"], state["violations"]


def test_unschedulable_job_fails_fast(tmp_path):
    """A request no node can ever satisfy fails before anything runs
    instead of waiting forever."""
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    orch.submit(JobSpec(name="whale", resources=Resources(gpus=64),
                        env={"RUN_KIND": "train"}))
    orch.submit_runs([_train_run("minnow", steps=4)])
    recs = orch.run_cluster(
        workers=2, poll_s=0.001, spawn=fake_spawn(), **FAST,
        inventory=[NodeSpec("small", gpus=1, gpu_memory_gb=16, cpus=8,
                            memory_gb=64, count=2)])
    assert recs["whale"].state == JobState.FAILED
    assert "unschedulable" in recs["whale"].error
    assert recs["minnow"].state == JobState.SUCCEEDED


def test_event_log_is_durable_and_replayable(tmp_path):
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    orch.submit_runs([_train_run(f"j{i}", seed=i, steps=4)
                      for i in range(4)])
    orch.run_cluster(workers=2, poll_s=0.001, **FAST, spawn=fake_spawn(
        plan={"j1": [-int(signal.SIGKILL), 0]}))
    events_path = pvc.path(EVENTS_REL)
    assert events_path.exists()
    lines = events_path.read_text().splitlines()
    # every line is intact JSON (fsynced append-only)
    parsed = [json.loads(ln) for ln in lines]
    assert parsed[0]["event"] == "campaign_start"
    assert parsed[-1]["event"] == "campaign_end"
    state = replay_events(lines)
    assert state["ended"] and state["consistent"], state["violations"]
    assert state["counts"] == {"Succeeded": 4}
    assert state["jobs"]["j1"]["preemptions"] == 1
    # a half-written trailing line (crash mid-append) is tolerated
    state2 = replay_events(lines + ['{"event": "succ'])
    assert state2["counts"] == {"Succeeded": 4}
    # replay after appending a second campaign keeps only the newest
    orch2 = Orchestrator(pvc)
    orch2.submit_runs([_train_run("solo", steps=4)])
    orch2.run_cluster(workers=1, poll_s=0.001, spawn=fake_spawn(), **FAST)
    state3 = replay_events(events_path.read_text().splitlines())
    assert set(state3["jobs"]) == {"solo"}


def test_campaign_status_cli(tmp_path, capsys):
    from repro.launch.__main__ import main
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    orch.submit_runs([_train_run("a", steps=4), _train_run("b", steps=4)])
    orch.run_cluster(workers=2, poll_s=0.001, spawn=fake_spawn(), **FAST)
    assert main(["campaign", "status", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "Succeeded" in out and "a" in out and "b" in out
    assert main(["campaign", "status", str(tmp_path), "--json"]) == 0
    state = json.loads(capsys.readouterr().out)
    assert state["counts"] == {"Succeeded": 2} and state["consistent"]
    assert main(["campaign", "status", str(tmp_path / "nowhere")]) == 2
    capsys.readouterr()


def test_priority_admission_order(tmp_path):
    """Single-slot pool: admission follows (-priority, submit order)."""
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    for name, prio in [("low", 0), ("high", 5), ("mid", 2), ("high2", 5)]:
        orch.submit(JobSpec(name=name, priority=prio,
                            env={"RUN_KIND": "train"},
                            resources=Resources(gpus=1, cpus=1,
                                                memory_gb=1)))
    orch.run_cluster(workers=1, poll_s=0.001, spawn=fake_spawn(), **FAST)
    events = [json.loads(ln) for ln
              in pvc.read_bytes(EVENTS_REL).decode().splitlines()]
    admitted = [e["job"] for e in events if e["event"] == "admitted"]
    assert admitted == ["high", "high2", "mid", "low"]


def test_run_local_summary_never_claims_real_makespan(tmp_path):
    """run_local's lane accounting is *simulated*: the field is
    simulated_makespan_s, and the real-wall-clock key (makespan_s, as
    written by run_cluster's _campaign_summary.json) must never appear
    there — BENCH consumers distinguish the two by name."""
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    for i in range(3):
        orch.submit(JobSpec(name=f"j{i}", payload=lambda **kw: "ok"))
    orch.run_local(parallelism=2)
    summary = json.loads(pvc.read_bytes("results/_local_run_summary.json"))
    assert "simulated_makespan_s" in summary
    assert "makespan_s" not in summary
    assert summary["simulated_makespan_s"] <= summary["serial_s"] + 1e-9


def test_pin_cpus_exports_affinity_per_worker_slot(tmp_path):
    """pin_cpus=True turns the Resources.cpus request into a per-slot
    REPRO_CPU_AFFINITY core list (round-robin over host cores)."""
    if not hasattr(os, "sched_getaffinity"):
        pytest.skip("no sched_getaffinity on this platform")
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    for i in range(4):
        orch.submit(JobSpec(name=f"j{i}", env={"RUN_KIND": "train"},
                            resources=Resources(gpus=0, cpus=1,
                                                memory_gb=1.0)))
    seen = {}

    def spawn(job, attempt, argv, env, stdout_fh, stderr_fh):
        seen[job.name] = env.get("REPRO_CPU_AFFINITY")
        return FakeProc(job, attempt, stdout_fh)

    orch.run_cluster(workers=4, poll_s=0.001, spawn=spawn, pin_cpus=True,
                     **FAST)
    host = sorted(os.sched_getaffinity(0))
    assert len(seen) == 4
    for cores in seen.values():
        assert cores is not None
        parsed = [int(c) for c in cores.split(",")]
        assert len(parsed) == 1 and parsed[0] in host
    # slots cycle round-robin over the host cores
    assert len({seen[f"j{i}"] for i in range(4)}) == min(4, len(host))


def test_parse_trailing_report_skips_step_logs():
    text = ("step     0 loss 10.9 lr 1e-3 gnorm 1.0\n"
            "{'not': 'json'}\n"
            + json.dumps({"status": "succeeded", "kind": "train",
                          "name": "x", "metrics": {}}, indent=1))
    rep = parse_trailing_report(text)
    assert rep and rep["status"] == "succeeded"
    assert parse_trailing_report("no json here") is None


def test_job_run_argv_round_trip():
    from repro.api.spec import RunSpec
    spec = _train_run("rt", seed=3, steps=7, lr=1e-4,
                      checkpoint_dir="/tmp/x")
    argv = job_run_argv(spec.to_job())
    rebuilt = RunSpec.from_args(argv[1:])
    assert rebuilt.kind == "train" and rebuilt.name == "rt"
    assert rebuilt.seed == 3
    assert rebuilt.overrides["steps"] == 7
    assert rebuilt.overrides["lr"] == 1e-4
    assert rebuilt.overrides["checkpoint_dir"] == "/tmp/x"
    argv_resume = job_run_argv(spec.to_job(), resume=True)
    assert RunSpec.from_args(argv_resume[1:]).overrides["resume"] is True


# --------------------------------------------------------------------------
# The chaos system test: real subprocesses, real SIGKILL, real resume.
# --------------------------------------------------------------------------
def _final_checkpoint_tree(ckpt_dir):
    from repro.checkpoint import list_checkpoints, load_checkpoint
    ckpts = list_checkpoints(ckpt_dir)
    assert ckpts, f"no published checkpoints under {ckpt_dir}"
    step, path = ckpts[-1]
    tree, mstep = load_checkpoint(path)
    return tree, int(mstep)


STEPS, CKPT_EVERY = 6, 2
TRAIN_KW = dict(batch=2, seq=16, log_every=0)


@pytest.mark.timeout(600)
def test_campaign_chaos_kill_resume_bitwise_identical(tmp_path):
    """End-to-end campaign of tiny train runs under SIGKILL injection:
    every run completes, final params are bitwise identical to an
    uninterrupted in-process run, and the event log replays to a
    consistent terminal state."""
    from repro.launch.train import train_main

    pvc = PersistentVolume(tmp_path / "campaign")
    orch = Orchestrator(pvc)
    seeds = (0, 1)
    runs = [_train_run(f"chaos{s}", seed=s, steps=STEPS,
                       checkpoint_every=CKPT_EVERY,
                       checkpoint_dir=str(tmp_path / f"ck{s}"), **TRAIN_KW)
            for s in seeds]
    orch.submit_runs(runs)
    chaos = ChaosSpec.sample([r.run_name for r in runs], fraction=1.0,
                             seed=7, after_checkpoints=1)
    assert set(chaos.kill_jobs) == {"chaos0", "chaos1"}
    recs = orch.run_cluster(workers=2, chaos=chaos, attempt_timeout_s=240)

    # every run eventually completes, each through a real preemption
    for s in seeds:
        rec = recs[f"chaos{s}"]
        assert rec.state == JobState.SUCCEEDED
        result = json.loads(pvc.read_bytes(f"results/chaos{s}.json"))
        outcomes = [h["outcome"] for h in result["attempt_history"]]
        assert "preempted" in outcomes and outcomes[-1] == "succeeded"
        resumed = result["attempt_history"][-1].get("resumed_from_step")
        assert resumed is not None and resumed >= CKPT_EVERY

    # the event log replays to a consistent terminal state
    state = replay_events(pvc.read_bytes(EVENTS_REL).decode().splitlines())
    assert state["ended"] and state["consistent"], state["violations"]
    assert state["counts"] == {"Succeeded": 2}
    assert all(st["chaos_kills"] >= 1 for st in state["jobs"].values())

    summary = json.loads(pvc.read_bytes("results/_campaign_summary.json"))
    assert summary["preemptions"] >= 2
    assert summary["steps_salvaged_by_resume"] >= 2 * CKPT_EVERY
    assert 0.0 < summary["wall_goodput"] < 1.0

    # bitwise identity vs uninterrupted execution (same seed/config)
    for s in seeds:
        ref_dir = tmp_path / f"ref{s}"
        train_main("stablelm-1.6b", reduced=True, steps=STEPS, seed=s,
                   checkpoint_dir=str(ref_dir),
                   checkpoint_every=CKPT_EVERY, checkpoint_async=False,
                   **TRAIN_KW)
        got, got_step = _final_checkpoint_tree(tmp_path / f"ck{s}")
        want, want_step = _final_checkpoint_tree(ref_dir)
        assert got_step == want_step == STEPS
        assert set(got) == set(want) and len(want) > 0
        for key in sorted(want):   # every leaf: params, opt state, step
            np.testing.assert_array_equal(got[key], want[key],
                                          err_msg=f"seed {s}: {key}")


def test_timeout_gets_its_own_outcome_and_requeues(tmp_path):
    """A timed-out attempt is not a generic kill: it gets the 'timeout'
    outcome, its own event, a retry, and its wall counts as lost work."""
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    orch.submit_runs([_train_run("slowpoke", steps=4)])

    def spawn(job, attempt, argv, env, stdout_fh, stderr_fh):
        # first attempt hangs until the executor kills it; retry is quick
        return FakeProc(job, attempt, stdout_fh,
                        ticks=10_000 if attempt == 1 else 2)

    recs = orch.run_cluster(workers=1, poll_s=0.001, spawn=spawn,
                            attempt_timeout_s=0.05, **FAST)
    assert recs["slowpoke"].state == JobState.SUCCEEDED
    result = json.loads(pvc.read_bytes("results/slowpoke.json"))
    assert [h["outcome"] for h in result["attempt_history"]] \
        == ["timeout", "succeeded"]
    summary = json.loads(pvc.read_bytes("results/_campaign_summary.json"))
    assert summary["timeouts"] == 1
    assert summary["preemptions"] == 1       # timeouts count as lost work
    assert summary["lost_attempt_wall_s"] > 0
    assert 0.0 < summary["wall_goodput"] < 1.0
    events = [json.loads(ln) for ln
              in pvc.read_bytes(EVENTS_REL).decode().splitlines()]
    assert any(e["event"] == "timeout_kill" for e in events)
    timeout_evs = [e for e in events if e["event"] == "attempt_timeout"]
    assert len(timeout_evs) == 1 and timeout_evs[0]["requeued"] is True
    state = replay_events(events)
    assert state["jobs"]["slowpoke"]["timeouts"] == 1
    assert state["consistent"], state["violations"]


class _TickClock:
    """Injected wall clock: every observation advances time a little, so
    backoff windows pass deterministically without real sleeping."""

    def __init__(self, start=1_000.0, tick=0.01):
        self.t, self.tick = start, tick

    def __call__(self):
        self.t += self.tick
        return self.t


def test_retry_backoff_exponential_jitter_deterministic(tmp_path):
    """Failure retries back off exponentially with full jitter; the
    sequence is a pure function of backoff_seed under an injected clock,
    and the requeued attempt does not start before its gate."""
    def run_once(root):
        pvc = PersistentVolume(root)
        orch = Orchestrator(pvc)
        orch.submit_runs([_train_run("flappy", steps=4)])
        orch.run_cluster(workers=1, poll_s=0.0, telemetry=False,
                         spawn=fake_spawn(plan={"flappy": [1, 1, 0]}),
                         retry_backoff_base_s=4.0, retry_backoff_cap_s=30.0,
                         backoff_seed=7, clock=_TickClock())
        return [json.loads(ln) for ln
                in pvc.read_bytes(EVENTS_REL).decode().splitlines()]

    ev1 = run_once(tmp_path / "a")
    ev2 = run_once(tmp_path / "b")
    backoffs = [e["backoff_s"] for e in ev1
                if e["event"] == "attempt_failed" and e["requeued"]]
    assert backoffs == [e["backoff_s"] for e in ev2
                        if e["event"] == "attempt_failed" and e["requeued"]]
    # full-jitter envelope: base * 2**(nfail-1) * [0.5, 1.0]
    assert len(backoffs) == 2
    assert 2.0 <= backoffs[0] <= 4.0
    assert 4.0 <= backoffs[1] <= 8.0
    # the requeued attempt never starts inside the backoff window
    fails = [e for e in ev1 if e["event"] == "attempt_failed"]
    starts = {e["attempt"]: e for e in ev1 if e["event"] == "started"}
    for nfail, fail in enumerate(fails, start=1):
        nxt = starts.get(fail["attempt"] + 1)
        assert nxt is not None
        assert nxt["t"] >= fail["t"] + fail["backoff_s"] - 1e-6


def test_preemption_requeues_without_backoff(tmp_path):
    """A signal preemption is the cluster's fault, not the job's: the
    resume attempt is admitted immediately (no backoff gate), even with
    backoff configured."""
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    orch.submit_runs([_train_run("victim", steps=4)])
    orch.run_cluster(workers=1, poll_s=0.0, telemetry=False,
                     spawn=fake_spawn(
                         plan={"victim": [-int(signal.SIGKILL), 0]}),
                     retry_backoff_base_s=60.0, clock=_TickClock())
    events = [json.loads(ln) for ln
              in pvc.read_bytes(EVENTS_REL).decode().splitlines()]
    pre = next(e for e in events if e["event"] == "preempted")
    assert "backoff_s" not in pre and pre["requeued"] is True
    restart = next(e for e in events if e["event"] == "started"
                   and e["attempt"] == 2)
    assert restart["t"] - pre["t"] < 1.0      # gate would have been 30s+


# --------------------------------------------------------------------------
# Scheduler-crash system test: SIGKILL the *scheduler* mid-campaign,
# restart with --resume, lose nothing.
# --------------------------------------------------------------------------
N_SCHED_RUNS = 12


@pytest.mark.timeout(900)
def test_scheduler_sigkill_resume_no_rework_bitwise_identical(tmp_path):
    """Drive a 12-run campaign through ``repro.launch campaign run`` (the
    driver process *is* the scheduler), SIGKILL the driver once a few
    runs have completed, restart with ``--resume``: every run completes,
    no job that succeeded before the kill is ever re-executed, live
    orphan attempts are adopted rather than restarted, and every final
    checkpoint is bitwise identical to uninterrupted execution."""
    import subprocess
    import sys
    import time

    from repro.core.executor import _src_path
    from repro.launch.train import train_main

    workdir = tmp_path / "campaign"
    jobs = []
    for s in range(N_SCHED_RUNS):
        spec = _train_run(f"run{s:02d}", seed=s, steps=STEPS,
                          checkpoint_every=CKPT_EVERY,
                          checkpoint_async=False,
                          checkpoint_dir=str(tmp_path / f"ck{s}"),
                          **TRAIN_KW)
        d = spec.to_dict()
        d["resources"] = {"gpus": 0, "cpus": 1, "memory_gb": 2.0}
        jobs.append(d)
    jobs_file = tmp_path / "jobs.json"
    jobs_file.write_text(json.dumps(jobs))

    env = {**os.environ}
    env["PYTHONPATH"] = (_src_path() + os.pathsep
                         + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    argv = [sys.executable, "-m", "repro.launch", "campaign", "run",
            "--jobs", str(jobs_file), "--workdir", str(workdir),
            "--workers", "2"]
    events_path = workdir / "repro-data" / EVENTS_REL

    def read_events():
        if not events_path.exists():
            return []
        out = []
        for ln in events_path.read_text(errors="replace").splitlines():
            try:
                out.append(json.loads(ln))
            except ValueError:
                pass                  # torn trailing line mid-append
        return out

    def succeeded_jobs():
        return {e["job"] for e in read_events()
                if e.get("event") == "succeeded"}

    proc = subprocess.Popen(argv, env=env, stdout=subprocess.DEVNULL,
                            stderr=subprocess.PIPE)
    try:
        deadline = time.time() + 420
        while len(succeeded_jobs()) < 3:
            rc = proc.poll()
            assert rc is None, (
                f"scheduler exited early rc={rc}: "
                f"{proc.stderr.read().decode(errors='replace')[-2000:]}")
            assert time.time() < deadline, "no successes before deadline"
            time.sleep(0.5)
        proc.kill()                   # SIGKILL the scheduler itself
        proc.wait()
    finally:
        if proc.poll() is None:
            proc.kill()
    done_before = succeeded_jobs()
    assert len(done_before) >= 3

    res = subprocess.run(argv + ["--resume"], env=env,
                         capture_output=True, timeout=420)
    assert res.returncode == 0, res.stderr.decode(errors="replace")[-2000:]
    out = res.stdout.decode(errors="replace")
    summary = json.loads(out[out.index("{"):])
    assert summary["states"] == {"Succeeded": N_SCHED_RUNS}
    assert summary["resumed"] is True
    assert summary["resumed_done"] >= len(done_before)

    events = read_events()
    # exactly one terminal success per job across driver generations —
    # zero completed attempts re-executed
    succ = [e["job"] for e in events if e["event"] == "succeeded"]
    assert len(succ) == N_SCHED_RUNS and len(set(succ)) == N_SCHED_RUNS
    resume_idx = max(i for i, e in enumerate(events)
                     if e["event"] == "campaign_resume")
    for e in events[resume_idx:]:
        if e["event"] == "started":
            assert e["job"] not in done_before, \
                f"completed job {e['job']} was re-executed"
    state = replay_events(events)
    assert state["ended"] and state["consistent"], state["violations"]
    assert state["counts"] == {"Succeeded": N_SCHED_RUNS}
    assert state["resumes"] == 1
    assert done_before <= set(succ)

    # bitwise identity of every final checkpoint vs uninterrupted
    # in-process execution of the same spec
    for s in range(N_SCHED_RUNS):
        ref_dir = tmp_path / f"ref{s}"
        train_main("stablelm-1.6b", reduced=True, steps=STEPS, seed=s,
                   checkpoint_dir=str(ref_dir),
                   checkpoint_every=CKPT_EVERY, checkpoint_async=False,
                   **TRAIN_KW)
        got, got_step = _final_checkpoint_tree(tmp_path / f"ck{s}")
        want, want_step = _final_checkpoint_tree(ref_dir)
        assert got_step == want_step == STEPS
        assert set(got) == set(want) and len(want) > 0
        for key in sorted(want):
            np.testing.assert_array_equal(got[key], want[key],
                                          err_msg=f"seed {s}: {key}")
