"""Data-parallel training subsystem tests.

Three layers, mirroring how the subsystem is built:

* **units** (jax-free or world=1): row sharding, the analytic
  all-reduce traffic model, per-rank argv construction, the Indexed-Job
  manifest rendering, and the seekable-cursor round trip through
  :class:`repro.distributed.data.ShardedBatches`;
* **hermetic executor gang scheduling** over the injectable fake
  spawn: one process per rank sharing a coordinator, whole-gang
  kill+requeue when one rank dies (second attempt resumes), fail-fast
  unschedulable gangs with zero spawns, worker-cap accounting in
  process units, and the ``campaign status`` gang row;
* **system oracle + chaos** (real subprocesses, real SIGKILL): a
  world=2 gang through the campaign executor matches a single-process
  run at the same global batch to documented tolerance, and a
  chaos-killed gang (one rank SIGKILLed mid-run) resumes to final
  params **bitwise identical** to the undisturbed gang.

The world=1 distributed path is asserted *bitwise* equal to the plain
single-process trainer — same step function, same stream, a one-device
mesh — so the tolerance in the cross-world oracle isolates exactly the
``psum`` reassociation of the batch-mean gradient.
"""
import json

import numpy as np
import pytest

from repro.core import (ChaosSpec, JobSpec, JobState, NodeSpec,
                        Orchestrator, PersistentVolume, Resources,
                        replay_events)
from repro.core.executor import EVENTS_REL, format_status
from repro.distributed.data import shard_rows
from repro.distributed.gang import rank_argv
from repro.distributed.trainer import allreduce_bytes_per_step

from test_campaign_exec import FakeProc, fake_spawn


# --------------------------------------------------------------------------
# Units
# --------------------------------------------------------------------------
def test_shard_rows_contiguous_partition():
    batch = {"tokens": np.arange(8 * 3).reshape(8, 3)}
    parts = [shard_rows(batch, r, 4)["tokens"] for r in range(4)]
    assert all(p.shape == (2, 3) for p in parts)
    np.testing.assert_array_equal(np.concatenate(parts),
                                  batch["tokens"])
    with pytest.raises(ValueError):
        shard_rows(batch, 0, 3)          # 8 rows not divisible by 3


def test_allreduce_bytes_analytic_model():
    gb = 1_000_000
    assert allreduce_bytes_per_step(gb, 1) == 0
    assert allreduce_bytes_per_step(gb, 2) == gb          # 2*(1/2)
    assert allreduce_bytes_per_step(gb, 4) == 1_500_000   # 2*(3/4)


def test_rank_argv_appends_dist_flags():
    base = ["python", "-m", "repro.launch", "run", "train", "--steps=3"]
    got = rank_argv(base, 1, "127.0.0.1:555")
    assert got[:len(base)] == base
    assert got[len(base):] == ["--dist_rank=1",
                               "--coordinator=127.0.0.1:555"]
    assert base[-1] == "--steps=3"       # input untouched


def test_gang_manifest_renders_indexed_job():
    job = JobSpec(name="ddp", gang=4)
    spec = job.manifest()["spec"]
    assert spec["completionMode"] == "Indexed"
    assert spec["completions"] == spec["parallelism"] == 4
    assert "completionMode" not in JobSpec(name="solo").manifest()["spec"]


def test_world_size_override_becomes_gang():
    from repro.api import RunSpec
    spec = RunSpec(kind="train", arch="stablelm-1.6b", seed=0,
                   name="ddp", overrides={"world_size": 2, "steps": 2})
    assert spec.to_job().gang == 2
    assert RunSpec(kind="train", arch="stablelm-1.6b", seed=0,
                   name="solo").to_job().gang == 1


def test_sharded_batches_cursor_round_trip():
    """Every rank advances the identical global stream; seeking the
    shared cursor replays identical local shards (world=1 mesh)."""
    from repro.configs import get_reduced
    from repro.data.tokens import SeekableTokenBatches
    from repro.distributed.context import init_distributed
    from repro.distributed.data import ShardedBatches

    ctx = init_distributed(1)
    cfg = get_reduced("stablelm-1.6b")
    inner = SeekableTokenBatches(cfg.vocab, 4, 8, seed=0)
    data = ShardedBatches(
        inner, ctx, to_named=lambda raw: {"tokens": raw[0],
                                          "labels": raw[1]},
        global_rows=4)
    _ = data.next_batch()
    mark = data.cursor()
    want = [np.asarray(data.next_batch()["tokens"]) for _ in range(3)]
    data.seek(mark)
    got = [np.asarray(data.next_batch()["tokens"]) for _ in range(3)]
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


# --------------------------------------------------------------------------
# Hermetic gang scheduling (fake spawn — no jax per job)
# --------------------------------------------------------------------------
def _gang_job(name, gang, *, retries=3, cpus=1, priority=0):
    return JobSpec(name=name, gang=gang, retries=retries,
                   priority=priority,
                   resources=Resources(gpus=0, cpus=cpus, memory_gb=1.0),
                   env={"RUN_KIND": "train"})


def test_gang_spawns_one_process_per_rank_shared_coordinator(tmp_path):
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    orch.submit(_gang_job("ddp", 2))
    seen = []

    def spawn(job, attempt, argv, env, stdout_fh, stderr_fh):
        seen.append(argv)
        return FakeProc(job, attempt, stdout_fh)

    recs = orch.run_cluster(workers=2, poll_s=0.0, telemetry=False,
                            retry_backoff_base_s=0.0, spawn=spawn)
    assert recs["ddp"].state == JobState.SUCCEEDED
    assert len(seen) == 2
    ranks = sorted(a for argv in seen for a in argv
                   if a.startswith("--dist_rank="))
    assert ranks == ["--dist_rank=0", "--dist_rank=1"]
    coords = {a for argv in seen for a in argv
              if a.startswith("--coordinator=")}
    assert len(coords) == 1              # both ranks share one address


def test_gang_rank_death_requeues_whole_gang_and_resumes(tmp_path):
    """One rank dying kills the gang (the survivor is reaped, not
    orphaned), the whole gang is requeued as preempted, and the retry
    attempt re-spawns EVERY rank with the resume overlay."""
    from repro.api import RunSpec
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    # the RunSpec path (not a raw JobSpec): to_job maps world_size to
    # gang AND fills the retry_env resume overlay for train kinds
    orch.submit_runs([RunSpec(
        kind="train", arch="stablelm-1.6b", seed=0, name="ddp",
        overrides={"steps": 4, "world_size": 2,
                   "checkpoint_dir": str(tmp_path / "ck")})])
    attempts = []

    def spawn(job, attempt, argv, env, stdout_fh, stderr_fh):
        rank = next(int(a.split("=")[1]) for a in argv
                    if a.startswith("--dist_rank="))
        attempts.append((attempt, rank, argv))
        import signal as _sig
        rc = -int(_sig.SIGKILL) if (attempt == 1 and rank == 1) else 0
        return FakeProc(job, attempt, stdout_fh, rc=rc)

    recs = orch.run_cluster(workers=2, poll_s=0.0, telemetry=False,
                            retry_backoff_base_s=0.0, spawn=spawn)
    assert recs["ddp"].state == JobState.SUCCEEDED
    assert sorted((a, r) for a, r, _ in attempts) \
        == [(1, 0), (1, 1), (2, 0), (2, 1)]
    for a, _r, argv in attempts:
        assert ("--resume=true" in argv) == (a == 2)
    events = [json.loads(ln) for ln
              in pvc.read_bytes(EVENTS_REL).decode().splitlines()]
    exits = [(e["attempt"], e["rank"], e["returncode"]) for e in events
             if e["event"] == "rank_exited"]
    assert len(exits) == 4               # every rank's exit is logged
    assert any(e["event"] == "preempted" for e in events)
    state = replay_events(events)
    assert state["consistent"], state["violations"]
    assert state["jobs"]["ddp"]["gang"] == 2
    assert state["jobs"]["ddp"]["preemptions"] == 1


def test_unschedulable_gang_fails_fast_without_spawning(tmp_path):
    """A gang that can never be placed — more ranks than worker slots,
    or per-rank requests no inventory satisfies — fails at submit
    validation, before any process starts."""
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    orch.submit(_gang_job("too-wide", 4))
    spawned = []

    def spawn(job, attempt, argv, env, stdout_fh, stderr_fh):
        spawned.append(job.name)
        return FakeProc(job, attempt, stdout_fh)

    recs = orch.run_cluster(workers=2, poll_s=0.0, telemetry=False,
                            retry_backoff_base_s=0.0, spawn=spawn)
    assert recs["too-wide"].state == JobState.FAILED
    assert "unschedulable" in recs["too-wide"].error
    assert "gang" in recs["too-wide"].error
    assert spawned == []
    events = [json.loads(ln) for ln
              in pvc.read_bytes(EVENTS_REL).decode().splitlines()]
    assert any(e["event"] == "unschedulable" and e.get("gang") == 4
               for e in events)


def test_gang_counts_against_worker_cap_in_processes(tmp_path):
    """workers=2 with a 2-rank gang plus singletons: never more than 2
    live processes, and everything completes."""
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    orch.submit(_gang_job("ddp", 2))
    for i in range(3):
        orch.submit(_gang_job(f"solo{i}", 1))
    tracker = {"active": 0, "max": 0}
    recs = orch.run_cluster(workers=2, poll_s=0.0, telemetry=False,
                            retry_backoff_base_s=0.0,
                            spawn=fake_spawn(tracker=tracker))
    assert tracker["max"] <= 2
    assert all(r.state == JobState.SUCCEEDED for r in recs.values())


def test_status_renders_gang_as_one_row_with_rank_states(tmp_path):
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    orch.submit(_gang_job("ddp", 2))
    orch.run_cluster(workers=2, poll_s=0.0, telemetry=False,
                     retry_backoff_base_s=0.0, spawn=fake_spawn())
    state = replay_events(pvc.read_bytes(EVENTS_REL).decode()
                          .splitlines())
    st = state["jobs"]["ddp"]
    assert st["gang"] == 2 and st["gang_id"] == "ddp.g1"
    assert {r["returncode"] for r in st["ranks"].values()} == {0}
    text = format_status(state)
    assert sum(ln.startswith("ddp") for ln in text.splitlines()) == 1
    assert "2[0:0 1:0]" in text


# --------------------------------------------------------------------------
# System: world=1 bitwise identity, world=2 oracle + chaos resume
# --------------------------------------------------------------------------
STEPS, CKPT_EVERY, GLOBAL_BATCH, SEQ = 6, 2, 4, 16


def _final_tree(ckpt_dir):
    from repro.checkpoint import list_checkpoints, load_checkpoint
    ckpts = list_checkpoints(ckpt_dir)
    assert ckpts, f"no published checkpoints under {ckpt_dir}"
    tree, step = load_checkpoint(ckpts[-1][1])
    return tree, int(step)


@pytest.mark.timeout(300)
def test_dist_world1_bitwise_equals_single_process(tmp_path):
    """The distributed trainer at world=1 (one-device mesh, no
    distributed runtime) IS the single-process trainer: identical loss
    scalars and bitwise-identical final checkpoints."""
    from repro.distributed.trainer import dist_train_main
    from repro.launch.train import train_main

    kw = dict(reduced=True, steps=STEPS, batch=GLOBAL_BATCH, seq=SEQ,
              seed=0, log_every=0, checkpoint_every=CKPT_EVERY,
              checkpoint_async=False)
    plain = train_main("stablelm-1.6b",
                       checkpoint_dir=str(tmp_path / "plain"), **kw)
    dist = dist_train_main("stablelm-1.6b", world_size=1,
                           checkpoint_dir=str(tmp_path / "dist"), **kw)
    assert dist["dist"]["allreduce_bytes_per_step"] == 0
    assert dist["first_loss"] == plain["first_loss"]
    assert dist["final_loss"] == plain["final_loss"]
    got, got_step = _final_tree(tmp_path / "dist")
    want, want_step = _final_tree(tmp_path / "plain")
    assert got_step == want_step == STEPS
    assert set(got) == set(want) and len(want) > 0
    for key in sorted(want):
        np.testing.assert_array_equal(got[key], want[key], err_msg=key)


def _gang_run(name, *, ckpt_dir, seed=0):
    from repro.api import RunSpec
    return RunSpec(kind="train", arch="stablelm-1.6b", seed=seed,
                   name=name,
                   overrides={"steps": STEPS, "batch": GLOBAL_BATCH,
                              "seq": SEQ, "world_size": 2,
                              "log_every": 0,
                              "checkpoint_every": CKPT_EVERY,
                              "checkpoint_dir": str(ckpt_dir)})


@pytest.mark.timeout(600)
def test_gang_world2_oracle_and_chaos_resume_bitwise(tmp_path):
    """The tentpole's end-to-end contract, in two campaign legs:

    1. a world=2 gang through the executor reproduces the world=1 loss
       trajectory at the same global batch to documented tolerance (the
       only divergence is psum reassociation of the batch mean, ~1e-6);
    2. the same gang with chaos — one rank SIGKILLed mid-run — gang-
       requeues, resumes from the shared checkpoint, and lands final
       params bitwise identical to the undisturbed gang (identical
       world partitioning, so not even reassociation differs).
    """
    from repro.distributed.trainer import dist_train_main

    ref = dist_train_main(
        "stablelm-1.6b", world_size=1, reduced=True, steps=STEPS,
        batch=GLOBAL_BATCH, seq=SEQ, seed=0, log_every=0)

    # ---- leg 1: undisturbed gang campaign -> tolerance oracle
    pvc = PersistentVolume(tmp_path / "campA")
    orch = Orchestrator(pvc)
    orch.submit_runs([_gang_run("ddp-a", ckpt_dir=tmp_path / "ckA")])
    recs = orch.run_cluster(workers=2, retry_backoff_base_s=0.0,
                            telemetry=False)
    assert recs["ddp-a"].state == JobState.SUCCEEDED
    metrics = recs["ddp-a"].result["metrics"]
    assert metrics["dist"]["world_size"] == 2
    assert metrics["dist"]["allreduce_bytes_per_step"] \
        == metrics["dist"]["grad_bytes"]       # 2*(N-1)/N at N=2
    np.testing.assert_allclose(metrics["losses"], ref["losses"],
                               rtol=5e-4, atol=5e-4)

    # ---- leg 2: chaos kills one rank; gang resume is bitwise
    pvc_b = PersistentVolume(tmp_path / "campB")
    orch_b = Orchestrator(pvc_b)
    orch_b.submit_runs([_gang_run("ddp-b", ckpt_dir=tmp_path / "ckB")])
    recs_b = orch_b.run_cluster(
        workers=2, retry_backoff_base_s=0.0, telemetry=False,
        chaos=ChaosSpec(kill_jobs=("ddp-b",), after_checkpoints=1))
    assert recs_b["ddp-b"].state == JobState.SUCCEEDED
    events = [json.loads(ln) for ln
              in pvc_b.read_bytes(EVENTS_REL).decode().splitlines()]
    kills = [e for e in events if e["event"] == "chaos_kill"]
    assert kills and all(e["rank"] == 1 for e in kills)
    state = replay_events(events)
    assert state["consistent"], state["violations"]
    st = state["jobs"]["ddp-b"]
    assert st["gang"] == 2 and st["preemptions"] >= 1
    assert recs_b["ddp-b"].result["metrics"]["resumed_from_step"] \
        is not None

    got, got_step = _final_tree(tmp_path / "ckB")
    want, want_step = _final_tree(tmp_path / "ckA")
    assert got_step == want_step == STEPS
    assert set(got) == set(want) and len(want) > 0
    for key in sorted(want):
        np.testing.assert_array_equal(got[key], want[key], err_msg=key)
