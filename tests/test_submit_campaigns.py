"""`launch.submit` campaign construction vs the paper's tables: job
counts, wall-hour totals, name uniqueness, and the RunSpec plumbing."""
import pytest

from repro.api import RunSpec
from repro.launch.submit import (DETECTION_MODELS, build_campaign,
                                 build_campaign_runs)


def test_burned_area_matches_paper_table():
    """Sect. III-B / Table V: 72 experiments x 2 architectures = 144
    models, 518 total wall-clock hours, 2 GPUs each."""
    jobs = build_campaign("burned_area")
    assert len(jobs) == 144
    assert len({j.name for j in jobs}) == 144
    assert sum(j.duration_h for j in jobs) == pytest.approx(518.0)
    assert all(j.resources.gpus == 2 for j in jobs)
    # both architectures present, 72 each
    unet = [j for j in jobs if j.labels["experiment"] == "ba-unet"]
    deeplab = [j for j in jobs if j.labels["experiment"] == "ba-deeplabv3"]
    assert len(unet) == 72 and len(deeplab) == 72


def test_detection_hours_sum_to_table_v():
    """Table V: 2,142 wall-clock hours across the 30 detection models."""
    jobs = build_campaign("detection")
    assert len(jobs) == len(DETECTION_MODELS) * 3 == 30
    assert len({j.name for j in jobs}) == 30
    assert sum(j.duration_h for j in jobs) == pytest.approx(2142.0)
    assert all(j.resources.gpus == 4 for j in jobs)


def test_deforestation_campaign():
    jobs = build_campaign("deforestation")
    assert len(jobs) == 60
    assert sum(j.duration_h for j in jobs) == pytest.approx(1380.0)


def test_all_campaigns_are_the_papers_234_models():
    jobs = []
    for name in ("burned_area", "detection", "deforestation"):
        jobs.extend(build_campaign(name))
    assert len(jobs) == 234                      # Table V bottom line
    assert len({j.name for j in jobs}) == 234    # globally unique names
    assert sum(j.duration_h for j in jobs) == pytest.approx(4040.0)


def test_campaigns_are_runspecs():
    """Campaigns produce RunSpecs directly; JobSpecs are derived, and the
    manifest env round-trips back to the same overrides."""
    runs = build_campaign_runs("burned_area")
    assert all(isinstance(r, RunSpec) for r in runs)
    assert all(r.kind == "train" for r in runs)
    sample = runs[0]
    job = sample.to_job()
    assert job.name == sample.run_name
    back = RunSpec.from_env(job.env)
    assert back.overrides == sample.overrides
    assert back.arch == sample.arch
    # grid params surfaced as overrides (lr/batch_size/init/optimizer/ds)
    assert {"lr", "batch_size", "init", "optimizer",
            "dataset"} == set(sample.overrides)
