"""Concurrent CheckpointManager writers — the regime ``run_cluster``
creates: multiple *processes* checkpointing at once into sibling run
directories (one per campaign job), and runs SIGKILLed mid-write.

* sibling writers never cross-contaminate each other's directories;
* a writer SIGKILLed mid-save leaves every *published* checkpoint
  intact (atomic tmp+rename protocol), and ``restore_latest`` falls
  back past a torn newest checkpoint to the last good one.
"""
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, list_checkpoints

SRC = str(Path(__file__).resolve().parents[1] / "src")

# Writer subprocess: saves ``steps`` checkpoints tagged with its id into
# <root>/run<tag>; with steps=0, loops forever (the SIGKILL victim).
_WRITER = r"""
import sys, time
import numpy as np
sys.path.insert(0, {src!r})
from repro.checkpoint import CheckpointManager

tag, root, steps = int(sys.argv[1]), sys.argv[2], int(sys.argv[3])
mgr = CheckpointManager(f"{{root}}/run{{tag}}", keep_last=3,
                        async_saves=False)
step = 0
while steps == 0 or step < steps:
    step += 1
    state = {{"w": np.full((64,), float(tag * 1000 + step), np.float32),
              "tag": np.array([tag], np.int32)}}
    mgr.save(state, step, extra={{"tag": tag, "step": step}})
print("done", flush=True)
"""


def _writer_proc(tag: int, root, steps: int, **popen_kw):
    return subprocess.Popen(
        [sys.executable, "-c", _WRITER.format(src=SRC), str(tag),
         str(root), str(steps)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, **popen_kw)


@pytest.mark.timeout(300)
def test_sibling_writers_do_not_cross_contaminate(tmp_path):
    """Two real processes checkpointing concurrently into sibling dirs:
    each directory holds exactly its own writer's data."""
    procs = [_writer_proc(tag, tmp_path, steps=5) for tag in (1, 2)]
    for p in procs:
        out, err = p.communicate(timeout=240)
        assert p.returncode == 0, err.decode()
    for tag in (1, 2):
        d = tmp_path / f"run{tag}"
        steps = [s for s, _ in list_checkpoints(d)]
        assert steps == [3, 4, 5]                     # keep_last rotation
        mgr = CheckpointManager(d)
        tree, step, extra = mgr.restore_latest()
        assert step == 5 and extra["tag"] == tag
        np.testing.assert_array_equal(
            tree["w"], np.full((64,), float(tag * 1000 + 5), np.float32))
        assert int(tree["tag"][0]) == tag
        # no in-flight debris, and nothing from the sibling writer
        assert not [p for p in d.iterdir() if p.name.startswith(".tmp")]
        manifests = [json.loads((p / "manifest.json").read_text())
                     for _, p in list_checkpoints(d)]
        assert all(m["metadata"]["tag"] == tag for m in manifests)


@pytest.mark.timeout(300)
def test_restore_falls_back_past_torn_checkpoint_after_sigkill(tmp_path):
    """SIGKILL a writer mid-stream: all published checkpoints stay
    valid; a torn newest directory (the shape a kill mid-write leaves
    before the rename) is skipped by restore_latest."""
    proc = _writer_proc(3, tmp_path, steps=0)         # loops forever
    d = tmp_path / "run3"
    deadline = time.time() + 120
    try:
        while time.time() < deadline:
            if len(list_checkpoints(d)) >= 3:
                break
            time.sleep(0.02)
        else:
            pytest.fail("writer produced no checkpoints in time")
    finally:
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=30)
    assert proc.returncode == -signal.SIGKILL

    published = list_checkpoints(d)
    assert len(published) >= 3
    # every published checkpoint survived the kill intact
    mgr = CheckpointManager(d)
    tree, step, extra = mgr.restore_latest()
    assert step == published[-1][0] and extra["tag"] == 3

    # now tear the newest (what a kill inside save_checkpoint's write —
    # before the publishing rename — leaves if the tmp dir got renamed
    # half-fsynced): truncated manifest, then a missing-shard variant
    newest_step = published[-1][0]
    torn = d / f"step_{newest_step + 1:08d}"
    torn.mkdir()
    (torn / "manifest.json").write_text('{"keys": {"w": {"shard"')
    mgr2 = CheckpointManager(d)
    tree2, step2, _ = mgr2.restore_latest()
    assert step2 == newest_step                       # fell back
    np.testing.assert_array_equal(tree2["w"], tree["w"])
    assert mgr2.restore_skipped
    assert f"step_{newest_step + 1:08d}" in mgr2.restore_skipped[0]

    torn2 = d / f"step_{newest_step + 2:08d}"
    torn2.mkdir()
    (torn2 / "manifest.json").write_text(json.dumps(
        {"step": newest_step + 2, "keys":
         {"w": {"shard": "shard_0000.npz", "shape": [64],
                "dtype": "float32"}}, "metadata": {}}))
    mgr3 = CheckpointManager(d)
    tree3, step3, _ = mgr3.restore_latest()
    assert step3 == newest_step
    np.testing.assert_array_equal(tree3["w"], tree["w"])
    assert len(mgr3.restore_skipped) == 2
