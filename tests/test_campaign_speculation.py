"""Hermetic speculative-execution tests: straggler detection against an
injected progress probe, first-finisher-wins for both orderings,
checkpoint-dir promotion, and the duplicate-failure no-harm property —
all over fake processes (no jax import, no real training)."""
import json
from pathlib import Path

from repro.core import (JobState, Orchestrator, PersistentVolume,
                        SpeculationSpec, replay_events)
from repro.core.executor import EVENTS_REL


class FakeProc:
    """Popen-shaped: poll() returns None ``ticks`` times, then writes a
    RunReport and exits ``rc`` (see tests/test_campaign_exec.py)."""

    def __init__(self, job, attempt, stdout_fh, *, rc=0, ticks=2):
        self.job, self.attempt = job, attempt
        self.stdout_fh = stdout_fh
        self.rc, self.ticks = rc, ticks
        self.pid = 4242

    def poll(self):
        self.ticks -= 1
        if self.ticks > 0:
            return None
        if self.rc == 0:
            report = {"kind": "train", "name": self.job.name,
                      "status": "succeeded", "metrics": {}}
            self.stdout_fh.write(json.dumps(report, indent=1).encode())
            self.stdout_fh.flush()
        return self.rc

    def send_signal(self, sig):
        self.rc, self.ticks = -sig, 1


def spec_spawn(plans):
    """plans: {(job_name, attempt_seq): {"rc":, "ticks":}}.  Every spawn
    materializes its checkpoint dir (from the rebuilt argv) with a
    ``who.txt`` marker, so dir promotion is observable."""
    started = []

    def spawn(job, attempt, argv, env, stdout_fh, stderr_fh):
        plan = plans.get((job.name, attempt), {})
        ck = next((a.split("=", 1)[1] for a in argv
                   if a.startswith("--checkpoint_dir=")), None)
        if ck:
            p = Path(ck)
            p.mkdir(parents=True, exist_ok=True)
            (p / "who.txt").write_text(f"{job.name}:{attempt}")
        started.append({"job": job.name, "attempt": attempt, "ckpt": ck})
        return FakeProc(job, attempt, stdout_fh,
                        rc=plan.get("rc", 0), ticks=plan.get("ticks", 2))
    spawn.started = started
    return spawn


def _train_run(name, seed=0, **overrides):
    from repro.api import RunSpec
    return RunSpec(kind="train", arch="stablelm-1.6b", seed=seed,
                   name=name, overrides=overrides)


# every test injects the progress probe; SPEC makes stragglers eligible
# immediately (no grace gate, single peer suffices)
SPEC = SpeculationSpec(slow_fraction=0.5, min_runtime_s=0.0, grace=None,
                       min_peers=1, max_duplicates_per_job=1)
FAST = dict(retry_backoff_base_s=0.0, telemetry=False, poll_s=0.001)


def _progress(slow_names):
    """Primary attempts of ``slow_names`` crawl; everyone else cruises."""
    def probe(run, now):
        if run.rec.spec.name in slow_names and not run.speculative:
            return 0.05
        return 1.0
    return probe


def _campaign(tmp_path, plans, *, names, ckpt=True, spec=SPEC,
              slow=("slow",), workers=4):
    pvc = PersistentVolume(tmp_path / "pvc")
    orch = Orchestrator(pvc)
    runs = []
    for i, name in enumerate(names):
        kw = {"steps": 4}
        if ckpt:
            kw["checkpoint_dir"] = str(tmp_path / f"ck_{name}")
        runs.append(_train_run(name, seed=i, **kw))
    orch.submit_runs(runs)
    spawn = spec_spawn(plans)
    recs = orch.run_cluster(workers=workers, spawn=spawn, speculate=spec,
                            progress_fn=_progress(set(slow)), **FAST)
    events = [json.loads(ln) for ln
              in pvc.read_bytes(EVENTS_REL).decode().splitlines()]
    summary = json.loads(pvc.read_bytes("results/_campaign_summary.json"))
    return pvc, recs, spawn, events, summary


def test_duplicate_wins_loser_killed_dir_promoted(tmp_path):
    """The straggler's duplicate finishes first: the primary is killed
    and logged as speculation_loss, the duplicate's checkpoint dir is
    promoted onto the declared path, and the job succeeds with its
    primary attempt count untouched."""
    plans = {("slow", 1): {"ticks": 10_000},   # the straggler crawls
             ("slow", 2): {"ticks": 3}}        # its duplicate is healthy
    pvc, recs, spawn, events, summary = _campaign(
        tmp_path, plans, names=["slow", "peer1", "peer2"])

    assert recs["slow"].state == JobState.SUCCEEDED
    assert recs["slow"].attempts == 1          # duplicates are not retries
    dup_started = [s for s in spawn.started
                   if s["job"] == "slow" and s["attempt"] == 2]
    assert len(dup_started) == 1
    assert dup_started[0]["ckpt"].endswith(".spec2")

    by_kind = {}
    for e in events:
        by_kind.setdefault(e["event"], []).append(e)
    assert any(e.get("speculative") for e in by_kind["admitted"])
    assert len(by_kind["speculation_win"]) == 1
    assert len(by_kind["speculation_loss"]) == 1
    assert by_kind["speculation_loss"][0]["wall_s"] >= 0
    promo = by_kind["speculation_promote"][0]
    assert promo["error"] is None

    # the declared dir now holds the winner's artifacts; the loser's are
    # parked, not destroyed
    orig = tmp_path / "ck_slow"
    assert (orig / "who.txt").read_text() == "slow:2"
    assert (orig.parent / "ck_slow.loser" / "who.txt").read_text() \
        == "slow:1"

    assert summary["speculation"] == {
        "launches": 1, "wins": 1, "losses": 1,
        "loss_wall_s": summary["speculation"]["loss_wall_s"]}
    assert summary["speculation"]["loss_wall_s"] > 0

    state = replay_events(events)
    st = state["jobs"]["slow"]
    assert st["speculative_launches"] == 1
    assert st["speculation_losses"] == 1
    assert st["promoted"] is True
    assert state["consistent"], state["violations"]


def test_primary_wins_duplicate_is_the_loser(tmp_path):
    """The slow-but-alive primary beats its duplicate: the duplicate is
    killed as speculation_loss and the declared checkpoint dir is left
    exactly as the primary wrote it (bitwise no-op)."""
    plans = {("slowpoke", 1): {"ticks": 8},        # finishes on its own
             ("slowpoke", 2): {"ticks": 10_000}}   # duplicate never will
    pvc, recs, spawn, events, summary = _campaign(
        tmp_path, plans, names=["slowpoke", "peer1", "peer2"],
        slow=("slowpoke",))

    assert recs["slowpoke"].state == JobState.SUCCEEDED
    kinds = [e["event"] for e in events]
    assert "speculation_win" not in kinds      # the primary won its race
    assert "speculation_promote" not in kinds
    assert sum(1 for e in events
               if e["event"] == "speculation_loss") == 1
    assert (tmp_path / "ck_slowpoke" / "who.txt").read_text() \
        == "slowpoke:1"
    assert not (tmp_path / "ck_slowpoke.loser").exists()
    assert summary["speculation"]["launches"] == 1
    assert summary["speculation"]["wins"] == 0
    state = replay_events(events)
    assert state["jobs"]["slowpoke"]["promoted"] is False
    assert state["consistent"], state["violations"]


def test_failed_duplicate_never_harms_the_job(tmp_path):
    """A duplicate that crashes on its own is just a speculation loss:
    no retry consumed, no requeue, the primary carries on to success."""
    plans = {("slow", 1): {"ticks": 12},
             ("slow", 2): {"rc": 1, "ticks": 2}}   # duplicate crashes
    pvc, recs, spawn, events, summary = _campaign(
        tmp_path, plans, names=["slow", "peer1", "peer2"])

    assert recs["slow"].state == JobState.SUCCEEDED
    assert recs["slow"].attempts == 1
    losses = [e for e in events if e["event"] == "speculation_loss"]
    assert len(losses) == 1 and losses[0]["reason"] == "failed"
    assert not any(e["event"] == "attempt_failed" for e in events)
    result = json.loads(pvc.read_bytes("results/slow.json"))
    outcomes = sorted(h["outcome"] for h in result["attempt_history"])
    assert outcomes == ["speculation_loss", "succeeded"]
    state = replay_events(events)
    assert state["consistent"], state["violations"]


def test_failed_primary_hands_off_to_live_duplicate(tmp_path):
    """The primary dies while its duplicate is racing: the duplicate is
    promoted to primary (no requeue — the race already restarted the
    work) and its dir is promoted on success."""
    plans = {("slow", 1): {"rc": 1, "ticks": 6},   # primary will crash
             ("slow", 2): {"ticks": 20}}           # duplicate outlives it
    pvc, recs, spawn, events, summary = _campaign(
        tmp_path, plans, names=["slow", "peer1", "peer2"])

    assert recs["slow"].state == JobState.SUCCEEDED
    fails = [e for e in events if e["event"] == "attempt_failed"]
    assert len(fails) == 1 and fails[0]["duplicate_continues"] is True
    assert fails[0]["requeued"] is False
    # only two attempts ever spawned: the duplicate was the retry
    assert [s["attempt"] for s in spawn.started
            if s["job"] == "slow"] == [1, 2]
    assert (tmp_path / "ck_slow" / "who.txt").read_text() == "slow:2"
    state = replay_events(events)
    assert state["jobs"]["slow"]["state"] == "Succeeded"
    assert state["consistent"], state["violations"]


def test_speculation_opt_out_and_capacity_respect(tmp_path):
    """A job with speculation=False never gets duplicates, and with no
    spare worker slot nothing speculates at all."""
    pvc = PersistentVolume(tmp_path / "pvc")
    orch = Orchestrator(pvc)
    runs = [_train_run("slow", steps=4), _train_run("peer", seed=1,
                                                    steps=4)]
    orch.submit_runs(runs)
    orch.records["slow"].spec.speculation = False
    spawn = spec_spawn({("slow", 1): {"ticks": 12}})
    orch.run_cluster(workers=4, spawn=spawn, speculate=SPEC,
                     progress_fn=_progress({"slow"}), **FAST)
    assert [s["attempt"] for s in spawn.started
            if s["job"] == "slow"] == [1]

    # saturated workers: an eligible straggler still gets no duplicate
    pvc2 = PersistentVolume(tmp_path / "pvc2")
    orch2 = Orchestrator(pvc2)
    orch2.submit_runs([_train_run("slow", steps=4),
                       _train_run("peer", seed=1, steps=4)])
    spawn2 = spec_spawn({("slow", 1): {"ticks": 12}})
    orch2.run_cluster(workers=2, spawn=spawn2, speculate=SPEC,
                      progress_fn=_progress({"slow"}), **FAST)
    assert all(not s["ckpt"] or ".spec" not in s["ckpt"]
               for s in spawn2.started)
    summary2 = json.loads(
        pvc2.read_bytes("results/_campaign_summary.json"))
    assert summary2["speculation"]["launches"] == 0
