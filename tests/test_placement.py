"""Placement policies, gang co-location, and the utilization ledger.

Covers the pluggable :mod:`repro.core.placement` surface end-to-end:
policy registry + selection, the never-oversubscribe/conservation
property for EVERY policy (pool and sim share the policies), a
deterministic fixture where ``pack`` beats ``best_fit``, gang
co-location using no more nodes than the rank-at-a-time scatter
baseline, and the event-log-derived busy/goodput utilization ledger —
plus regression tests for the bugs this work exposed (add_node name
collision after remove_node, sim priority ordering, busy-vs-goodput
reconciliation under preemption).
"""
import json

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (JobSpec, JobState, NodeSpec, Orchestrator,
                        PersistentVolume, PLACEMENT_POLICIES, Resources,
                        get_placement_policy, replay_events)
from repro.core.executor import EVENTS_REL, ResourcePool
from repro.core.placement import BestFit, PlacementPolicy
from repro.core.scheduler import ClusterSim

from test_campaign_exec import FAST, _train_run, fake_spawn


# --------------------------------------------------------------------------
# Registry / selection
# --------------------------------------------------------------------------
def test_policy_registry_and_selection():
    assert set(PLACEMENT_POLICIES) == {"best_fit", "worst_fit", "pack"}
    assert get_placement_policy(None).name == "best_fit"
    for name in PLACEMENT_POLICIES:
        assert get_placement_policy(name).name == name
    inst = BestFit()
    assert get_placement_policy(inst) is inst
    with pytest.raises(ValueError, match="worst_fit"):
        get_placement_policy("bogus")


def test_pool_and_sim_accept_same_names():
    inv = [NodeSpec("n", gpus=1, gpu_memory_gb=11.0, cpus=4,
                    memory_gb=16.0)]
    for name in PLACEMENT_POLICIES:
        assert ResourcePool(inv, policy=name).policy.name == name
        assert ClusterSim(inv, placement=name).placement.name == name
    with pytest.raises(ValueError):
        ClusterSim(inv, placement="nope")


# --------------------------------------------------------------------------
# Every policy preserves the pool invariants
# --------------------------------------------------------------------------
def _resources(seed: int) -> Resources:
    return Resources(gpus=seed % 3, cpus=1 + (seed // 3) % 4,
                     memory_gb=float(4 + (seed // 12) % 3 * 10))


def _inventory(seed: int):
    return [NodeSpec("small", gpus=2, gpu_memory_gb=11.0, cpus=4,
                     memory_gb=24.0, count=1 + seed % 2),
            NodeSpec("big", gpus=4, gpu_memory_gb=48.0, cpus=8,
                     memory_gb=64.0, count=1 + (seed // 2) % 2)]


def _check_conservation(pool: ResourcePool):
    for node in pool.nodes:
        assert 0 <= node.gpus_free <= node.spec.gpus
        assert 0 <= node.cpus_free <= node.spec.cpus
        assert -1e-9 <= node.mem_free <= node.spec.memory_gb + 1e-9


@settings(max_examples=30, deadline=None)
@given(seeds=st.lists(st.integers(0, 2**31 - 1), min_size=1,
                      max_size=14),
       inv_seed=st.integers(0, 3))
def test_every_policy_never_oversubscribes(seeds, inv_seed):
    """Admit/release churn under each policy: per-node free capacity
    stays within [0, spec] (admit itself raises on oversubscription —
    this asserts it never fires) and releases restore exactly what was
    taken."""
    for name in sorted(PLACEMENT_POLICIES):
        pool = ResourcePool(_inventory(inv_seed), policy=name)
        held = []
        for s in seeds:
            res = _resources(s)
            node = pool.admit(res)
            if node is not None:
                held.append((node, res))
            _check_conservation(pool)
            if s % 3 == 0 and held:
                nd, r = held.pop(s % len(held))
                pool.release(nd, r)
                _check_conservation(pool)
        for nd, r in held:
            pool.release(nd, r)
        _check_conservation(pool)
        assert all(n.gpus_free == n.spec.gpus
                   and n.cpus_free == n.spec.cpus
                   and abs(n.mem_free - n.spec.memory_gb) < 1e-9
                   for n in pool.nodes)


@settings(max_examples=25, deadline=None)
@given(seeds=st.lists(st.integers(0, 2**31 - 1), min_size=1,
                      max_size=10),
       gang=st.integers(2, 5), inv_seed=st.integers(0, 3))
def test_every_policy_gang_invariants(seeds, gang, inv_seed):
    """Gang admission under each policy: all-or-nothing, never
    oversubscribed, co-location uses <= nodes of the rank-at-a-time
    scatter baseline, and admits exactly when scatter would (identical
    ranks: the two are feasibility-equivalent)."""
    for name in sorted(PLACEMENT_POLICIES):
        pool = ResourcePool(_inventory(inv_seed), policy=name)
        for s in seeds:
            res = _resources(s)
            # scatter baseline on a clone: one rank at a time
            trial = pool.clone()
            scatter = []
            for _ in range(gang):
                nd = trial.admit(res)
                if nd is None:
                    break
                scatter.append(nd)
            before = {n.name: (n.gpus_free, n.cpus_free, n.mem_free)
                      for n in pool.nodes}
            placements = pool.admit_gang(res, gang)
            if placements is None:
                # atomic failure: nothing held, and scatter couldn't
                # place the full gang either
                assert len(scatter) < gang
                assert before == {n.name: (n.gpus_free, n.cpus_free,
                                           n.mem_free)
                                  for n in pool.nodes}
                continue
            assert len(scatter) == gang
            assert len(placements) == gang
            assert len(set(placements)) <= len(set(scatter))
            _check_conservation(pool)
            for nd in placements:
                pool.release(nd, res)
            assert before == {n.name: (n.gpus_free, n.cpus_free,
                                       n.mem_free)
                              for n in pool.nodes}


def test_gang_colocates_on_one_node_where_scatter_spreads():
    """2 nodes x 8 cpus, gang of 4 x 2 cpus: worst_fit scatter
    alternates nodes (it always picks the emptiest), while admit_gang
    packs all ranks onto a single node — the NVLink-vs-network
    distinction the topology cost models."""
    inv = [NodeSpec("a", gpus=0, gpu_memory_gb=0.0, cpus=8,
                    memory_gb=32.0),
           NodeSpec("b", gpus=0, gpu_memory_gb=0.0, cpus=8,
                    memory_gb=32.0)]
    res = Resources(gpus=0, cpus=2, memory_gb=1.0)
    pool = ResourcePool(inv, policy="worst_fit")
    scatter_pool = pool.clone()
    scatter = [scatter_pool.admit(res) for _ in range(4)]
    assert len(set(scatter)) == 2          # the old rank-at-a-time spread
    placements = pool.admit_gang(res, 4)
    assert placements is not None and len(placements) == 4
    assert len(set(placements)) == 1


def test_gang_atomic_rollback_on_partial_fit():
    inv = [NodeSpec("only", gpus=0, gpu_memory_gb=0.0, cpus=8,
                    memory_gb=32.0)]
    pool = ResourcePool(inv, policy="pack")
    res = Resources(gpus=0, cpus=2, memory_gb=1.0)
    assert pool.admit_gang(res, 5) is None       # 5 ranks x 2 > 8 cpus
    node = pool.nodes[0]
    assert (node.gpus_free, node.cpus_free, node.mem_free) \
        == (0, 8, 32.0)


# --------------------------------------------------------------------------
# pack beats best_fit on a fragmentation-prone job set
# --------------------------------------------------------------------------
def test_pack_beats_best_fit_deterministic():
    """Two equal-VRAM nodes with unequal CPUs.  best_fit scores only
    the VRAM class, so the 4-cpu job lands on the 8-cpu node (inventory
    tie-break) and strands the 8-cpu job for a second wave; pack scores
    the actual leftover and steers the small job to the small node,
    keeping the big node whole — one wave, half the makespan."""
    inv = [NodeSpec("bigcpu", gpus=0, gpu_memory_gb=11.0, cpus=8,
                    memory_gb=64.0),
           NodeSpec("smallcpu", gpus=0, gpu_memory_gb=11.0, cpus=4,
                    memory_gb=64.0)]
    jobs = [JobSpec(name="j-small", duration_h=1.0,
                    resources=Resources(gpus=0, cpus=4, memory_gb=1.0)),
            JobSpec(name="j-big", duration_h=1.0,
                    resources=Resources(gpus=0, cpus=8, memory_gb=1.0))]
    best = ClusterSim(inv, placement="best_fit").run(jobs)
    pack = ClusterSim(inv, placement="pack").run(jobs)
    assert best.makespan_h == pytest.approx(2.0)
    assert pack.makespan_h == pytest.approx(1.0)


# --------------------------------------------------------------------------
# Satellite: add_node name collision after remove_node
# --------------------------------------------------------------------------
def test_add_remove_add_never_collides():
    """Names once came from len(self.nodes): grow -> shrink -> grow
    regenerated an existing name and raised mid-campaign.  The
    monotonic counter never rewinds."""
    inv = [NodeSpec("w", gpus=0, gpu_memory_gb=0.0, cpus=1,
                    memory_gb=1.0, count=2)]          # w-000, w-001
    pool = ResourcePool(inv)
    spec = NodeSpec("w", gpus=0, gpu_memory_gb=0.0, cpus=1,
                    memory_gb=1.0)
    n2 = pool.add_node(spec)                          # w-002
    pool.drain("w-001")
    pool.remove_node("w-001")
    n3 = pool.add_node(spec)                          # must NOT be w-002
    assert n3 != n2
    assert len({n.name for n in pool.nodes}) == len(pool.nodes)
    # interleave harder: the counter survives removing its own products
    pool.drain(n3)
    pool.remove_node(n3)
    n4 = pool.add_node(spec)
    assert n4 not in {n2, n3, "w-000", "w-001"}
    # clones carry the counter: a cloned pool can't re-mint live names
    dup = pool.clone()
    assert dup.add_node(spec) not in {n.name for n in pool.nodes}


# --------------------------------------------------------------------------
# Satellite: sim priority ordering mirrors the executor
# --------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(prios=st.lists(st.integers(-2, 2), min_size=2, max_size=6))
def test_sim_schedules_fifo_within_priority(prios):
    """One 1-cpu node runs the jobs strictly serially: start order must
    be (-priority, submission index) — the executor's admission order —
    not raw submission order."""
    inv = [NodeSpec("one", gpus=0, gpu_memory_gb=0.0, cpus=1,
                    memory_gb=8.0)]
    jobs = [JobSpec(name=f"p{i}", priority=p, duration_h=1.0,
                    resources=Resources(gpus=0, cpus=1, memory_gb=1.0))
            for i, p in enumerate(prios)]
    res = ClusterSim(inv, placement="best_fit").run(jobs)
    expected = [f"p{i}" for i in sorted(range(len(prios)),
                                        key=lambda i: (-prios[i], i))]
    started = sorted(res.records, key=lambda r: r.start_time)
    assert [r.spec.name for r in started] == expected


# --------------------------------------------------------------------------
# Satellite: busy vs goodput reconcile under preemption
# --------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       ckpt=st.sampled_from([0.0, 0.25]),
       n_jobs=st.integers(2, 8))
def test_sim_busy_goodput_reconcile(seed, ckpt, n_jobs):
    """sum(per_node_busy_h) == total_gpu_hours + lost_gpu_hours and
    sum(per_node_goodput_h) == total_gpu_hours, exactly — the
    accounting bug was busy silently including lost hours while
    gpu_utilization counted only useful ones."""
    inv = [NodeSpec("g", gpus=2, gpu_memory_gb=11.0, cpus=8,
                    memory_gb=32.0, count=2)]
    jobs = [JobSpec(name=f"j{i}", duration_h=1.0 + (i % 3) * 0.5,
                    resources=Resources(gpus=1, cpus=1, memory_gb=2.0))
            for i in range(n_jobs)]
    sim = ClusterSim(inv, seed=seed, preemption_rate=0.5,
                     checkpoint_every_h=ckpt)
    res = sim.run(jobs)
    assert sum(res.per_node_busy_h.values()) == pytest.approx(
        res.total_gpu_hours + res.lost_gpu_hours)
    assert sum(res.per_node_goodput_h.values()) == pytest.approx(
        res.total_gpu_hours)
    for name, busy in res.per_node_busy_h.items():
        assert busy + 1e-9 >= res.per_node_goodput_h.get(name, 0.0)
    assert res.gpu_utilization == pytest.approx(res.goodput_utilization)
    assert res.busy_utilization + 1e-9 >= res.goodput_utilization
    if res.preemptions and res.lost_gpu_hours:
        assert res.busy_utilization > res.goodput_utilization


def test_sim_cpu_only_inventory_no_division_error():
    inv = [NodeSpec("cpu", gpus=0, gpu_memory_gb=0.0, cpus=2,
                    memory_gb=8.0)]
    jobs = [JobSpec(name="c", duration_h=1.0,
                    resources=Resources(gpus=0, cpus=1, memory_gb=1.0))]
    res = ClusterSim(inv).run(jobs)
    assert res.gpu_utilization == 0.0
    assert res.busy_utilization == 0.0


# --------------------------------------------------------------------------
# The utilization ledger: handcrafted log, exact numbers
# --------------------------------------------------------------------------
def _ev(event, t, **kw):
    return {"event": event, "t": t, **kw}


def test_ledger_handcrafted_log_exact_auc():
    """A two-attempt job on an elastic inventory: attempt 1 (lost) on
    n0, node n1 added mid-window, attempt 2 (succeeded) on n1, n0
    removed before the end.  Every area-under-curve number is checked
    by hand."""
    res = {"gpus": 1, "cpus": 2, "memory_gb": 2.0}
    lines = [
        _ev("campaign_start", 0.0, workers=2,
            inventory=[{"name": "n0", "gpus": 2, "cpus": 4,
                        "memory_gb": 8.0}]),
        _ev("submitted", 0.0, job="jobA", resources=res),
        _ev("admitted", 10.0, job="jobA", attempt=1, node="n0",
            resources=res),
        _ev("node_added", 20.0, node="n1", gpus=2, cpus=4,
            memory_gb=8.0),
        _ev("exited", 30.0, job="jobA", attempt=1, returncode=-9),
        _ev("preempted", 30.0, job="jobA", attempt=1),
        _ev("admitted", 40.0, job="jobA", attempt=2, node="n1",
            resources=res),
        _ev("node_removed", 45.0, node="n0"),
        _ev("exited", 50.0, job="jobA", attempt=2, returncode=0),
        _ev("succeeded", 50.0, job="jobA", attempt=2),
    ]
    state = replay_events(lines)
    assert state["consistent"], state["violations"]
    util = state["utilization"]
    n0, n1 = util["nodes"]["n0"], util["nodes"]["n1"]
    # n0: available 0..45 at 2 gpus; busy 10..30 at 1 gpu, none goodput
    assert n0["available_gpu_s"] == pytest.approx(90.0)
    assert n0["busy_gpu_s"] == pytest.approx(20.0)
    assert n0["goodput_gpu_s"] == pytest.approx(0.0)
    assert n0["busy_gpu_util"] == pytest.approx(20.0 / 90.0, abs=1e-4)
    # n1: available 20..50; busy 40..50, all goodput (attempt 2 won)
    assert n1["available_gpu_s"] == pytest.approx(60.0)
    assert n1["busy_gpu_s"] == pytest.approx(10.0)
    assert n1["goodput_gpu_s"] == pytest.approx(10.0)
    assert n1["goodput_gpu_util"] == pytest.approx(10.0 / 60.0, abs=1e-4)
    # cpu axis accrues with the same windows at the cpu request
    assert n0["busy_cpu_s"] == pytest.approx(40.0)
    assert n1["goodput_cpu_s"] == pytest.approx(20.0)
    cl = util["cluster"]
    assert cl["available_gpu_s"] == pytest.approx(150.0)
    assert cl["busy_gpu_s"] == pytest.approx(30.0)
    assert cl["goodput_gpu_s"] == pytest.approx(10.0)
    assert cl["busy_gpu_util"] == pytest.approx(30.0 / 150.0, abs=1e-4)
    assert cl["goodput_gpu_util"] == pytest.approx(10.0 / 150.0,
                                                   abs=1e-4)
    # recomputing from the same lines is bit-identical (the acceptance
    # criterion behind `--resume-campaign` replay equality)
    assert replay_events(lines)["utilization"] == util
    # and the ledger folds incrementally like every other replay field
    half = replay_events(lines[:5])
    folded = replay_events(lines[5:], state=half)
    assert folded["utilization"] == util


def test_ledger_open_intervals_close_at_newest_event():
    """A still-running attempt contributes busy seconds up to the
    newest event time without mutating the fold state (a later fold
    continues from the same accumulators)."""
    res = {"gpus": 1, "cpus": 1, "memory_gb": 1.0}
    lines = [
        _ev("campaign_start", 0.0, workers=1,
            inventory=[{"name": "n0", "gpus": 1, "cpus": 1,
                        "memory_gb": 4.0}]),
        _ev("submitted", 0.0, job="live", resources=res),
        _ev("admitted", 5.0, job="live", attempt=1, node="n0",
            resources=res),
        _ev("heartbeat", 25.0),
    ]
    state = replay_events(lines)
    row = state["utilization"]["nodes"]["n0"]
    assert row["available_gpu_s"] == pytest.approx(25.0)
    assert row["busy_gpu_s"] == pytest.approx(20.0)   # 5..25 still open
    assert row["goodput_gpu_s"] == pytest.approx(0.0)
    # the open interval was closed virtually: continuing the fold to
    # the real exit accrues from the admission stamp, not the horizon
    done = replay_events(
        [_ev("exited", 45.0, job="live", attempt=1, returncode=0),
         _ev("succeeded", 45.0, job="live", attempt=1)], state=state)
    row = done["utilization"]["nodes"]["n0"]
    assert row["busy_gpu_s"] == pytest.approx(40.0)
    assert row["goodput_gpu_s"] == pytest.approx(40.0)


# --------------------------------------------------------------------------
# End-to-end: executor summary == status replay, policy name threaded
# --------------------------------------------------------------------------
def test_campaign_summary_utilization_matches_status_replay(tmp_path):
    """The summary's ledger is derived solely from event-log replay, so
    `campaign status --json` over the same log reproduces it exactly;
    the chosen placement policy is stamped on campaign_start and in the
    summary."""
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    orch.submit_runs([_train_run(f"r{i}", steps=2) for i in range(3)])
    orch.run_cluster(workers=2, spawn=fake_spawn(), poll_s=0.001,
                     placement="pack", **FAST)
    summary = json.loads(pvc.read_bytes("results/_campaign_summary.json"))
    assert summary["placement"] == "pack"
    lines = pvc.read_bytes(EVENTS_REL).decode().splitlines()
    state = replay_events(lines)
    assert state["consistent"], state["violations"]
    # the status --json schema: utilization with per-node + cluster AUC
    assert set(state["utilization"]) == {"nodes", "cluster"}
    for row in state["utilization"]["nodes"].values():
        assert {"available_gpu_s", "busy_gpu_s", "goodput_gpu_s",
                "busy_gpu_util", "goodput_gpu_util",
                "available_cpu_s", "busy_cpu_s", "goodput_cpu_s",
                "busy_cpu_util", "goodput_cpu_util"} <= set(row)
    assert summary["utilization"] == state["utilization"]
    # the whole state survives the CLI's json.dumps path
    json.dumps(state, sort_keys=True, default=str)
    start = json.loads(lines[0])
    assert start["event"] == "campaign_start"
    assert start["placement"] == "pack"
    # all work succeeded: every busy second is a goodput second
    cl = state["utilization"]["cluster"]
    assert cl["busy_cpu_s"] == pytest.approx(cl["goodput_cpu_s"])
