"""Example-application models (paper baselines): U-Net family +
ChangeFormer — shapes, grads, metric correctness, and a short real
training run on the synthetic burned-area data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.chipping import make_chips
from repro.data.loader import ChipLoader
from repro.data.normalize import percentile_stretch
from repro.data.rasters import synth_change_pair, synth_raster
from repro.models.changeformer import (changeformer_apply, changeformer_init,
                                       changeformer_loss)
from repro.models.segmentation import (SEG_MODELS, seg_apply, seg_init,
                                       seg_loss, seg_metrics)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", sorted(SEG_MODELS))
def test_seg_model_shapes_and_grads(name):
    p = seg_init(name, KEY, width=8)
    x = jax.random.normal(KEY, (2, 64, 64, 3))
    m = (jax.random.uniform(KEY, (2, 64, 64)) < 0.3).astype(jnp.int32)
    logits = seg_apply(name, p, x)
    assert logits.shape == (2, 64, 64, 2)
    loss, grads = jax.value_and_grad(lambda p: seg_loss(name, p, x, m))(p)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gn > 0


def test_seg_metrics_exact():
    logits = jnp.zeros((1, 2, 2, 2))
    logits = logits.at[..., 1].set(
        jnp.array([[[5.0, -5.0], [5.0, -5.0]]]))  # pred = [[1,0],[1,0]]
    masks = jnp.array([[[1, 0], [0, 1]]])
    m = seg_metrics(logits, masks)
    assert float(m["precision"]) == pytest.approx(0.5)
    assert float(m["recall"]) == pytest.approx(0.5)
    assert float(m["iou"]) == pytest.approx(1 / 3)
    assert float(m["accuracy"]) == pytest.approx(0.5)


def test_unet_learns_synthetic_burned_area():
    """Few steps of real training on the synthetic pipeline beats the
    initialization loss clearly."""
    scene = synth_raster("train-scene", 256, 256, seed=1)
    img = percentile_stretch(scene.raster)[..., :3]
    chips = make_chips(img, scene.mask, "s", chip=64, overlap=0.5,
                       min_frac=0.05)
    assert len(chips) >= 4
    loader = ChipLoader(chips, batch_size=4, seed=0, drop_last=False)
    params = seg_init("unet", KEY, width=8)

    @jax.jit
    def step(p, x, m):
        l, g = jax.value_and_grad(lambda p: seg_loss("unet", p, x, m))(p)
        p = jax.tree.map(lambda w, gw: w - 0.05 * gw, p, g)
        return p, l

    losses = []
    for epoch in range(8):
        for x, m in loader.epoch():
            params, l = step(params, jnp.asarray(x), jnp.asarray(m))
            losses.append(float(l))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])


def test_changeformer_on_synthetic_pair():
    a, b, m = synth_change_pair("p", 64, 64, bands=3, seed=0)
    a = jnp.asarray(percentile_stretch(a))[None]
    b = jnp.asarray(percentile_stretch(b))[None]
    m = jnp.asarray(m, jnp.int32)[None]
    p = changeformer_init(KEY, in_ch=3)
    logits = changeformer_apply(p, a, b)
    assert logits.shape == (1, 64, 64, 2)
    loss, grads = jax.value_and_grad(
        lambda p: changeformer_loss(p, a, b, m))(p)
    assert bool(jnp.isfinite(loss))
