"""End-to-end behaviour tests for the paper's system: the full
orchestrated flow — grid -> manifests -> scheduled jobs -> real (tiny) JAX
training payloads -> artifacts in S3 -> cluster-accounting vs the paper's
published totals."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ClusterSim, ExperimentGrid, JobSpec, JobState,
                        Orchestrator, PersistentVolume, Resources, S3Store)
from repro.core.scheduler import NAUTILUS_INVENTORY


def _tiny_train_payload(lr="0.01", steps="30", seed="0", **kw):
    """A real JAX training job (tiny quadratic fit) — the containerized
    payload stand-in used by the orchestration end-to-end test."""
    import jax
    import jax.numpy as jnp
    key = jax.random.PRNGKey(int(seed))
    target = jax.random.normal(key, (8,))
    w = jnp.zeros(8)
    lr_f = float(lr)

    def loss(w):
        return jnp.sum((w - target) ** 2)

    for _ in range(int(steps)):
        w = w - lr_f * jax.grad(loss)(w)
    return {"final_loss": float(loss(w))}


def test_full_orchestrated_grid(tmp_path):
    """Grid -> submit -> manifests -> run -> results in S3; best config
    identified from collected results (the paper's hyperparameter-search
    workflow at miniature scale)."""
    pvc = PersistentVolume(tmp_path)
    s3 = S3Store(tmp_path)
    orch = Orchestrator(pvc, s3)
    grid = ExperimentGrid("fit", {"lr": [0.001, 0.03, 0.3],
                                  "seed": [0, 1]})
    specs = grid.expand()
    assert len(specs) == 6
    for spec in specs:
        pvc.stage_bytes(f"configs/{spec.name}.json",
                        spec.config_json().encode())
        orch.submit(JobSpec(
            name=spec.name, payload=_tiny_train_payload,
            env={k: str(v) for k, v in spec.params.items()},
            resources=Resources(gpus=2, cpus=4, memory_gb=24),
            duration_h=3.6, labels={"experiment": "fit"}))
    # paper flow: all configs + manifests generated before any submission
    assert len(pvc.listdir("configs")) == 6
    assert len(pvc.listdir("manifests")) == 6

    orch.run_local()
    assert all(r.state == JobState.SUCCEEDED for r in orch.records.values())

    # pick best config from the collected results
    results = {}
    for key in s3.list("results/"):
        rec = json.loads(s3.get_bytes(key))
        results[key] = rec["result"]["final_loss"]
    best = min(results, key=results.get)
    assert "lr0p3" in best or "lr0p03" in best  # higher lr fits quadratic

    # cluster accounting on the Nautilus inventory
    sim = orch.simulate()
    assert sim.makespan_h == pytest.approx(3.6)      # fully parallel
    assert sim.total_gpu_hours == pytest.approx(6 * 3.6 * 2)


def test_paper_table_v_accounting():
    """Reproduce Table V's bottom line: 234 models / 4,040 wall-clock
    hours run in parallel ~ 5.5+ months serialized on one server."""
    rows = [  # (models, total wall h, gpus per job) per application
        ("transformers", 30, 2142.0, 4),
        ("burned_area", 144, 518.0, 2),
        ("deforestation", 60, 1380.0, 1),
    ]
    jobs = []
    for app, n, total_h, gpus in rows:
        per = total_h / n
        for i in range(n):
            jobs.append(JobSpec(
                name=f"{app}-{i}", duration_h=per,
                resources=Resources(gpus=gpus, cpus=4, memory_gb=24),
                labels={"experiment": app}))
    assert len(jobs) == 234
    total_wall = sum(j.duration_h for j in jobs)
    assert total_wall == pytest.approx(4040.0)

    res = ClusterSim(NAUTILUS_INVENTORY).run(jobs)
    assert all(r.state == JobState.SUCCEEDED for r in res.records)
    # cluster-parallel makespan is bounded by the longest job class
    assert res.makespan_h < 100.0
    # the paper's serial-equivalent claim: single 1-job-at-a-time server
    # takes the full 4,040 h ~ 5.6 months
    months_serial = total_wall / (24 * 30)
    assert months_serial > 5.5
    assert res.speedup_vs_serial() > 40


def test_dryrun_artifacts_complete():
    """The committed dry-run sweep must cover every (arch x shape x mesh)
    cell: 76 compiled + 4 structural skips (encoder-only decode)."""
    import pathlib
    d = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run artifacts not generated yet")
    recs = [json.loads(p.read_text()) for p in sorted(d.glob("*.json"))]
    cells = {(r["arch"], r["shape"], r["mesh"]) for r in recs}
    from repro.configs import list_archs
    from repro.launch.mesh import INPUT_SHAPES
    missing = []
    for arch in list_archs():
        for shape in INPUT_SHAPES:
            for mesh in ("16x16", "2x16x16"):
                if (arch, shape, mesh) not in cells:
                    missing.append((arch, shape, mesh))
    # skipped cells are recorded as json too (status == skipped)
    assert not missing, missing[:5]
    bad = [r for r in recs if r["status"] == "error"]
    assert not bad, [(r["arch"], r["shape"]) for r in bad]
