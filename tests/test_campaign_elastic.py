"""Elasticity + preemption classes (hermetic: fake subprocesses,
injected clock — no jax, no real signals).

* priority eviction: a high-priority head evicts the lowest-priority
  running attempt (checkpoint + requeue) and the eviction consumes no
  retry budget and triggers no backoff;
* graceful escalation: SIGTERM first, SIGKILL only after the grace
  window (a victim that ignores SIGTERM still dies);
* elastic inventory via the watched nodes.json control file: grow adds
  admittable capacity mid-campaign, shrink drains (no new admissions,
  residents evicted with grace, node removed once empty) and the
  replayed log shows no oversubscription at any point;
* elastic gangs: a requeued gang that no longer fits shrinks its world
  to the largest admissible size >= gang_min and the restart argv
  carries the shrunk world_size.
"""
import json
import signal

from repro.core import (JobState, NodeSpec, Orchestrator,
                        PersistentVolume, replay_events)
from repro.core.executor import EVENTS_REL, format_status

from test_campaign_exec import FAST, FakeProc, _TickClock, _train_run


def _events(pvc):
    return [json.loads(ln) for ln
            in pvc.read_bytes(EVENTS_REL).decode().splitlines()]


def _spawn_ticks(ticks_plan=None, plan=None, tracker=None, on_spawn=None,
                 proc_cls=FakeProc):
    """fake_spawn with per-(job, attempt) tick counts: ticks_plan maps
    job name -> [ticks_attempt1, ticks_attempt2, ...] (default 2)."""
    def spawn(job, attempt, argv, env, stdout_fh, stderr_fh):
        rcs = (plan or {}).get(job.name, [])
        rc = rcs[attempt - 1] if attempt <= len(rcs) else 0
        tks = (ticks_plan or {}).get(job.name, [])
        ticks = tks[attempt - 1] if attempt <= len(tks) else 2
        if on_spawn is not None:
            on_spawn(job, attempt, argv)
        return proc_cls(job, attempt, stdout_fh, rc=rc, ticks=ticks,
                        tracker=tracker)
    return spawn


def _write_nodes(path, specs):
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps({"nodes": specs}))


# sized for exactly ONE default train request (gpus=1, cpus=4, 24GB)
ONE_JOB_NODE = {"name": "w", "gpus": 1, "gpu_memory_gb": 80,
                "cpus": 4, "memory_gb": 24}


# --------------------------------------------------------------------------
# Priority eviction
# --------------------------------------------------------------------------
def test_high_priority_head_evicts_lowest_priority_running(tmp_path):
    """The preempting scheduler class: when the backoff gate releases
    the high-priority head and the pool is full of lower-priority work,
    the head evicts the victim — SIGTERM, requeue with NO retry cost and
    NO backoff — and both jobs finish."""
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    hi = _train_run("hi", steps=4)
    hi.labels["priority"] = "5"
    lo = _train_run("lo", steps=4)
    orch.submit_runs([hi, lo])
    # retries=0 on the victim: only a FREE requeue lets it run again
    orch.records["lo"].spec.retries = 0
    recs = orch.run_cluster(
        workers=1, poll_s=0.0, telemetry=False, preempt=True,
        clock=_TickClock(tick=0.05),
        retry_backoff_base_s=2.0, backoff_seed=3,
        inventory=[NodeSpec("w", gpus=1, gpu_memory_gb=80, cpus=4,
                            memory_gb=24)],
        # hi fails once -> backs off; lo (600 ticks ~ forever) fills the
        # slot; when hi's gate opens it must evict lo to get back in
        spawn=_spawn_ticks(plan={"hi": [1, 0]},
                           ticks_plan={"lo": [600, 2]}))
    assert recs["hi"].state == JobState.SUCCEEDED
    assert recs["lo"].state == JobState.SUCCEEDED   # retries=0, yet re-ran
    events = _events(pvc)
    ev = next(e for e in events if e["event"] == "evict")
    assert ev["job"] == "lo" and ev["head"] == "hi"
    assert ev["victim_priority"] < ev["head_priority"]
    evd = next(e for e in events if e["event"] == "evicted")
    assert evd["job"] == "lo" and evd["requeued"] is True
    assert evd["signal"] == int(signal.SIGTERM)
    assert "backoff_s" not in evd                   # no backoff on eviction
    # the eviction consumed no retry budget: attempt 2 started anyway
    assert any(e["event"] == "started" and e["job"] == "lo"
               and e["attempt"] == 2 for e in events)
    state = replay_events(events)
    assert state["ended"] and state["consistent"], state["violations"]
    assert state["jobs"]["lo"]["evictions"] == 1
    # summary accounting: evictions counted with preemptions
    summary = json.loads(
        pvc.read_bytes("results/_campaign_summary.json").decode())
    assert summary["evictions"] == 1
    assert summary["preemptions"] >= 1
    # CLI surface: the status table shows the eviction column
    table = format_status(state)
    assert "evict" in table.splitlines()[0]


def test_no_eviction_without_preempt_class(tmp_path):
    """Same scenario, preempt=False: the head waits instead (here the
    victim finishes on its own) and no evict event is ever emitted."""
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    hi = _train_run("hi", steps=4)
    hi.labels["priority"] = "5"
    lo = _train_run("lo", steps=4)
    orch.submit_runs([hi, lo])
    recs = orch.run_cluster(
        workers=1, poll_s=0.0, telemetry=False, preempt=False,
        clock=_TickClock(tick=0.05),
        retry_backoff_base_s=2.0, backoff_seed=3,
        inventory=[NodeSpec("w", gpus=1, gpu_memory_gb=80, cpus=4,
                            memory_gb=24)],
        spawn=_spawn_ticks(plan={"hi": [1, 0]},
                           ticks_plan={"lo": [40, 2]}))
    assert all(r.state == JobState.SUCCEEDED for r in recs.values())
    assert not any(e["event"] in ("evict", "evicted")
                   for e in _events(pvc))


# --------------------------------------------------------------------------
# Graceful escalation
# --------------------------------------------------------------------------
class _StubbornProc(FakeProc):
    """Ignores SIGTERM (a child stuck in an uninterruptible save);
    only SIGKILL takes it down."""

    def send_signal(self, sig):
        if sig == int(signal.SIGKILL):
            super().send_signal(sig)


def test_sigterm_escalates_to_sigkill_after_grace(tmp_path):
    pvc = PersistentVolume(tmp_path)
    orch = Orchestrator(pvc)
    hi = _train_run("hi", steps=4)
    hi.labels["priority"] = "5"
    lo = _train_run("lo", steps=4)
    orch.submit_runs([hi, lo])
    recs = orch.run_cluster(
        workers=1, poll_s=0.0, telemetry=False, preempt=True,
        grace_s=0.5, clock=_TickClock(tick=0.05),
        retry_backoff_base_s=2.0, backoff_seed=3,
        inventory=[NodeSpec("w", gpus=1, gpu_memory_gb=80, cpus=4,
                            memory_gb=24)],
        spawn=_spawn_ticks(plan={"hi": [1, 0]},
                           ticks_plan={"lo": [600, 2]},
                           proc_cls=_StubbornProc))
    assert all(r.state == JobState.SUCCEEDED for r in recs.values())
    events = _events(pvc)
    exp = next(e for e in events if e["event"] == "grace_expired")
    assert exp["job"] == "lo" and exp["reason"] == "evict"
    evd = next(e for e in events if e["event"] == "evicted")
    assert evd["escalated"] is True
    assert evd["signal"] == int(signal.SIGKILL)


# --------------------------------------------------------------------------
# Elastic inventory (nodes.json)
# --------------------------------------------------------------------------
def test_nodes_file_bootstrap_and_grow(tmp_path):
    """The pool bootstraps from campaign/nodes.json; rewriting the file
    mid-campaign adds the new node and later jobs land on it."""
    pvc = PersistentVolume(tmp_path)
    nodes_file = pvc.path("campaign/nodes.json")
    _write_nodes(nodes_file, [ONE_JOB_NODE])
    orch = Orchestrator(pvc)
    orch.submit_runs([_train_run("a", steps=4), _train_run("b", steps=4)])
    grown = {"done": False}

    def on_spawn(job, attempt, argv):
        if not grown["done"]:           # grow as soon as 'a' occupies w
            grown["done"] = True
            _write_nodes(nodes_file,
                         [ONE_JOB_NODE, {**ONE_JOB_NODE, "name": "x"}])

    tracker = {"active": 0, "max": 0}
    recs = orch.run_cluster(
        workers=2, poll_s=0.0, clock=_TickClock(), **FAST,
        spawn=_spawn_ticks(ticks_plan={"a": [30]}, tracker=tracker,
                           on_spawn=on_spawn))
    assert all(r.state == JobState.SUCCEEDED for r in recs.values())
    events = _events(pvc)
    start = next(e for e in events if e["event"] == "campaign_start")
    assert [n["name"] for n in start["inventory"]] == ["w-000"]
    added = next(e for e in events if e["event"] == "node_added")
    assert added["node"] == "x-000" and added["cpus"] == 4
    # 'b' could only have run concurrently on the grown node
    assert tracker["max"] == 2
    b_admit = next(e for e in events if e["event"] == "admitted"
                   and e["job"] == "b")
    assert b_admit["node"] == "x-000"
    state = replay_events(events)
    assert state["ended"] and state["consistent"], state["violations"]
    assert set(state["nodes"]) == {"w-000", "x-000"}


def test_nodes_file_drain_completes_all_jobs(tmp_path):
    """Shrinking nodes.json drains the removed node: its resident is
    gracefully evicted (free requeue), the node is removed once empty,
    nothing is ever admitted to it again, and every job completes."""
    pvc = PersistentVolume(tmp_path)
    nodes_file = pvc.path("campaign/nodes.json")
    two = [ONE_JOB_NODE, {**ONE_JOB_NODE, "name": "x"}]
    _write_nodes(nodes_file, two)
    orch = Orchestrator(pvc)
    orch.submit_runs([_train_run("a", steps=4), _train_run("b", steps=4)])
    orch.records["b"].spec.retries = 0   # survives only via free requeue
    shrunk = {"n": 0}

    def on_spawn(job, attempt, argv):
        shrunk["n"] += 1
        if shrunk["n"] == 2:            # both running -> drop node x
            _write_nodes(nodes_file, [ONE_JOB_NODE])

    recs = orch.run_cluster(
        workers=2, poll_s=0.0, clock=_TickClock(), **FAST,
        spawn=_spawn_ticks(ticks_plan={"a": [40], "b": [40, 2]},
                           on_spawn=on_spawn))
    assert all(r.state == JobState.SUCCEEDED for r in recs.values())
    events = _events(pvc)
    drain = next(e for e in events if e["event"] == "node_draining")
    assert drain["node"] == "x-000" and drain["residents"] == ["b"]
    evd = next(e for e in events if e["event"] == "evicted")
    assert evd["job"] == "b" and evd["reason"] == "drain"
    removed = next(e for e in events if e["event"] == "node_removed")
    assert removed["node"] == "x-000"
    # no admission to the drained node after the drain line
    drain_i = events.index(drain)
    assert not any(e["event"] == "admitted" and e.get("node") == "x-000"
                   for e in events[drain_i:])
    state = replay_events(events)
    assert state["ended"] and state["consistent"], state["violations"]
    assert set(state["nodes"]) == {"w-000"}
    summary = json.loads(
        pvc.read_bytes("results/_campaign_summary.json").decode())
    assert summary["nodes"]["drained"] == 1
    assert summary["nodes"]["removed"] == 1
    assert [n["name"] for n in summary["nodes"]["final"]] == ["w-000"]


def test_torn_nodes_file_is_ignored_until_valid(tmp_path):
    """A half-written control file must not take down the campaign: the
    rewrite is ignored and retried, and the pool stays intact."""
    pvc = PersistentVolume(tmp_path)
    nodes_file = pvc.path("campaign/nodes.json")
    _write_nodes(nodes_file, [ONE_JOB_NODE])
    orch = Orchestrator(pvc)
    orch.submit_runs([_train_run("a", steps=4)])

    def on_spawn(job, attempt, argv):
        nodes_file.write_text('{"nodes": [{"name": "w", "cp')  # torn

    recs = orch.run_cluster(workers=1, poll_s=0.0, clock=_TickClock(),
                            **FAST, spawn=_spawn_ticks(on_spawn=on_spawn))
    assert recs["a"].state == JobState.SUCCEEDED
    events = _events(pvc)
    assert not any(e["event"].startswith("node_") for e in events)


# --------------------------------------------------------------------------
# Elastic gangs
# --------------------------------------------------------------------------
def test_gang_shrinks_to_gang_min_after_drain(tmp_path):
    """A 2-rank gang loses a node to a drain; with gang_min=1 it shrinks
    to world=1 instead of failing, and the restart argv carries the
    shrunk --world_size."""
    pvc = PersistentVolume(tmp_path)
    nodes_file = pvc.path("campaign/nodes.json")
    two = [ONE_JOB_NODE, {**ONE_JOB_NODE, "name": "x"}]
    _write_nodes(nodes_file, two)
    orch = Orchestrator(pvc)
    orch.submit_runs([_train_run("g", steps=4, world_size=2, gang_min=1)])
    argvs = {}
    state_holder = {"drained": False}

    def on_spawn(job, attempt, argv):
        argvs.setdefault(attempt, list(argv))
        if not state_holder["drained"]:
            state_holder["drained"] = True
            _write_nodes(nodes_file, [ONE_JOB_NODE])

    recs = orch.run_cluster(
        workers=2, poll_s=0.0, clock=_TickClock(), **FAST,
        spawn=_spawn_ticks(ticks_plan={"g": [40, 40, 2]},
                           on_spawn=on_spawn))
    assert recs["g"].state == JobState.SUCCEEDED
    events = _events(pvc)
    shrunk = next(e for e in events if e["event"] == "gang_shrunk")
    assert shrunk == {**shrunk, "job": "g", "gang_from": 2, "gang_to": 1,
                      "gang_min": 1}
    # the re-placement runs a single process with the shrunk world
    final_attempt = max(argvs)
    assert any(a == "--world_size=1" for a in argvs[final_attempt]), \
        argvs[final_attempt]
    assert not any("--dist_rank" in a for a in argvs[final_attempt])
    state = replay_events(events)
    assert state["ended"] and state["consistent"], state["violations"]
    assert state["jobs"]["g"]["gang"] == 1
    assert state["jobs"]["g"]["gang_shrunk_from"] == 2
    # the status table shows the shrink
    assert "2->1" in format_status(state)


def test_rigid_gang_without_gang_min_fails_unschedulable(tmp_path):
    """gang_min=0 keeps PR 8 rigid semantics: after a drain leaves
    capacity the gang cannot atomically fit, it is NOT shrunk — the
    requeued gang fails fast as unschedulable (while non-gang work keeps
    running on the surviving node)."""
    pvc = PersistentVolume(tmp_path)
    nodes_file = pvc.path("campaign/nodes.json")
    two = [ONE_JOB_NODE, {**ONE_JOB_NODE, "name": "x"}]
    _write_nodes(nodes_file, two)
    orch = Orchestrator(pvc)
    orch.submit_runs([_train_run("g", steps=4, world_size=2)])
    drained = {"done": False}

    def on_spawn(job, attempt, argv):
        if not drained["done"]:
            drained["done"] = True
            _write_nodes(nodes_file, [ONE_JOB_NODE])

    recs = orch.run_cluster(
        workers=2, poll_s=0.0, clock=_TickClock(), **FAST,
        spawn=_spawn_ticks(ticks_plan={"g": [40]}, on_spawn=on_spawn))
    assert recs["g"].state == JobState.FAILED
    assert "unschedulable" in (recs["g"].error or "")
    events = _events(pvc)
    assert not any(e["event"] == "gang_shrunk" for e in events)
    assert any(e["event"] == "unschedulable" and e["job"] == "g"
               for e in events)
    state = replay_events(events)
    assert state["ended"] and state["consistent"], state["violations"]


# --------------------------------------------------------------------------
# System tests: real subprocesses, real SIGTERM, real jax training.
# --------------------------------------------------------------------------
import os
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

S_STEPS, S_CKPT_EVERY = 6, 2
S_KW = dict(batch=2, seq=16, log_every=0)


def _subproc_env():
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parents[1] / "src")
    existing = env.get("PYTHONPATH", "")
    if src not in existing.split(os.pathsep):
        env["PYTHONPATH"] = src + os.pathsep + existing if existing else src
    return env


def _assert_trees_equal(got_dir, want_dir, *, step):
    from repro.checkpoint import list_checkpoints, load_checkpoint
    got, gstep = load_checkpoint(list_checkpoints(got_dir)[-1][1])
    want, wstep = load_checkpoint(list_checkpoints(want_dir)[-1][1])
    assert int(gstep) == int(wstep) == step
    assert set(got) == set(want) and len(want) > 0
    for key in sorted(want):
        np.testing.assert_array_equal(got[key], want[key], err_msg=key)


@pytest.mark.timeout(600)
def test_sigterm_salvage_checkpoint_and_bitwise_resume(tmp_path):
    """Acceptance (a): a real ``run train`` subprocess SIGTERMed
    mid-run salvages a final atomic checkpoint at the completed step
    (with NO cadence checkpoint to fall back on), exits rc=-SIGTERM so
    the scheduler still classifies a preemption, and the resumed run
    lands final params bitwise identical to an uninterrupted oracle —
    at most the one in-flight step is lost."""
    from repro.checkpoint import list_checkpoints, read_manifest
    from repro.launch.train import train_main

    ck = tmp_path / "ck"
    steps = 8
    argv = [sys.executable, "-m", "repro.launch", "run", "train",
            "--arch", "stablelm-1.6b", "--seed", "0", "--name", "victim",
            f"--steps={steps}", "--batch=2", "--seq=16", "--log_every=1",
            "--checkpoint_every=1000",      # cadence NEVER fires
            f"--checkpoint_dir={ck}"]
    proc = subprocess.Popen(argv, env=_subproc_env(),
                            stdout=subprocess.PIPE,
                            stderr=subprocess.DEVNULL, text=True)
    # wait for two completed steps, then preempt between steps
    seen = []
    while len(seen) < 2:
        line = proc.stdout.readline()
        assert line, "train subprocess exited before producing steps"
        if line.startswith("step "):
            seen.append(int(line.split()[1]))
    proc.send_signal(__import__("signal").SIGTERM)
    rest, _ = proc.communicate(timeout=300)
    assert proc.returncode == -15          # preemption, never a success
    last_step = max(seen + [int(ln.split()[1]) for ln in rest.splitlines()
                            if ln.startswith("step ")])
    ckpts = list_checkpoints(ck)
    assert len(ckpts) >= 1                 # the salvage IS the checkpoint
    salvage_step, salvage_path = ckpts[-1]
    meta = read_manifest(salvage_path).get("metadata", {})
    assert meta.get("sigterm") is True
    assert "data_cursor" in meta
    # <=1 step lost: saved exactly at the last completed (0-based) step
    assert salvage_step == last_step + 1
    assert salvage_step < steps

    res = subprocess.run(argv + ["--resume=true"], env=_subproc_env(),
                         capture_output=True, timeout=300)
    assert res.returncode == 0, res.stderr[-2000:]

    train_main("stablelm-1.6b", reduced=True, steps=steps, seed=0,
               batch=2, seq=16, log_every=0, checkpoint_async=False,
               checkpoint_dir=str(tmp_path / "oracle"))
    _assert_trees_equal(ck, tmp_path / "oracle", step=steps)


@pytest.mark.timeout(900)
def test_drain_midcampaign_completes_all_jobs_bitwise(tmp_path):
    """Acceptance (b): a real campaign loses a node to a nodes.json
    shrink mid-flight; the drained node's resident is gracefully
    evicted and requeued, every job completes, the replayed event log
    shows zero allocation violations, and every final checkpoint is
    bitwise identical to its uninterrupted oracle."""
    from repro.checkpoint import list_checkpoints
    from repro.launch.train import train_main

    pvc = PersistentVolume(tmp_path / "camp")
    nodes_file = pvc.path("campaign/nodes.json")
    _write_nodes(nodes_file, [ONE_JOB_NODE,
                              {**ONE_JOB_NODE, "name": "x"}])
    seeds = (0, 1, 2)
    runs = [_train_run(f"el{s}", seed=s, steps=S_STEPS,
                       checkpoint_every=S_CKPT_EVERY,
                       checkpoint_dir=str(tmp_path / f"ck{s}"), **S_KW)
            for s in seeds]
    orch = Orchestrator(pvc)
    orch.submit_runs(runs)

    def shrink_when_running():
        # drain node x once the first two runs are both checkpointing
        deadline = time.monotonic() + 300
        while time.monotonic() < deadline:
            if all(list_checkpoints(tmp_path / f"ck{s}")
                   for s in seeds[:2]):
                _write_nodes(nodes_file, [ONE_JOB_NODE])
                return
            time.sleep(0.2)

    th = threading.Thread(target=shrink_when_running, daemon=True)
    th.start()
    recs = orch.run_cluster(workers=2, retry_backoff_base_s=0.0,
                            telemetry=False, grace_s=60.0,
                            attempt_timeout_s=300)
    th.join(timeout=10)
    assert all(recs[f"el{s}"].state == JobState.SUCCEEDED for s in seeds)
    events = _events(pvc)
    drain = next(e for e in events if e["event"] == "node_draining")
    assert drain["node"] == "x-000"
    assert any(e["event"] == "evicted" and e["reason"] == "drain"
               for e in events)
    assert any(e["event"] == "node_removed" for e in events)
    state = replay_events(events)
    assert state["ended"] and state["consistent"], state["violations"]
    assert set(state["nodes"]) == {"w-000"}
    summary = json.loads(
        pvc.read_bytes("results/_campaign_summary.json").decode())
    assert summary["evictions"] >= 1
    for s in seeds:
        train_main("stablelm-1.6b", reduced=True, steps=S_STEPS, seed=s,
                   checkpoint_every=S_CKPT_EVERY, checkpoint_async=False,
                   checkpoint_dir=str(tmp_path / f"ref{s}"), **S_KW)
        _assert_trees_equal(tmp_path / f"ck{s}", tmp_path / f"ref{s}",
                            step=S_STEPS)


@pytest.mark.timeout(900)
def test_gang_shrink_world2_to_1_matches_world1_losses(tmp_path):
    """Acceptance (c): a 2-rank gang (gang_min=1) loses a node
    mid-campaign, shrinks to world=1, resumes from the shared
    rank-agnostic checkpoint, and its post-shrink losses match the
    world=1 trajectory at the same global batch within the documented
    psum tolerance (rtol/atol 5e-4, as in test_distributed)."""
    from repro.checkpoint import list_checkpoints
    from repro.distributed.trainer import dist_train_main
    from repro.api import RunSpec

    steps, ckpt_every, global_batch, seq = 12, 2, 4, 16
    ref = dist_train_main("stablelm-1.6b", world_size=1, reduced=True,
                          steps=steps, batch=global_batch, seq=seq,
                          seed=0, log_every=0)

    pvc = PersistentVolume(tmp_path / "camp")
    nodes_file = pvc.path("campaign/nodes.json")
    _write_nodes(nodes_file, [ONE_JOB_NODE,
                              {**ONE_JOB_NODE, "name": "x"}])
    ck = tmp_path / "ck"
    spec = RunSpec(kind="train", arch="stablelm-1.6b", seed=0,
                   name="elastic-gang",
                   overrides={"steps": steps, "batch": global_batch,
                              "seq": seq, "world_size": 2, "gang_min": 1,
                              "log_every": 0,
                              "checkpoint_every": ckpt_every,
                              "checkpoint_dir": str(ck)})
    orch = Orchestrator(pvc)
    orch.submit_runs([spec])

    def shrink_on_first_checkpoint():
        deadline = time.monotonic() + 400
        while time.monotonic() < deadline:
            if list_checkpoints(ck):
                _write_nodes(nodes_file, [ONE_JOB_NODE])
                return
            time.sleep(0.2)

    th = threading.Thread(target=shrink_on_first_checkpoint, daemon=True)
    th.start()
    recs = orch.run_cluster(workers=2, retry_backoff_base_s=0.0,
                            telemetry=False, grace_s=60.0)
    th.join(timeout=10)
    assert recs["elastic-gang"].state == JobState.SUCCEEDED
    events = _events(pvc)
    shrunk = next(e for e in events if e["event"] == "gang_shrunk")
    assert shrunk["gang_from"] == 2 and shrunk["gang_to"] == 1
    state = replay_events(events)
    assert state["ended"] and state["consistent"], state["violations"]
    st = state["jobs"]["elastic-gang"]
    assert st["gang"] == 1 and st["gang_shrunk_from"] == 2
    # the final (world=1) attempt resumed from the shared checkpoint and
    # its losses continue the world=1 trajectory within psum tolerance
    metrics = recs["elastic-gang"].result["metrics"]
    assert metrics["resumed_from_step"] is not None
    got = metrics["losses"]
    assert 0 < len(got) <= steps
    np.testing.assert_allclose(got, ref["losses"][-len(got):],
                               rtol=5e-4, atol=5e-4)
    # and the campaign drove it to completion: final checkpoint at steps
    assert list_checkpoints(ck)[-1][0] == steps
