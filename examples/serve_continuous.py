"""Continuous-batching serving walkthrough: a bursty open-loop trace
through the ServeScheduler — priority/SLO admission, paged-KV
budgeting, and token streaming.

    PYTHONPATH=src python examples/serve_continuous.py

Three acts:
  1. submit a bursty arrival trace with a TTFT SLO and mixed priorities,
     run it open-loop, and print goodput (SLO-met completions/s) plus
     shed/eviction counts;
  2. stream one request's tokens as the host sees them (the engine keeps
     decoding every co-batched request underneath the iterator);
  3. squeeze the paged KV pool to half capacity and watch LRU eviction +
     requeue keep every request completing anyway.
"""
import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import init_params
from repro.serve import Request, ServeScheduler, bursty_trace

ARCH = "granite-3-2b"


def act1_bursty_slo(cfg, params):
    sched = ServeScheduler(cfg, params, slots=4, cache_len=64,
                           slo_deadline_ms=None)
    # warm every prefill bucket (8/16/32) + the decode program, so the
    # measured trace sees steady-state latency instead of compile time
    for i, plen in enumerate((4, 12, 20, 30)):
        sched.submit(Request(rid=10_000 + i,
                             prompt=np.arange(1, plen + 1) % cfg.vocab,
                             max_tokens=8))
    sched.run()

    deadline_ms = 100.0
    t0 = sched.clock.now()
    trace = bursty_trace(cfg.vocab, 24, rate_qps=500.0, burst_size=8,
                         seed=0, max_tokens=10, priorities=(0, 1, 2),
                         deadline_ms=deadline_ms)
    sched.submit_trace([(t0 + t, r) for t, r in trace])
    sched.run()
    wall = sched.clock.now() - t0
    reqs = [r for _, r in trace]
    met = [r for r in reqs if r.met_deadline()]
    shed = [r for r in reqs if r.status == "shed"]
    ttft = sorted(1e3 * r.ttft_s for r in reqs if r.ttft_s is not None)
    print(f"act 1: bursty trace, {deadline_ms:.0f}ms TTFT SLO -> "
          f"{len(reqs) - len(shed)} completed ({len(met)} in SLO), "
          f"{len(shed)} shed, {sched.stats()['evictions']} evicted; "
          f"goodput {len(met) / wall:.1f} req/s, "
          f"ttft p50 {ttft[len(ttft) // 2]:.1f}ms, "
          f"decode compiles {sched.decode_compiles} (flat)")


def act2_streaming(cfg, params):
    sched = ServeScheduler(cfg, params, slots=2, cache_len=64)
    # a background request decodes alongside the streamed one
    sched.submit(Request(rid=1, prompt=np.arange(3, 10) % cfg.vocab,
                         max_tokens=12))
    star = Request(rid=0, prompt=np.arange(5, 11) % cfg.vocab,
                   max_tokens=8)
    chunks = []
    for tok in sched.stream(star):
        chunks.append(tok)          # arrives the moment the host sees it
    sched.run()                     # drain the co-batched request
    print(f"act 2: streamed {len(chunks)} tokens {chunks} "
          f"(ttft {1e3 * star.ttft_s:.1f}ms at first yield); "
          f"co-batched request also finished: "
          f"{sched.stats()['completed'] == 2}")


def act3_paged_pool(cfg, params):
    # half the KV budget of slots*cache_len: admission is block-budgeted,
    # LRU eviction recycles blocks, evicted requests resume by
    # re-prefilling prompt+generated — nobody is lost
    sched = ServeScheduler(cfg, params, slots=4, cache_len=64,
                           max_kv_blocks=16, kv_block_size=8)
    rng = np.random.default_rng(7)
    for i in range(8):
        sched.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, size=10),
            max_tokens=28))
    done = sched.run()
    s = sched.stats()
    print(f"act 3: half-size paged pool -> {len(done)}/8 completed, "
          f"{s['evictions']} evictions, peak "
          f"{s['kv']['peak_blocks_in_use']}/{s['kv']['total_blocks']} "
          f"blocks")
    assert len(done) == 8


def main():
    cfg = get_reduced(ARCH)
    params = init_params(jax.random.PRNGKey(0), cfg)
    act1_bursty_slo(cfg, params)
    act2_streaming(cfg, params)
    act3_paged_pool(cfg, params)
    print("continuous serving demo OK")


if __name__ == "__main__":
    main()
