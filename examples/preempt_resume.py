"""Kill-and-resume demo / CI smoke: preemption-resilient training.

Runs the same reduced-config training three ways through the unified run
API:

1. uninterrupted (the reference),
2. with an injected preemption mid-flight (``preempt_at_step``) and
   cadence checkpoints of the full TrainState,
3. resumed from the newest checkpoint.

Asserts the resumed run reaches the same step count with a bitwise
identical final loss on CPU — the property that makes the paper's
234-model campaigns survivable on a preemptible cluster.

    PYTHONPATH=src python examples/preempt_resume.py \
        --steps 30 --preempt-at 15 --checkpoint-every 5 --workdir ckpt_smoke
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.api import RunSpec, run  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--preempt-at", type=int, default=15)
    ap.add_argument("--checkpoint-every", type=int, default=5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--workdir", default="ckpt_smoke")
    args = ap.parse_args()

    ckdir = str(pathlib.Path(args.workdir) / "checkpoints")
    base_over = {"steps": args.steps, "batch": args.batch, "seq": args.seq,
                 "log_every": 0}

    print(f"[1/3] uninterrupted {args.steps}-step reference run")
    ref = run(RunSpec(kind="train", arch=args.arch, overrides=base_over))
    assert ref.ok, ref.error

    print(f"[2/3] same run, killed before step {args.preempt_at} "
          f"(checkpoint every {args.checkpoint_every})")
    killed = run(RunSpec(kind="train", arch=args.arch, overrides={
        **base_over, "checkpoint_dir": ckdir,
        "checkpoint_every": args.checkpoint_every,
        "preempt_at_step": args.preempt_at}))
    assert not killed.ok and "Preemption" in (killed.error or ""), killed

    print("[3/3] resume from the newest checkpoint")
    resumed = run(RunSpec(kind="train", arch=args.arch, overrides={
        **base_over, "checkpoint_dir": ckdir,
        "checkpoint_every": args.checkpoint_every, "resume": True}))
    assert resumed.ok, resumed.error

    m, r = resumed.metrics, ref.metrics
    summary = {
        "steps": m["steps"],
        "resumed_from_step": m["resumed_from_step"],
        "final_loss_resumed": m["final_loss"],
        "final_loss_uninterrupted": r["final_loss"],
        "bitwise_identical": m["final_loss"] == r["final_loss"],
        "checkpoint": m.get("checkpoint"),
    }
    print(json.dumps(summary, indent=1))
    assert m["steps"] == r["steps"] == args.steps
    assert m["resumed_from_step"] >= args.preempt_at - args.checkpoint_every
    assert m["final_loss"] == r["final_loss"], (
        f"resumed loss {m['final_loss']} != uninterrupted {r['final_loss']}")
    print("OK: killed+resumed run is bitwise identical to uninterrupted")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
