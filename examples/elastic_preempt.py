"""Elasticity + preemption-classes demo / CI smoke (real processes).

Two campaign legs through the public orchestrator API:

1. **drain-one-node** — a 3-job campaign on a 2-node ``nodes.json``
   inventory; mid-flight the file is rewritten to one node.  The
   drained node's resident is gracefully evicted (SIGTERM -> salvage
   checkpoint -> free requeue), the node is removed once empty, every
   job completes, and every final checkpoint is bitwise identical to
   an uninterrupted reference run.
2. **high-priority eviction** — a priority-5 job fails its first
   attempt (injected ``preempt_at_step``) and backs off; a priority-0
   job takes the only node; when the gate reopens the preempting
   scheduler class evicts the low-priority run (checkpoint + requeue,
   no retry consumed) to place the head.  Both jobs complete, finals
   bitwise identical to references.

    PYTHONPATH=src python examples/elastic_preempt.py \
        --steps 6 --checkpoint-every 2 --workdir elastic_smoke
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import threading
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np                                             # noqa: E402

from repro.api import RunSpec                                  # noqa: E402
from repro.checkpoint import list_checkpoints, load_checkpoint  # noqa: E402
from repro.core import (JobState, NodeSpec, Orchestrator,      # noqa: E402
                        PersistentVolume, replay_events)
from repro.core.executor import EVENTS_REL                     # noqa: E402
from repro.launch.train import train_main                      # noqa: E402

KW = dict(batch=2, seq=16, log_every=0)
NODE = {"name": "w", "gpus": 1, "gpu_memory_gb": 80,
        "cpus": 4, "memory_gb": 24}


def _train(name, seed, ckdir, steps, every, **extra):
    return RunSpec(kind="train", arch="stablelm-1.6b", seed=seed,
                   name=name,
                   overrides={"steps": steps, "checkpoint_every": every,
                              "checkpoint_dir": str(ckdir), **KW, **extra})


def _events(pvc):
    return [json.loads(ln) for ln
            in pvc.read_bytes(EVENTS_REL).decode().splitlines()]


def _assert_bitwise(got_dir, seed, steps, every, refdir):
    train_main("stablelm-1.6b", reduced=True, steps=steps, seed=seed,
               checkpoint_every=every, checkpoint_async=False,
               checkpoint_dir=str(refdir), **KW)
    got, gstep = load_checkpoint(list_checkpoints(got_dir)[-1][1])
    want, wstep = load_checkpoint(list_checkpoints(refdir)[-1][1])
    assert int(gstep) == int(wstep) == steps, (gstep, wstep)
    assert set(got) == set(want) and len(want) > 0
    for key in sorted(want):
        assert np.array_equal(got[key], want[key]), f"seed {seed}: {key}"


def drain_leg(root: pathlib.Path, steps: int, every: int) -> dict:
    pvc = PersistentVolume(root / "drain")
    nodes_file = pvc.path("campaign/nodes.json")
    nodes_file.parent.mkdir(parents=True, exist_ok=True)
    nodes_file.write_text(json.dumps(
        {"nodes": [NODE, {**NODE, "name": "x"}]}))
    seeds = (0, 1, 2)
    orch = Orchestrator(pvc)
    orch.submit_runs([_train(f"el{s}", s, root / f"drain-ck{s}",
                             steps, every) for s in seeds])

    def shrink():
        deadline = time.monotonic() + 300
        while time.monotonic() < deadline:
            if all(list_checkpoints(root / f"drain-ck{s}")
                   for s in seeds[:2]):
                nodes_file.write_text(json.dumps({"nodes": [NODE]}))
                return
            time.sleep(0.2)

    th = threading.Thread(target=shrink, daemon=True)
    th.start()
    recs = orch.run_cluster(workers=2, retry_backoff_base_s=0.0,
                            telemetry=False, grace_s=60.0,
                            attempt_timeout_s=300)
    th.join(timeout=10)
    assert all(recs[f"el{s}"].state == JobState.SUCCEEDED for s in seeds)
    events = _events(pvc)
    assert any(e["event"] == "node_draining" for e in events)
    assert any(e["event"] == "evicted" and e["reason"] == "drain"
               for e in events)
    assert any(e["event"] == "node_removed" for e in events)
    state = replay_events(events)
    assert state["ended"] and state["consistent"], state["violations"]
    for s in seeds:
        _assert_bitwise(root / f"drain-ck{s}", s, steps, every,
                        root / f"drain-ref{s}")
    summary = orch.last_campaign_summary
    return {"jobs": len(seeds), "evictions": summary["evictions"],
            "nodes_drained": summary["nodes"]["drained"],
            "nodes_removed": summary["nodes"]["removed"],
            "bitwise_identical": True}


def evict_leg(root: pathlib.Path, steps: int, every: int) -> dict:
    pvc = PersistentVolume(root / "evict")
    hi = _train("hi", 0, root / "evict-ckhi", steps, every,
                preempt_at_step=every)     # attempt 1 dies -> backoff
    hi.labels["priority"] = "5"
    lo = _train("lo", 1, root / "evict-cklo", steps, every)
    orch = Orchestrator(pvc)
    orch.submit_runs([hi, lo])
    recs = orch.run_cluster(
        workers=1, preempt=True, telemetry=False, grace_s=60.0,
        retry_backoff_base_s=2.0, attempt_timeout_s=300,
        inventory=[NodeSpec("w", gpus=1, gpu_memory_gb=80, cpus=4,
                            memory_gb=24)])
    assert recs["hi"].state == JobState.SUCCEEDED
    assert recs["lo"].state == JobState.SUCCEEDED
    events = _events(pvc)
    ev = next(e for e in events if e["event"] == "evict")
    assert ev["job"] == "lo" and ev["head"] == "hi", ev
    evd = next(e for e in events if e["event"] == "evicted")
    assert evd["reason"] == "evict" and evd["requeued"] is True, evd
    state = replay_events(events)
    assert state["ended"] and state["consistent"], state["violations"]
    assert state["jobs"]["lo"]["evictions"] >= 1
    _assert_bitwise(root / "evict-ckhi", 0, steps, every,
                    root / "evict-refhi")
    _assert_bitwise(root / "evict-cklo", 1, steps, every,
                    root / "evict-reflo")
    return {"evicted": evd["job"], "head": ev["head"],
            "evictions": state["jobs"]["lo"]["evictions"],
            "bitwise_identical": True}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--checkpoint-every", type=int, default=2)
    ap.add_argument("--workdir", default="elastic_smoke")
    args = ap.parse_args()
    root = pathlib.Path(args.workdir)
    root.mkdir(parents=True, exist_ok=True)

    print("[1/2] drain-one-node leg (nodes.json shrink mid-campaign)")
    drain = drain_leg(root, args.steps, args.checkpoint_every)
    print(json.dumps(drain, indent=1))

    print("[2/2] high-priority eviction leg (preempting scheduler class)")
    evict = evict_leg(root, args.steps, args.checkpoint_every)
    print(json.dumps(evict, indent=1))

    print("OK: drained + evicted campaigns complete, finals bitwise "
          "identical to uninterrupted references")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
