"""Campaign execution end-to-end: submit a grid -> run it as real
concurrent subprocesses -> inspect the durable event log -> watch a
SIGKILLed run resume from its checkpoint.

    PYTHONPATH=src python examples/campaign_local.py [--workers 2]

This is the paper's cluster workflow at laptop scale: every run is a
``python -m repro.launch run train ...`` subprocess (container
semantics), admission is gated by worker slots + Resources requests over
a NodeSpec inventory, and preemption is a real SIGKILL — the re-admitted
attempt restores from the last durable checkpoint exactly like a
Nautilus job surviving an opportunistic eviction.
"""
import argparse
import json
import tempfile
from pathlib import Path

from repro.api import RunSpec
from repro.core import (ChaosSpec, ExperimentGrid, Orchestrator,
                        PersistentVolume, Resources)
from repro.core.executor import EVENTS_REL, format_status, replay_events


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--steps", type=int, default=4)
    args = ap.parse_args()

    work = Path(tempfile.mkdtemp(prefix="campaign-local-"))
    print(f"workdir: {work}")

    # --- 1. a tiny grid, expanded into RunSpecs ----------------------
    grid = ExperimentGrid("demo", {"lr": [3e-3, 1e-3], "seed": [0]})
    runs = [
        spec.replace(overrides={**spec.overrides, "steps": args.steps,
                                "batch": 2, "seq": 16, "log_every": 0,
                                "checkpoint_dir": str(work / f"ck{i}"),
                                "checkpoint_every": 2})
        for i, spec in enumerate(grid.to_runs(
            kind="train", arch="stablelm-1.6b",
            # the knobs a cluster job would declare: admission gates on
            # these against the NodeSpec inventory
            resources=Resources(gpus=1, cpus=2, memory_gb=8)))
    ]

    # --- 2. submit + run concurrently --------------------------------
    pvc = PersistentVolume(work / "pvc")
    orch = Orchestrator(pvc)
    orch.submit_runs(runs)                    # manifests render here
    # chaos: SIGKILL the first run once its first checkpoint publishes;
    # the executor re-admits it with resume=true
    chaos = ChaosSpec(kill_jobs=[runs[0].run_name], after_checkpoints=1)
    recs = orch.run_cluster(workers=args.workers, chaos=chaos)

    # --- 3. status: replay the durable event log ---------------------
    events = pvc.path(EVENTS_REL).read_text().splitlines()
    state = replay_events(events)
    print()
    print(format_status(state))               # the CLI view:
    #   python -m repro.launch campaign status <workdir>

    # --- 4. the preempted run resumed, and completed -----------------
    victim = runs[0].run_name
    result = json.loads(pvc.read_bytes(f"results/{victim}.json"))
    history = result["attempt_history"]
    assert [h["outcome"] for h in history][-1] == "succeeded"
    assert any(h["outcome"] == "preempted" for h in history)
    print(f"\n{victim}: "
          f"{' -> '.join(h['outcome'] for h in history)} "
          f"(resumed from step "
          f"{history[-1].get('resumed_from_step')})")
    summary = json.loads(
        pvc.read_bytes("results/_campaign_summary.json"))
    print(f"campaign: makespan={summary['makespan_s']}s "
          f"goodput={summary['wall_goodput']} "
          f"preemptions={summary['preemptions']}")
    assert all(r.state.value == "Succeeded" for r in recs.values())
    print("campaign_local OK")


if __name__ == "__main__":
    main()
