"""End-to-end serving driver: a small model (reduced GLM-4 family,
GQA kv=2) serving batched requests through the continuous-batching
engine — bucketed batched prefill, jitted slot admission, fused
decode+sample steps (only token ids cross to host), EOS/max-token
retirement.  Also demonstrates the MoE and SSM families serve through
the identical engine, and per-request temperature/top-k sampling.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import init_params
from repro.serve import Request, ServeEngine


def serve_arch(arch: str, requests: int = 10, max_tokens: int = 12):
    cfg = get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, slots=4, cache_len=96)
    rng = np.random.default_rng(0)
    for rid in range(requests):
        engine.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab,
                                         size=int(rng.integers(4, 16))),
            max_tokens=max_tokens))
    t0 = time.time()
    done = engine.run()
    wall = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    st = engine.stats
    print(f"{arch:28s} {len(done)} requests, {toks} tokens, "
          f"{wall:.1f}s ({toks / wall:.1f} tok/s on 1 CPU core), "
          f"{engine.prefill_compiles} prefill compiles, "
          f"{st['host_transfer_bytes']} host bytes over "
          f"{st['decode_steps']} decode steps")
    assert len(done) == requests


def serve_sampled(arch: str = "glm4-9b"):
    """Per-request sampling knobs through the fused on-device head."""
    cfg = get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, slots=2, cache_len=64, seed=7)
    prompt = np.arange(10) % cfg.vocab
    engine.submit(Request(rid=0, prompt=prompt, max_tokens=8))  # greedy
    engine.submit(Request(rid=1, prompt=prompt, max_tokens=8,
                          temperature=0.9, top_k=40))
    done = {r.rid: r.generated for r in engine.run()}
    print(f"{arch:28s} greedy {done[0]} vs sampled(T=0.9,k=40) {done[1]}")


def main():
    for arch in ["glm4-9b", "qwen3-moe-30b-a3b", "mamba2-2.7b",
                 "jamba-1.5-large-398b"]:
        serve_arch(arch)
    serve_sampled()
    print("serving demo OK — dense, MoE, SSM and hybrid all serve "
          "through one engine")


if __name__ == "__main__":
    main()
