"""Burned-area hyperparameter grid — the paper's Sect. III-B workflow at
reduced scale, run end-to-end through the orchestration layer:

  synthetic Sentinel-2 rasters -> percentile normalization -> polygon
  rasterization -> 25%-overlap chipping -> an ExperimentGrid of
  (lr x optimizer x init) U-Net jobs -> Orchestrator (manifests, retries,
  PVC staging, S3 export) -> best-config selection.

    PYTHONPATH=src python examples/burned_area_grid.py
"""
import json
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ExperimentGrid, JobSpec, Orchestrator,
                        PersistentVolume, Resources, S3Store)
from repro.data.chipping import dedup_chips, make_chips, split_by_raster
from repro.data.loader import ChipLoader
from repro.data.normalize import percentile_stretch
from repro.data.rasters import synth_raster
from repro.models.segmentation import seg_init, seg_apply, seg_loss, seg_metrics
from repro.optim import get_optimizer


def build_dataset(n_scenes=4, size=192, chip=64):
    chips = []
    for i in range(n_scenes):
        scene = synth_raster(f"ba-scene-{i}", size, size, seed=i)
        img = percentile_stretch(scene.raster)[..., :3]
        chips.extend(make_chips(img, scene.mask, scene.scene_id,
                                chip=chip, overlap=0.25, min_frac=0.08))
    chips = dedup_chips(chips)
    return split_by_raster(chips, fractions=(0.7, 0.15, 0.15))


def make_payload(split):
    def train_unet(lr="1e-3", optimizer="adam", init_seed="0",
                   epochs="4", **kw):
        params = seg_init("unet", jax.random.PRNGKey(int(init_seed)), width=8)
        opt = get_optimizer(optimizer)
        opt_state = opt.init(params)
        loader = ChipLoader(split["train"], batch_size=4, seed=0,
                            drop_last=False)

        @jax.jit
        def step(p, s, i, x, m):
            l, g = jax.value_and_grad(lambda p: seg_loss("unet", p, x, m))(p)
            p, s = opt.update(g, s, p, i, float(lr))
            return p, s, l

        i = jnp.zeros((), jnp.int32)
        for _ in range(int(epochs)):
            for x, m in loader.epoch():
                params, opt_state, loss = step(
                    params, opt_state, i, jnp.asarray(x), jnp.asarray(m))
                i += 1
        # validation F1
        vx = jnp.asarray(np.stack([c.image for c in split["val"]]))
        vm = jnp.asarray(np.stack([c.mask for c in split["val"]]),
                         jnp.int32)
        metrics = seg_metrics(seg_apply("unet", params, vx), vm)
        return {k: float(v) for k, v in metrics.items()}
    return train_unet


def main():
    split = build_dataset()
    print({k: len(v) for k, v in split.items()})

    grid = ExperimentGrid("ba-unet", {
        "lr": [1e-2, 1e-3, 1e-4],
        "optimizer": ["adam", "lamb"],
    })
    specs = grid.expand()
    print(f"grid: {len(specs)} experiments "
          f"(paper ran 72 per arch at full scale)")

    with tempfile.TemporaryDirectory() as td:
        pvc, s3 = PersistentVolume(td), S3Store(td)
        orch = Orchestrator(pvc, s3)
        payload = make_payload(split)
        for spec in specs:
            pvc.stage_bytes(f"configs/{spec.name}.json",
                            spec.config_json().encode())
            orch.submit(JobSpec(
                name=spec.name, payload=payload,
                env={k: str(v) for k, v in spec.params.items()},
                resources=Resources(gpus=2, cpus=4, memory_gb=24),
                duration_h=518.0 / 144,
                labels={"experiment": "ba-grid"}))
        orch.run_local()
        print("orchestrator:", orch.summary())

        results = {name: rec.result for name, rec in orch.records.items()}
        best = max(results, key=lambda n: results[n]["f1"])
        print("\nper-config val F1:")
        for name in sorted(results, key=lambda n: -results[n]["f1"]):
            r = results[name]
            print(f"  {name:40s} F1={r['f1']:.3f} IoU={r['iou']:.3f}")
        print(f"\nbest config: {best}")

        sim = orch.simulate()
        print(f"cluster sim: makespan={sim.makespan_h:.2f}h "
              f"speedup vs serial={sim.speedup_vs_serial():.1f}x")


if __name__ == "__main__":
    main()
