"""End-to-end training driver: a ~100M-parameter dense model trained for
a few hundred steps on the synthetic Markov-Zipf corpus, with
checkpointing and S3 export — the paper's per-job training flow.

    PYTHONPATH=src python examples/train_100m.py [--steps 200]

~100M params on one CPU core is slow; the default settings keep a full
run under ~30 minutes.  Use --steps 20 for a quick look.
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import export_to_s3, save_checkpoint
from repro.configs.base import ArchConfig
from repro.core.artifacts import S3Store
from repro.data.tokens import lm_batch_iterator
from repro.optim import get_optimizer, warmup_cosine
from repro.train import init_train_state, make_train_step

CFG_100M = ArchConfig(
    name="dense-100m",
    family="dense",
    source="stablelm-2 family scaled to ~100M",
    n_layers=10,
    d_model=640,
    n_heads=10,
    n_kv_heads=10,
    d_ff=2560,
    vocab=32_000,
    norm="layernorm",
    param_dtype="float32",
    optimizer="adamw",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--out", default="experiments/train_100m")
    args = ap.parse_args()

    cfg = CFG_100M
    print(f"{cfg.name}: {cfg.param_count() / 1e6:.1f}M params")
    opt = get_optimizer("adamw")
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    # jitted + donated: the input state is consumed each step
    step = make_train_step(
        cfg, opt, lr_schedule=warmup_cosine(3e-4, args.steps,
                                            warmup_steps=args.steps // 10))
    it = lm_batch_iterator(cfg.vocab, args.batch, args.seq, seed=0)
    losses = []
    t0 = time.time()
    for i in range(args.steps):
        toks, labels = next(it)
        state, m = step(state, {"tokens": jnp.asarray(toks),
                                "labels": jnp.asarray(labels)})
        losses.append(float(m["loss"]))
        if i % 10 == 0 or i == args.steps - 1:
            el = time.time() - t0
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"({el:.0f}s, {(i + 1) / el:.2f} steps/s)", flush=True)
    result = {"params_m": cfg.param_count() / 1e6,
              "steps": args.steps,
              "first_loss": losses[0], "final_loss": losses[-1],
              "wall_s": round(time.time() - t0, 1)}
    d = save_checkpoint(f"{args.out}/ckpt", state.params,
                        step=int(state.step), metadata=result)
    n = export_to_s3(d, S3Store(args.out), f"models/{cfg.name}")
    result["s3_objects"] = n
    print(json.dumps(result, indent=1))
    assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
