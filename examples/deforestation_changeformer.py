"""Deforestation change detection — the paper's Sect. III-C application,
end-to-end at reduced scale: synthetic Sentinel-2 pairs (PRODES-style
polygons), NIR-R-G band composite, chipping, ChangeFormer training, and
change-class metrics vs the U-Net-style baseline comparison the paper
makes (ChangeFormer > FC-DenseNet by >10% F1 at full scale).

    PYTHONPATH=src python examples/deforestation_changeformer.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.normalize import percentile_stretch
from repro.data.rasters import synth_change_pair
from repro.models.changeformer import (changeformer_apply, changeformer_init,
                                       changeformer_loss)
from repro.models.segmentation import seg_metrics
from repro.optim import get_optimizer


def build_pairs(n=6, size=64):
    pairs = []
    for i in range(n):
        a, b, m = synth_change_pair(f"defo-{i}", size, size, bands=4, seed=i)
        # NIR-R-G composite (paper's winning band combination)
        a3 = percentile_stretch(np.stack([a[..., 3], a[..., 0], a[..., 1]], -1))
        b3 = percentile_stretch(np.stack([b[..., 3], b[..., 0], b[..., 1]], -1))
        pairs.append((a3, b3, m))
    return pairs


def main():
    pairs = build_pairs()
    train, test = pairs[:4], pairs[4:]
    xa = jnp.asarray(np.stack([p[0] for p in train]))
    xb = jnp.asarray(np.stack([p[1] for p in train]))
    ym = jnp.asarray(np.stack([p[2] for p in train]), jnp.int32)
    ta = jnp.asarray(np.stack([p[0] for p in test]))
    tb = jnp.asarray(np.stack([p[1] for p in test]))
    tm = jnp.asarray(np.stack([p[2] for p in test]), jnp.int32)

    params = changeformer_init(jax.random.PRNGKey(0), in_ch=3)
    opt = get_optimizer("adamw")   # paper: AdamW optimal for ChangeFormer
    opt_state = opt.init(params)

    @jax.jit
    def step(p, s, i):
        l, g = jax.value_and_grad(
            lambda p: changeformer_loss(p, xa, xb, ym))(p)
        p, s = opt.update(g, s, p, i, 1e-3)
        return p, s, l

    t0 = time.time()
    for i in range(60):
        params, opt_state, loss = step(params, opt_state, jnp.asarray(i))
        if i % 10 == 0 or i == 59:
            print(f"step {i:3d} loss {float(loss):.4f}")
    print(f"train wall: {time.time() - t0:.1f}s")

    logits = changeformer_apply(params, ta, tb)
    m = {k: float(v) for k, v in seg_metrics(logits, tm).items()}
    print("test change-class metrics:", {k: round(v, 3) for k, v in m.items()})
    print(f"overall accuracy {m['accuracy']:.1%} "
          f"(paper reports 94% at full scale, F1 90%)")


if __name__ == "__main__":
    main()
