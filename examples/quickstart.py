"""Quickstart: the unified run API end-to-end — train a small LM, then
serve it with batched greedy decoding, both as ``RunSpec -> RunReport``.

    PYTHONPATH=src python examples/quickstart.py

Every workload kind (train, serve, dryrun, perfprobe, simulate) goes
through the same two types: build a :class:`repro.api.RunSpec`, hand it
to :func:`repro.api.run`, get a :class:`repro.api.RunReport` back.  The
same spec also round-trips through CLI flags (``python -m repro.launch
run train --steps 80``) and container env vars (the paper's bash
interface) — see ``spec.to_env()`` below.
"""
import tempfile

from repro.api import RunSpec, run


def main():
    # --- train ------------------------------------------------------
    ckpt = tempfile.mkdtemp(prefix="quickstart-ckpt-")
    # precision="bf16" selects bf16 compute with f32 master params /
    # optimizer state; attention_backend/mixer_backend pick the kernel
    # path ("auto" = Pallas on TPU, pure-jnp elsewhere) — same knobs as
    # the CLI's --precision / --attention-backend / --mixer-backend.
    train_spec = RunSpec(
        kind="train", arch="stablelm-1.6b", seed=0,
        overrides={"steps": 80, "batch": 8, "seq": 64, "lr": 3e-3,
                   "checkpoint_dir": ckpt, "precision": "bf16",
                   "attention_backend": "auto"})
    print(f"spec: {train_spec.run_name}")
    print(f"  as env (the paper's bash interface): {train_spec.to_env()}")

    report = run(train_spec)
    assert report.ok, report.error
    print(f"  {report.summary()}")
    print(f"  loss {report.metrics['first_loss']:.3f} -> "
          f"{report.metrics['final_loss']:.3f} in "
          f"{report.metrics['steps']} steps "
          f"({report.metrics['steps_per_s']:.1f} steps/s)")
    print(f"  artifacts: {list(report.artifacts)}")

    # --- kill & resume ----------------------------------------------
    # the same run, preempted mid-flight and resumed from its durable
    # checkpoint: the resumed run ends bitwise identical on CPU (see
    # examples/preempt_resume.py for the full demonstration)
    resume_ckpt = tempfile.mkdtemp(prefix="quickstart-resume-")
    over = {"steps": 20, "batch": 4, "seq": 32, "log_every": 0,
            "checkpoint_dir": resume_ckpt, "checkpoint_every": 5}
    killed = run(RunSpec(kind="train", arch="stablelm-1.6b",
                         overrides={**over, "preempt_at_step": 10}))
    assert not killed.ok                      # preempted at step 10
    resumed = run(RunSpec(kind="train", arch="stablelm-1.6b",
                          overrides={**over, "resume": True}))
    assert resumed.ok, resumed.error
    print(f"  killed at step 10, resumed from "
          f"{resumed.metrics['resumed_from_step']} -> "
          f"finished step {resumed.metrics['steps']} "
          f"(loss {resumed.metrics['final_loss']:.3f})")

    # --- serve ------------------------------------------------------
    serve_report = run(RunSpec(
        kind="serve", arch="stablelm-1.6b", seed=1,
        overrides={"requests": 6, "slots": 4, "cache_len": 96,
                   "max_tokens": 12}))
    assert serve_report.ok, serve_report.error
    print(f"  {serve_report.summary()}")
    print(f"  {serve_report.metrics['tokens']} tokens at "
          f"{serve_report.metrics['tokens_per_s']:.1f} tok/s over "
          f"{serve_report.metrics['requests']} requests")

    # both reports serialize the same way — the uniform result record
    # the orchestrator ships to PVC/S3 for every job kind

    # --- campaigns: real concurrent execution ------------------------
    # Many specs become a campaign: Orchestrator.run_cluster(workers=N)
    # executes each as a `python -m repro.launch run <kind>` subprocess,
    # N at a time, admission-gated by each spec's Resources request
    # (gpus/cpus/memory_gb) against a NodeSpec inventory — and SIGKILLed
    # runs resume from their checkpoints.  See examples/campaign_local.py
    # and `python -m repro.launch campaign status <workdir>`:
    #
    #   orch = Orchestrator(PersistentVolume("work"))
    #   orch.submit_runs([train_spec.replace(name=f"t{i}", seed=i)
    #                     for i in range(8)])
    #   orch.run_cluster(workers=4)
    print("quickstart OK")


if __name__ == "__main__":
    main()
