"""Quickstart: train a small LM with the framework's public API, then
serve it with batched greedy decoding.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data.tokens import lm_batch_iterator
from repro.optim import get_optimizer, warmup_cosine
from repro.serve import Request, ServeEngine
from repro.train import init_train_state, make_train_step


def main():
    cfg = get_reduced("stablelm-1.6b")
    print(f"arch: {cfg.name}  params: {cfg.param_count():,}")

    # --- train ------------------------------------------------------
    opt = get_optimizer("adamw")
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt,
                                   lr_schedule=warmup_cosine(3e-3, 80, 10)))
    it = lm_batch_iterator(cfg.vocab, batch=8, seq=64, seed=0)
    for i in range(80):
        toks, labels = next(it)
        state, m = step(state, {"tokens": jnp.asarray(toks),
                                "labels": jnp.asarray(labels)})
        if i % 10 == 0 or i == 79:
            print(f"  step {i:3d}  loss {float(m['loss']):.3f}")

    # --- serve ------------------------------------------------------
    engine = ServeEngine(cfg, state.params, slots=4, cache_len=96)
    rng = np.random.default_rng(1)
    for rid in range(6):
        engine.submit(Request(rid=rid,
                              prompt=rng.integers(0, cfg.vocab, size=8),
                              max_tokens=12))
    done = engine.run()
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  request {r.rid}: generated {r.generated}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
