"""HLO text analysis: per-collective byte counts.

``compiled.cost_analysis()`` has no collective accounting, so we parse the
partitioned HLO module text and sum operand bytes for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Async pairs (-start/-done) are counted once (on -start).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

# one tensor shape like  bf16[16,128]{1,0}  or  f32[] or s32[4]
_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(COLLECTIVES) + r")(-start)?\(")


def parse_shape_bytes(shape_text: str) -> int:
    """Total bytes of every tensor literal appearing in `shape_text`."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_NAME = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?condition=\s*%?([\w.\-]+),\s*body=\s*%?([\w.\-]+)",
    re.DOTALL)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"\bs32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """Map computation name -> its body text (brace-balanced blocks).

    Header lines look like ``%name (args...) -> type {`` where args may
    contain nested tuple parens — so the name is extracted without trying
    to match the parameter list.
    """
    comps: Dict[str, str] = {}
    cur_name, cur_lines, depth = None, [], 0
    for line in hlo_text.splitlines():
        if cur_name is None:
            s = line.rstrip()
            if s.endswith("{") and "->" in s:
                m = _COMP_NAME.match(s)
                if m:
                    cur_name = m.group(1)
                    cur_lines = [line]
                    depth = line.count("{") - line.count("}")
            continue
        cur_lines.append(line)
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            comps[cur_name] = "\n".join(cur_lines)
            cur_name = None
    return comps


def loop_trip_multipliers(hlo_text: str) -> Dict[str, int]:
    """Computation name -> product of trip counts of enclosing while loops.

    XLA lowers lax.scan to `while`; cost/byte accounting must multiply the
    body's contribution by its trip count (XLA's own cost_analysis does
    NOT — it counts each computation once).  Trip counts are recovered
    from the loop-condition's comparison constant; nesting is resolved by
    which computation contains the `while` op.
    """
    comps = _split_computations(hlo_text)
    body_parent = {}   # body comp -> (parent comp, trip count)
    for name, text in comps.items():
        for line in text.splitlines():
            m = _WHILE_RE.search(line)
            if not m:
                continue
            cond, body = m.group(1), m.group(2)
            # XLA records the statically-known trip count on the while op
            tm = _TRIP_RE.search(line)
            if tm:
                trips = int(tm.group(1))
            else:
                trips = 1
                if cond in comps:
                    consts = [int(c) for c in _CONST_RE.findall(comps[cond])]
                    if consts:
                        trips = max(consts)
            body_parent[body] = (name, max(trips, 1))

    mult: Dict[str, int] = {}

    def resolve(name, seen=()):
        if name in mult:
            return mult[name]
        if name in seen:
            return 1
        if name not in body_parent:
            mult[name] = 1
            return 1
        parent, trips = body_parent[name]
        m = trips * resolve(parent, seen + (name,))
        mult[name] = m
        return m

    for name in comps:
        resolve(name)
    # called computations (fusions, regions) inherit their caller's
    # multiplier only when uniquely called from a while body; we
    # approximate non-body computations at 1x — collectives live in the
    # loop bodies themselves after SPMD partitioning.
    return mult


def collective_bytes_scaled(hlo_text: str) -> Dict[str, int]:
    """Like :func:`collective_bytes` but multiplies collectives inside
    while-loop bodies by their trip counts."""
    comps = _split_computations(hlo_text)
    mult = loop_trip_multipliers(hlo_text)
    out: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)
    for name, text in comps.items():
        m = mult.get(name, 1)
        for line in text.splitlines():
            mm = _COLL_RE.search(line)
            if not mm or "-done(" in line:
                continue
            nbytes = parse_shape_bytes(mm.group(1))
            out[mm.group(2)] += nbytes * m
            counts[mm.group(2)] += m
    result = dict(out)
    result["_counts"] = dict(counts)
    result["total"] = sum(out.values())
    return result


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Bytes moved per collective kind (operand bytes, start ops only).

    For all-gather / all-reduce the operand bytes are what each device
    contributes; the *result* of an all-gather is larger, but link traffic
    scales with operand size per participant, which is the roofline-relevant
    quantity.  We use the op *result* bytes for all-gather (the gathered
    tensor materializes over the links) and operand bytes otherwise —
    operands are unavailable without building a full def-use map of shapes,
    so we approximate both with the op's own declared shape, which for
    all-reduce/permute equals the operand and for all-gather equals the
    gathered result (an upper bound on per-device traffic).
    """
    out: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_txt, kind, start = m.group(1), m.group(2), m.group(3)
        if "-done(" in line:
            continue
        nbytes = parse_shape_bytes(shape_txt)
        out[kind] += nbytes
        counts[kind] += 1
    out_total = dict(out)
    out_total["_counts"] = dict(counts)
    out_total["total"] = sum(out.values())
    return out_total
