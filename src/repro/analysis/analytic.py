"""Analytic per-chip cost model for the roofline.

Why analytic: XLA's ``cost_analysis()`` counts a ``while`` (lax.scan) body
ONCE, not multiplied by its trip count.  Every architecture here scans over
layer periods, so compiled HLO FLOPs/bytes undercount by ~n_layers (verified
experimentally: granite-3-2b compiled flops  2.5e12/chip vs analytic
6ND/chip = 6.2e13 — ratio ~= n_layers=40 after accounting for the
once-counted body).  The analytic model computes FLOPs / HBM bytes /
collective bytes from first principles given (arch config, input shape,
mesh, layout); the HLO-parsed numbers are kept alongside as a structural
cross-check (which collectives appear, body-level costs).

All quantities are per-chip per-step.  Matmul FLOPs = 2*M*N*K.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class MeshDims:
    pod: int = 1
    data: int = 16
    model: int = 16

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model


def mesh_dims(mesh) -> MeshDims:
    d = dict(mesh.shape)
    return MeshDims(pod=d.get("pod", 1), data=d.get("data", 1),
                    model=d.get("model", 1))


def _dtype_bytes(cfg: ArchConfig) -> int:
    return 2 if "bf16" in cfg.param_dtype or "16" in cfg.param_dtype else 4


# --------------------------------------------------------------------------
def flops_forward(cfg: ArchConfig, batch: int, seq: int, kind: str,
                  window_override=None) -> float:
    """Global forward FLOPs for one step."""
    d = cfg.d_model
    tokens = batch * (1 if kind == "decode" else seq)

    # linear / matmul params: active params minus gather-only embedding;
    # tied embeddings still pay the logits matmul.
    n_lin = cfg.active_param_count() - cfg.vocab * d
    if cfg.tie_embeddings:
        n_lin += cfg.vocab * d
    if cfg.moe is not None:
        # capacity-based dispatch computes E*C slots ~= cf * T*K tokens
        n_moe_active = (cfg.n_layers // cfg.moe.every) * cfg.moe.top_k \
            * 3 * d * cfg.moe.expert_d_ff
        n_lin += n_moe_active * (cfg.moe.capacity_factor - 1.0)
    total = 2.0 * n_lin * tokens

    # attention score/value matmuls
    kinds = cfg.layer_kinds()
    n_attn = sum(1 for k in kinds if k == "attn")
    n_ssm = len(kinds) - n_attn
    window = window_override if window_override is not None else cfg.sliding_window
    if n_attn and cfg.n_heads:
        H, hd = cfg.n_heads, cfg.head_dim
        if kind == "decode":
            ctx = min(seq, window) if window else seq
            total += n_attn * 4.0 * batch * ctx * H * hd
        else:
            ctx = min(seq, window) if window else seq
            causal = 0.5 if (cfg.causal and ctx == seq) else 1.0
            total += n_attn * 4.0 * batch * seq * ctx * H * hd * causal

    # SSD terms
    if n_ssm and cfg.ssm is not None:
        s = cfg.ssm
        nh = s.n_heads(d)
        hp = s.head_dim
        gN = s.n_groups * s.d_state
        if kind == "decode":
            # recurrent update: h' = a h + dt x B ; y = C h
            total += n_ssm * batch * (4.0 * nh * hp * s.d_state)
        else:
            Q = min(s.chunk, seq)
            per_tok = (2.0 * Q * gN * 0.5            # C B^T (causal half)
                       + 2.0 * Q * nh * hp * 0.5     # M @ x
                       + 4.0 * nh * hp * s.d_state)  # inter-chunk + state
            total += n_ssm * batch * seq * per_tok
    return total


def flops_per_chip(cfg: ArchConfig, batch: int, seq: int, kind: str,
                   md: MeshDims, remat: bool = True,
                   window_override=None) -> float:
    fwd = flops_forward(cfg, batch, seq, kind, window_override)
    mult = 1.0
    if kind == "train":
        mult = 4.0 if remat else 3.0     # bwd = 2x fwd; remat adds 1x fwd
    return fwd * mult / md.chips


# --------------------------------------------------------------------------
def hbm_bytes_per_chip(cfg: ArchConfig, batch: int, seq: int, kind: str,
                       md: MeshDims, layout: str = "fsdp_tp") -> float:
    pb = _dtype_bytes(cfg)
    P = cfg.param_count() * pb
    d = cfg.d_model
    shards = md.chips if layout == "fsdp_tp" else md.model * 1  # dp: replicated
    if layout == "dp":
        shards = 1
    p_local = P / shards

    total = 0.0
    if kind == "train":
        # fwd + remat + bwd weight reads, grad write, optimizer read/write
        opt_mult = {"sgd": 0, "sgdm": 1, "adam": 2, "adamw": 2, "lamb": 2}[
            cfg.optimizer]
        total += p_local * (3          # weight reads (fwd, remat-fwd, bwd)
                            + 2        # grad write + read
                            + 2 * (1 + opt_mult))  # param + moments r/w
    else:
        total += p_local  # one streaming read of (local) weights

    # activations: ~6 bytes moved per element per layer boundary (read+write
    # through residual/norm/proj chain), batch sharded over (pod, data),
    # d sharded over model in fsdp_tp
    toks_local = batch * (1 if kind == "decode" else seq) / max(
        md.pod * md.data, 1)
    act_shard = md.model if layout == "fsdp_tp" else 1
    total += 6.0 * cfg.n_layers * toks_local * d * pb / act_shard * (
        3 if kind == "train" else 1)

    if kind == "decode":
        # KV cache / SSM state read+write — usually decode's dominant term
        total += decode_state_bytes(cfg, batch, seq) / md.chips * 2
    return total


def decode_state_bytes(cfg: ArchConfig, batch: int, seq: int) -> float:
    pb = _dtype_bytes(cfg)
    kinds = cfg.layer_kinds()
    n_attn = sum(1 for k in kinds if k == "attn")
    n_ssm = len(kinds) - n_attn
    total = 0.0
    if n_attn:
        cache = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
        total += n_attn * 2 * batch * cache * cfg.n_kv_heads * cfg.head_dim * pb
    if n_ssm and cfg.ssm is not None:
        s = cfg.ssm
        nh = s.n_heads(cfg.d_model)
        total += n_ssm * batch * nh * s.head_dim * s.d_state * 4  # fp32 h
    return total


# --------------------------------------------------------------------------
def collective_bytes_per_chip(cfg: ArchConfig, batch: int, seq: int,
                              kind: str, md: MeshDims,
                              layout: str = "fsdp_tp") -> Dict[str, float]:
    """Per-chip collective traffic (ICI), by mechanism."""
    pb = _dtype_bytes(cfg)
    P = cfg.param_count() * pb
    d = cfg.d_model
    toks_local = batch * (1 if kind == "decode" else seq) / max(
        md.pod * md.data, 1)
    out: Dict[str, float] = {"fsdp_allgather": 0.0, "grad_reducescatter": 0.0,
                             "tp_allreduce": 0.0, "moe_alltoall": 0.0,
                             "pod_gradsync": 0.0}

    if layout == "dp":
        if kind == "train":
            # plain DP: ring all-reduce of full grads ~ 2*P per chip
            out["grad_reducescatter"] = 2.0 * P
        return out

    p_model_shard = P / md.model
    if kind == "train" and md.data > 1:
        ag = p_model_shard * (md.data - 1) / md.data
        out["fsdp_allgather"] = 2.0 * ag          # fwd + bwd gathers
        out["grad_reducescatter"] = ag            # RS of grads
        if md.pod > 1:
            out["pod_gradsync"] = 2.0 * (P / (md.data * md.model)) \
                * (md.pod - 1) / md.pod
    elif kind != "train" and md.data > 1:
        # weights stay sharded; no FSDP gather needed at batch>=data when
        # activations are model-sharded; count one gather for generality
        out["fsdp_allgather"] = 0.0

    passes = 4 if kind == "train" else 1          # fwd, remat, bwd(x2)
    if layout == "fsdp_sp" and md.model > 1:
        # sequence-parallel boundaries: norms/MLP/router local; per
        # attention layer one K/V gather at kv-head granularity (+ its
        # gradient reduction); per SSM layer only the segment-state
        # exchange (tiny) + conv halo.
        kinds = cfg.layer_kinds()
        n_attn = sum(1 for k in kinds if k == "attn")
        if n_attn and cfg.n_kv_heads:
            kv_bytes = (batch / max(md.pod * md.data, 1)) * seq \
                * 2 * cfg.n_kv_heads * cfg.head_dim * pb
            out["tp_allreduce"] = (n_attn * passes * kv_bytes
                                   * (md.model - 1) / md.model)
        n_ssm = len(kinds) - n_attn
        if n_ssm and cfg.ssm is not None:
            s = cfg.ssm
            state = (batch / max(md.pod * md.data, 1)) * s.n_heads(d) \
                * s.head_dim * s.d_state * 4
            out["tp_allreduce"] += n_ssm * passes * state * md.model
    elif md.model > 1:
        # tensor-parallel: one AR per mixer + one per ffn output, ring ~2
        n_ar = 2 * cfg.n_layers
        out["tp_allreduce"] = (n_ar * passes * 2.0 * toks_local * d * pb
                               * (md.model - 1) / md.model)

    if cfg.moe is not None and md.model > 1:
        n_moe = cfg.n_layers // cfg.moe.every
        mpasses = 3 if kind == "train" else 1
        # tokens cross expert shards twice (dispatch + combine).  Under
        # fsdp_sp the dispatch is chip-local-grouped: each chip exchanges
        # only ITS tokens (toks divided by model too); under fsdp_tp the
        # capacity buffer spans the model axis.
        toks_moe = toks_local / (md.model if layout == "fsdp_sp" else 1)
        out["moe_alltoall"] = (n_moe * mpasses * 2.0 * toks_moe
                               * cfg.moe.top_k * d * pb
                               * (md.model - 1) / md.model
                               * cfg.moe.capacity_factor)
    return out


# --------------------------------------------------------------------------
def analytic_roofline(cfg: ArchConfig, batch: int, seq: int, kind: str,
                      mesh, layout: str = "fsdp_tp", remat: bool = True,
                      window_override=None,
                      peak_flops: float = 197e12, hbm_bw: float = 819e9,
                      link_bw: float = 50e9) -> Dict:
    md = mesh_dims(mesh)
    fl = flops_per_chip(cfg, batch, seq, kind, md, remat, window_override)
    hb = hbm_bytes_per_chip(cfg, batch, seq, kind, md, layout)
    coll = collective_bytes_per_chip(cfg, batch, seq, kind, md, layout)
    coll_total = sum(coll.values())
    terms = {
        "compute_s": fl / peak_flops,
        "memory_s": hb / hbm_bw,
        "collective_s": coll_total / link_bw,
    }
    dominant = max(terms, key=terms.get)
    n_act = cfg.active_param_count()
    tokens = batch * (1 if kind == "decode" else seq)
    model_fl = (6.0 if kind == "train" else 2.0) * n_act * tokens / md.chips
    return {
        **terms,
        "dominant": dominant,
        "flops_per_chip": fl,
        "hbm_bytes_per_chip": hb,
        "collective_bytes_per_chip": coll_total,
        "collective_breakdown": coll,
        "model_flops_per_chip": model_fl,
        "useful_flops_ratio": model_fl / fl if fl else 0.0,
        "step_time_lower_bound_s": max(terms.values()),
        "mfu_upper_bound": model_fl / peak_flops / max(terms.values())
        if max(terms.values()) else 0.0,
    }
