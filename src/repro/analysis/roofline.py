"""Three-term roofline from a compiled dry-run artifact.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  ``compiled.cost_analysis()`` analyzes the partitioned
(per-device) HLO module, so its FLOPs/bytes are already per-chip;
collective bytes come from :mod:`repro.analysis.hlo` over the same module.

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = collective_bytes_per_chip / link_bw
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.analysis.hlo import collective_bytes, collective_bytes_scaled


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    link_bw: float = 50e9           # bytes/s per ICI link


HW = Hardware()


def model_flops(cfg, batch: int, seq: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    n = cfg.active_param_count()
    tokens = batch * (1 if kind == "decode" else seq)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens


def roofline_terms(cost: Dict[str, float], hlo_text: str, n_chips: int,
                   cfg=None, batch: int = 0, seq: int = 0,
                   kind: str = "train", hw: Hardware = HW) -> Dict:
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    # trip-count-scaled: collectives inside lax.scan while bodies are
    # multiplied by their loop trip counts (XLA counts them once)
    coll = collective_bytes_scaled(hlo_text)
    t_compute = flops / hw.peak_flops
    t_memory = bytes_acc / hw.hbm_bw
    t_coll = coll["total"] / hw.link_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    out = {
        **terms,
        "dominant": dominant,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_acc,
        "collective_bytes_per_chip": coll["total"],
        "collectives": {k: v for k, v in coll.items()
                        if k not in ("total",)},
        "n_chips": n_chips,
    }
    if cfg is not None:
        mf = model_flops(cfg, batch, seq, kind)
        out["model_flops_total"] = mf
        out["model_flops_per_chip"] = mf / n_chips
        out["useful_flops_ratio"] = (mf / n_chips) / flops if flops else 0.0
    return out
