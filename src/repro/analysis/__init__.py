from repro.analysis.hlo import collective_bytes, parse_shape_bytes
from repro.analysis.roofline import roofline_terms, HW

__all__ = ["collective_bytes", "parse_shape_bytes", "roofline_terms", "HW"]
