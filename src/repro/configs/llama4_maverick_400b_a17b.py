"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family].

MoE with 128 routed experts, top-1 routing, one shared expert, MoE layers
interleaved every 2nd layer (matching the A17B active budget), early-fusion
multimodal lineage (text path modeled here).
"""
from repro.configs.base import ArchConfig, MoEConfig, register, reduce_config

FULL = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,            # dense-layer MLP + shared expert ff
    vocab=202_048,
    moe=MoEConfig(n_experts=128, top_k=1, expert_d_ff=8192, every=2,
                  shared_expert=True),
    sliding_window=8192,   # used by the long_500k decode variant
    # SGD+momentum: the paper's own default optimizer for most models, and
    # the 400B-class memory budget (1 moment, not 2) — see DESIGN.md §5.
    optimizer="sgdm",
)

register(FULL, lambda: reduce_config(FULL))
