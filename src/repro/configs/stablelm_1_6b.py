"""StableLM-2 1.6B [hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs.base import ArchConfig, register, reduce_config

FULL = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100_352,
    norm="layernorm",
    sliding_window=8192,
    optimizer="adamw",
)

register(FULL, lambda: reduce_config(FULL))
