"""Architecture configuration system.

Every assigned architecture gets one module in this package defining a
full-size :class:`ArchConfig` (used only by the lowering dry-run — no real
allocation) plus a ``reduced()`` variant (2 layers, d_model<=512, <=4
experts) that smoke tests instantiate and train on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_d_ff: int
    # MoE replaces the dense MLP every `every` layers (1 = every layer).
    every: int = 1
    shared_expert: bool = False
    router_aux_weight: float = 0.01
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256
    expand: int = 2
    conv_width: int = 4

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # one of FAMILIES
    source: str                      # citation / model card
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int                        # dense MLP hidden (per-expert ff lives in moe)
    vocab: int
    d_head: Optional[int] = None     # explicit head dim (qwen3); default d_model//n_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: one attention layer every `attn_every` layers; rest are SSM.
    attn_every: int = 0
    causal: bool = True              # False => encoder-only (audio)
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu (swiglu) | gelu
    tie_embeddings: bool = False
    # sliding-window attention (tokens); None = full attention.  The
    # long_500k decode shape forces a window for full-attention archs.
    sliding_window: Optional[int] = None
    # modality frontend stub: number of embedding positions supplied by the
    # stubbed encoder for vlm/audio archs (0 for text-only).
    frontend_tokens: int = 0
    param_dtype: str = "bfloat16"
    # paper-faithful optimizer default (the paper uses SGD for most models).
    optimizer: str = "adamw"
    # kernel backends for train/prefill hot paths: "jnp" | "pallas" |
    # "auto" ("auto" = the Pallas kernels where they compile natively —
    # TPU — and the pure-jnp lowering elsewhere).  attention_backend
    # drives attn_apply; mixer_backend drives the Mamba2 SSD scan.
    attention_backend: str = "auto"
    mixer_backend: str = "auto"

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        if self.d_head is not None:
            return self.d_head
        if self.n_heads == 0:
            return 0
        return self.d_model // self.n_heads

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind string: 'attn' | 'ssm' for the mixer slot."""
        kinds = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                kinds.append("ssm")
            elif self.family == "hybrid":
                # Jamba: 1 attention layer per `attn_every` layers
                # (attention at position attn_every//2 of each period).
                kinds.append(
                    "attn" if i % self.attn_every == self.attn_every // 2 else "ssm"
                )
            else:
                kinds.append("attn")
        return tuple(kinds)

    def moe_layer_mask(self) -> Tuple[bool, ...]:
        if self.moe is None:
            return tuple(False for _ in range(self.n_layers))
        return tuple((i % self.moe.every) == (self.moe.every - 1)
                     for i in range(self.n_layers))

    # ---------------- parameter accounting (for autobatch/roofline) -----
    def param_count(self) -> int:
        d, v = self.d_model, self.vocab
        hd = self.head_dim
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        kinds = self.layer_kinds()
        moe_mask = self.moe_layer_mask()
        norm_mult = 2 if self.norm == "layernorm" else 1  # scale (+bias)
        for i in range(self.n_layers):
            total += 2 * d * norm_mult  # pre-norms
            if kinds[i] == "attn":
                total += d * self.n_heads * hd          # q
                total += 2 * d * self.n_kv_heads * hd   # k,v
                total += self.n_heads * hd * d          # o
            else:
                s = self.ssm
                di = s.d_inner(d)
                nh = s.n_heads(d)
                conv_ch = di + 2 * s.n_groups * s.d_state
                # in_proj -> [z, x, B, C, dt]
                total += d * (2 * di + 2 * s.n_groups * s.d_state + nh)
                total += conv_ch * s.conv_width + conv_ch  # depthwise conv + bias
                total += nh * 3             # dt_bias, A_log, D
                total += di                 # gated-norm scale
                total += di * d             # out_proj
            if moe_mask[i]:
                m = self.moe
                total += d * m.n_experts            # router
                total += m.n_experts * 3 * d * m.expert_d_ff
                if m.shared_expert:
                    total += 3 * d * (self.d_ff or m.expert_d_ff)
            elif self.d_ff:
                mult = 3 if self.act == "silu" else 2
                total += mult * d * self.d_ff
        total += d * norm_mult  # final norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE counts top_k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full_experts = self.n_layers // m.every * m.n_experts * 3 * self.d_model * m.expert_d_ff
        active_experts = self.n_layers // m.every * m.top_k * 3 * self.d_model * m.expert_d_ff
        return self.param_count() - full_experts + active_experts


_REGISTRY: dict = {}


def register(cfg_full, reduced_fn):
    _REGISTRY[cfg_full.name] = (cfg_full, reduced_fn)
    return cfg_full


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name][0]


def get_reduced(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REGISTRY[name][1]()


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        llama4_maverick_400b_a17b,
        llava_next_mistral_7b,
        jamba_1_5_large_398b,
        hubert_xlarge,
        stablelm_1_6b,
        mamba2_2_7b,
        granite_3_2b,
        glm4_9b,
        qwen3_moe_30b_a3b,
        codeqwen1_5_7b,
    )


def reduce_config(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Generic reduced variant: 2 layers, d_model<=512, <=4 experts."""
    changes = dict(
        n_layers=2,
        d_model=min(cfg.d_model, 256),
        n_heads=min(cfg.n_heads, 4) if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab=min(cfg.vocab, 512),
        d_head=64 if cfg.d_head is not None else None,
        frontend_tokens=min(cfg.frontend_tokens, 16) if cfg.frontend_tokens else 0,
        param_dtype="float32",
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            expert_d_ff=min(cfg.moe.expert_d_ff, 256),
            every=min(cfg.moe.every, 2),
        )
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=32, head_dim=32, chunk=32)
    if cfg.attn_every:
        changes["attn_every"] = 2
        changes["n_layers"] = 4  # keep one attn + ssm mix
    if cfg.sliding_window:
        changes["sliding_window"] = 64
    changes.update(overrides)
    changes["name"] = cfg.name + "-reduced"
    return dataclasses.replace(cfg, **changes)
