"""GLM-4 9B [hf:THUDM/glm-4-9b] — RoPE, aggressive GQA (kv=2)."""
from repro.configs.base import ArchConfig, register, reduce_config

FULL = ArchConfig(
    name="glm4-9b",
    family="dense",
    source="hf:THUDM/glm-4-9b",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=151_552,
    sliding_window=8192,
    optimizer="adamw",
)

register(FULL, lambda: reduce_config(FULL))
