"""Jamba-1.5-Large 398B [arXiv:2403.19887].

Hybrid Mamba+attention, 1:7 attn:mamba interleave, MoE 16 experts top-2 on
every other layer.
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register, reduce_config

FULL = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65_536,
    moe=MoEConfig(n_experts=16, top_k=2, expert_d_ff=24576, every=2),
    ssm=SSMConfig(d_state=128, head_dim=128, n_groups=8, chunk=256, expand=2),
    attn_every=8,          # 1 attention layer per 8 => 1:7 interleave
    optimizer="sgdm",      # 398B-class memory budget (see DESIGN.md §5)
)

register(FULL, lambda: reduce_config(FULL))
