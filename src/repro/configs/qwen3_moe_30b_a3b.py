"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — 128 experts, top-8, head_dim=128."""
from repro.configs.base import ArchConfig, MoEConfig, register, reduce_config

FULL = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=0,                # all layers are MoE (no dense MLP layers)
    vocab=151_936,
    d_head=128,            # explicit head_dim (> d_model // n_heads)
    moe=MoEConfig(n_experts=128, top_k=8, expert_d_ff=768, every=1),
    sliding_window=8192,
    optimizer="adamw",
)

register(FULL, lambda: reduce_config(FULL))
