"""Granite-3.0 2B base [hf:ibm-granite/granite-3.0-2b-base] — GQA."""
from repro.configs.base import ArchConfig, register, reduce_config

FULL = ArchConfig(
    name="granite-3-2b",
    family="dense",
    source="hf:ibm-granite/granite-3.0-2b-base",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=49_155,
    tie_embeddings=True,
    sliding_window=8192,
    optimizer="adamw",
)

register(FULL, lambda: reduce_config(FULL))
