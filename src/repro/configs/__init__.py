from repro.configs.base import (
    ArchConfig,
    MoEConfig,
    SSMConfig,
    get_config,
    get_reduced,
    list_archs,
    reduce_config,
)

__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig",
    "get_config", "get_reduced", "list_archs", "reduce_config",
]
