"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

VLM: anyres-tiled vision frontend is a STUB per instructions —
``input_specs()`` supplies projected patch embeddings; this config is the
Mistral-7B language decoder that consumes them.
"""
from repro.configs.base import ArchConfig, register, reduce_config

FULL = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32_000,
    frontend_tokens=1024,  # anyres patch embeddings supplied by the stub
    sliding_window=8192,
    optimizer="adamw",
)

register(FULL, lambda: reduce_config(FULL))
