"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B] — qwen1.5 arch, MHA kv=32."""
from repro.configs.base import ArchConfig, register, reduce_config

FULL = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    source="hf:Qwen/CodeQwen1.5-7B",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92_416,
    sliding_window=8192,
    optimizer="adamw",
)

register(FULL, lambda: reduce_config(FULL))
