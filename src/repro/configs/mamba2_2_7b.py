"""Mamba2-2.7B [arXiv:2405.21060] — SSD (state-space duality), attention-free."""
from repro.configs.base import ArchConfig, SSMConfig, register, reduce_config

FULL = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=64,
    d_model=2560,
    n_heads=0,             # attention-free
    n_kv_heads=0,
    d_ff=0,                # Mamba2 blocks have no separate MLP
    vocab=50_280,
    ssm=SSMConfig(d_state=128, head_dim=64, n_groups=1, chunk=256, expand=2),
    tie_embeddings=True,
    optimizer="adamw",
)

register(FULL, lambda: reduce_config(FULL))
