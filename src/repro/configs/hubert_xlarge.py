"""HuBERT X-Large [arXiv:2106.07447].

Encoder-only audio transformer (same backbone as wav2vec2).  The
mel/conv feature extractor is a STUB per instructions — ``input_specs()``
supplies frame embeddings; loss is masked-prediction CE over the 504-unit
(500 clusters + specials) codebook.  No decode step exists (encoder-only):
decode_32k / long_500k are skipped, see DESIGN.md §4.
"""
from repro.configs.base import ArchConfig, register, reduce_config

FULL = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    source="arXiv:2106.07447",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,          # encoder-only
    norm="layernorm",
    act="gelu",
    frontend_tokens=-1,    # the whole input is frontend frames
    optimizer="adamw",
)

register(FULL, lambda: reduce_config(FULL))
