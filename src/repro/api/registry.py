"""Runner registry: kind -> Runner, plus the top-level ``run()``.

A runner is anything with ``run(spec) -> RunReport`` — usually a plain
function registered via ``@register_runner(kind)``.  The five built-in
kinds are adapters over the existing launch bodies and are imported
lazily, so ``import repro.api`` never imports jax.

A kind may declare process-env prerequisites (``register_runner(...,
env=...)`` or ``_KIND_ENV`` for the lazy built-ins); ``run()`` applies
them with ``setdefault`` before the runner module — and therefore jax —
loads.  That makes the dryrun/perfprobe fake-device trick work whenever
the fake-device kind is the first jax user in the process; if another
kind already initialized the backend with fewer devices, the mesh layer
raises an actionable error (jax cannot resize a live backend).

Adding a workload kind is one registry entry:

    from repro.api import RunReport, register_runner

    @register_runner("evaluate")
    def run_evaluate(spec):
        ...
        return RunReport(kind="evaluate", name=spec.run_name, ...)
"""
from __future__ import annotations

import importlib
import os
import time
import traceback
from typing import Callable, Dict, List, Optional, Union

from repro.api.report import FAILED, RunReport
from repro.api.spec import RunSpec

RunnerFn = Callable[[RunSpec], RunReport]


class Runner:
    """Optional base class for stateful runners."""

    kind: str = ""

    def run(self, spec: RunSpec) -> RunReport:  # pragma: no cover
        raise NotImplementedError


_RUNNERS: Dict[str, Union[RunnerFn, Runner]] = {}

# Built-in kinds resolve on first use by importing the module that
# registers them (keeps ``import repro.api`` free of jax).
_LAZY_BUILTINS = {
    "train": "repro.api.runners.train",
    "serve": "repro.api.runners.serve",
    "dryrun": "repro.api.runners.dryrun",
    "perfprobe": "repro.api.runners.perfprobe",
    "simulate": "repro.api.runners.simulate",
}

_FAKE_DEVICES = {"XLA_FLAGS": "--xla_force_host_platform_device_count=512"}
# per-kind process-env prerequisites, applied (setdefault) by run()
# before the runner module loads
_KIND_ENV: Dict[str, Dict[str, str]] = {
    "dryrun": _FAKE_DEVICES,       # lower against the 512-chip CPU mesh
    "perfprobe": _FAKE_DEVICES,
}


def register_runner(kind: str, runner: Union[RunnerFn, Runner, None] = None,
                    *, env: Optional[Dict[str, str]] = None):
    """Register a runner for ``kind``; usable as a decorator.  ``env``
    declares process-env defaults the kind needs in place before it (or
    jax) first loads."""
    if env:
        _KIND_ENV[kind] = dict(env)
    if runner is not None:
        _RUNNERS[kind] = runner
        return runner

    def deco(fn):
        _RUNNERS[kind] = fn
        return fn
    return deco


def prepare_env(kind: str) -> None:
    """Apply a kind's declared env prerequisites (non-destructively)."""
    for key, val in _KIND_ENV.get(kind, {}).items():
        os.environ.setdefault(key, val)


def get_runner(kind: str) -> Union[RunnerFn, Runner]:
    if kind not in _RUNNERS and kind in _LAZY_BUILTINS:
        importlib.import_module(_LAZY_BUILTINS[kind])
    if kind not in _RUNNERS:
        raise KeyError(f"no runner registered for kind {kind!r}; "
                       f"known kinds: {runner_kinds()}")
    return _RUNNERS[kind]


def runner_kinds() -> List[str]:
    return sorted(set(_RUNNERS) | set(_LAZY_BUILTINS))


def run(spec: RunSpec) -> RunReport:
    """Execute a spec through its registered runner.

    Exceptions become a ``failed`` RunReport (the job-level fault barrier
    the orchestrator relies on); timing and spec provenance are filled in
    if the runner didn't.
    """
    prepare_env(spec.kind)
    runner = get_runner(spec.kind)
    call = runner.run if isinstance(runner, Runner) else runner
    t0 = time.time()
    try:
        report = call(spec)
    except Exception as e:  # noqa: BLE001 — uniform failure reporting
        return RunReport(
            kind=spec.kind, name=spec.run_name, status=FAILED,
            wall_s=round(time.time() - t0, 3),
            error=f"{type(e).__name__}: {e}",
            metrics={"traceback": traceback.format_exc()[-2000:]},
            spec=spec.to_dict())
    if not isinstance(report, RunReport):
        raise TypeError(f"runner for {spec.kind!r} returned "
                        f"{type(report).__name__}, expected RunReport")
    updates = {}
    if report.wall_s == 0.0:
        updates["wall_s"] = round(time.time() - t0, 3)
    if report.spec is None:
        updates["spec"] = spec.to_dict()
    return report.replace(**updates) if updates else report
