"""`RunSpec` — the single typed description of *any* run.

The seed repo had five disconnected entrypoints (train, serve, dryrun,
perfprobe, submit), each with its own argparse schema, kwargs signature
and result dict.  `RunSpec` is the one declarative surface that all of
them now share: the same spec round-trips through

* CLI flags            — :meth:`RunSpec.from_args` (``repro.launch run``)
* env-var manifests    — :meth:`RunSpec.to_env` / :meth:`RunSpec.from_env`
                         (the paper's bash-automation interface: a
                         Kubernetes Job passes the experiment definition
                         to the container via environment variables)
* JSON configs         — :meth:`RunSpec.to_json` / :meth:`RunSpec.from_json`
                         (the paper's per-experiment JSON config file)
* grid expansion       — :meth:`RunSpec.from_experiment` /
                         :meth:`RunSpec.to_experiment`
                         (``ExperimentSpec.params`` <-> ``overrides``)

Execution happens through the runner registry (:mod:`repro.api.registry`):
``run(spec) -> RunReport``.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.core.experiment import ExperimentSpec
from repro.core.jobs import JobSpec, Resources

# Kinds shipped with the repo.  The registry accepts new kinds freely —
# a sixth workload is a ``@register_runner`` entry, not a new entrypoint —
# this tuple just drives CLI help and validation error messages.
KNOWN_KINDS = ("train", "serve", "dryrun", "perfprobe", "simulate")

# Kinds whose runner understands a ``resume`` override (restart from the
# last durable checkpoint).  ``to_job`` gives these a retry-env overlay so
# an orchestrator retry resumes instead of recomputing from step 0.
RESUMABLE_KINDS = ("train",)

# Reserved env keys; override keys are declared in RUN_OVERRIDE_KEYS so
# reconstruction never has to guess which env vars belong to the spec.
_ENV_KIND = "RUN_KIND"
_ENV_NAME = "RUN_NAME"
_ENV_ARCH = "ARCH"
_ENV_SEED = "SEED"
_ENV_OVERRIDE_KEYS = "RUN_OVERRIDE_KEYS"
_ENV_RESOURCES = "RESOURCES"
_ENV_DURATION = "DURATION_H"
_ENV_LABELS = "LABELS"
_RESERVED_ENV = {_ENV_KIND, _ENV_NAME, _ENV_ARCH, _ENV_SEED,
                 _ENV_OVERRIDE_KEYS, _ENV_RESOURCES, _ENV_DURATION,
                 _ENV_LABELS}


def _parse_scalar(text: str) -> Any:
    """str -> typed value: JSON where it parses, raw string otherwise
    (so ``"8"`` -> 8, ``"1e-05"`` -> 1e-05, ``"imagenet"`` -> str)."""
    try:
        return json.loads(text)
    except (ValueError, TypeError):
        return text


def _encode_scalar(value: Any) -> str:
    if isinstance(value, str):
        try:
            json.loads(value)
        except (ValueError, TypeError):
            return value            # unambiguous plain string
        return json.dumps(value)    # would mis-parse ("8", "true"): quote
    return json.dumps(value)


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """A fully reproducible description of one run of any kind."""

    kind: str
    arch: str = "stablelm-1.6b"
    name: Optional[str] = None
    overrides: Dict[str, Any] = dataclasses.field(default_factory=dict)
    resources: Resources = dataclasses.field(default_factory=Resources)
    seed: int = 0
    # scheduling hints, used when the spec becomes a cluster JobSpec
    duration_h: float = 1.0
    labels: Dict[str, str] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.kind or not isinstance(self.kind, str):
            raise ValueError(f"RunSpec.kind must be a non-empty string, "
                             f"got {self.kind!r} (known: {KNOWN_KINDS})")
        bad = _RESERVED_ENV.intersection(k.upper() for k in self.overrides)
        if bad:
            raise ValueError(f"override keys collide with reserved env "
                             f"names: {sorted(bad)}")

    # ----------------------------------------------------------- naming
    @property
    def run_name(self) -> str:
        """Explicit name, or a deterministic one derived from content."""
        if self.name:
            return self.name
        base = f"{self.kind}-{self.arch}".replace("_", "-").replace(".", "p")
        if self.overrides:
            return f"{base}-{self.short_hash()}"
        return base

    def short_hash(self) -> str:
        return hashlib.sha1(self.to_json().encode()).hexdigest()[:8]

    # ------------------------------------------------------------- JSON
    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "arch": self.arch,
            "name": self.name,
            "overrides": dict(self.overrides),
            "resources": dataclasses.asdict(self.resources),
            "seed": self.seed,
            "duration_h": self.duration_h,
            "labels": dict(self.labels),
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True,
                          default=str)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RunSpec":
        res = d.get("resources", {})
        if isinstance(res, Mapping):
            res = Resources(**res)
        return cls(kind=d["kind"], arch=d.get("arch", "stablelm-1.6b"),
                   name=d.get("name"), overrides=dict(d.get("overrides", {})),
                   resources=res, seed=int(d.get("seed", 0)),
                   duration_h=float(d.get("duration_h", 1.0)),
                   labels=dict(d.get("labels", {})))

    @classmethod
    def from_json(cls, text: str) -> "RunSpec":
        return cls.from_dict(json.loads(text))

    # -------------------------------------------------------------- env
    def to_env(self, *, full: bool = False) -> Dict[str, str]:
        """The paper's bash interface: the spec as container env vars.

        Default form carries kind/arch/seed/name + overrides (what a Job
        manifest shows); ``full=True`` adds resources/duration/labels so
        ``from_env(to_env(full=True))`` reconstructs the spec exactly.
        """
        env = {_ENV_KIND: self.kind, _ENV_ARCH: self.arch,
               _ENV_SEED: str(self.seed)}
        if self.name:
            env[_ENV_NAME] = self.name
        env[_ENV_OVERRIDE_KEYS] = ",".join(sorted(self.overrides))
        for k, v in sorted(self.overrides.items()):
            env[k.upper()] = _encode_scalar(v)
        if full:
            env[_ENV_RESOURCES] = json.dumps(
                dataclasses.asdict(self.resources), sort_keys=True)
            env[_ENV_DURATION] = repr(self.duration_h)
            env[_ENV_LABELS] = json.dumps(self.labels, sort_keys=True)
        return env

    @classmethod
    def from_env(cls, env: Optional[Mapping[str, str]] = None,
                 *, kind: Optional[str] = None) -> "RunSpec":
        """Rebuild a spec from environment variables (``os.environ`` by
        default).  Override keys come from ``RUN_OVERRIDE_KEYS`` when
        present (``to_env`` always writes it).  Without the declaration,
        an explicitly passed mapping is treated as curated — every
        non-reserved uppercase key becomes an override (the hand-written
        bash interface) — while bare ``os.environ`` contributes no
        overrides, so PATH/XLA_FLAGS/... are never swept in."""
        curated = env is not None
        env = dict(os.environ if env is None else env)
        k = kind or env.get(_ENV_KIND)
        if not k:
            raise ValueError(f"no {_ENV_KIND} in environment and no "
                             f"kind= given (known kinds: {KNOWN_KINDS})")
        resources = Resources()
        if _ENV_RESOURCES in env:
            resources = Resources(**json.loads(env[_ENV_RESOURCES]))
        if _ENV_OVERRIDE_KEYS in env:
            declared = [key for key in
                        env[_ENV_OVERRIDE_KEYS].split(",") if key]
            missing = [key for key in declared if key.upper() not in env]
            if missing:
                raise ValueError(f"{_ENV_OVERRIDE_KEYS} declares "
                                 f"{missing} but the env vars are not set")
            overrides = {key: _parse_scalar(env[key.upper()])
                         for key in declared}
        elif curated:
            overrides = {key.lower(): _parse_scalar(val)
                         for key, val in env.items()
                         if key not in _RESERVED_ENV and key.isupper()}
        else:
            overrides = {}
        return cls(kind=k, arch=env.get(_ENV_ARCH, "stablelm-1.6b"),
                   name=env.get(_ENV_NAME), overrides=overrides,
                   resources=resources,
                   seed=int(env.get(_ENV_SEED, 0)),
                   duration_h=float(env.get(_ENV_DURATION, 1.0)),
                   labels=json.loads(env.get(_ENV_LABELS, "{}")))

    # -------------------------------------------------------------- CLI
    @classmethod
    def from_args(cls, argv: Sequence[str]) -> "RunSpec":
        """Build a spec from CLI tokens: ``<kind> [--arch A] [--seed N]
        [--name NAME] [--key value | --key=value | --flag] ...``.

        Unknown ``--key`` flags become overrides (dashes -> underscores,
        values JSON-parsed), so every runner knob is reachable without a
        per-kind argparse schema.
        """
        ap = argparse.ArgumentParser(
            prog="repro.launch run", add_help=False,
            description="unified run dispatcher")
        ap.add_argument("kind")
        ap.add_argument("--arch",
                        default=os.environ.get(_ENV_ARCH, "stablelm-1.6b"))
        ap.add_argument("--seed", type=int,
                        default=int(os.environ.get(_ENV_SEED, 0)))
        ap.add_argument("--name", default=None)
        ns, extra = ap.parse_known_args(list(argv))
        return cls(kind=ns.kind, arch=ns.arch, seed=ns.seed, name=ns.name,
                   overrides=_parse_extra_flags(extra))

    # ------------------------------------------------- experiment grids
    @classmethod
    def from_experiment(cls, spec: ExperimentSpec, *, kind: str = "train",
                        arch: str = "stablelm-1.6b",
                        resources: Optional[Resources] = None,
                        seed: int = 0, duration_h: float = 1.0,
                        labels: Optional[Dict[str, str]] = None) -> "RunSpec":
        """An :class:`ExperimentSpec` (one grid point) as a RunSpec:
        ``params`` become ``overrides``, the grid name is kept.  Params
        named after core spec fields (``arch``, ``seed``) land on those
        fields instead of in overrides."""
        params = dict(spec.params)
        arch = str(params.pop("arch", arch))
        seed = int(params.pop("seed", seed))
        return cls(kind=kind, arch=arch, name=spec.name, overrides=params,
                   resources=resources or Resources(), seed=seed,
                   duration_h=duration_h, labels=dict(labels or {}))

    def to_experiment(self) -> ExperimentSpec:
        return ExperimentSpec(self.run_name, dict(self.overrides))

    # ------------------------------------------------------ cluster job
    def to_job(self, payload=None) -> JobSpec:
        """The spec as a schedulable cluster job (manifest env in the
        paper's uppercase bash style).  Resumable kinds additionally get
        a ``retry_env`` — the same spec with ``resume=True`` — so an
        orchestrator retry continues from the last checkpoint instead of
        restarting."""
        retry_env: Dict[str, str] = {}
        if self.kind in RESUMABLE_KINDS and "resume" not in self.overrides:
            retry_env = self.replace(
                overrides={**self.overrides, "resume": True}).to_env()
        # a data-parallel world_size makes the job a gang: all ranks
        # placed atomically by the executor (per-rank `resources`);
        # gang_min opts the gang into elastic shrink on requeue
        gang = max(1, int(self.overrides.get("world_size") or 1))
        return JobSpec(name=self.run_name, payload=payload,
                       env=self.to_env(), retry_env=retry_env,
                       resources=self.resources,
                       priority=int(self.labels.get("priority", 0)),
                       gang=gang,
                       gang_min=int(self.overrides.get("gang_min") or 0),
                       duration_h=self.duration_h, labels=dict(self.labels))

    # ---------------------------------------------------------- helpers
    def merged_overrides(self, defaults: Mapping[str, Any]) -> Dict[str, Any]:
        """defaults <- overrides, rejecting unknown keys (typo guard)."""
        unknown = sorted(set(self.overrides) - set(defaults))
        if unknown:
            raise ValueError(
                f"unknown overrides for kind {self.kind!r}: {unknown}; "
                f"accepted: {sorted(defaults)}")
        return {**defaults, **self.overrides}

    def replace(self, **changes) -> "RunSpec":
        return dataclasses.replace(self, **changes)


def _parse_extra_flags(tokens: Sequence[str]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    i = 0
    while i < len(tokens):
        tok = tokens[i]
        if not tok.startswith("--"):
            raise ValueError(f"unexpected argument {tok!r} "
                             f"(overrides are --key value / --key=value)")
        if "=" in tok:
            key, val = tok[2:].split("=", 1)
            i += 1
        elif i + 1 < len(tokens) and not tokens[i + 1].startswith("--"):
            key, val = tok[2:], tokens[i + 1]
            i += 2
        else:                       # bare flag -> boolean override
            key, val = tok[2:], "true"
            i += 1
        out[key.replace("-", "_")] = _parse_scalar(val)
    return out


def grid_to_runs(grid, *, kind: str = "train", arch: str = "stablelm-1.6b",
                 resources: Optional[Resources] = None, seed: int = 0,
                 duration_h: float = 1.0,
                 labels: Optional[Dict[str, str]] = None) -> List[RunSpec]:
    """Expand an :class:`~repro.core.experiment.ExperimentGrid` straight
    into RunSpecs (the implementation behind ``ExperimentGrid.to_runs``)."""
    return [RunSpec.from_experiment(s, kind=kind, arch=arch,
                                    resources=resources, seed=seed,
                                    duration_h=duration_h, labels=labels)
            for s in grid.expand()]
