# The typed front door for every kind of run: RunSpec in, RunReport out.
# This package deliberately imports no jax — runner adapters load lazily
# per kind (see registry._LAZY_BUILTINS), so env tricks like the dryrun
# XLA host-device-count flag still land before jax initializes.
from repro.api.report import FAILED, SKIPPED, SUCCEEDED, RunReport
from repro.api.registry import (Runner, get_runner, register_runner, run,
                                runner_kinds)
from repro.api.spec import KNOWN_KINDS, RunSpec, grid_to_runs

__all__ = [
    "RunSpec", "RunReport", "Runner",
    "register_runner", "get_runner", "run", "runner_kinds",
    "grid_to_runs", "KNOWN_KINDS",
    "SUCCEEDED", "FAILED", "SKIPPED",
]
