"""`RunReport` — the single typed result of *any* run.

Replaces the five incompatible ad-hoc result dicts the seed entrypoints
returned.  Every runner produces one; the orchestrator serializes it
uniformly to the PVC / S3 stores.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Mapping, Optional, Tuple

SUCCEEDED = "succeeded"
FAILED = "failed"
SKIPPED = "skipped"
_STATUSES = (SUCCEEDED, FAILED, SKIPPED)


@dataclasses.dataclass(frozen=True)
class RunReport:
    kind: str
    name: str
    status: str = SUCCEEDED
    metrics: Dict[str, Any] = dataclasses.field(default_factory=dict)
    wall_s: float = 0.0
    artifacts: Tuple[str, ...] = ()
    error: Optional[str] = None
    spec: Optional[Dict[str, Any]] = None    # RunSpec.to_dict() provenance

    def __post_init__(self):
        if self.status not in _STATUSES:
            raise ValueError(f"status must be one of {_STATUSES}, "
                             f"got {self.status!r}")
        # artifacts arrive as lists from runners / JSON; normalize
        object.__setattr__(self, "artifacts", tuple(self.artifacts))

    @property
    def ok(self) -> bool:
        return self.status != FAILED

    # ------------------------------------------------------------- JSON
    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "name": self.name,
            "status": self.status,
            "metrics": dict(self.metrics),
            "wall_s": self.wall_s,
            "artifacts": list(self.artifacts),
            "error": self.error,
            "spec": self.spec,
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1, sort_keys=True,
                          default=str)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RunReport":
        return cls(kind=d["kind"], name=d["name"],
                   status=d.get("status", SUCCEEDED),
                   metrics=dict(d.get("metrics", {})),
                   wall_s=float(d.get("wall_s", 0.0)),
                   artifacts=tuple(d.get("artifacts", ())),
                   error=d.get("error"), spec=d.get("spec"))

    @classmethod
    def from_json(cls, text: str) -> "RunReport":
        return cls.from_dict(json.loads(text))

    def replace(self, **changes) -> "RunReport":
        return dataclasses.replace(self, **changes)

    # ---------------------------------------------------------- summary
    def summary(self) -> str:
        head = f"[{self.kind}] {self.name}: {self.status}"
        if self.error:
            return f"{head} ({self.error})"
        keys = list(self.metrics)[:4]
        tail = " ".join(f"{k}={self.metrics[k]}" for k in keys)
        return f"{head} wall_s={self.wall_s:.2f} {tail}".rstrip()
