"""perfprobe runner: adapts :func:`repro.launch.perfprobe.probe`."""
from __future__ import annotations

import time

from repro.api.report import RunReport
from repro.api.registry import register_runner
from repro.api.spec import RunSpec

DEFAULTS = {
    "shape": None,          # required
    "layout": "fsdp_tp",
    "multi_pod": False,
    "microbatches": 1,
    "save": None,
}


@register_runner("perfprobe")
def run_perfprobe(spec: RunSpec) -> RunReport:
    from repro.launch.perfprobe import probe
    o = spec.merged_overrides(DEFAULTS)
    if not o["shape"]:
        raise ValueError("perfprobe requires a --shape override")
    t0 = time.time()
    rec = probe(spec.arch, o["shape"], o["layout"],
                multi_pod=bool(o["multi_pod"]),
                microbatches=int(o["microbatches"]), save=o["save"])
    return RunReport(kind="perfprobe", name=spec.run_name, metrics=rec,
                     wall_s=round(time.time() - t0, 3),
                     artifacts=(o["save"],) if o["save"] else (),
                     spec=spec.to_dict())
