"""simulate runner: campaign expansion -> manifests -> cluster-sim
accounting (the paper's Tables III/V bottom lines), via the same
Orchestrator path the seed ``repro.launch.submit`` CLI used.
"""
from __future__ import annotations

import time

from repro.api.report import RunReport
from repro.api.registry import register_runner
from repro.api.spec import RunSpec

DEFAULTS = {
    "campaign": "burned_area",   # burned_area | detection | deforestation | all
    "mode": "simulate",          # simulate | manifests
    "workdir": "experiments/campaigns",
    "preemption_rate": 0.0,      # per-attempt preemption probability
    "checkpoint_every_h": 0.0,   # durable-checkpoint cadence (0 = restart
                                 # from scratch on preemption)
    "placement": "best_fit",     # best_fit | worst_fit | pack — same
                                 # names as `campaign run --placement`
}

CAMPAIGNS = ("burned_area", "detection", "deforestation")


@register_runner("simulate")
def run_simulate(spec: RunSpec) -> RunReport:
    from repro.core import Orchestrator, PersistentVolume, S3Store
    from repro.launch.submit import build_campaign_runs

    o = spec.merged_overrides(DEFAULTS)
    if o["mode"] not in ("simulate", "manifests"):
        raise ValueError(f"mode must be simulate|manifests, got {o['mode']!r}")
    names = CAMPAIGNS if o["campaign"] == "all" else (o["campaign"],)
    t0 = time.time()
    runs = []
    for n in names:
        runs.extend(build_campaign_runs(n))

    pvc = PersistentVolume(o["workdir"], name=f"campaign-{o['campaign']}")
    orch = Orchestrator(pvc, S3Store(o["workdir"]))
    orch.submit_runs(runs)
    n_manifests = len(pvc.listdir("manifests"))
    print(f"submitted {len(runs)} jobs; {n_manifests} manifests rendered")

    metrics = {"jobs": len(runs), "manifests": n_manifests}
    if o["mode"] == "simulate":
        res = orch.simulate(preemption_rate=float(o["preemption_rate"]),
                            checkpoint_every_h=float(o["checkpoint_every_h"]),
                            placement=o["placement"])
        metrics.update({
            "total_gpu_hours": round(res.total_gpu_hours, 1),
            "total_wall_hours": round(res.total_wall_hours, 1),
            "cluster_makespan_h": round(res.makespan_h, 2),
            "speedup_vs_serial": round(res.speedup_vs_serial(), 1),
            "mean_queue_wait_h": round(res.queue_wait_h_mean, 3),
            "placement": o["placement"],
            "busy_utilization": round(res.busy_utilization, 4),
            "goodput_utilization": round(res.goodput_utilization, 4),
        })
        if float(o["preemption_rate"]) > 0:
            metrics.update({
                "preemptions": res.preemptions,
                "lost_gpu_hours": round(res.lost_gpu_hours, 1),
                "goodput": round(res.goodput, 4),
            })
    return RunReport(kind="simulate", name=spec.run_name, metrics=metrics,
                     wall_s=round(time.time() - t0, 3),
                     artifacts=(str(pvc.root / "manifests"),),
                     spec=spec.to_dict())
