# Built-in runner adapters, one module per kind.  Imported lazily by the
# registry so `import repro.api` stays jax-free; importing this package
# eagerly registers everything (useful for tests / introspection).
from repro.api.runners import (dryrun, perfprobe, serve,  # noqa: F401
                               simulate, train)
