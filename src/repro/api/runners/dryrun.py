"""dryrun runner: the multi-pod lowering sweep as a Runner.

``spec.arch`` may be a single arch, a comma list, or ``"all"``.
Importing this module (the registry does it lazily, before any jax use
on the CLI path) triggers ``repro.launch.dryrun``'s XLA host-device
trick so a 512-device CPU mesh is available.
"""
from __future__ import annotations

import time

from repro.api.report import FAILED, RunReport, SUCCEEDED
from repro.api.registry import register_runner
from repro.api.spec import RunSpec

DEFAULTS = {
    "shape": "all",
    "mesh": "single",       # single | multi | both
    "layout": "fsdp_tp",
    "microbatches": 1,
    "out": "experiments/dryrun",
}


@register_runner("dryrun")
def run_dryrun(spec: RunSpec) -> RunReport:
    from repro.launch.dryrun import dryrun_sweep
    from repro.launch.mesh import INPUT_SHAPES

    o = spec.merged_overrides(DEFAULTS)
    if o["mesh"] not in ("single", "multi", "both"):
        raise ValueError(f"mesh must be single|multi|both, got {o['mesh']!r}")
    if o["shape"] != "all" and o["shape"] not in INPUT_SHAPES:
        raise ValueError(f"unknown shape {o['shape']!r} "
                         f"(have {list(INPUT_SHAPES)})")

    t0 = time.time()
    results = dryrun_sweep(
        archs=spec.arch, shapes=o["shape"], meshes=o["mesh"],
        layout=o["layout"], microbatches=int(o["microbatches"]),
        out=o["out"])
    counts = {"ok": 0, "skipped": 0, "error": 0}
    for rec in results:
        counts[rec["status"]] = counts.get(rec["status"], 0) + 1
    metrics = {
        "cells": len(results),
        **counts,
        "results": [{k: r.get(k) for k in ("arch", "shape", "mesh",
                                           "layout", "status")}
                    for r in results],
    }
    if counts["error"]:
        metrics["errors"] = [
            {"arch": r["arch"], "shape": r["shape"], "error": r["error"]}
            for r in results if r["status"] == "error"]
    artifacts = tuple(
        f"{o['out']}/{r['arch']}_{r['shape']}_{r['mesh']}_{r['layout']}.json"
        for r in results) if o["out"] else ()
    return RunReport(
        kind="dryrun", name=spec.run_name,
        status=FAILED if counts["error"] else SUCCEEDED,
        error=(f"{counts['error']}/{len(results)} cells failed"
               if counts["error"] else None),
        metrics=metrics, wall_s=round(time.time() - t0, 3),
        artifacts=artifacts, spec=spec.to_dict())
