"""train runner: adapts :func:`repro.launch.train.train_main`."""
from __future__ import annotations

import time

from repro.api.report import RunReport
from repro.api.registry import register_runner
from repro.api.spec import RunSpec

DEFAULTS = {
    "full": False,          # full-size config instead of reduced
    "steps": 100,
    "batch": 8,
    "seq": 128,
    "lr": 3e-4,
    "optimizer": None,
    "checkpoint_dir": None,
    "checkpoint_every": 0,   # full-TrainState save cadence (steps); 0 = end only
    "checkpoint_keep": 3,    # keep-last-N rotation
    "checkpoint_async": True,  # background-thread saves off the hot path
    "resume": False,         # restore newest valid checkpoint before training
    "preempt_at_step": None,  # fault hook: raise Preemption before this step
    "s3_root": None,
    "log_every": 10,
    "precision": "f32",       # mixed-precision policy (f32 | bf16)
    "grad_clip": None,        # clip global grad norm (fused with the metric)
    "attention_backend": None,  # jnp | pallas | auto (None = config default)
    "mixer_backend": None,      # jnp | pallas | auto (None = config default)
    # -- data-parallel (repro.distributed): batch is the GLOBAL batch --
    "world_size": 1,          # >1 = N-process data-parallel gang
    "gang_min": 0,            # >=1 lets the executor shrink a requeued
                              # gang's world down to this floor (elastic)
    "dist_rank": None,        # set per rank by the gang launcher/executor
    "coordinator": None,      # host:port of rank 0 (jax.distributed)
    "microbatches": 1,        # grad-accumulation chunks per step
}

# campaign-grid vocabulary (paper Sect. III-B axes / detection env):
# renames map onto trainer knobs; the rest is carried as provenance in
# the report, not consumed by the local LM trainer.
GRID_ALIASES = {"batch_size": "batch"}
GRID_METADATA = ("init", "dataset", "model", "config")


@register_runner("train")
def run_train(spec: RunSpec) -> RunReport:
    # no jax-importing module may load before the dist branch below:
    # jax.distributed.initialize must run before any jax computation
    overrides = dict(spec.overrides)
    grid_meta = {k: overrides.pop(k) for k in GRID_METADATA
                 if k in overrides}
    for grid_key, knob in GRID_ALIASES.items():
        if grid_key in overrides:
            overrides[knob] = overrides.pop(grid_key)
    o = spec.replace(overrides=overrides).merged_overrides(DEFAULTS)
    t0 = time.time()
    world = int(o["world_size"] or 1)
    common = dict(
        reduced=not o["full"], steps=int(o["steps"]),
        batch=int(o["batch"]), seq=int(o["seq"]), lr=float(o["lr"]),
        optimizer=o["optimizer"], seed=spec.seed,
        checkpoint_dir=o["checkpoint_dir"],
        checkpoint_every=int(o["checkpoint_every"]),
        checkpoint_keep=int(o["checkpoint_keep"]),
        checkpoint_async=bool(o["checkpoint_async"]),
        resume=bool(o["resume"]),
        preempt_at_step=(None if o["preempt_at_step"] is None
                         else int(o["preempt_at_step"])),
        s3_root=o["s3_root"], log_every=int(o["log_every"]),
        precision=str(o["precision"]),
        grad_clip=(None if o["grad_clip"] is None else float(o["grad_clip"])),
        microbatches=int(o["microbatches"]),
        attention_backend=o["attention_backend"],
        mixer_backend=o["mixer_backend"])
    if world > 1 and o["dist_rank"] is None:
        # gang self-launch: this process stays jax-free and spawns one
        # rank subprocess per process index (the executor does its own
        # per-rank spawn and never takes this path)
        from repro.distributed.gang import run_gang_local
        result = run_gang_local(spec.replace(overrides=overrides), world)
    elif o["dist_rank"] is not None:
        from repro.distributed.trainer import dist_train_main
        result = dist_train_main(
            spec.arch, world_size=world, dist_rank=int(o["dist_rank"]),
            coordinator=o["coordinator"], **common)
    else:
        from repro.launch.train import train_main
        result = train_main(spec.arch, **common)
    artifacts = []
    if o["checkpoint_dir"]:
        artifacts.append(str(o["checkpoint_dir"]))
    if o["s3_root"]:
        artifacts.append(f"{o['s3_root']}/models/{result['arch']}")
    if grid_meta:
        result = {**result, "grid_params": grid_meta}
    return RunReport(kind="train", name=spec.run_name, metrics=result,
                     wall_s=round(time.time() - t0, 3),
                     artifacts=tuple(artifacts), spec=spec.to_dict())
