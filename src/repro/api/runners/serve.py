"""serve runner: adapts :func:`repro.launch.serve.serve_main`.

Two modes behind one kind: ``arrival_rate == 0`` (default) drains a
static batch through :class:`~repro.serve.ServeEngine`;
``arrival_rate > 0`` drives the continuous-batching
:class:`~repro.serve.ServeScheduler` with an open-loop ``trace``
(``poisson`` | ``bursty``), SLO shedding (``slo_deadline_ms``) and a
paged KV pool (``max_kv_blocks`` / ``kv_block_size``).  Either way the
report's metrics carry per-request service timing (TTFT / TPOT /
queue-wait percentiles, eviction count) so campaign summaries can
aggregate serving latency like any other contract metric.
"""
from __future__ import annotations

import time

from repro.api.report import RunReport
from repro.api.registry import register_runner
from repro.api.spec import RunSpec

DEFAULTS = {
    "requests": 16,
    "slots": 4,
    "cache_len": 128,
    "max_tokens": 16,
    "temperature": 0.0,
    "top_k": 0,
    # continuous-batching knobs (CLI: --arrival-rate, --slo-deadline-ms,
    # --max-kv-blocks; 0 means "off"/"auto" so the static path is the
    # default and every knob round-trips through overrides as a scalar)
    "arrival_rate": 0.0,
    "trace": "poisson",
    "slo_deadline_ms": 0.0,
    "max_kv_blocks": 0,
    "kv_block_size": 16,
}


@register_runner("serve")
def run_serve(spec: RunSpec) -> RunReport:
    from repro.launch.serve import serve_main
    o = spec.merged_overrides(DEFAULTS)
    t0 = time.time()
    result = serve_main(
        spec.arch, requests=int(o["requests"]), slots=int(o["slots"]),
        cache_len=int(o["cache_len"]), max_tokens=int(o["max_tokens"]),
        seed=spec.seed, temperature=float(o["temperature"]),
        top_k=int(o["top_k"]), arrival_rate=float(o["arrival_rate"]),
        trace=str(o["trace"]),
        slo_deadline_ms=float(o["slo_deadline_ms"]),
        max_kv_blocks=int(o["max_kv_blocks"]),
        kv_block_size=int(o["kv_block_size"]))
    return RunReport(kind="serve", name=spec.run_name, metrics=result,
                     wall_s=round(time.time() - t0, 3),
                     spec=spec.to_dict())
