"""serve runner: adapts :func:`repro.launch.serve.serve_main`."""
from __future__ import annotations

import time

from repro.api.report import RunReport
from repro.api.registry import register_runner
from repro.api.spec import RunSpec

DEFAULTS = {
    "requests": 16,
    "slots": 4,
    "cache_len": 128,
    "max_tokens": 16,
    "temperature": 0.0,
    "top_k": 0,
}


@register_runner("serve")
def run_serve(spec: RunSpec) -> RunReport:
    from repro.launch.serve import serve_main
    o = spec.merged_overrides(DEFAULTS)
    t0 = time.time()
    result = serve_main(
        spec.arch, requests=int(o["requests"]), slots=int(o["slots"]),
        cache_len=int(o["cache_len"]), max_tokens=int(o["max_tokens"]),
        seed=spec.seed, temperature=float(o["temperature"]),
        top_k=int(o["top_k"]))
    return RunReport(kind="serve", name=spec.run_name, metrics=result,
                     wall_s=round(time.time() - t0, 3),
                     spec=spec.to_dict())
