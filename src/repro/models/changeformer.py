"""ChangeFormer-style siamese change-detection transformer (paper
Sect. III-C, after Bandara & Patel 2022): a shared hierarchical
transformer encoder applied to both timestamps, per-stage difference
modules, and a lightweight MLP decoder that fuses multi-scale differences
into a 2-class change map."""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.models.layers import naive_attention
from repro.models.segmentation import conv, conv_init, group_norm, _upsample

Init = jax.nn.initializers.he_normal()


def _block_init(key, dim, heads, mlp_ratio=4):
    ks = jax.random.split(key, 5)
    return {
        "qkv": {"w": Init(ks[0], (dim, 3 * dim), jnp.float32)},
        "proj": {"w": Init(ks[1], (dim, dim), jnp.float32)},
        "fc1": {"w": Init(ks[2], (dim, mlp_ratio * dim), jnp.float32)},
        "fc2": {"w": Init(ks[3], (mlp_ratio * dim, dim), jnp.float32)},
        "n1": jnp.ones((dim,)), "n2": jnp.ones((dim,)),
    }


def _ln(x, scale):
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * scale


def _block_apply(p, x, H: int):
    B, T, D = x.shape
    h = _ln(x, p["n1"])
    qkv = h @ p["qkv"]["w"]
    q, k, v = jnp.split(qkv.reshape(B, T, 3, H, D // H), 3, axis=2)
    out = naive_attention(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                          causal=False, window=None)
    x = x + out.reshape(B, T, D) @ p["proj"]["w"]
    h = _ln(x, p["n2"])
    x = x + jax.nn.gelu(h @ p["fc1"]["w"]) @ p["fc2"]["w"]
    return x


def changeformer_init(key, in_ch=3, classes=2, dims=(32, 64),
                      depths=(2, 2), heads=(2, 4)):
    keys = iter(jax.random.split(key, 64))
    stages = []
    c = in_ch
    for si, d in enumerate(dims):
        stage = {
            "patch": conv_init(next(keys), 3, 3, c, d),
            "blocks": [_block_init(next(keys), d, heads[si])
                       for _ in range(depths[si])],
            # difference module: conv over concat(a, b, |a-b|)
            "diff": conv_init(next(keys), 3, 3, 3 * d, d),
        }
        stages.append(stage)
        c = d
    dec_in = sum(dims)
    return {
        "stages": stages,
        "dec1": conv_init(next(keys), 1, 1, dec_in, dims[-1]),
        "dec2": conv_init(next(keys), 3, 3, dims[-1], dims[-1]),
        "head": conv_init(next(keys), 1, 1, dims[-1], classes),
    }


DEFAULT_HEADS = (2, 4)


def _encode(stages, x, heads=DEFAULT_HEADS):
    feats = []
    for si, st in enumerate(stages):
        x = jax.nn.relu(group_norm(conv(st["patch"], x, stride=2)))
        B, H, W, D = x.shape
        t = x.reshape(B, H * W, D)
        for blk in st["blocks"]:
            t = _block_apply(blk, t, heads[si])
        x = t.reshape(B, H, W, D)
        feats.append(x)
    return feats


def changeformer_apply(params, img_a, img_b, heads=DEFAULT_HEADS):
    """img_a/img_b: (B, H, W, C) two timestamps -> (B, H, W, classes)."""
    fa = _encode(params["stages"], img_a, heads)
    fb = _encode(params["stages"], img_b, heads)
    diffs = []
    H0, W0 = fa[0].shape[1], fa[0].shape[2]
    for st, a, b in zip(params["stages"], fa, fb):
        d = jax.nn.relu(conv(st["diff"], jnp.concatenate(
            [a, b, jnp.abs(a - b)], axis=-1)))
        if d.shape[1] != H0:
            d = jax.image.resize(d, (d.shape[0], H0, W0, d.shape[-1]),
                                 "bilinear")
        diffs.append(d)
    y = jnp.concatenate(diffs, axis=-1)
    y = jax.nn.relu(conv(params["dec1"], y))
    y = jax.nn.relu(group_norm(conv(params["dec2"], y)))
    y = conv(params["head"], y)
    return _upsample(y, 2)


def changeformer_loss(params, a, b, masks):
    logits = changeformer_apply(params, a, b)
    ll = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(masks, logits.shape[-1])
    return -(onehot * ll).sum(-1).mean()
