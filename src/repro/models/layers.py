"""Core neural layers: norms, projections, RoPE, attention (GQA, causal /
bidirectional / sliding-window; naive, chunked-flash and decode paths),
and gated MLPs.  Parameters are plain pytrees (nested dicts); every layer
is an ``init`` + ``apply`` pair of pure functions.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import resolve_backend
from repro.kernels.flash_attention import flash_attention as \
    flash_attention_pallas
from repro.sharding import constrain

DEFAULT_INIT_SCALE = 0.02


# --------------------------------------------------------------------------
# basics
# --------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype) -> dict:
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * DEFAULT_INIT_SCALE
    return {"w": w.astype(dtype)}


def dense(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ params["w"]


def norm_init(kind: str, d: int, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype=dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype=dtype)
    return p


def norm_apply(kind: str, params: dict, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    else:
        raise ValueError(kind)
    y = y * params["scale"].astype(jnp.float32)
    if "bias" in params:
        y = y + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# --------------------------------------------------------------------------
def mlp_init(key, d: int, d_ff: int, act: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d, d_ff, dtype),
         "down": dense_init(ks[1], d_ff, d, dtype)}
    if act == "silu":  # gated
        p["gate"] = dense_init(ks[2], d, d_ff, dtype)
    return p


def mlp_apply(params: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    h = dense(params["up"], x)
    if act == "silu":
        h = jax.nn.silu(dense(params["gate"], x)) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", "seq", "mlp")
    return dense(params["down"], h)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    causal: bool = True
    window: Optional[int] = None     # sliding window (tokens), None = full
    rope_theta: float = 10_000.0
    # pure-JAX flash chunking (used when seq > naive_threshold).  The
    # threshold admits train_4k through the unchunked path: with
    # sequence-parallel activations the (B,H,Sq_local,Sk) score tile is
    # small, and the chunk reshape would fight the S-sharding.
    q_chunk: int = 1024
    k_chunk: int = 1024
    naive_threshold: int = 4096
    # kernel backend for train/prefill self-attention: "jnp" (naive /
    # chunked-flash lowering), "pallas" (repro.kernels.flash_attention,
    # custom-VJP so it trains), or "auto" (pallas where it compiles
    # natively — TPU — jnp elsewhere).
    backend: str = "jnp"


def attn_init(key, d_model: int, spec: AttnSpec, dtype) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, spec.n_heads * spec.head_dim, dtype),
        "wk": dense_init(ks[1], d_model, spec.n_kv_heads * spec.head_dim, dtype),
        "wv": dense_init(ks[2], d_model, spec.n_kv_heads * spec.head_dim, dtype),
        "wo": dense_init(ks[3], spec.n_heads * spec.head_dim, d_model, dtype),
    }


def _split_heads(x, n_heads, head_dim):
    return x.reshape(*x.shape[:-1], n_heads, head_dim)


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def _multi_device() -> bool:
    """True when a multi-device sharding context is active — the regime
    where activations may be mesh-sharded and only the jnp attention
    lowerings (which GSPMD can partition) are safe."""
    from repro.sharding.ctx import current_ctx
    ctx = current_ctx()
    return ctx is not None and ctx.mesh is not None and ctx.mesh.size > 1


def _mask_bias(q_pos, k_pos, causal, window):
    """(Sq, Sk) additive bias from absolute positions."""
    m = jnp.zeros((q_pos.shape[0], k_pos.shape[0]), jnp.float32)
    if causal:
        m = jnp.where(k_pos[None, :] > q_pos[:, None], NEG_INF, m)
    if window is not None:
        m = jnp.where(k_pos[None, :] <= q_pos[:, None] - window, NEG_INF, m)
    return m


def naive_attention(q, k, v, *, causal, window, q_offset=0):
    """q: (B,Sq,H,hd), k/v: (B,Sk,Kh,hd).  Reference/small-seq path.

    GQA is computed against the *un-repeated* K/V (grouped einsum) so no
    H-sized key/value tensor is ever materialized — on a sharded mesh the
    K/V gathers and their gradient reductions then move kv_heads-sized
    tensors, not n_heads-sized ones (8x for a 32q/4kv config).
    """
    B, Sq, H, hd = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    rep = H // Kh
    qg = q.reshape(B, Sq, Kh, rep, hd).astype(jnp.float32)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    bias = _mask_bias(jnp.arange(Sq) + q_offset, jnp.arange(Sk), causal, window)
    probs = jax.nn.softmax(scores + bias[None, None, None], axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def flash_attention_jnp(q, k, v, *, causal, window, q_chunk, k_chunk):
    """Pure-JAX blockwise online-softmax attention.

    Memory is O(q_chunk * k_chunk) per step instead of O(Sq * Sk) — this is
    the lowering path for the 32k-prefill dry-runs; the Pallas kernel in
    ``repro.kernels.flash_attention`` is the TPU runtime path.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    n_rep = H // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    nq = -(-Sq // q_chunk)
    nk = -(-Sk // k_chunk)
    pad_q = nq * q_chunk - Sq
    pad_k = nk * k_chunk - Sk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    # (nq, B, H, qc, hd)
    qb = jnp.moveaxis(qp.reshape(B, nq, q_chunk, H, hd), (1, 3), (0, 2))
    kb = jnp.moveaxis(kp.reshape(B, nk, k_chunk, H, hd), (1, 3), (0, 2))
    vb = jnp.moveaxis(vp.reshape(B, nk, k_chunk, H, hd), (1, 3), (0, 2))

    def q_step(_, qi_q):
        qi, qblk = qi_q
        qblk = qblk.astype(jnp.float32) * scale
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki_kv):
            acc, m, l = carry
            ki, kblk, vblk = ki_kv
            k_pos = ki * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk, kblk.astype(jnp.float32))
            bias = _mask_bias(q_pos, k_pos, causal, window)
            bias = jnp.where(k_pos[None, :] >= Sk, NEG_INF, bias)  # kv padding
            s = s + bias[None, None]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
            return (acc, m_new, l_new), None

        init = (jnp.zeros((B, H, q_chunk, hd), jnp.float32),
                jnp.full((B, H, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((B, H, q_chunk), jnp.float32))
        (acc, m, l), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out

    _, ob = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    # (nq, B, H, qc, hd) -> (B, Sq, H, hd)
    out = jnp.moveaxis(ob, (0, 2), (1, 3)).reshape(B, nq * q_chunk, H, hd)
    return out[:, :Sq].astype(q.dtype)


def attn_apply(params: dict, x: jnp.ndarray, spec: AttnSpec,
               positions: jnp.ndarray, return_kv: bool = False):
    """Training / prefill self-attention.  x: (B,S,d); positions: (B,S)."""
    B, S, _ = x.shape
    q = _split_heads(dense(params["wq"], x), spec.n_heads, spec.head_dim)
    k = _split_heads(dense(params["wk"], x), spec.n_kv_heads, spec.head_dim)
    v = _split_heads(dense(params["wv"], x), spec.n_kv_heads, spec.head_dim)
    q = apply_rope(q, positions, spec.rope_theta)
    k = apply_rope(k, positions, spec.rope_theta)
    # q keeps the sequence shard (fsdp_sp) or the head shard (fsdp_tp);
    # k/v replicate over seq at KV-HEAD granularity — the cheap gather
    # (kv_heads * hd << n_heads * hd for GQA).
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    window = spec.window if (spec.window and spec.window < S) else None
    if resolve_backend(spec.backend) == "pallas" and not _multi_device():
        # pallas only on single-device runs: pallas_call has no GSPMD
        # partitioning rule, so mesh-sharded programs (the multi-pod
        # launchers) stay on the partitionable jnp lowerings below
        out = flash_attention_pallas(q, k, v, causal=spec.causal,
                                     window=window)
    elif S <= spec.naive_threshold:
        out = naive_attention(q, k, v, causal=spec.causal, window=window)
    else:
        out = flash_attention_jnp(q, k, v, causal=spec.causal, window=window,
                                  q_chunk=spec.q_chunk, k_chunk=spec.k_chunk)
    out = constrain(out, "batch", "seq", "heads", None)
    out = out.reshape(B, S, spec.n_heads * spec.head_dim)
    out = dense(params["wo"], out)
    if return_kv:
        return out, (k, v)
    return out


def kv_to_cache(k: jnp.ndarray, v: jnp.ndarray, cache_len: int, dtype,
                lengths=None) -> dict:
    """Place prefill keys/values (B,S,Kh,hd) into the decode cache layout
    (ring buffer of ``cache_len`` slots; slot for position p is
    ``p % cache_len``).

    ``lengths`` ((B,) int32, optional) marks true per-row prompt lengths
    for right-padded batches: slot j then takes the row's last kept
    position congruent to j — ``(len-1) - ((len-1-j) % cache_len)`` — the
    same ring layout :func:`attn_decode` expects, so pad keys never enter
    the cache and window eviction counts real tokens, not pad.
    """
    B, S, Kh, hd = k.shape
    if lengths is None:
        buf_k = jnp.zeros((B, cache_len, Kh, hd), dtype)
        buf_v = jnp.zeros((B, cache_len, Kh, hd), dtype)
        start = max(0, S - cache_len)
        slots = (jnp.arange(start, S) % cache_len).astype(jnp.int32)
        buf_k = buf_k.at[:, slots].set(k[:, start:].astype(dtype))
        buf_v = buf_v.at[:, slots].set(v[:, start:].astype(dtype))
        return {"k": buf_k, "v": buf_v}
    j = jnp.arange(cache_len)[None, :]                        # (1, L)
    last = lengths[:, None] - 1                               # (B, 1)
    pos = last - ((last - j) % cache_len)                     # (B, L)
    valid = pos >= 0
    idx = jnp.clip(pos, 0, S - 1).astype(jnp.int32)[..., None, None]
    m = valid[..., None, None]
    buf_k = jnp.where(m, jnp.take_along_axis(k, idx, axis=1), 0)
    buf_v = jnp.where(m, jnp.take_along_axis(v, idx, axis=1), 0)
    return {"k": buf_k.astype(dtype), "v": buf_v.astype(dtype)}


# --------------------------------------------------------------------------
# decode with KV cache (full-length or ring-buffer sliding window)
# --------------------------------------------------------------------------
def kv_cache_init(batch: int, cache_len: int, spec: AttnSpec, dtype) -> dict:
    shp = (batch, cache_len, spec.n_kv_heads, spec.head_dim)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


def attn_decode(params: dict, cache: dict, x: jnp.ndarray, spec: AttnSpec,
                position: jnp.ndarray):
    """One-token decode.  x: (B,1,d); position: (B,) absolute position.

    The cache holds RoPE'd keys at absolute positions.  For sliding-window
    configs the cache is a ring buffer of ``window`` slots; the slot for
    position p is ``p % cache_len`` and slots further than ``window`` back
    (or not yet written) are masked out.
    """
    B = x.shape[0]
    cache_len = cache["k"].shape[1]
    q = _split_heads(dense(params["wq"], x), spec.n_heads, spec.head_dim)
    k = _split_heads(dense(params["wk"], x), spec.n_kv_heads, spec.head_dim)
    v = _split_heads(dense(params["wv"], x), spec.n_kv_heads, spec.head_dim)
    q = apply_rope(q, position[:, None], spec.rope_theta)
    k = apply_rope(k, position[:, None], spec.rope_theta)

    slot = (position % cache_len).astype(jnp.int32)       # (B,)
    bidx = jnp.arange(B)
    new_k = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
    new_v = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
    new_cache = {"k": new_k, "v": new_v}

    kk = _repeat_kv(new_k, spec.n_heads // spec.n_kv_heads)
    vv = _repeat_kv(new_v, spec.n_heads // spec.n_kv_heads)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) / jnp.sqrt(spec.head_dim)
    # validity: slot j holds absolute position j + cache_len*floor stuff; a
    # slot is valid iff it has been written and is within the window:
    # written positions are (pos - cache_len, pos]; slot j's latest write is
    # pos - ((pos - j) % cache_len).
    j = jnp.arange(cache_len)[None, :]                     # (1, L)
    abs_pos = position[:, None] - ((position[:, None] - j) % cache_len)
    valid = abs_pos >= 0
    if spec.window is not None:
        valid &= abs_pos > position[:, None] - spec.window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv.astype(jnp.float32))
    out = out.reshape(B, 1, spec.n_heads * spec.head_dim).astype(x.dtype)
    return dense(params["wo"], out), new_cache
