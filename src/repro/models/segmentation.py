"""Semantic-segmentation model family from the paper's burned-area study:
U-Net, U-Net++, DeepLabV3, DeepLabV3+ (Table IV), in JAX/NHWC.

Compact but architecturally faithful: U-Net encoder/decoder with skip
connections; U-Net++ adds the nested dense skip nodes; DeepLabV3 uses an
atrous-spatial-pyramid-pooling head over a strided backbone; V3+ adds the
low-level-feature decoder.  All share init/apply conventions with the rest
of the framework (pure pytrees)."""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

Init = jax.nn.initializers.he_normal()


def conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    return {"w": Init(key, (kh, kw, cin, cout), dtype),
            "b": jnp.zeros((cout,), dtype)}


def conv(params, x, stride=1, dilation=1, transpose=False):
    if transpose:
        y = jax.lax.conv_transpose(
            x, params["w"], (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    else:
        y = jax.lax.conv_general_dilated(
            x, params["w"], (stride, stride), "SAME",
            rhs_dilation=(dilation, dilation),
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + params["b"]


def group_norm(x, groups=8, eps=1e-5):
    N, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xr = x.reshape(N, H, W, g, C // g)
    mu = xr.mean(axis=(1, 2, 4), keepdims=True)
    var = xr.var(axis=(1, 2, 4), keepdims=True)
    return ((xr - mu) * jax.lax.rsqrt(var + eps)).reshape(N, H, W, C)


def double_conv_init(key, cin, cout):
    k1, k2 = jax.random.split(key)
    return {"c1": conv_init(k1, 3, 3, cin, cout),
            "c2": conv_init(k2, 3, 3, cout, cout)}


def double_conv(params, x):
    x = jax.nn.relu(group_norm(conv(params["c1"], x)))
    return jax.nn.relu(group_norm(conv(params["c2"], x)))


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "SAME")


def _upsample(x, factor=2):
    N, H, W, C = x.shape
    return jax.image.resize(x, (N, H * factor, W * factor, C), "nearest")


# ---------------------------------------------------------------- U-Net
def unet_init(key, in_ch=3, classes=2, width=16, depth=4):
    ks = jax.random.split(key, 2 * depth + 2)
    enc, dec = [], []
    c = in_ch
    for i in range(depth):
        enc.append(double_conv_init(ks[i], c, width * 2 ** i))
        c = width * 2 ** i
    for i in range(depth - 1):
        cin = width * 2 ** (depth - 1 - i) + width * 2 ** (depth - 2 - i)
        dec.append(double_conv_init(ks[depth + i], cin,
                                    width * 2 ** (depth - 2 - i)))
    return {"enc": enc, "dec": dec,
            "head": conv_init(ks[-1], 1, 1, width, classes)}


def unet_apply(params, x):
    skips = []
    for i, p in enumerate(params["enc"]):
        x = double_conv(p, x)
        if i < len(params["enc"]) - 1:
            skips.append(x)
            x = _pool(x)
    for p, skip in zip(params["dec"], reversed(skips)):
        x = _upsample(x)
        x = jnp.concatenate([x, skip], axis=-1)
        x = double_conv(p, x)
    return conv(params["head"], x)


# ------------------------------------------------------------- U-Net++
def unetpp_init(key, in_ch=3, classes=2, width=16, depth=3):
    """Nested U-Net: node X[i][j] refines upsampled X[i+1][j-1] with dense
    skips from X[i][0..j-1]."""
    keys = iter(jax.random.split(key, 64))
    enc = []
    c = in_ch
    for i in range(depth + 1):
        enc.append(double_conv_init(next(keys), c, width * 2 ** i))
        c = width * 2 ** i
    nodes = {}
    for j in range(1, depth + 1):
        for i in range(depth + 1 - j):
            ci = width * 2 ** i
            cin = ci * j + width * 2 ** (i + 1)
            nodes[f"{i}_{j}"] = double_conv_init(next(keys), cin, ci)
    return {"enc": enc, "nodes": nodes,
            "head": conv_init(next(keys), 1, 1, width, classes)}


def unetpp_apply(params, x):
    depth = len(params["enc"]) - 1
    X: Dict[str, jnp.ndarray] = {}
    cur = x
    for i, p in enumerate(params["enc"]):
        cur2 = double_conv(p, cur)
        X[f"{i}_0"] = cur2
        cur = _pool(cur2)
    for j in range(1, depth + 1):
        for i in range(depth + 1 - j):
            ups = _upsample(X[f"{i + 1}_{j - 1}"])
            cat = jnp.concatenate(
                [X[f"{i}_{k}"] for k in range(j)] + [ups], axis=-1)
            X[f"{i}_{j}"] = double_conv(params["nodes"][f"{i}_{j}"], cat)
    return conv(params["head"], X[f"0_{depth}"])


# ------------------------------------------------------------ DeepLabV3
def _backbone_init(keys, in_ch, width):
    return [
        double_conv_init(next(keys), in_ch, width),        # /1
        double_conv_init(next(keys), width, width * 2),    # /2
        double_conv_init(next(keys), width * 2, width * 4),  # /4
        double_conv_init(next(keys), width * 4, width * 8),  # /8 (atrous)
    ]


def _backbone_apply(blocks, x):
    low = None
    for i, p in enumerate(blocks):
        x = double_conv(p, x)
        if i == 1:
            low = x
        if i < 2:
            x = _pool(x)
    return x, low


ASPP_RATES = (1, 6, 12)


def aspp_init(key, cin, cout, rates=ASPP_RATES):
    ks = jax.random.split(key, len(rates) + 2)
    return {
        "branches": [conv_init(ks[i], 3 if r > 1 else 1,
                               3 if r > 1 else 1, cin, cout)
                     for i, r in enumerate(rates)],
        "pool_proj": conv_init(ks[-2], 1, 1, cin, cout),
        "proj": conv_init(ks[-1], 1, 1, cout * (len(rates) + 1), cout),
    }


def aspp_apply(params, x, rates=ASPP_RATES):
    outs = [jax.nn.relu(conv(p, x, dilation=r))
            for p, r in zip(params["branches"], rates)]
    gp = x.mean(axis=(1, 2), keepdims=True)
    gp = jax.nn.relu(conv(params["pool_proj"], gp))
    gp = jnp.broadcast_to(gp, outs[0].shape)
    cat = jnp.concatenate(outs + [gp], axis=-1)
    return jax.nn.relu(conv(params["proj"], cat))


def deeplabv3_init(key, in_ch=3, classes=2, width=16, plus=False):
    keys = iter(jax.random.split(key, 16))
    p = {"backbone": _backbone_init(keys, in_ch, width),
         "aspp": aspp_init(next(keys), width * 8, width * 4),
         "head": conv_init(next(keys), 1, 1, width * 4, classes)}
    if plus:
        p["low_proj"] = conv_init(next(keys), 1, 1, width * 2, width)
        p["dec"] = double_conv_init(next(keys), width * 4 + width, width * 4)
    return p


def deeplabv3_apply(params, x, plus=False):
    feats, low = _backbone_apply(params["backbone"], x)
    y = aspp_apply(params["aspp"], feats)
    if plus:
        y = _upsample(y, 2)
        low = jax.nn.relu(conv(params["low_proj"], low))
        y = double_conv(params["dec"],
                        jnp.concatenate([y, low], axis=-1))
        y = conv(params["head"], y)
        return _upsample(y, 2)
    y = conv(params["head"], y)
    return _upsample(y, 4)


# ------------------------------------------------------------- registry
SEG_MODELS = {
    "unet": (unet_init, unet_apply),
    "unetpp": (unetpp_init, unetpp_apply),
    "deeplabv3": (deeplabv3_init,
                  lambda p, x: deeplabv3_apply(p, x, plus=False)),
    "deeplabv3plus": (functools.partial(deeplabv3_init, plus=True),
                      lambda p, x: deeplabv3_apply(p, x, plus=True)),
}


def seg_init(name, key, in_ch=3, classes=2, width=16):
    return SEG_MODELS[name][0](key, in_ch=in_ch, classes=classes, width=width)


def seg_apply(name, params, x):
    return SEG_MODELS[name][1](params, x)


def seg_loss(name, params, images, masks):
    logits = seg_apply(name, params, images)
    ll = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(masks, logits.shape[-1])
    return -(onehot * ll).sum(-1).mean()


def seg_metrics(logits, masks, positive: int = 1) -> Dict[str, float]:
    """Paper Table IV metrics for the positive (burned/changed) class."""
    pred = jnp.argmax(logits, axis=-1)
    tp = jnp.sum((pred == positive) & (masks == positive))
    fp = jnp.sum((pred == positive) & (masks != positive))
    fn = jnp.sum((pred != positive) & (masks == positive))
    prec = tp / jnp.maximum(tp + fp, 1)
    rec = tp / jnp.maximum(tp + fn, 1)
    f1 = 2 * prec * rec / jnp.maximum(prec + rec, 1e-9)
    iou = tp / jnp.maximum(tp + fp + fn, 1)
    acc = jnp.mean(pred == masks)
    return {"precision": prec, "recall": rec, "f1": f1, "iou": iou,
            "accuracy": acc}
