"""Model assembly for all assigned architecture families.

Layers are grouped into repeating *periods* (length = lcm of the attention
interleave and the MoE interleave) and the stack is a ``jax.lax.scan`` over
periods with stacked parameters, so HLO size and compile time are
depth-independent — 72-layer Jamba lowers as a 9-step scan over an
8-layer period body.  Each period slot is one of:

    mixer: attention (GQA + RoPE, causal/bidirectional/sliding-window)
           or Mamba2 SSD
    ffn:   dense (Sw)GLU MLP, MoE (capacity-routed), or none

The same definition serves train (forward+loss), prefill, and decode
(KV-cache / recurrent-state step), and is mesh-agnostic via the logical
sharding context (repro.sharding).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.sharding import constrain


# --------------------------------------------------------------------------
# structure helpers
# --------------------------------------------------------------------------
def period_len(cfg: ArchConfig) -> int:
    p = 1
    if cfg.attn_every:
        p = math.lcm(p, cfg.attn_every)
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe.every)
    assert cfg.n_layers % p == 0, (cfg.name, cfg.n_layers, p)
    return p


def _slot_plan(cfg: ArchConfig):
    """[(kind, has_moe, has_dense_ffn)] for each slot within one period."""
    p = period_len(cfg)
    kinds = cfg.layer_kinds()[:p]
    moe_mask = cfg.moe_layer_mask()[:p]
    plan = []
    for i in range(p):
        has_moe = moe_mask[i]
        has_dense = (cfg.d_ff > 0) and not has_moe
        plan.append((kinds[i], has_moe, has_dense))
    return plan


def attn_spec(cfg: ArchConfig, window: Optional[int] = "cfg") -> L.AttnSpec:
    return L.AttnSpec(
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim,
        causal=cfg.causal,
        window=cfg.sliding_window if window == "cfg" else window,
        rope_theta=cfg.rope_theta,
        backend=cfg.attention_backend,
    )


def _pdtype(cfg):
    return jnp.dtype(cfg.param_dtype)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init_params(key, cfg: ArchConfig) -> Dict[str, Any]:
    dtype = _pdtype(cfg)
    d = cfg.d_model
    plan = _slot_plan(cfg)
    n_periods = cfg.n_layers // len(plan)
    k_embed, k_head, k_blocks = jax.random.split(key, 3)

    def init_period(pk):
        slot_keys = jax.random.split(pk, len(plan))
        period = {}
        for i, (kind, has_moe, has_dense) in enumerate(plan):
            sk = jax.random.split(slot_keys[i], 4)
            slot = {"norm1": L.norm_init(cfg.norm, d, dtype),
                    "norm2": L.norm_init(cfg.norm, d, dtype)}
            if kind == "attn":
                slot["attn"] = L.attn_init(sk[0], d, attn_spec(cfg), dtype)
            else:
                slot["ssm"] = SSM.ssm_init(sk[0], d, cfg.ssm, dtype)
            if has_moe:
                slot["moe"] = MOE.moe_init(sk[1], d, cfg.moe, cfg.act, dtype)
                if cfg.moe.shared_expert:
                    slot["shared_mlp"] = L.mlp_init(
                        sk[2], d, cfg.d_ff or cfg.moe.expert_d_ff,
                        cfg.act, dtype)
            elif has_dense:
                slot["mlp"] = L.mlp_init(sk[1], d, cfg.d_ff, cfg.act, dtype)
            period[f"slot{i}"] = slot
        return period

    params = {
        "embed": {"w": (jax.random.normal(k_embed, (cfg.vocab, d), jnp.float32)
                        * L.DEFAULT_INIT_SCALE).astype(dtype)},
        "final_norm": L.norm_init(cfg.norm, d, dtype),
        "periods": jax.vmap(init_period)(jax.random.split(k_blocks, n_periods)),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(k_head, d, cfg.vocab, dtype)
    return params


def param_specs(cfg: ArchConfig):
    """ShapeDtypeStructs of the param tree (no allocation)."""
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.random.PRNGKey(0))


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------
def _slot_forward(slot_params, x, positions, cfg, kind, has_moe, has_dense):
    spec = attn_spec(cfg)
    h = L.norm_apply(cfg.norm, slot_params["norm1"], x)
    if kind == "attn":
        mix = L.attn_apply(slot_params["attn"], h, spec, positions)
    else:
        mix = SSM.ssm_apply(slot_params["ssm"], h, cfg.ssm,
                            backend=cfg.mixer_backend)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    h = L.norm_apply(cfg.norm, slot_params["norm2"], x)
    if has_moe:
        y, aux = MOE.moe_apply(slot_params["moe"], h, cfg.moe, cfg.act)
        if "shared_mlp" in slot_params:
            y = y + L.mlp_apply(slot_params["shared_mlp"], h, cfg.act)
        x = x + y
    elif has_dense:
        x = x + L.mlp_apply(slot_params["mlp"], h, cfg.act)
    x = constrain(x, "batch", "seq", "embed")
    return x, aux


def embed_inputs(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray]):
    """Returns (x (B,S,d), positions (B,S), loss_mask (B,S))."""
    dtype = _pdtype(cfg)
    if cfg.family == "audio":
        x = batch["features"].astype(dtype)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        return x, positions, batch["mask"]
    tok_emb = jnp.take(params["embed"]["w"], batch["tokens"], axis=0)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(dtype)
        x = jnp.concatenate([patches, tok_emb], axis=1)
        B, S, _ = x.shape
        P = patches.shape[1]
        loss_mask = jnp.concatenate(
            [jnp.zeros((B, P), bool), jnp.ones(batch["tokens"].shape, bool)],
            axis=1)
    else:
        x = tok_emb
        B, S, _ = x.shape
        loss_mask = jnp.ones((B, S), bool)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    return x, positions, loss_mask


def backbone(params, cfg: ArchConfig, x, positions, remat: bool = True):
    plan = _slot_plan(cfg)

    def period_body(carry, period_params):
        x, aux = carry
        for i, (kind, has_moe, has_dense) in enumerate(plan):
            x, a = _slot_forward(period_params[f"slot{i}"], x, positions,
                                 cfg, kind, has_moe, has_dense)
            aux = aux + a
        return (x, aux), None

    body = period_body
    if remat:
        body = jax.checkpoint(
            period_body,
            policy=jax.checkpoint_policies.nothing_saveable)
    x = constrain(x, "batch", "seq", "embed")
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["periods"])
    x = L.norm_apply(cfg.norm, params["final_norm"], x)
    return x, aux


def logits_fn(params, cfg: ArchConfig, x):
    w = (params["embed"]["w"].T if cfg.tie_embeddings
         else params["head"]["w"])
    logits = x @ w
    return constrain(logits, "batch", None, "vocab")


def forward(params, cfg: ArchConfig, batch, remat: bool = True):
    """Full forward -> (logits (B,S,V), aux_loss)."""
    x, positions, _ = embed_inputs(params, cfg, batch)
    x, aux = backbone(params, cfg, x, positions, remat=remat)
    return logits_fn(params, cfg, x), aux


# --------------------------------------------------------------------------
# loss (vocab- and sequence-chunked cross entropy)
# --------------------------------------------------------------------------
def _xent_chunk(x, w, labels, mask):
    """x: (B,c,d); w: (d,V); labels: (B,c); mask: (B,c)."""
    logits = (x @ w).astype(jnp.float32)
    logits = constrain(logits, "batch", None, "vocab")
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return nll.sum(), mask.sum()


def cast_floating(tree, dtype):
    """Cast every floating-point leaf of ``tree`` to ``dtype``; integer
    leaves (steps, token ids) pass through untouched.  (Re-exported as
    ``repro.train.cast_floating`` — this module is the leaf both the
    precision policy and the model can import.)"""
    dtype = jnp.dtype(dtype)
    return jax.tree.map(
        lambda x: x.astype(dtype)
        if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating) else x, tree)


def cast_compute_params(params, dtype):
    """Mixed-precision compute cast: backbone params go to ``dtype``; the
    embedding and loss-head matrices stay in their master dtype.  The
    vocab-sized matmuls are the numerically hottest ops in the model
    (logits feed logsumexp) *and* the largest matrices — keeping them
    f32 is the standard bf16 recipe and avoids a full-vocab cast on
    every step.  Activations entering the head are cast by the matmul's
    own type promotion."""
    out = dict(params)
    for key in ("periods", "final_norm"):
        if key in out:
            out[key] = cast_floating(out[key], dtype)
    return out


def train_loss(params, cfg: ArchConfig, batch, remat: bool = True,
               loss_chunk: int = 512, compute_dtype=None):
    """Scalar mean CE (+ MoE aux).  Sequence-chunked so the (B,S,V)
    logits tensor is never materialized (critical for 200k vocabs).

    ``compute_dtype`` (e.g. ``"bfloat16"``) runs the backbone in that
    dtype: backbone params and activations are cast at entry (see
    :func:`cast_compute_params`); the embedding table, loss head and the
    loss reduction itself stay f32 (``_xent_chunk`` upcasts before
    logsumexp).  Gradients flow back to the *caller's* param dtype
    through the cast's VJP, so a bf16-compute step still accumulates
    f32 master grads.
    """
    if compute_dtype is not None:
        params = cast_compute_params(params, compute_dtype)
    x, positions, loss_mask = embed_inputs(params, cfg, batch)
    if compute_dtype is not None:
        x = x.astype(jnp.dtype(compute_dtype))
    x, aux = backbone(params, cfg, x, positions, remat=remat)
    w = (params["embed"]["w"].T if cfg.tie_embeddings else params["head"]["w"])

    if cfg.family == "audio":
        labels = batch["labels"]
        mask = loss_mask
        xs, ls, ms = x, labels, mask
    else:
        # causal shift: predict token t+1 from position t
        xs = x[:, :-1]
        ls = batch["labels"][:, 1:] if "labels" in batch else None
        if ls is None:
            full = batch["tokens"]
            if cfg.family == "vlm":
                P = batch["patches"].shape[1]
                pad = jnp.zeros((x.shape[0], P), full.dtype)
                full = jnp.concatenate([pad, full], axis=1)
            ls = full[:, 1:]
        ms = loss_mask[:, 1:].astype(jnp.float32)

    B, S, d = xs.shape
    c = min(loss_chunk, S)
    n = S // c
    rem = S - n * c

    def chunk_step(carry, inp):
        tot, cnt = carry
        xc, lc, mc = inp
        s, m = _xent_chunk(xc, w, lc, mc)
        return (tot + s, cnt + m), None

    xsc = jnp.moveaxis(xs[:, :n * c].reshape(B, n, c, d), 1, 0)
    lsc = jnp.moveaxis(ls[:, :n * c].reshape(B, n, c), 1, 0)
    msc = jnp.moveaxis(ms[:, :n * c].reshape(B, n, c).astype(jnp.float32), 1, 0)
    (tot, cnt), _ = jax.lax.scan(
        chunk_step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xsc, lsc, msc))
    if rem:
        s, m = _xent_chunk(xs[:, n * c:], w, ls[:, n * c:],
                           ms[:, n * c:].astype(jnp.float32))
        tot, cnt = tot + s, cnt + m
    return tot / jnp.maximum(cnt, 1.0) + aux


# --------------------------------------------------------------------------
# prefill (fills the decode caches, returns last-token logits)
# --------------------------------------------------------------------------
def prefill(params, cfg: ArchConfig, batch, cache_len: int, dtype=None,
            lengths=None):
    """Inference prefill: forward over the prompt, collecting KV caches /
    recurrent states in the decode layout.  Returns (last_logits (B,V),
    decode_state).

    ``lengths`` ((B,) int32, optional) marks true per-row prompt lengths
    for right-padded batches: the returned logits are taken at position
    ``lengths-1`` per row, SSM recurrent states are frozen at the last
    real token, and the per-row KV ring layout zero-masks pad slots so
    pad keys never enter the cache (see :func:`repro.models.layers.
    kv_to_cache`); the decode-side validity mask already treats those
    slots as unwritten until decode overwrites them.
    For MoE configs, pad/dummy tokens are excluded from expert capacity
    via the router token mask, but real tokens of co-batched rows still
    share one capacity pool (sized from the padded token count), so
    batched prefill is not bit-identical to per-request prefill.
    """
    plan = _slot_plan(cfg)
    spec = attn_spec(cfg)
    dtype = dtype or _pdtype(cfg)
    attn_len = cache_len
    if cfg.sliding_window is not None:
        attn_len = min(cache_len, cfg.sliding_window)
    x, positions, _ = embed_inputs(params, cfg, batch)
    token_mask = None
    if lengths is not None:
        token_mask = jnp.arange(x.shape[1])[None, :] < lengths[:, None]

    def period_body(x, period_params):
        states = {}
        for i, (kind, has_moe, has_dense) in enumerate(plan):
            sp = period_params[f"slot{i}"]
            h = L.norm_apply(cfg.norm, sp["norm1"], x)
            if kind == "attn":
                mix, (k, v) = L.attn_apply(sp["attn"], h, spec, positions,
                                           return_kv=True)
                states[f"slot{i}"] = L.kv_to_cache(k, v, attn_len, dtype,
                                                   lengths=lengths)
            else:
                mix, st = SSM.ssm_apply(sp["ssm"], h, cfg.ssm,
                                        return_state=True, seq_len=lengths,
                                        backend=cfg.mixer_backend)
                states[f"slot{i}"] = st
            x = x + mix
            h = L.norm_apply(cfg.norm, sp["norm2"], x)
            if has_moe:
                y, _ = MOE.moe_apply(sp["moe"], h, cfg.moe, cfg.act,
                                     token_mask=token_mask)
                if "shared_mlp" in sp:
                    y = y + L.mlp_apply(sp["shared_mlp"], h, cfg.act)
                x = x + y
            elif has_dense:
                x = x + L.mlp_apply(sp["mlp"], h, cfg.act)
            x = constrain(x, "batch", "seq", "embed")
        return x, states

    x, states = jax.lax.scan(period_body, x, params["periods"])
    x = L.norm_apply(cfg.norm, params["final_norm"], x)
    if lengths is None:
        x_last = x[:, -1:, :]
    else:
        last = jnp.clip(lengths - 1, 0, x.shape[1] - 1)
        x_last = jnp.take_along_axis(
            x, last[:, None, None].astype(jnp.int32), axis=1)
    logits = logits_fn(params, cfg, x_last)
    return logits[:, 0, :], states


# --------------------------------------------------------------------------
# decode (serve_step)
# --------------------------------------------------------------------------
def init_decode_state(cfg: ArchConfig, batch: int, cache_len: int,
                      dtype=None) -> Dict[str, Any]:
    """Stacked decode caches per slot (leading dim = n_periods)."""
    dtype = dtype or _pdtype(cfg)
    plan = _slot_plan(cfg)
    n_periods = cfg.n_layers // len(plan)
    spec = attn_spec(cfg)
    attn_len = cache_len
    if cfg.sliding_window is not None:
        attn_len = min(cache_len, cfg.sliding_window)

    def one_period(_):
        state = {}
        for i, (kind, _, _) in enumerate(plan):
            if kind == "attn":
                state[f"slot{i}"] = L.kv_cache_init(batch, attn_len, spec, dtype)
            else:
                state[f"slot{i}"] = SSM.ssm_state_init(
                    batch, cfg.d_model, cfg.ssm, dtype)
        return state

    states = [one_period(i) for i in range(n_periods)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def decode_step(params, cfg: ArchConfig, state, tokens, position):
    """One decode step.  tokens: (B,1) int32; position: (B,) absolute.
    Returns (logits (B,V), new_state)."""
    plan = _slot_plan(cfg)
    spec = attn_spec(cfg)
    x = jnp.take(params["embed"]["w"], tokens, axis=0)  # (B,1,d)

    def period_body(x, scanned):
        period_params, period_state = scanned
        new_state = {}
        for i, (kind, has_moe, has_dense) in enumerate(plan):
            sp = period_params[f"slot{i}"]
            h = L.norm_apply(cfg.norm, sp["norm1"], x)
            if kind == "attn":
                mix, ns = L.attn_decode(sp["attn"], period_state[f"slot{i}"],
                                        h, spec, position)
            else:
                mix, ns = SSM.ssm_decode_step(sp["ssm"],
                                              period_state[f"slot{i}"],
                                              h, cfg.ssm)
            new_state[f"slot{i}"] = ns
            x = x + mix
            h = L.norm_apply(cfg.norm, sp["norm2"], x)
            if has_moe:
                y, _ = MOE.moe_apply(sp["moe"], h, cfg.moe, cfg.act)
                if "shared_mlp" in sp:
                    y = y + L.mlp_apply(sp["shared_mlp"], h, cfg.act)
                x = x + y
            elif has_dense:
                x = x + L.mlp_apply(sp["mlp"], h, cfg.act)
        return x, new_state

    x, new_states = jax.lax.scan(period_body, x,
                                 (params["periods"], state))
    x = L.norm_apply(cfg.norm, params["final_norm"], x)
    logits = logits_fn(params, cfg, x)
    return logits[:, 0, :], new_states


# --------------------------------------------------------------------------
# sampling head (device-resident: serve steps return token ids, not logits)
# --------------------------------------------------------------------------
def sample_tokens(logits, key, temperature, top_k, greedy_only=False):
    """Per-row sampling over a (B,V) logits batch, fully on device.

    temperature: (B,) float32 — rows with temperature <= 0 decode greedily
    (argmax); others sample from softmax(logits/temperature).
    top_k: (B,) int32 — rows with top_k > 0 restrict sampling to the k
    highest logits (traced per row via a sorted threshold, so one compiled
    program covers every (temperature, top_k) mix).  Returns (B,) int32.

    ``greedy_only`` is a Python-static fast path: when the caller knows
    every row is greedy it skips the O(V log V) sort and the categorical
    draw entirely (the default serve decode program).
    """
    V = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    if greedy_only:
        return greedy_tok
    scaled = lf / jnp.maximum(temperature, 1e-6)[:, None]
    k = jnp.where(top_k <= 0, V, jnp.minimum(top_k, V))
    thresh = jnp.take_along_axis(
        jnp.sort(scaled, axis=-1)[:, ::-1], (k - 1)[:, None], axis=-1)
    scaled = jnp.where(scaled < thresh, -jnp.inf, scaled)
    sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy_tok, sampled)


def decode_and_sample(params, cfg: ArchConfig, state, tokens, position, key,
                      temperature, top_k, greedy_only=False):
    """Fused decode + sample: only (B,) token ids leave the device.
    Returns (sampled (B,) int32, new_state).  ``greedy_only`` is static —
    see :func:`sample_tokens`."""
    logits, new_state = decode_step(params, cfg, state, tokens, position)
    return sample_tokens(logits, key, temperature, top_k,
                         greedy_only=greedy_only), new_state


def prefill_and_sample(params, cfg: ArchConfig, batch, cache_len: int, key,
                       temperature, top_k, lengths=None, dtype=None):
    """Fused prefill + first-token sample.  Returns ((B,) int32, state)."""
    logits, state = prefill(params, cfg, batch, cache_len=cache_len,
                            dtype=dtype, lengths=lengths)
    return sample_tokens(logits, key, temperature, top_k), state
