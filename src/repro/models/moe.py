"""Mixture-of-Experts layer with capacity-based token-choice routing.

TPU-native dispatch (Switch/GShard style): top-k expert assignment with a
static per-expert capacity; tokens are scattered into a dense
``(E, capacity, d)`` buffer, expert FFNs run as one batched einsum against
the stacked ``(E, d, ff)`` expert weights (MXU-friendly, expert-parallel
over the ``model`` mesh axis), and outputs gather back per token.  Tokens
over capacity are dropped (standard on TPU; the aux load-balance loss keeps
drops rare).  This replaces a CUDA-style ragged grouped-GEMM with a
fixed-shape formulation XLA shards with a single all-to-all-class pattern.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_init, DEFAULT_INIT_SCALE
from repro.sharding import constrain


def moe_init(key, d_model: int, cfg: MoEConfig, act: str, dtype) -> dict:
    ks = jax.random.split(key, 5)
    E, f = cfg.n_experts, cfg.expert_d_ff

    def ekernel(k, a, b):
        w = jax.random.normal(k, (E, a, b), jnp.float32) * DEFAULT_INIT_SCALE
        return w.astype(dtype)

    p = {
        "router": dense_init(ks[0], d_model, E, jnp.float32),
        "up": ekernel(ks[1], d_model, f),
        "down": ekernel(ks[2], f, d_model),
    }
    if act == "silu":
        p["gate"] = ekernel(ks[3], d_model, f)
    return p


def router_probs(params, x):
    """x: (T, d) -> (T, E) fp32 probabilities."""
    logits = x.astype(jnp.float32) @ params["router"]["w"]
    return jax.nn.softmax(logits, axis=-1), logits


def load_balance_loss(probs, expert_mask):
    """GShard aux loss: E * sum_e f_e * p_e.

    probs: (T, E) router probabilities; expert_mask: (T, E) 0/1 counts of
    routed (pre-drop) assignments summed over k.
    """
    E = probs.shape[-1]
    f = expert_mask.mean(axis=0)          # fraction of tokens per expert
    p = probs.mean(axis=0)
    return E * jnp.sum(f * p)


def _axis_extent(logical_name: str) -> int:
    from repro.sharding.ctx import current_ctx
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None:
        return 1
    axis = ctx.logical.get(logical_name)
    if axis is None:
        return 1
    names = (axis,) if isinstance(axis, str) else axis
    g = 1
    for n in names:
        g *= dict(ctx.mesh.shape)[n]
    return g


def _dispatch_groups(B: int, S: int):
    """(batch groups, seq groups) for the all-to-all dispatch: one group
    per (data-shard x seq-shard) so router/rank/scatter are fully local
    per chip and the expert exchange is ONE sharding flip of the
    (groups, E, C_local, d) buffer — an all-to-all whose per-chip volume
    is just that chip's own routed tokens."""
    gs = _axis_extent("seq")
    if gs <= 1 or S % gs:
        gs = 1
    gb = _axis_extent("batch")
    if gb <= 1 or B % gb:
        gb = 1
    return gb, gs


def _local_top_k(x: jnp.ndarray, k: int):
    """top_k over the last dim via k iterated argmaxes (shard-local under
    GSPMD, unlike the TopK custom-call partitioner)."""
    vals, idxs = [], []
    cur = x
    for _ in range(k):
        i = jnp.argmax(cur, axis=-1)
        v = jnp.take_along_axis(cur, i[..., None], axis=-1)[..., 0]
        vals.append(v)
        idxs.append(i)
        cur = cur - jax.nn.one_hot(i, x.shape[-1], dtype=cur.dtype) * 1e9
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def _ranks_in_expert(e_ids: jnp.ndarray, E: int) -> jnp.ndarray:
    """Position of each entry within its expert's segment, via a stable
    argsort (O(n log n); no (n, E) cumsum, which XLA costs/executes as an
    O(n^2) reduce-window on some backends).

    ids may include the sentinel E (masked tokens, see ``moe_apply``):
    sentinels form their own segment ranked like any other, so real
    experts' ranks never shift."""
    n = e_ids.shape[0]
    order = jnp.argsort(e_ids, stable=True)
    sorted_e = e_ids[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E + 1))
    rank_sorted = jnp.arange(n) - seg_start[sorted_e]
    return jnp.zeros((n,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))


def moe_apply(params: dict, x: jnp.ndarray, cfg: MoEConfig, act: str,
              capacity_factor: float = None, token_mask=None):
    """x: (B, S, d) -> (y, aux_loss).

    Grouped token-choice dispatch: tokens are processed in G groups
    (G = model-shard count when the sequence is model-sharded, else 1).
    Routing, ranking and the capacity scatter are group-local; experts
    receive their (G, Cg) slots via ONE sharding flip of the
    (G, E, Cg, d) buffer — GSPMD lowers that to an all-to-all, the
    classic TPU expert-parallel exchange.

    ``token_mask`` ((B, S) bool, optional): False marks pad/dummy tokens
    (right-padded serve prefill).  Masked tokens route to a sentinel
    expert id E — the stable in-expert ranking then never counts them, so
    they cannot claim capacity slots from real tokens, and the sentinel
    rows vanish in the ``mode="drop"`` scatter.  Their combined outputs
    are garbage; callers only read unmasked positions."""
    if capacity_factor is None:
        capacity_factor = cfg.capacity_factor
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    GB, GS = _dispatch_groups(B, S)
    G = GB * GS
    Bl, Sg = B // GB, S // GS
    Tg = Bl * Sg                                    # tokens per group (local)
    capacity = max(int(Tg * K / E * capacity_factor), 4)

    # (GB, Bl, GS, Sg, d) -> (GB*GS, Bl*Sg, d); the group dim carries the
    # (batch x seq) sharding, so every group is one chip's tokens
    xg = x.reshape(GB, Bl, GS, Sg, d).transpose(0, 2, 1, 3, 4)
    xg = xg.reshape(G, Tg, d)

    probs, _ = router_probs(params, xg)             # (G, Tg, E)
    probs = constrain(probs, ("batch", "seq"), None, None)
    # iterated-argmax top-k: K argmax passes stay shard-local, whereas
    # GSPMD's TopK partitioner all-gathers the full (G, Tg, E) operand
    # across all 256 chips (measured: 51.6 GB/chip/step on qwen3)
    gate_vals, expert_idx = _local_top_k(probs, K)   # (G, Tg, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)
    gate_vals = constrain(gate_vals, ("batch", "seq"), None, None)
    if token_mask is not None:
        mg = token_mask.reshape(GB, Bl, GS, Sg).transpose(0, 2, 1, 3)
        mg = mg.reshape(G, Tg)
        expert_idx = jnp.where(mg[..., None], expert_idx, E)

    e_flat = expert_idx.reshape(G, Tg * K)
    slot = jax.vmap(lambda e: _ranks_in_expert(e, E))(e_flat)
    slot = slot.reshape(G, Tg, K)
    keep = slot < capacity

    # group-local scatter into (G, E, Cg, d) — vmapped over G so the group
    # dim stays a parallel (sharded) batch dim through the scatter
    w = jnp.where(keep, 1.0, 0.0).astype(x.dtype)
    src = (xg[:, :, None, :] * w[..., None]).reshape(G, Tg * K, d)
    s_flat = jnp.where(keep, slot, capacity - 1).reshape(G, Tg * K)

    def scatter_one(srcg, eg, sg):
        return jnp.zeros((E, capacity, d), x.dtype).at[eg, sg].add(
            srcg, mode="drop")

    buf = jax.vmap(scatter_one)(src, e_flat, s_flat)
    # produced group-local: group dim sharded over (batch-axes, seq-axes)
    buf = constrain(buf, ("batch", "seq"), None, None, None)

    # >>> the expert exchange: flip the seq shard onto E (all-to-all);
    # the batch shard stays on the group dim <<<
    buf = constrain(buf, "batch", "experts", None, None)

    # expert FFN: (G*Cg) slots per expert against stacked weights
    h = jnp.einsum("gecd,edf->gecf", buf, params["up"])
    if act == "silu":
        g = jnp.einsum("gecd,edf->gecf", buf, params["gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["down"])
    out_buf = constrain(out_buf, "batch", "experts", None, None)

    # return trip: flip back to group-local and combine (vmapped gather)
    out_buf = constrain(out_buf, ("batch", "seq"), None, None, None)
    y = jax.vmap(lambda ob, eg, sg: ob[eg, sg])(out_buf, e_flat, s_flat)
    y = y.reshape(G, Tg, K, d)
    y = (y * (gate_vals * keep).astype(y.dtype)[..., None]).sum(axis=2)
    y = y.reshape(GB, GS, Bl, Sg, d).transpose(0, 2, 1, 3, 4)
    y = y.reshape(B, S, d)

    # load-balance aux: reduce group-locally to (G, E) first so the big
    # (G, Tg, E) probs tensor never needs gathering, then mean over groups
    f_g = jax.vmap(lambda e: jnp.zeros((E,), jnp.float32).at[e].add(1.0))(
        e_flat) / (Tg * K)                            # (G, E)
    p_g = probs.mean(axis=1)                          # (G, E)
    aux = E * jnp.sum(f_g * p_g, axis=-1).mean()
    return y, cfg.router_aux_weight * aux
