from repro.models.model import (
    init_params,
    forward,
    train_loss,
    prefill,
    init_decode_state,
    decode_step,
    param_specs,
    sample_tokens,
    decode_and_sample,
    prefill_and_sample,
)

__all__ = [
    "init_params", "forward", "train_loss", "prefill",
    "init_decode_state", "decode_step", "param_specs",
    "sample_tokens", "decode_and_sample", "prefill_and_sample",
]
