from repro.models.model import (
    init_params,
    forward,
    train_loss,
    prefill,
    init_decode_state,
    decode_step,
    param_specs,
)

__all__ = [
    "init_params", "forward", "train_loss", "prefill",
    "init_decode_state", "decode_step", "param_specs",
]
