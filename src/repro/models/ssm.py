"""Mamba2 block — SSD (state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD form: intra-chunk terms are dense
(Q x Q) masked matmuls (MXU-friendly) and inter-chunk terms are a
``lax.scan`` recurrence over chunk states — exactly the structure the
Pallas kernel in ``repro.kernels.ssd_scan`` implements on TPU.  Decode is
the O(1) recurrent update.  Heads shard over the ``model`` mesh axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.kernels.common import resolve_backend
from repro.kernels.ssd_scan import ssd_scan
from repro.models.layers import _multi_device, dense_init, norm_apply, dense
from repro.sharding import constrain


def ssm_init(key, d_model: int, cfg: SSMConfig, dtype) -> dict:
    ks = jax.random.split(key, 8)
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    gN = cfg.n_groups * cfg.d_state
    conv_ch = di + 2 * gN
    p = {
        "in_z": dense_init(ks[0], d_model, di, dtype),
        "in_x": dense_init(ks[1], d_model, di, dtype),
        "in_B": dense_init(ks[2], d_model, gN, dtype),
        "in_C": dense_init(ks[3], d_model, gN, dtype),
        "in_dt": dense_init(ks[4], d_model, nh, dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "conv_w": (jax.random.normal(ks[5], (cfg.conv_width, conv_ch), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "norm_scale": jnp.ones((di,), dtype),
        "out": dense_init(ks[6], di, d_model, dtype),
    }
    return p


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: (B,S,C), w: (W,C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return out + b


def _conv_decode(state, xnew, w, b):
    """state: (B, W-1, C); xnew: (B, C) -> (out (B,C), new_state)."""
    window = jnp.concatenate([state, xnew[:, None, :]], axis=1)   # (B,W,C)
    out = jnp.einsum("bwc,wc->bc", window, w) + b
    return out, window[:, 1:, :]


def ssd_chunked(x, dt, A, B_, C_, cfg: SSMConfig, h0=None):
    """Chunked SSD scan.

    x: (B,S,nh,hp); dt: (B,S,nh) (post-softplus, fp32); A: (nh,) negative;
    B_, C_: (B,S,g,N).  Returns (y (B,S,nh,hp), h_final (B,nh,hp,N)).
    """
    Bsz, S, nh, hp = x.shape
    g, N = B_.shape[2], B_.shape[3]
    rep = nh // g
    in_dtype = x.dtype
    Q = min(cfg.chunk, S)
    pad = (-S) % Q
    if pad:
        # pad dt with zeros: exp(0*A)=1 decay and zero contribution, so the
        # carried state is frozen across pad steps and y[:, :S] is exact.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    xf = x.astype(jnp.float32).reshape(Bsz, nc, Q, nh, hp)
    dtc = dt.reshape(Bsz, nc, Q, nh)
    Bc = B_.astype(jnp.float32).reshape(Bsz, nc, Q, g, N)
    Cc = C_.astype(jnp.float32).reshape(Bsz, nc, Q, g, N)
    del x, dt, B_, C_
    # move chunk axis to front for scan
    xf, dtc, Bc, Cc = (jnp.moveaxis(a, 1, 0) for a in (xf, dtc, Bc, Cc))

    if h0 is None:
        h0 = jnp.zeros((Bsz, nh, hp, N), jnp.float32)

    def chunk_step(h, inp):
        xq, dtq, Bq, Cq = inp                      # (B,Q,nh,hp) etc.
        la = jnp.cumsum(dtq * A, axis=1)           # (B,Q,nh) cumulative log-decay
        la_last = la[:, -1:, :]                    # (B,1,nh)
        Bh = jnp.repeat(Bq, rep, axis=2)           # (B,Q,nh,N)
        Ch = jnp.repeat(Cq, rep, axis=2)

        # ---- intra-chunk (dense, masked) ----
        Gg = jnp.einsum("bign,bjgn->bijg", Cq, Bq)         # (B,Q,Q,g)
        Gh = jnp.repeat(Gg, rep, axis=3)                   # (B,Q,Q,nh)
        # mask the EXPONENT, not the product: exp of the (unused) upper
        # triangle overflows to inf and poisons the backward pass.
        diff = la[:, :, None, :] - la[:, None, :, :]        # (B,Q,Q,nh)
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        diff = jnp.where(mask[None, :, :, None], diff, -jnp.inf)
        M = Gh * jnp.exp(diff)
        M = constrain(M, "batch", None, None, "heads")
        y_intra = jnp.einsum("bijh,bjh,bjhp->bihp", M, dtq, xq)

        # ---- inter-chunk (carry h) ----
        decay_in = jnp.exp(la)                              # (B,Q,nh)
        y_inter = jnp.einsum("bihn,bhpn->bihp", Ch * decay_in[..., None], h)

        # ---- state update ----
        decay_out = jnp.exp(la_last - la)                   # (B,Q,nh)
        dx = xq * (dtq * decay_out)[..., None]              # (B,Q,nh,hp)
        h_new = jnp.exp(la_last[:, 0, :])[:, :, None, None] * h + \
            jnp.einsum("bjhp,bjhn->bhpn", dx, Bh)
        h_new = constrain(h_new, "batch", "heads", None, None)
        return h_new, y_intra + y_inter

    h_final, yc = jax.lax.scan(chunk_step, h0, (xf, dtc, Bc, Cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(Bsz, Sp, nh, hp)[:, :S]
    return y.astype(in_dtype), h_final


def ssd_decode(h, x, dt, A, B_, C_):
    """Single-token recurrence.  x: (B,nh,hp); dt: (B,nh); B_/C_: (B,g,N);
    h: (B,nh,hp,N)."""
    nh = x.shape[1]
    g = B_.shape[1]
    rep = nh // g
    Bh = jnp.repeat(B_, rep, axis=1)                 # (B,nh,N)
    Ch = jnp.repeat(C_, rep, axis=1)
    a = jnp.exp(dt * A)                              # (B,nh)
    h_new = a[:, :, None, None] * h + jnp.einsum(
        "bhp,bhn->bhpn", x * dt[..., None], Bh)
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Ch)
    return y, h_new


def _seq_shards(S: int) -> int:
    """Sequence shard count from the active layout (fsdp_sp), else 1."""
    from repro.sharding.ctx import current_ctx
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None:
        return 1
    axis = ctx.logical.get("seq")
    if axis is None:
        return 1
    names = (axis,) if isinstance(axis, str) else axis
    g = 1
    for n in names:
        g *= dict(ctx.mesh.shape)[n]
    return g if (g > 1 and S % g == 0) else 1


def ssd_seq_parallel(x, dt, A, B_, C_, cfg: SSMConfig, n_seg: int):
    """Sequence-parallel SSD: the chunk recurrence is an associative scan,
    so each sequence shard runs its segment independently (h0 = 0), the
    per-segment final states are combined in one tiny cross-shard scan,
    and each segment adds the incoming-state correction locally.

    Cross-shard traffic = the (n_seg, B, nh, hp, N) segment states —
    megabytes — instead of gathering every (B, S, ...) activation
    (measured: 385 GB/chip/step of all-gathers on mamba2 train_4k).
    """
    Bsz, S, nh, hp = x.shape
    g = B_.shape[2]
    rep = nh // g
    Sl = S // n_seg

    def seg(xs, dts, Bs, Cs):
        return ssd_chunked(xs, dts, A, Bs, Cs, cfg)

    xs = jnp.moveaxis(x.reshape(Bsz, n_seg, Sl, nh, hp), 1, 0)
    dts = jnp.moveaxis(dt.reshape(Bsz, n_seg, Sl, nh), 1, 0)
    Bs = jnp.moveaxis(B_.reshape(Bsz, n_seg, Sl, g, -1), 1, 0)
    Cs = jnp.moveaxis(C_.reshape(Bsz, n_seg, Sl, g, -1), 1, 0)
    # the segment dim carries the model (seq) shard
    xs = constrain(xs, "seq", "batch", None, None, None)
    dts = constrain(dts, "seq", "batch", None, None)
    Bs = constrain(Bs, "seq", "batch", None, None, None)
    Cs = constrain(Cs, "seq", "batch", None, None, None)
    y_loc, h_seg = jax.vmap(seg)(xs, dts, Bs, Cs)   # (n_seg,B,Sl,nh,hp), (n_seg,B,nh,hp,N)
    y_loc = constrain(y_loc, "seq", "batch", None, None, None)

    # per-segment total decay and incoming states (tiny cross-shard scan)
    la_seg = jnp.cumsum(dts * A, axis=2)            # (n_seg,B,Sl,nh)
    seg_decay = jnp.exp(la_seg[:, :, -1, :])        # (n_seg,B,nh)

    def combine(h_in, inp):
        decay, h_out = inp
        return decay[..., None, None] * h_in + h_out, h_in

    h0 = jnp.zeros_like(h_seg[0])
    _, h_in = jax.lax.scan(combine, h0, (seg_decay, h_seg))  # (n_seg,B,nh,hp,N)

    # local correction: y[t] += C_t . (exp(la_local[t]) * h_in[segment])
    Ch = jnp.repeat(Cs, rep, axis=3)                # (n_seg,B,Sl,nh,N)
    decay_in = jnp.exp(la_seg)                      # (n_seg,B,Sl,nh)
    y_corr = jnp.einsum("sbthn,sbhpn->sbthp",
                        Ch * decay_in[..., None], h_in)
    y = y_loc + y_corr.astype(y_loc.dtype)
    h_final = seg_decay[-1][..., None, None] * h_in[-1] + h_seg[-1]
    y = jnp.moveaxis(y, 0, 1).reshape(Bsz, S, nh, hp)
    return y, h_final


def ssm_apply(params: dict, x: jnp.ndarray, cfg: SSMConfig,
              return_state: bool = False, seq_len=None,
              backend: str = "jnp"):
    """Training/prefill Mamba2 block.  x: (B,S,d) -> (B,S,d).

    ``seq_len`` ((B,) int32, optional) marks the true per-row sequence
    length for right-padded batches: dt is zeroed past ``seq_len`` so the
    recurrent state is frozen at the last real token (exp(0)=1 decay, zero
    contribution), and the returned conv state is gathered from the window
    ending at the last real token.  Outputs at padded positions are
    garbage and must be ignored by the caller.

    ``backend`` selects the mixer scan: "jnp" (chunked ``lax.scan``),
    "pallas" (``repro.kernels.ssd_scan``, custom-VJP so it trains), or
    "auto" (pallas where it compiles natively — TPU — jnp elsewhere).
    Mesh-sharded runs always use the jnp lowerings (``pallas_call`` has
    no GSPMD partitioning rule): sequence shards take the
    sequence-parallel decomposition, anything else the chunked scan.
    """
    B, S, d = x.shape
    di = cfg.d_inner(d)
    nh = cfg.n_heads(d)
    gN = cfg.n_groups * cfg.d_state

    z = dense(params["in_z"], x)
    xc = dense(params["in_x"], x)
    Bc = dense(params["in_B"], x)
    Cc = dense(params["in_C"], x)
    dt = dense(params["in_dt"], x).astype(jnp.float32)

    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"],
                                        params["conv_b"]))
    xc, Bc, Cc = jnp.split(conv_out, [di, di + gN], axis=-1)

    dt = jax.nn.softplus(dt + params["dt_bias"])
    if seq_len is not None:
        in_seq = jnp.arange(S)[None, :] < seq_len[:, None]        # (B,S)
        dt = dt * in_seq[..., None].astype(dt.dtype)
    A = -jnp.exp(params["A_log"])
    xh = xc.reshape(B, S, nh, cfg.head_dim)
    xh = constrain(xh, "batch", "seq", "heads", None)
    Bg = Bc.reshape(B, S, cfg.n_groups, cfg.d_state)
    Cg = Cc.reshape(B, S, cfg.n_groups, cfg.d_state)

    n_seg = _seq_shards(S)
    if n_seg > 1 and (S // n_seg) >= cfg.chunk:
        y, h_final = ssd_seq_parallel(xh, dt, A, Bg, Cg, cfg, n_seg)
    elif resolve_backend(backend) == "pallas" and not _multi_device():
        # pallas only on single-device runs: pallas_call has no GSPMD
        # partitioning rule, so mesh-sharded runs stay on the jnp
        # lowerings (ssd_seq_parallel above / ssd_chunked below)
        y, h_final = ssd_scan(xh, dt, A, Bg, Cg, chunk=cfg.chunk,
                              return_state=True)
    else:
        y, h_final = ssd_chunked(xh, dt, A, Bg, Cg, cfg)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B, S, di)
    y = norm_apply("rmsnorm", {"scale": params["norm_scale"]},
                   y * jax.nn.silu(z))
    out = dense(params["out"], y)
    if return_state:
        W = cfg.conv_width
        if seq_len is None:
            conv_state = conv_in[:, -(W - 1):, :] if S >= W - 1 else jnp.pad(
                conv_in, ((0, 0), (W - 1 - S, 0), (0, 0)))
        else:
            # per-row window of the last W-1 *real* inputs (zeros before
            # the sequence start, matching decode's zero-initialized conv
            # state for short prompts).
            idx = seq_len[:, None] - (W - 1) + jnp.arange(W - 1)[None, :]
            got = jnp.take_along_axis(
                conv_in, jnp.clip(idx, 0, S - 1)[..., None], axis=1)
            conv_state = jnp.where((idx >= 0)[..., None], got, 0.0)
        return out, {"h": h_final, "conv": conv_state.astype(x.dtype)}
    return out


def ssm_state_init(batch: int, d_model: int, cfg: SSMConfig, dtype) -> dict:
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    conv_ch = di + 2 * cfg.n_groups * cfg.d_state
    return {
        "h": jnp.zeros((batch, nh, cfg.head_dim, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
    }


def ssm_decode_step(params: dict, state: dict, x: jnp.ndarray,
                    cfg: SSMConfig):
    """One-token decode.  x: (B,1,d) -> (y (B,1,d), new_state)."""
    B, _, d = x.shape
    di = cfg.d_inner(d)
    nh = cfg.n_heads(d)
    gN = cfg.n_groups * cfg.d_state
    xt = x[:, 0, :]

    z = dense(params["in_z"], xt)
    xc = dense(params["in_x"], xt)
    Bc = dense(params["in_B"], xt)
    Cc = dense(params["in_C"], xt)
    dt = dense(params["in_dt"], xt).astype(jnp.float32)

    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)       # (B, C)
    conv_out, new_conv = _conv_decode(state["conv"], conv_in,
                                      params["conv_w"], params["conv_b"])
    conv_out = jax.nn.silu(conv_out)
    xc, Bc, Cc = jnp.split(conv_out, [di, di + gN], axis=-1)

    dt = jax.nn.softplus(dt + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xc.reshape(B, nh, cfg.head_dim).astype(jnp.float32)
    Bg = Bc.reshape(B, cfg.n_groups, cfg.d_state).astype(jnp.float32)
    Cg = Cc.reshape(B, cfg.n_groups, cfg.d_state).astype(jnp.float32)

    y, h_new = ssd_decode(state["h"], xh, dt, A, Bg, Cg)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(B, di).astype(x.dtype)
    y = norm_apply("rmsnorm", {"scale": params["norm_scale"]},
                   y * jax.nn.silu(z))
    out = dense(params["out"], y)
    return out[:, None, :], {"h": h_new, "conv": new_conv}
