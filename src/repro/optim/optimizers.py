"""Optimizers used by the paper: SGD(+momentum) (most detection models),
Adam / AdamW (SWIN, Deformable DETR, ChangeFormer), and LAMB (the winning
burned-area configuration).  Implemented as pure ``init``/``update`` pairs
over parameter pytrees; optimizer-state dtype is configurable so that
very large architectures can hold moments in bf16 (see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable     # params -> state
    update: Callable   # (grads, state, params, step, lr) -> (new_params, new_state)
    name: str = ""


def _tree_zeros_like(params, dtype=None):
    return jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def _cast_like(x, p):
    return x.astype(p.dtype)


def sgd(weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, step, lr):
        def upd(p, g):
            g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * g).astype(p.dtype)
        return jax.tree.map(upd, params, grads), state

    return Optimizer(init, update, "sgd")


def sgdm(momentum: float = 0.9, weight_decay: float = 0.0,
         state_dtype=None) -> Optimizer:
    def init(params):
        return {"m": _tree_zeros_like(params, state_dtype)}

    def update(grads, state, params, step, lr):
        def upd(p, g, m):
            g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            m_new = momentum * m.astype(jnp.float32) + g
            p_new = p.astype(jnp.float32) - lr * m_new
            return p_new.astype(p.dtype), m_new.astype(m.dtype)
        out = jax.tree.map(upd, params, grads, state["m"])
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"m": new_m}

    return Optimizer(init, update, "sgdm")


def _adam_core(grads, state, params, step, lr, b1, b2, eps, wd,
               trust_ratio: bool):
    m, v = state["m"], state["v"]
    t = step.astype(jnp.float32) + 1.0

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mhat = m_new / (1 - b1 ** t)
        vhat = v_new / (1 - b2 ** t)
        u = mhat / (jnp.sqrt(vhat) + eps)
        if wd:
            u = u + wd * p.astype(jnp.float32)
        if trust_ratio:
            pn = jnp.linalg.norm(p.astype(jnp.float32))
            un = jnp.linalg.norm(u)
            ratio = jnp.where((pn > 0) & (un > 0), pn / jnp.maximum(un, 1e-9), 1.0)
            u = ratio * u
        p_new = p.astype(jnp.float32) - lr * u
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, m, v)
    is3 = lambda t: isinstance(t, tuple)
    return (jax.tree.map(lambda t: t[0], out, is_leaf=is3),
            {"m": jax.tree.map(lambda t: t[1], out, is_leaf=is3),
             "v": jax.tree.map(lambda t: t[2], out, is_leaf=is3)})


def adam(b1=0.9, b2=0.999, eps=1e-8, state_dtype=None) -> Optimizer:
    def init(params):
        return {"m": _tree_zeros_like(params, state_dtype),
                "v": _tree_zeros_like(params, state_dtype)}

    def update(grads, state, params, step, lr):
        return _adam_core(grads, state, params, step, lr, b1, b2, eps, 0.0,
                          trust_ratio=False)

    return Optimizer(init, update, "adam")


def adamw(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01,
          state_dtype=None) -> Optimizer:
    def init(params):
        return {"m": _tree_zeros_like(params, state_dtype),
                "v": _tree_zeros_like(params, state_dtype)}

    def update(grads, state, params, step, lr):
        return _adam_core(grads, state, params, step, lr, b1, b2, eps,
                          weight_decay, trust_ratio=False)

    return Optimizer(init, update, "adamw")


def lamb(b1=0.9, b2=0.999, eps=1e-6, weight_decay=0.01,
         state_dtype=None) -> Optimizer:
    """LAMB (You et al.) — layerwise trust-ratio Adam; the paper's winning
    burned-area optimizer."""
    def init(params):
        return {"m": _tree_zeros_like(params, state_dtype),
                "v": _tree_zeros_like(params, state_dtype)}

    def update(grads, state, params, step, lr):
        return _adam_core(grads, state, params, step, lr, b1, b2, eps,
                          weight_decay, trust_ratio=True)

    return Optimizer(init, update, "lamb")


def get_optimizer(name: str, *, state_dtype=None, **kw) -> Optimizer:
    name = name.lower()
    if name == "sgd":
        return sgd(**kw)
    if name == "sgdm":
        return sgdm(state_dtype=state_dtype, **kw)
    if name == "adam":
        return adam(state_dtype=state_dtype, **kw)
    if name == "adamw":
        return adamw(state_dtype=state_dtype, **kw)
    if name == "lamb":
        return lamb(state_dtype=state_dtype, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
