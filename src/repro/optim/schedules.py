"""Learning-rate schedules.  The paper's final burned-area training uses a
step decay (x0.5 every 50 epochs); warmup-cosine is the modern default for
the LM architectures."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def step_decay(lr: float, decay_factor: float = 0.5, every: int = 50):
    """Paper: 'the learning rate decreases by a factor of 0.5 every 50
    epochs'."""
    def fn(step):
        k = jnp.floor(step / every)
        return jnp.asarray(lr, jnp.float32) * (decay_factor ** k)
    return fn


def cosine(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(lr: float, total_steps: int, warmup_steps: int = 100,
                  final_frac: float = 0.1):
    def fn(step):
        warm = lr * jnp.minimum(step / max(warmup_steps, 1), 1.0)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn
