from repro.optim.optimizers import (
    Optimizer,
    sgd,
    sgdm,
    adam,
    adamw,
    lamb,
    get_optimizer,
)
from repro.optim.schedules import constant, cosine, step_decay, warmup_cosine

__all__ = [
    "Optimizer", "sgd", "sgdm", "adam", "adamw", "lamb", "get_optimizer",
    "constant", "cosine", "step_decay", "warmup_cosine",
]
