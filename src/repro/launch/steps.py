"""Shared step builders: given (arch config, mesh, layout, input shape)
produce the jitted step function plus argument ShapeDtypeStructs and
shardings.  Used by both the multi-pod dry-run (lower+compile only) and
the real launchers (train.py / serve.py)."""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.data.inputs import input_specs, decode_specs
from repro.models import model as M
from repro.optim import get_optimizer
from repro.sharding import ShardCtx, rules
from repro.sharding.ctx import use_ctx
from repro.train.step import TrainState, make_train_step

BIG_PARAM_THRESHOLD = 20e9   # above this, optimizer moments go bf16


def _replicated(mesh):
    return NamedSharding(mesh, P())


def _batch_shardings(mesh, specs: Dict[str, jax.ShapeDtypeStruct]):
    return {
        name: rules.batch_sharding(mesh, len(s.shape), 0, s.shape[0])
        for name, s in specs.items()
    }


def shard_ctx_for(mesh, layout: str) -> ShardCtx:
    return ShardCtx(mesh, rules.logical_axes(mesh, layout))


def pick_optimizer(cfg: ArchConfig):
    state_dtype = (jnp.bfloat16 if cfg.param_count() > BIG_PARAM_THRESHOLD
                   else None)
    return get_optimizer(cfg.optimizer, state_dtype=state_dtype)


def effective_config(cfg: ArchConfig, shape_name: str) -> ArchConfig:
    """Shape-specific config tweaks (documented in DESIGN.md §4):
    long_500k forces the sliding-window variant for attention archs."""
    if shape_name == "long_500k" and cfg.has_attention and cfg.family != "hybrid":
        if cfg.sliding_window is None:
            cfg = dataclasses.replace(cfg, sliding_window=8192)
    return cfg


# --------------------------------------------------------------------------
def build_train(cfg: ArchConfig, mesh, layout: str, batch: int, seq: int,
                microbatches: int = 1, remat: bool = True):
    optimizer = pick_optimizer(cfg)
    # bare python step: the sharded jit below owns compilation + donation
    step_fn = make_train_step(cfg, optimizer, remat=remat,
                              microbatches=microbatches, jit_compile=False)

    params_struct = M.param_specs(cfg)
    opt_struct = jax.eval_shape(optimizer.init, params_struct)
    state_struct = TrainState(params_struct, opt_struct,
                              jax.ShapeDtypeStruct((), jnp.int32))
    batch_struct = input_specs(cfg, batch, seq)

    p_sh = rules.param_shardings(params_struct, mesh, layout)
    o_sh = rules.param_shardings(opt_struct, mesh, layout)
    state_sh = TrainState(p_sh, o_sh, _replicated(mesh))
    batch_sh = _batch_shardings(mesh, batch_struct)
    metrics_sh = {"loss": _replicated(mesh), "lr": _replicated(mesh),
                  "grad_norm": _replicated(mesh)}

    jitted = jax.jit(step_fn,
                     in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, metrics_sh),
                     donate_argnums=(0,))
    return jitted, (state_struct, batch_struct), shard_ctx_for(mesh, layout)


def build_prefill(cfg: ArchConfig, mesh, layout: str, batch: int, seq: int):
    cache_len = seq
    if cfg.is_encoder_only:
        def fn(params, batch_in):
            logits, aux = M.forward(params, cfg, batch_in, remat=False)
            return logits[:, -1, :]
    else:
        def fn(params, batch_in):
            return M.prefill(params, cfg, batch_in, cache_len=cache_len)

    params_struct = M.param_specs(cfg)
    batch_struct = input_specs(cfg, batch, seq)
    p_sh = rules.param_shardings(params_struct, mesh, layout)
    batch_sh = _batch_shardings(mesh, batch_struct)

    jitted = jax.jit(fn, in_shardings=(p_sh, batch_sh))
    return jitted, (params_struct, batch_struct), shard_ctx_for(mesh, layout)


def build_decode(cfg: ArchConfig, mesh, layout: str, batch: int, seq: int):
    """serve_step: ONE new token against a cache of `seq` positions."""
    if cfg.is_encoder_only:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")

    def fn(params, state, tokens, position):
        return M.decode_step(params, cfg, state, tokens, position)

    params_struct = M.param_specs(cfg)
    state_struct = jax.eval_shape(
        lambda: M.init_decode_state(cfg, batch, seq))
    dspecs = decode_specs(cfg, batch)

    p_sh = rules.param_shardings(params_struct, mesh, layout)
    s_sh = rules.decode_state_shardings(state_struct, mesh, layout)
    tok_sh = rules.batch_sharding(mesh, 2, 0, batch)
    pos_sh = rules.batch_sharding(mesh, 1, 0, batch)
    logits_sh = rules.batch_sharding(mesh, 2, 0, batch)

    jitted = jax.jit(fn,
                     in_shardings=(p_sh, s_sh, tok_sh, pos_sh),
                     out_shardings=(logits_sh, s_sh),
                     donate_argnums=(1,))
    args = (params_struct, state_struct, dspecs["tokens"], dspecs["position"])
    return jitted, args, shard_ctx_for(mesh, layout)


def build(kind: str, cfg: ArchConfig, mesh, layout: str, batch: int,
          seq: int, **kw):
    if kind == "train":
        return build_train(cfg, mesh, layout, batch, seq, **kw)
    if kind == "prefill":
        return build_prefill(cfg, mesh, layout, batch, seq)
    if kind == "decode":
        return build_decode(cfg, mesh, layout, batch, seq)
    raise ValueError(kind)


def lower_step(kind: str, cfg: ArchConfig, mesh, layout: str, batch: int,
               seq: int, **kw):
    """Lower (trace + SPMD-partition-ready) a step under the mesh/ctx."""
    cfg = effective_config(cfg, kw.pop("shape_name", ""))
    jitted, args, ctx = build(kind, cfg, mesh, layout, batch, seq, **kw)
    with use_ctx(ctx):
        if kind == "decode":
            lowered = jitted.lower(*args)
        else:
            lowered = jitted.lower(*args)
    return lowered
