"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The single-pod mesh is one
TPU v5e pod (16 x 16 = 256 chips); the multi-pod mesh adds an outer
``pod`` axis (2 pods = 512 chips) — the paper's stated future work
("train models across multiple pods").
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 512 if multi_pod else 256
    n = len(jax.devices())
    if n < need:
        raise RuntimeError(
            f"production mesh {shape} needs {need} devices but the jax "
            f"backend initialized with {n}; on CPU, set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=512 before any jax "
            f"use (a fresh process — the backend cannot be resized)")
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    # older jax (< 0.5): meshes are Auto-typed by default
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist locally, as a 1-D data mesh (smoke tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


INPUT_SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}
