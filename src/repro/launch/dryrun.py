import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -------------------------------------
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.analysis.analytic import analytic_roofline         # noqa: E402
from repro.analysis.roofline import roofline_terms            # noqa: E402
from repro.configs import get_config, list_archs              # noqa: E402
from repro.launch.mesh import INPUT_SHAPES, make_production_mesh  # noqa: E402
from repro.launch.steps import lower_step, effective_config   # noqa: E402

# (arch, shape) pairs that are structurally skipped (encoder-only has no
# autoregressive decode) — recorded, not silently dropped.
STRUCTURAL_SKIPS = {
    ("hubert-xlarge", "decode_32k"): "encoder-only: no decode step",
    ("hubert-xlarge", "long_500k"): "encoder-only: no decode step",
}


def run_one(arch: str, shape_name: str, multi_pod: bool, layout: str,
            out_dir: str, microbatches: int = 1) -> dict:
    seq, batch, kind = INPUT_SHAPES[shape_name]
    rec = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "layout": layout, "seq": seq, "batch": batch,
    }
    if (arch, shape_name) in STRUCTURAL_SKIPS:
        rec["status"] = "skipped"
        rec["reason"] = STRUCTURAL_SKIPS[(arch, shape_name)]
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            tag = f"{arch}_{shape_name}_{rec['mesh']}_{layout}"
            with open(os.path.join(out_dir, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    cfg = get_config(arch)
    t0 = time.time()
    try:
        kw = {"microbatches": microbatches} if kind == "train" else {}
        lowered = lower_step(kind, cfg, mesh, layout, batch, seq,
                             shape_name=shape_name, **kw)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        if mem is not None:
            rec["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")
                if hasattr(mem, k)
            }
            print(f"  memory_analysis: {rec['memory_analysis']}")
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        hlo = compiled.as_text()
        eff_cfg = effective_config(cfg, shape_name)
        # primary roofline: analytic model (XLA cost_analysis counts scan
        # bodies once — see analysis/analytic.py docstring)
        rec["roofline"] = analytic_roofline(
            eff_cfg, batch, seq, kind, mesh, layout)
        # structural cross-check from the partitioned HLO
        rec["hlo_roofline"] = roofline_terms(
            cost, hlo, n_chips, cfg=eff_cfg, batch=batch, seq=seq, kind=kind)
        rec["cost_analysis"] = {
            k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "bytes accessed", "optimal_seconds")
                or k.startswith("bytes accessed"))
        }
        rec["status"] = "ok"
        r = rec["roofline"]
        print(f"  analytic: compute={r['compute_s']*1e3:.2f}ms "
              f"memory={r['memory_s']*1e3:.2f}ms "
              f"collective={r['collective_s']*1e3:.2f}ms "
              f"dominant={r['dominant']} "
              f"mfu_ub={r['mfu_upper_bound']:.2f}")
    except Exception as e:  # noqa: BLE001 — a dry-run failure is a bug report
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"  ERROR {type(e).__name__}: {str(e)[:400]}")
    rec["total_s"] = round(time.time() - t0, 1)

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{rec['mesh']}_{layout}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def dryrun_sweep(archs="all", shapes="all", meshes="single",
                 layout="fsdp_tp", microbatches: int = 1,
                 out: str = "experiments/dryrun") -> list:
    """The full (arch x shape x mesh) sweep; the body behind both the
    ``repro.api`` dryrun runner and this module's CLI shim."""
    arch_list = list_archs() if archs == "all" else archs.split(",")
    shape_list = list(INPUT_SHAPES) if shapes == "all" else [shapes]
    mesh_list = {"single": [False], "multi": [True],
                 "both": [False, True]}[meshes]

    results = []
    for arch in arch_list:
        for shape in shape_list:
            for mp in mesh_list:
                mesh_tag = "2x16x16" if mp else "16x16"
                print(f"[dryrun] {arch} x {shape} x {mesh_tag} x {layout}",
                      flush=True)
                rec = run_one(arch, shape, mp, layout, out, microbatches)
                results.append(rec)
                print(f"  -> {rec['status']} ({rec.get('total_s', 0)}s)",
                      flush=True)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = len(results) - n_ok - n_skip
    print(f"\n[dryrun] ok={n_ok} skipped={n_skip} error={n_err}")
    for r in results:
        if r["status"] == "error":
            print(f"  FAILED: {r['arch']} x {r['shape']} x {r['mesh']}: "
                  f"{r['error'][:200]}")
    return results


def main():
    # thin shim over the repro.api registry (RunSpec in, RunReport out)
    ap = argparse.ArgumentParser(description="multi-pod lowering dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all",
                    choices=["all", *INPUT_SHAPES])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--layout", default="fsdp_tp", choices=["fsdp_tp", "fsdp_sp", "dp"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from repro.api import RunSpec, run
    report = run(RunSpec(kind="dryrun", arch=args.arch, overrides={
        "shape": args.shape, "mesh": args.mesh, "layout": args.layout,
        "microbatches": args.microbatches, "out": args.out}))
    if not report.ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
