"""Training launcher.

``python -m repro.launch.train --arch <id> [--reduced] --steps N``

On this CPU container only reduced configs actually execute; the full
configs are exercised by the dry-run (``repro.launch.dryrun``).  The same
entrypoint is what a Kubernetes job manifest's container command would
invoke on real hardware — env-var overrides mirror the paper's
bash-automation interface.

Training runs through :class:`repro.train.TrainLoop`: step execution and
metrics live there, and with ``--checkpoint-dir`` the **full**
``TrainState`` (params + optimizer state + step) plus the data cursor is
checkpointed atomically on a ``--checkpoint-every`` cadence and at run
end.  ``--resume`` restores the newest valid checkpoint (falling back
past torn ones) so a preempted job continues instead of restarting;
``--preempt-at-step`` injects the kill for tests/CI.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, export_to_s3
from repro.configs import get_config, get_reduced
from repro.core.artifacts import S3Store
from repro.data.inputs import SeekableSyntheticBatches
from repro.data.tokens import SeekableTokenBatches
from repro.optim import get_optimizer, warmup_cosine
from repro.train import TrainLoop, init_train_state, make_train_step


class _LMDictBatches(SeekableTokenBatches):
    """Seekable LM stream yielding model-ready {'tokens','labels'} dicts."""

    def next_batch(self):
        toks, labels = super().next_batch()
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}


def train_main(arch: str, *, reduced: bool = True, steps: int = 100,
               batch: int = 8, seq: int = 128, lr: float = 3e-4,
               optimizer: str = None, seed: int = 0,
               checkpoint_dir: str = None, s3_root: str = None,
               log_every: int = 10, checkpoint_every: int = 0,
               checkpoint_keep: int = 3, checkpoint_async: bool = True,
               resume: bool = False, preempt_at_step: int = None,
               precision: str = "f32", grad_clip: float = None,
               microbatches: int = 1,
               attention_backend: str = None,
               mixer_backend: str = None) -> dict:
    cfg = get_reduced(arch) if reduced else get_config(arch)
    backends = {}
    if attention_backend:
        backends["attention_backend"] = attention_backend
    if mixer_backend:
        backends["mixer_backend"] = mixer_backend
    if backends:
        cfg = dataclasses.replace(cfg, **backends)
    opt = get_optimizer(optimizer or cfg.optimizer)
    state = init_train_state(jax.random.PRNGKey(seed), cfg, opt)
    # jit + donation live in make_train_step: the input TrainState is
    # consumed each step (params/opt_state updated in place)
    step_fn = make_train_step(
        cfg, opt, lr_schedule=warmup_cosine(lr, steps,
                                            warmup_steps=max(steps // 10, 1)),
        precision=precision, grad_clip=grad_clip,
        microbatches=max(1, int(microbatches)))

    text_lm = cfg.family in ("dense", "moe", "ssm", "hybrid")
    data = (_LMDictBatches(cfg.vocab, batch, seq, seed) if text_lm
            else SeekableSyntheticBatches(cfg, batch, seq, seed))

    ckpt = None
    if checkpoint_dir:
        ckpt = CheckpointManager(checkpoint_dir,
                                 keep_last=max(int(checkpoint_keep), 1),
                                 every_steps=int(checkpoint_every),
                                 async_saves=bool(checkpoint_async))
    loop = TrainLoop(step_fn, state, data, checkpointer=ckpt,
                     preempt_at_step=preempt_at_step, log_every=log_every)
    if resume:
        loop.resume()
    try:
        run = loop.run(steps)
    finally:
        if ckpt is not None:
            ckpt.wait()

    result = {
        "arch": cfg.name, "params": cfg.param_count(),
        **run,
    }
    if steps <= 512:
        # oracle tests compare full trajectories (e.g. an elastically
        # shrunk gang's world=1 continuation vs a pure world=1 run);
        # bounded so long runs don't bloat their reports
        result["losses"] = list(loop.losses)
    if ckpt is not None:
        loop.save_final(extra={"arch": cfg.name,
                               "final_loss": run.get("final_loss")})
        overhead = result.get("checkpoint", {}).get("overhead_frac", 0.0)
        result["checkpoint"] = {**ckpt.stats(), "overhead_frac": overhead}
        ckpt.close()
        if s3_root:
            s3 = S3Store(s3_root)
            n = export_to_s3(checkpoint_dir, s3, f"models/{cfg.name}")
            result["s3_objects"] = n
    return result


def main():
    # thin shim over the repro.api registry (RunSpec in, RunReport out)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=os.environ.get("ARCH", "stablelm-1.6b"))
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("STEPS", 100)))
    ap.add_argument("--batch", type=int,
                    default=int(os.environ.get("BATCH", 8)))
    ap.add_argument("--seq", type=int, default=int(os.environ.get("SEQ", 128)))
    ap.add_argument("--lr", type=float, default=float(os.environ.get("LR", 3e-4)))
    ap.add_argument("--optimizer", default=os.environ.get("OPTIMIZER"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="save the full TrainState every N steps")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest valid checkpoint before "
                         "training")
    ap.add_argument("--preempt-at-step", type=int, default=None,
                    help="fault hook: raise Preemption before this step")
    ap.add_argument("--s3-root", default=None)
    ap.add_argument("--precision", default=os.environ.get("PRECISION", "f32"),
                    choices=["f32", "bf16"],
                    help="mixed-precision policy: f32 master params + "
                         "optimizer state always; bf16 = bf16 "
                         "compute/activations")
    ap.add_argument("--grad-clip", type=float, default=None,
                    help="clip the global gradient norm to this value")
    ap.add_argument("--attention-backend", default=None,
                    choices=["jnp", "pallas", "auto"],
                    help="attention kernel backend (default: config's, "
                         "'auto' = Pallas on TPU, jnp elsewhere)")
    ap.add_argument("--mixer-backend", default=None,
                    choices=["jnp", "pallas", "auto"],
                    help="SSD mixer kernel backend")
    ap.add_argument("--world-size", type=int, default=1,
                    help=">1: data-parallel gang of N rank processes "
                         "(--batch is the GLOBAL batch)")
    ap.add_argument("--dist-rank", type=int, default=None,
                    help="this process's rank (set by the gang launcher)")
    ap.add_argument("--coordinator", default=None,
                    help="host:port of rank 0 (jax.distributed)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation chunks per step")
    args = ap.parse_args()

    from repro.api import RunSpec, run
    overrides = {"full": args.full, "steps": args.steps, "batch": args.batch,
                 "seq": args.seq, "lr": args.lr}
    if args.optimizer:
        overrides["optimizer"] = args.optimizer
    if args.precision != "f32":
        overrides["precision"] = args.precision
    if args.grad_clip is not None:
        overrides["grad_clip"] = args.grad_clip
    if args.attention_backend:
        overrides["attention_backend"] = args.attention_backend
    if args.mixer_backend:
        overrides["mixer_backend"] = args.mixer_backend
    if args.checkpoint_dir:
        overrides["checkpoint_dir"] = args.checkpoint_dir
    if args.checkpoint_every:
        overrides["checkpoint_every"] = args.checkpoint_every
    if args.resume:
        overrides["resume"] = True
    if args.preempt_at_step is not None:
        overrides["preempt_at_step"] = args.preempt_at_step
    if args.s3_root:
        overrides["s3_root"] = args.s3_root
    if args.world_size != 1:
        overrides["world_size"] = args.world_size
    if args.dist_rank is not None:
        overrides["dist_rank"] = args.dist_rank
    if args.coordinator:
        overrides["coordinator"] = args.coordinator
    if args.microbatches != 1:
        overrides["microbatches"] = args.microbatches
    report = run(RunSpec(kind="train", arch=args.arch, seed=args.seed,
                         overrides=overrides))
    print(json.dumps(report.metrics, indent=1))
    if not report.ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
