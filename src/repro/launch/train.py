"""Training launcher.

``python -m repro.launch.train --arch <id> [--reduced] --steps N``

On this CPU container only reduced configs actually execute; the full
configs are exercised by the dry-run (``repro.launch.dryrun``).  The same
entrypoint is what a Kubernetes job manifest's container command would
invoke on real hardware — env-var overrides mirror the paper's
bash-automation interface.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import export_to_s3, save_checkpoint
from repro.configs import get_config, get_reduced
from repro.core.artifacts import S3Store
from repro.data import make_batch
from repro.data.tokens import lm_batch_iterator
from repro.optim import get_optimizer, warmup_cosine
from repro.train import init_train_state, make_train_step


def train_main(arch: str, *, reduced: bool = True, steps: int = 100,
               batch: int = 8, seq: int = 128, lr: float = 3e-4,
               optimizer: str = None, seed: int = 0,
               checkpoint_dir: str = None, s3_root: str = None,
               log_every: int = 10) -> dict:
    cfg = get_reduced(arch) if reduced else get_config(arch)
    opt = get_optimizer(optimizer or cfg.optimizer)
    state = init_train_state(jax.random.PRNGKey(seed), cfg, opt)
    step_fn = jax.jit(make_train_step(
        cfg, opt, lr_schedule=warmup_cosine(lr, steps,
                                            warmup_steps=max(steps // 10, 1))))

    text_lm = cfg.family in ("dense", "moe", "ssm", "hybrid")
    it = lm_batch_iterator(cfg.vocab, batch, seq, seed) if text_lm else None

    losses = []
    t0 = time.time()
    for i in range(steps):
        if text_lm:
            toks, labels = next(it)
            b = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        else:
            b = make_batch(cfg, batch, seq, seed=seed + i)
        state, metrics = step_fn(state, b)
        losses.append(float(metrics["loss"]))
        if log_every and (i % log_every == 0 or i == steps - 1):
            print(f"step {i:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
    wall = time.time() - t0

    result = {
        "arch": cfg.name, "steps": steps, "wall_s": round(wall, 2),
        "steps_per_s": round(steps / wall, 3),
        "first_loss": losses[0], "final_loss": losses[-1],
        "loss_drop": losses[0] - losses[-1],
        "params": cfg.param_count(),
    }
    if checkpoint_dir:
        save_checkpoint(checkpoint_dir, state.params,
                        step=int(state.step), metadata=result)
        if s3_root:
            s3 = S3Store(s3_root)
            n = export_to_s3(checkpoint_dir, s3, f"models/{cfg.name}")
            result["s3_objects"] = n
    return result


def main():
    # thin shim over the repro.api registry (RunSpec in, RunReport out)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=os.environ.get("ARCH", "stablelm-1.6b"))
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("STEPS", 100)))
    ap.add_argument("--batch", type=int,
                    default=int(os.environ.get("BATCH", 8)))
    ap.add_argument("--seq", type=int, default=int(os.environ.get("SEQ", 128)))
    ap.add_argument("--lr", type=float, default=float(os.environ.get("LR", 3e-4)))
    ap.add_argument("--optimizer", default=os.environ.get("OPTIMIZER"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--s3-root", default=None)
    args = ap.parse_args()

    from repro.api import RunSpec, run
    overrides = {"full": args.full, "steps": args.steps, "batch": args.batch,
                 "seq": args.seq, "lr": args.lr}
    if args.optimizer:
        overrides["optimizer"] = args.optimizer
    if args.checkpoint_dir:
        overrides["checkpoint_dir"] = args.checkpoint_dir
    if args.s3_root:
        overrides["s3_root"] = args.s3_root
    report = run(RunSpec(kind="train", arch=args.arch, seed=args.seed,
                         overrides=overrides))
    print(json.dumps(report.metrics, indent=1))
    if not report.ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
