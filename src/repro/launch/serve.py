"""Serving launcher: batched decoding over synthetic requests.

``python -m repro.launch.serve --arch granite-3-2b --requests 16``

Decoding is greedy by default; ``--temperature``/``--top-k`` switch the
fused on-device sampling head (per-request knobs are available on
:class:`repro.serve.Request`).

Two serving modes share this entrypoint:

* **static batch** (default, ``--arrival-rate 0``): every request is
  queued up front and the :class:`~repro.serve.ServeEngine` drains them —
  the closed-loop throughput measurement.
* **continuous** (``--arrival-rate > 0`` requests/s): an open-loop
  Poisson or bursty arrival trace (``--trace``) drives the
  :class:`~repro.serve.ServeScheduler` — continuous admission into freed
  slots mid-decode, SLO shedding (``--slo-deadline-ms``), and paged-KV
  budgeting/eviction (``--max-kv-blocks``, ``--kv-block-size``).

Both modes report per-request service timing (TTFT / TPOT / queue-wait
percentiles) so campaign summaries can aggregate them.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import init_params
from repro.serve import Request, ServeEngine, ServeScheduler, make_trace


def _timing_metrics(stats_summary: dict) -> dict:
    keys = ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s",
            "queue_wait_p50_s", "queue_wait_p99_s", "evictions")
    return {k: stats_summary.get(k) for k in keys}


def serve_main(arch: str, *, requests: int = 16, slots: int = 4,
               cache_len: int = 128, max_tokens: int = 16,
               seed: int = 0, temperature: float = 0.0,
               top_k: int = 0, arrival_rate: float = 0.0,
               trace: str = "poisson", slo_deadline_ms: float = 0.0,
               max_kv_blocks: int = 0, kv_block_size: int = 16) -> dict:
    cfg = get_reduced(arch)
    params = init_params(jax.random.PRNGKey(seed), cfg)

    if arrival_rate > 0:
        return _serve_continuous(
            cfg, params, requests=requests, slots=slots,
            cache_len=cache_len, max_tokens=max_tokens, seed=seed,
            temperature=temperature, top_k=top_k,
            arrival_rate=arrival_rate, trace=trace,
            slo_deadline_ms=slo_deadline_ms, max_kv_blocks=max_kv_blocks,
            kv_block_size=kv_block_size)

    engine = ServeEngine(cfg, params, slots=slots, cache_len=cache_len,
                         seed=seed)
    rng = np.random.default_rng(seed)
    for i in range(requests):
        engine.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab,
                                       size=int(rng.integers(4, 24))),
            max_tokens=max_tokens, temperature=temperature, top_k=top_k))
    t0 = time.time()
    done = engine.run()
    wall = time.time() - t0
    tokens = sum(len(r.generated) for r in done)
    return {
        "arch": cfg.name, "mode": "static", "requests": len(done),
        "tokens": tokens,
        "wall_s": round(wall, 2),
        "tokens_per_s": round(tokens / wall, 2),
        "slots": slots,
        "decode_steps": engine.stats["decode_steps"],
        "prefill_compiles": engine.prefill_compiles,
        "decode_compiles": engine.decode_compiles,
        "host_transfer_bytes": engine.stats["host_transfer_bytes"],
        **_timing_metrics(engine.stats()),
    }


def _serve_continuous(cfg, params, *, requests, slots, cache_len,
                      max_tokens, seed, temperature, top_k, arrival_rate,
                      trace, slo_deadline_ms, max_kv_blocks,
                      kv_block_size) -> dict:
    sched = ServeScheduler(
        cfg, params, slots=slots, cache_len=cache_len, seed=seed,
        max_kv_blocks=max_kv_blocks or None, kv_block_size=kv_block_size,
        slo_deadline_ms=slo_deadline_ms or None)
    items = make_trace(trace, cfg.vocab, requests, arrival_rate,
                       seed=seed, max_tokens=max_tokens)
    for _, req in items:
        req.temperature, req.top_k = temperature, top_k
    t0 = sched.clock.now()
    sched.submit_trace([(t0 + t, r) for t, r in items])
    done = sched.run()
    wall = sched.clock.now() - t0
    s = sched.stats()
    tokens = sum(len(r.generated) for r in done)
    slo_tokens = sum(len(r.generated) for r in done if r.met_deadline())
    return {
        "arch": cfg.name, "mode": "continuous", "trace": trace,
        "arrival_rate_qps": arrival_rate,
        "requests": requests, "completed": s["completed"],
        "shed": s["shed"], "slo_met": s["slo_met"],
        "tokens": tokens,
        "wall_s": round(wall, 2),
        "tokens_per_s": round(tokens / max(wall, 1e-9), 2),
        "goodput_req_s": round(s["slo_met"] / max(wall, 1e-9), 3),
        "goodput_tok_s": round(slo_tokens / max(wall, 1e-9), 2),
        "slots": slots,
        "decode_steps": s["decode_steps"],
        "prefill_compiles": s["prefill_compiles"],
        "decode_compiles": s["decode_compiles"],
        "kv": s["kv"],
        **_timing_metrics(s),
    }


def main():
    # thin shim over the repro.api registry (RunSpec in, RunReport out)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop offered load in requests/s "
                         "(0 = static batch mode)")
    ap.add_argument("--trace", choices=("poisson", "bursty"),
                    default="poisson")
    ap.add_argument("--slo-deadline-ms", type=float, default=0.0,
                    help="TTFT SLO; queued requests past it are shed "
                         "(0 = no deadline)")
    ap.add_argument("--max-kv-blocks", type=int, default=0,
                    help="paged KV pool size in blocks "
                         "(0 = slots*cache_len, no oversubscription)")
    ap.add_argument("--kv-block-size", type=int, default=16)
    args = ap.parse_args()

    from repro.api import RunSpec, run
    report = run(RunSpec(kind="serve", arch=args.arch, overrides={
        "requests": args.requests, "slots": args.slots,
        "cache_len": args.cache_len, "max_tokens": args.max_tokens,
        "temperature": args.temperature, "top_k": args.top_k,
        "arrival_rate": args.arrival_rate, "trace": args.trace,
        "slo_deadline_ms": args.slo_deadline_ms,
        "max_kv_blocks": args.max_kv_blocks,
        "kv_block_size": args.kv_block_size}))
    print(json.dumps(report.metrics, indent=1))
    if not report.ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
