"""Serving launcher: batched greedy decoding over synthetic requests.

``python -m repro.launch.serve --arch granite-3-2b --requests 16``
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import init_params
from repro.serve import Request, ServeEngine


def serve_main(arch: str, *, requests: int = 16, slots: int = 4,
               cache_len: int = 128, max_tokens: int = 16,
               seed: int = 0) -> dict:
    cfg = get_reduced(arch)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    engine = ServeEngine(cfg, params, slots=slots, cache_len=cache_len)
    rng = np.random.default_rng(seed)
    for i in range(requests):
        engine.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab,
                                       size=int(rng.integers(4, 24))),
            max_tokens=max_tokens))
    t0 = time.time()
    done = engine.run()
    wall = time.time() - t0
    tokens = sum(len(r.generated) for r in done)
    return {
        "arch": cfg.name, "requests": len(done), "tokens": tokens,
        "wall_s": round(wall, 2),
        "tokens_per_s": round(tokens / wall, 2),
        "slots": slots,
    }


def main():
    # thin shim over the repro.api registry (RunSpec in, RunReport out)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-tokens", type=int, default=16)
    args = ap.parse_args()

    from repro.api import RunSpec, run
    report = run(RunSpec(kind="serve", arch=args.arch, overrides={
        "requests": args.requests, "slots": args.slots,
        "cache_len": args.cache_len, "max_tokens": args.max_tokens}))
    print(json.dumps(report.metrics, indent=1))
    if not report.ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
