"""Serving launcher: batched decoding over synthetic requests.

``python -m repro.launch.serve --arch granite-3-2b --requests 16``

Decoding is greedy by default; ``--temperature``/``--top-k`` switch the
fused on-device sampling head (per-request knobs are available on
:class:`repro.serve.Request`).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import init_params
from repro.serve import Request, ServeEngine


def serve_main(arch: str, *, requests: int = 16, slots: int = 4,
               cache_len: int = 128, max_tokens: int = 16,
               seed: int = 0, temperature: float = 0.0,
               top_k: int = 0) -> dict:
    cfg = get_reduced(arch)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    engine = ServeEngine(cfg, params, slots=slots, cache_len=cache_len,
                         seed=seed)
    rng = np.random.default_rng(seed)
    for i in range(requests):
        engine.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab,
                                       size=int(rng.integers(4, 24))),
            max_tokens=max_tokens, temperature=temperature, top_k=top_k))
    t0 = time.time()
    done = engine.run()
    wall = time.time() - t0
    tokens = sum(len(r.generated) for r in done)
    return {
        "arch": cfg.name, "requests": len(done), "tokens": tokens,
        "wall_s": round(wall, 2),
        "tokens_per_s": round(tokens / wall, 2),
        "slots": slots,
        "decode_steps": engine.stats["decode_steps"],
        "prefill_compiles": engine.prefill_compiles,
        "host_transfer_bytes": engine.stats["host_transfer_bytes"],
    }


def main():
    # thin shim over the repro.api registry (RunSpec in, RunReport out)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    args = ap.parse_args()

    from repro.api import RunSpec, run
    report = run(RunSpec(kind="serve", arch=args.arch, overrides={
        "requests": args.requests, "slots": args.slots,
        "cache_len": args.cache_len, "max_tokens": args.max_tokens,
        "temperature": args.temperature, "top_k": args.top_k}))
    print(json.dumps(report.metrics, indent=1))
    if not report.ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
