"""Campaign submission CLI — the paper's bash automation as a library
command: expand a grid, render every manifest + config, then either run
the jobs locally (reduced scale) or simulate the campaign on the Nautilus
inventory.

``python -m repro.launch.submit --campaign burned_area --mode simulate``
"""
from __future__ import annotations

import argparse
import json

from repro.core import (JobSpec, Orchestrator, PersistentVolume, Resources,
                        S3Store)
from repro.core.experiment import ExperimentGrid, paper_burned_area_grid


def build_campaign(name: str):
    if name == "burned_area":
        grids = paper_burned_area_grid()
        jobs = []
        for arch, grid in grids.items():
            for spec in grid.expand():
                jobs.append(JobSpec(
                    name=spec.name,
                    env={k: str(v) for k, v in spec.params.items()},
                    resources=Resources(gpus=2, cpus=4, memory_gb=24),
                    duration_h=518.0 / 144,   # paper: 518 h over 144 models
                    labels={"experiment": f"ba-{arch}"}))
        return jobs
    if name == "detection":
        models = ["convnext", "ssd", "retinanet", "fcos", "yolov3", "yolox",
                  "vit", "detr", "deformable-detr", "swin"]
        # Table V: 2,142 wall-clock hours over the 30 detection models,
        # apportioned per dataset by Table III's GPU-hour ratios.
        totals = {"rareplanes": 241.2, "dota": 580.4, "xview": 580.6}
        scale = 2142.0 / sum(totals.values())
        jobs = []
        for m in models:
            for ds, gpu_h in totals.items():
                jobs.append(JobSpec(
                    name=f"det-{m}-{ds}", env={"MODEL": m, "DATASET": ds},
                    resources=Resources(gpus=4, cpus=8, memory_gb=48),
                    duration_h=gpu_h / 10 * scale,
                    labels={"experiment": "detection"}))
        return jobs
    if name == "deforestation":
        return [JobSpec(name=f"cf-{i}", env={"CONFIG": str(i)},
                        resources=Resources(gpus=1, cpus=4, memory_gb=24),
                        duration_h=1380.0 / 60,
                        labels={"experiment": "deforestation"})
                for i in range(60)]
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--campaign", default="burned_area",
                    choices=["burned_area", "detection", "deforestation",
                             "all"])
    ap.add_argument("--mode", default="simulate",
                    choices=["simulate", "manifests"])
    ap.add_argument("--workdir", default="experiments/campaigns")
    args = ap.parse_args()

    names = (["burned_area", "detection", "deforestation"]
             if args.campaign == "all" else [args.campaign])
    jobs = []
    for n in names:
        jobs.extend(build_campaign(n))

    pvc = PersistentVolume(args.workdir, name=f"campaign-{args.campaign}")
    orch = Orchestrator(pvc, S3Store(args.workdir))
    orch.submit_many(jobs)
    print(f"submitted {len(jobs)} jobs; "
          f"{len(pvc.listdir('manifests'))} manifests rendered")

    if args.mode == "simulate":
        res = orch.simulate()
        out = {
            "jobs": len(jobs),
            "total_gpu_hours": round(res.total_gpu_hours, 1),
            "total_wall_hours": round(res.total_wall_hours, 1),
            "cluster_makespan_h": round(res.makespan_h, 2),
            "speedup_vs_serial": round(res.speedup_vs_serial(), 1),
            "mean_queue_wait_h": round(res.queue_wait_h_mean, 3),
        }
        print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
