"""Campaign submission CLI — the paper's bash automation as a library
command: expand a grid into :class:`repro.api.RunSpec`s, render every
manifest + config, then simulate the campaign on the Nautilus inventory
(or just emit the manifests).

``python -m repro.launch.submit --campaign burned_area --mode simulate``

is now a thin shim over ``python -m repro.launch run simulate ...``:
campaigns are lists of RunSpecs, jobs and manifests fall out of
``Orchestrator.submit_runs``, and the accounting matches the paper's
Tables III/V (144 burned-area models; 2,142 detection wall-hours).
"""
from __future__ import annotations

import argparse
import json
from typing import List

from repro.api import RunSpec
from repro.core import JobSpec, Resources
from repro.core.experiment import paper_burned_area_grid

# Table V rows this module reproduces
BURNED_AREA_TOTAL_H = 518.0          # over 144 models
DETECTION_TOTAL_H = 2142.0           # over 30 models
DEFORESTATION_TOTAL_H = 1380.0       # over 60 models

DETECTION_MODELS = ["convnext", "ssd", "retinanet", "fcos", "yolov3",
                    "yolox", "vit", "detr", "deformable-detr", "swin"]
# Table III GPU-hour ratios, used to apportion Table V's wall-clock total
DETECTION_DATASET_GPU_H = {"rareplanes": 241.2, "dota": 580.4,
                           "xview": 580.6}


def build_campaign_runs(name: str) -> List[RunSpec]:
    """A campaign as RunSpecs — the single declarative form every
    consumer (manifests, local runs, cluster sim) now starts from."""
    if name == "burned_area":
        runs: List[RunSpec] = []
        for arch, grid in paper_burned_area_grid().items():
            runs.extend(grid.to_runs(
                kind="train", arch=arch,
                resources=Resources(gpus=2, cpus=4, memory_gb=24),
                duration_h=BURNED_AREA_TOTAL_H / 144,
                labels={"experiment": f"ba-{arch}"}))
        return runs
    if name == "detection":
        scale = DETECTION_TOTAL_H / sum(DETECTION_DATASET_GPU_H.values())
        return [
            RunSpec(kind="train", arch=m, name=f"det-{m}-{ds}",
                    overrides={"model": m, "dataset": ds},
                    resources=Resources(gpus=4, cpus=8, memory_gb=48),
                    duration_h=gpu_h / len(DETECTION_MODELS) * scale,
                    labels={"experiment": "detection"})
            for m in DETECTION_MODELS
            for ds, gpu_h in DETECTION_DATASET_GPU_H.items()]
    if name == "deforestation":
        return [
            RunSpec(kind="train", arch="changeformer", name=f"cf-{i}",
                    overrides={"config": i},
                    resources=Resources(gpus=1, cpus=4, memory_gb=24),
                    duration_h=DEFORESTATION_TOTAL_H / 60,
                    labels={"experiment": "deforestation"})
            for i in range(60)]
    raise ValueError(name)


def build_campaign(name: str) -> List[JobSpec]:
    """Back-compat: the campaign as cluster JobSpecs."""
    return [run.to_job() for run in build_campaign_runs(name)]


def main():
    # thin shim over the repro.api registry (RunSpec in, RunReport out)
    ap = argparse.ArgumentParser()
    ap.add_argument("--campaign", default="burned_area",
                    choices=["burned_area", "detection", "deforestation",
                             "all"])
    ap.add_argument("--mode", default="simulate",
                    choices=["simulate", "manifests"])
    ap.add_argument("--workdir", default="experiments/campaigns")
    args = ap.parse_args()

    from repro.api import run
    report = run(RunSpec(kind="simulate", overrides={
        "campaign": args.campaign, "mode": args.mode,
        "workdir": args.workdir}))
    if not report.ok:
        raise SystemExit(report.error or 1)
    if args.mode == "simulate":
        out = {k: v for k, v in report.metrics.items() if k != "manifests"}
        print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
