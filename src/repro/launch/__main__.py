"""The one dispatching CLI: ``python -m repro.launch run <kind> ...``.

Every workload goes through the same door:

    python -m repro.launch run train     --arch stablelm-1.6b --steps 50
    python -m repro.launch run serve     --arch granite-3-2b --requests 8
    python -m repro.launch run dryrun    --arch stablelm-1.6b --shape train_4k
    python -m repro.launch run perfprobe --arch glm4-9b --shape decode_32k
    python -m repro.launch run simulate  --campaign burned_area
    python -m repro.launch campaign run --jobs jobs.json --workdir DIR
    python -m repro.launch campaign status [events.jsonl | workdir]
    python -m repro.launch kinds

``run`` builds a :class:`repro.api.RunSpec` from the argv (known flags:
``--arch/--seed/--name``; any other ``--key value`` becomes an override),
dispatches through the runner registry, prints the
:class:`repro.api.RunReport` as JSON, and exits nonzero iff the run
failed.  The old per-kind module entrypoints
(``python -m repro.launch.train`` etc.) remain as thin shims over this
same registry.

``campaign run`` drives a whole campaign from a jobs file (a JSON list
of RunSpec dicts): it submits every spec to an Orchestrator and executes
them with ``run_cluster`` — this process *is* the scheduler, so chaos
tests SIGKILL it and restart with ``--resume-campaign`` to exercise
crash recovery (completed jobs are never re-executed; live orphan
attempts are re-adopted by pid + start-time identity).  Knobs:
``--workers``, ``--speculate`` (straggler duplicates), ``--backfill``,
``--pin-cpus``, ``--attempt-timeout``, ``--no-telemetry``,
``--retry-backoff-base``.  Prints the campaign summary JSON; exits
nonzero unless every job succeeded.

``campaign status`` replays a ``run_cluster`` campaign's durable event
log (``campaign/events.jsonl``) into a per-job state table — pass the
events file or any directory to search (default ``experiments``).  Add
``--json`` for the machine-readable replay (including each job's
telemetry summary: peak RSS, mean/peak CPU%, declared-vs-observed
request ratio).  Exits 1 if the log replays to an inconsistent state.
"""
from __future__ import annotations

import os
import sys

_USAGE = __doc__.split("\n\n")[1]


def _apply_cpu_affinity() -> None:
    """Honor a campaign executor's CPU limit (``REPRO_CPU_AFFINITY``,
    the local analogue of a Kubernetes CPU limit) before jax — and its
    thread pools — load."""
    spec = os.environ.get("REPRO_CPU_AFFINITY")
    if spec and hasattr(os, "sched_setaffinity"):
        try:
            os.sched_setaffinity(0, {int(c) for c in spec.split(",") if c})
        except (ValueError, OSError):
            pass                      # stale/foreign core list: run unpinned


def main(argv=None) -> int:
    _apply_cpu_affinity()
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help", "help"):
        print(f"usage: python -m repro.launch <run|campaign|kinds> ..."
              f"\n\n{_USAGE}")
        return 0
    cmd, rest = argv[0], argv[1:]
    if cmd == "kinds":
        from repro.api import runner_kinds
        print("\n".join(runner_kinds()))
        return 0
    if cmd == "campaign":
        return _campaign(rest)
    if cmd != "run":
        print(f"unknown command {cmd!r} (expected 'run', 'campaign' "
              f"or 'kinds')", file=sys.stderr)
        return 2
    if not rest:
        print("usage: python -m repro.launch run <kind> [flags]",
              file=sys.stderr)
        return 2

    # kinds declare their env prerequisites on the registry (e.g. the
    # dryrun/perfprobe fake-device XLA flag); run() applies them before
    # the runner module — and therefore jax — is imported, and nothing
    # on the path up to there touches jax.
    from repro.api import RunSpec, run
    try:
        spec = RunSpec.from_args(rest)
        report = run(spec)
    except (KeyError, ValueError) as e:   # unknown kind / malformed flags
        print(str(e).strip('"'), file=sys.stderr)
        return 2
    print(report.to_json())
    return 0 if report.ok else 1


def _campaign(rest) -> int:
    """``campaign run|status ...`` — drive or inspect a campaign (no jax
    import on either path: the scheduler process stays lightweight)."""
    import json
    from repro.core.executor import (find_events_file, format_status,
                                     replay_events)
    if rest and rest[0] == "run":
        return _campaign_run(rest[1:])
    if not rest or rest[0] != "status":
        print("usage: python -m repro.launch campaign "
              "{run --jobs FILE --workdir DIR | status "
              "[events.jsonl | dir] [--json]}", file=sys.stderr)
        return 2
    args = [a for a in rest[1:] if a != "--json"]
    as_json = "--json" in rest
    target = args[0] if args else "experiments"
    events = find_events_file(target)
    if events is None:
        print(f"no campaign event log found under {target!r} "
              f"(looked for events.jsonl)", file=sys.stderr)
        return 2
    with open(events, encoding="utf-8") as fh:
        state = replay_events(fh)
    if as_json:
        print(json.dumps(state, indent=1, sort_keys=True, default=str))
    else:
        print(f"# {events}")
        print(format_status(state))
    return 0 if state["consistent"] else 1


def _campaign_run(rest) -> int:
    """``campaign run --jobs FILE --workdir DIR [knobs]`` — this process
    is the campaign scheduler (the SIGKILL target of the scheduler-chaos
    tests; restart with ``--resume-campaign`` to recover)."""
    import argparse
    import json
    from pathlib import Path

    ap = argparse.ArgumentParser(
        prog="python -m repro.launch campaign run", add_help=True)
    ap.add_argument("--jobs", required=True,
                    help="JSON file: a list of RunSpec dicts")
    ap.add_argument("--workdir", required=True,
                    help="campaign root (PVC mount)")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--speculate", action="store_true",
                    help="first-finisher-wins straggler duplicates")
    ap.add_argument("--backfill", action="store_true",
                    help="small jobs may pass a blocked queue head "
                         "(never delaying its earliest feasible start)")
    ap.add_argument("--resume", "--resume-campaign", action="store_true",
                    dest="resume",
                    help="replay campaign/events.jsonl: keep completed "
                         "work, adopt live orphans, re-queue dead ones")
    ap.add_argument("--pin-cpus", action="store_true")
    ap.add_argument("--attempt-timeout", type=float, default=None)
    ap.add_argument("--no-telemetry", action="store_true")
    ap.add_argument("--retry-backoff-base", type=float, default=1.0)
    ap.add_argument("--grace", type=float, default=5.0, metavar="S",
                    help="SIGTERM->SIGKILL escalation window for "
                         "evictions, drains and speculation kills "
                         "(the pod terminationGracePeriod analogue)")
    ap.add_argument("--preempt", action="store_true",
                    help="preempting scheduler class: a high-priority "
                         "queue head evicts (checkpoint + free requeue) "
                         "lower-priority running attempts when their "
                         "release makes it placeable")
    ap.add_argument("--placement", default="best_fit",
                    help="placement policy ordering candidate nodes: "
                         "best_fit (default), worst_fit, or pack — the "
                         "same names `simulate` accepts, so a policy "
                         "evaluated in the sim is the one run here")
    ap.add_argument("--nodes-file", default=None, metavar="FILE",
                    help="watched node-inventory control file "
                         "(default WORKDIR/campaign/nodes.json): "
                         "rewrite it mid-campaign to grow the pool or "
                         "drain+remove nodes")
    ap.add_argument("--chaos-kill", default=None, metavar="NAME[,NAME]",
                    help="kill these jobs mid-run (a gang job loses "
                         "ONE rank) to exercise the requeue+resume path")
    ap.add_argument("--chaos-signal", default="kill",
                    choices=("kill", "term"),
                    help="chaos kill signal: 'kill' = SIGKILL (lose "
                         "work since the last cadence checkpoint), "
                         "'term' = SIGTERM (the handler salvages a "
                         "final checkpoint first)")
    ap.add_argument("--chaos-after-checkpoints", type=int, default=1,
                    help="fire each chaos kill once the victim has "
                         "published this many checkpoints (0: kill on "
                         "liveness instead)")
    ns = ap.parse_args(rest)

    # repro.api.spec is jax-free; the scheduler never loads an ML stack
    from repro.api.spec import RunSpec
    from repro.core.artifacts import PersistentVolume
    from repro.core.jobs import JobState
    from repro.core.orchestrator import Orchestrator

    entries = json.loads(Path(ns.jobs).read_text(encoding="utf-8"))
    if not isinstance(entries, list):
        print(f"{ns.jobs}: expected a JSON list of RunSpec dicts",
              file=sys.stderr)
        return 2
    runs = [RunSpec.from_dict(e) for e in entries]
    extra = {}
    if ns.chaos_kill:
        import signal as _sig
        from repro.core.executor import ChaosSpec
        extra["chaos"] = ChaosSpec(
            kill_jobs=tuple(n for n in ns.chaos_kill.split(",") if n),
            after_checkpoints=ns.chaos_after_checkpoints,
            signal=int(_sig.SIGTERM if ns.chaos_signal == "term"
                       else _sig.SIGKILL))
    orch = Orchestrator(PersistentVolume(ns.workdir))
    orch.submit_runs(runs)
    orch.run_cluster(
        workers=ns.workers, resume=ns.resume, speculate=ns.speculate,
        backfill=ns.backfill, pin_cpus=ns.pin_cpus,
        telemetry=not ns.no_telemetry,
        attempt_timeout_s=ns.attempt_timeout,
        retry_backoff_base_s=ns.retry_backoff_base,
        grace_s=ns.grace, preempt=ns.preempt,
        placement=ns.placement,
        nodes_file=ns.nodes_file, **extra)
    print(json.dumps(orch.last_campaign_summary, indent=1,
                     sort_keys=True, default=str))
    return 0 if all(r.state == JobState.SUCCEEDED
                    for r in orch.records.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
