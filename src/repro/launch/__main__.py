"""The one dispatching CLI: ``python -m repro.launch run <kind> ...``.

Every workload goes through the same door:

    python -m repro.launch run train     --arch stablelm-1.6b --steps 50
    python -m repro.launch run serve     --arch granite-3-2b --requests 8
    python -m repro.launch run dryrun    --arch stablelm-1.6b --shape train_4k
    python -m repro.launch run perfprobe --arch glm4-9b --shape decode_32k
    python -m repro.launch run simulate  --campaign burned_area
    python -m repro.launch kinds

``run`` builds a :class:`repro.api.RunSpec` from the argv (known flags:
``--arch/--seed/--name``; any other ``--key value`` becomes an override),
dispatches through the runner registry, prints the
:class:`repro.api.RunReport` as JSON, and exits nonzero iff the run
failed.  The old per-kind module entrypoints
(``python -m repro.launch.train`` etc.) remain as thin shims over this
same registry.
"""
from __future__ import annotations

import sys

_USAGE = __doc__.split("\n\n")[1]


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help", "help"):
        print(f"usage: python -m repro.launch <run|kinds> ...\n\n{_USAGE}")
        return 0
    cmd, rest = argv[0], argv[1:]
    if cmd == "kinds":
        from repro.api import runner_kinds
        print("\n".join(runner_kinds()))
        return 0
    if cmd != "run":
        print(f"unknown command {cmd!r} (expected 'run' or 'kinds')",
              file=sys.stderr)
        return 2
    if not rest:
        print("usage: python -m repro.launch run <kind> [flags]",
              file=sys.stderr)
        return 2

    # kinds declare their env prerequisites on the registry (e.g. the
    # dryrun/perfprobe fake-device XLA flag); run() applies them before
    # the runner module — and therefore jax — is imported, and nothing
    # on the path up to there touches jax.
    from repro.api import RunSpec, run
    try:
        spec = RunSpec.from_args(rest)
        report = run(spec)
    except (KeyError, ValueError) as e:   # unknown kind / malformed flags
        print(str(e).strip('"'), file=sys.stderr)
        return 2
    print(report.to_json())
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
