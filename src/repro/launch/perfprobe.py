import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# perf-iteration probe: lower+compile one (arch x shape x layout) cell and
# report MEASURED quantities — trip-count-scaled collective bytes from the
# partitioned HLO, memory_analysis temp/argument sizes — alongside the
# analytic roofline.  Used by the §Perf hillclimb loop.
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402

from repro.analysis.analytic import analytic_roofline                  # noqa: E402
from repro.analysis.hlo import collective_bytes_scaled                 # noqa: E402
from repro.configs import get_config                                   # noqa: E402
from repro.launch.mesh import INPUT_SHAPES, make_production_mesh       # noqa: E402
from repro.launch.steps import effective_config, lower_step            # noqa: E402


def probe(arch: str, shape: str, layout: str, *, multi_pod: bool = False,
          microbatches: int = 1, save: str = None) -> dict:
    seq, batch, kind = INPUT_SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    t0 = time.time()
    kw = {"microbatches": microbatches} if kind == "train" else {}
    lowered = lower_step(kind, cfg, mesh, layout, batch, seq,
                         shape_name=shape, **kw)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    coll = collective_bytes_scaled(hlo)
    mem = compiled.memory_analysis()
    eff = effective_config(cfg, shape)
    rec = {
        "arch": arch, "shape": shape, "layout": layout,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "microbatches": microbatches,
        "compile_s": round(time.time() - t0, 1),
        "measured_collective_bytes_per_chip": coll["total"],
        "measured_collective_s": coll["total"] / 50e9,
        "collectives": {k: v for k, v in coll.items() if k != "_counts"},
        "collective_counts": coll["_counts"],
        "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
        "arg_gb": getattr(mem, "argument_size_in_bytes", 0) / 1e9,
        "analytic": analytic_roofline(eff, batch, seq, kind, mesh, layout),
    }
    print(f"[{arch} x {shape} x {layout}"
          f"{' x mb' + str(microbatches) if microbatches > 1 else ''}] "
          f"compile={rec['compile_s']}s")
    print(f"  measured collectives/chip: {coll['total'] / 1e9:.2f} GB "
          f"(={rec['measured_collective_s'] * 1e3:.0f} ms @50GB/s) "
          f"{ {k: round(v / 1e9, 2) for k, v in coll.items() if isinstance(v, int) and k != 'total'} }")
    print(f"  temp={rec['temp_gb']:.1f} GB  args={rec['arg_gb']:.2f} GB  "
          f"analytic compute={rec['analytic']['compute_s'] * 1e3:.0f}ms")
    if save:
        os.makedirs(os.path.dirname(save) or ".", exist_ok=True)
        with open(save, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    # thin shim over the repro.api registry (RunSpec in, RunReport out)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(INPUT_SHAPES))
    ap.add_argument("--layout", default="fsdp_tp",
                    choices=["dp", "fsdp_tp", "fsdp_sp"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--save", default=None)
    args = ap.parse_args()

    from repro.api import RunSpec, run
    overrides = {"shape": args.shape, "layout": args.layout,
                 "multi_pod": args.multi_pod,
                 "microbatches": args.microbatches}
    if args.save:
        overrides["save"] = args.save
    report = run(RunSpec(kind="perfprobe", arch=args.arch,
                         overrides=overrides))
    if not report.ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
