"""Pluggable placement policies for the executor pool and cluster sim.

The paper packs 234 heterogeneous models onto Nautilus's mixed fleet
(GTX-1080 11 GB through A100 80 GB); *where* each job lands decides how
much of that fleet is usable for the next one.  Both placement surfaces
— :class:`repro.core.executor.ResourcePool` (real campaigns) and
:class:`repro.core.scheduler.ClusterSim` (planning) — consult one of
these policies, selected by the same name end-to-end
(``run_cluster(placement=...)`` / ``campaign run --placement`` /
``simulate`` knobs), so a policy evaluated in the sim is the policy the
campaign runs.

A policy ranks *candidate* nodes (already filtered to fit the request);
it never sees unfittable nodes and cannot oversubscribe — capacity
accounting stays in the pool/sim, so every policy inherits the
never-oversubscribe invariant.

Candidates are duck-typed: anything with ``spec`` (a
:class:`repro.core.scheduler.NodeSpec`), ``gpus_free``, ``cpus_free``
and ``mem_free`` — which is exactly the executor's ``_FreeNode`` and
the sim's ``_Node``.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple, Union

from repro.core.jobs import Resources


class PlacementPolicy:
    """Orders candidate nodes for one resource request; lowest key wins.

    Subclasses implement :meth:`key`.  ``order`` is a stable sort, so
    inventory order breaks remaining ties deterministically.
    """

    name = "base"

    def key(self, node, res: Resources) -> Tuple:
        raise NotImplementedError

    def order(self, cands: Sequence, res: Resources) -> List:
        return sorted(cands, key=lambda n: self.key(n, res))


def _cpu_frac_left(node, res: Resources) -> float:
    return (node.cpus_free - res.cpus) / max(1, node.spec.cpus)


def _mem_frac_left(node, res: Resources) -> float:
    return (node.mem_free - res.memory_gb) / max(1e-9, node.spec.memory_gb)


class BestFit(PlacementPolicy):
    """Smallest sufficient GPU memory, then fewest free devices — the
    historical hard-coded rule: small jobs shouldn't hog A100s."""

    name = "best_fit"

    def key(self, node, res: Resources) -> Tuple:
        return (node.spec.gpu_memory_gb, node.gpus_free)


class WorstFit(PlacementPolicy):
    """Most leftover capacity after placement: spreads load across the
    fleet (keeps every node's headroom for growth), at the cost of
    fragmenting large slots."""

    name = "worst_fit"

    def key(self, node, res: Resources) -> Tuple:
        return (-(node.gpus_free - res.gpus),
                -_cpu_frac_left(node, res),
                -_mem_frac_left(node, res),
                node.spec.gpu_memory_gb)


class Pack(PlacementPolicy):
    """Fragmentation-scored bin packing: place where the *leftover*
    after placement is smallest — first unusable GPU stubs, then
    stranded CPU/memory fractions — preferring the cheapest VRAM class
    among equal fits.  Unlike ``best_fit`` it scores the actual free
    capacity being consumed, not just the VRAM class, so it keeps whole
    nodes open for the big requests still queued."""

    name = "pack"

    def key(self, node, res: Resources) -> Tuple:
        return (node.gpus_free - res.gpus,
                _cpu_frac_left(node, res),
                _mem_frac_left(node, res),
                node.spec.gpu_memory_gb)


PLACEMENT_POLICIES: Dict[str, type] = {
    cls.name: cls for cls in (BestFit, WorstFit, Pack)
}


def get_placement_policy(
        policy: Union[str, PlacementPolicy, None]) -> PlacementPolicy:
    """Resolve a policy by name (the CLI/runner path) or pass an
    instance through (the library path).  ``None`` means the default
    ``best_fit``."""
    if policy is None:
        return BestFit()
    if isinstance(policy, PlacementPolicy):
        return policy
    cls = PLACEMENT_POLICIES.get(str(policy))
    if cls is None:
        raise ValueError(
            f"unknown placement policy {policy!r} "
            f"(expected one of {sorted(PLACEMENT_POLICIES)})")
    return cls()


def gang_rank_capacity(node, res: Resources, cap: int) -> int:
    """How many identical ``res`` ranks this node can host at its
    current free capacity, clamped to ``cap`` (the gang size still
    unplaced).  VRAM is a per-device property, so one rank fitting
    implies any count does on the device axis."""
    if not res.fits(node.gpus_free, node.cpus_free, node.mem_free,
                    node.spec.gpu_memory_gb):
        return 0
    n = cap
    if res.gpus > 0:
        n = min(n, node.gpus_free // res.gpus)
    if res.cpus > 0:
        n = min(n, node.cpus_free // res.cpus)
    if res.memory_gb > 0:
        n = min(n, int(node.mem_free / res.memory_gb + 1e-9))
    return max(0, n)
