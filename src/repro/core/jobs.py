"""Declarative jobs — the Kubernetes-Job analogue.

A :class:`JobSpec` is a fully reproducible unit of work: a named payload,
explicit resource requests (the paper allocates e.g. "24GB of memory, four
CPUs, and two GPUs for each model"), environment variables (the paper's
bash automation passes the model/dataset selection via env), retry policy
(Nautilus preempts opportunistic jobs), and labels for bookkeeping.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Any, Callable, Dict, Optional


@dataclasses.dataclass(frozen=True)
class Resources:
    gpus: int = 1
    cpus: int = 4
    memory_gb: float = 24.0
    gpu_memory_gb_min: float = 0.0   # schedule only on nodes with >= this VRAM

    def fits(self, gpus_free: int, cpus_free: int, mem_free: float,
             gpu_memory_gb: float) -> bool:
        return (gpus_free >= self.gpus and cpus_free >= self.cpus
                and mem_free >= self.memory_gb
                and gpu_memory_gb >= self.gpu_memory_gb_min)


class JobState(enum.Enum):
    PENDING = "Pending"
    RUNNING = "Running"
    SUCCEEDED = "Succeeded"
    FAILED = "Failed"
    PREEMPTED = "Preempted"


@dataclasses.dataclass
class JobSpec:
    name: str
    payload: Optional[Callable[..., Any]] = None  # the "container entrypoint"
    env: Dict[str, str] = dataclasses.field(default_factory=dict)
    # env overlay applied to attempts after the first: resume semantics —
    # a retried train job restarts *from its last checkpoint* instead of
    # from scratch (RunSpec.to_job fills this for resumable kinds)
    retry_env: Dict[str, str] = dataclasses.field(default_factory=dict)
    resources: Resources = dataclasses.field(default_factory=Resources)
    retries: int = 3
    # admission ordering for the real executor: higher runs first, FIFO
    # within a priority class (Kubernetes PriorityClass analogue)
    priority: int = 0
    # opt this job out of speculative duplicate launches (a job with
    # side effects beyond its checkpoint dir must not run twice at once)
    speculation: bool = True
    # >1: a gang-scheduled multi-process job (the Kubernetes Indexed-Job
    # analogue).  The executor places all `gang` ranks atomically — each
    # rank gets its own `resources` request — or none, and one rank's
    # death kills and requeues the whole gang.
    gang: int = 1
    # elastic-gang floor: 0 (default) = rigid — a gang that no longer
    # fits waits or fails unschedulable; 1 <= gang_min < gang = the
    # executor may shrink a *requeued* gang's world to the largest
    # admissible size >= gang_min and resume it from the shared
    # rank-agnostic checkpoint instead of queueing at full size
    gang_min: int = 0
    # scheduler-sim fields: how long the job runs (the paper's Tables III/V
    # provide measured GPU-hours for the real workloads)
    duration_h: float = 1.0
    labels: Dict[str, str] = dataclasses.field(default_factory=dict)

    def manifest(self) -> dict:
        """Kubernetes-Job-shaped manifest dict (see templating.render).
        Gang jobs render as Indexed Jobs: ``completions = parallelism =
        gang`` ranks, each addressed by its completion index."""
        gang = {}
        if self.gang > 1:
            gang = {"completionMode": "Indexed",
                    "completions": self.gang,
                    "parallelism": self.gang}
        return {
            "apiVersion": "batch/v1",
            "kind": "Job",
            "metadata": {"name": self.name, "labels": dict(self.labels)},
            "spec": {
                "backoffLimit": self.retries,
                **gang,
                "template": {
                    "spec": {
                        "containers": [{
                            "name": self.name,
                            "image": "repro/trainer:latest",
                            "env": [{"name": k, "value": str(v)}
                                    for k, v in sorted(self.env.items())],
                            "resources": {
                                "limits": {
                                    "nvidia.com/gpu": self.resources.gpus,
                                    "cpu": self.resources.cpus,
                                    "memory": f"{self.resources.memory_gb:g}Gi",
                                },
                            },
                        }],
                        "restartPolicy": "Never",
                    },
                },
            },
        }


@dataclasses.dataclass
class JobRecord:
    spec: JobSpec
    state: JobState = JobState.PENDING
    attempts: int = 0
    node: Optional[str] = None
    submit_time: float = 0.0
    start_time: Optional[float] = None
    end_time: Optional[float] = None
    result: Any = None
    error: Optional[str] = None
    # observed-usage summary of the winning attempt (executor telemetry
    # sampler): samples, cpu_pct_mean/peak, rss_peak_mb, io_read/write_mb
    telemetry: Optional[Dict[str, Any]] = None

    @property
    def wall_h(self) -> Optional[float]:
        if self.start_time is None or self.end_time is None:
            return None
        return self.end_time - self.start_time
