"""Experiment grids.

The paper's burned-area study expands {3 learning rates} x {3 batch sizes}
x {2 inits} x {2 optimizers} x {2 datasets} = 72 experiments x 2
architectures = 144 trained models, each with an auto-generated JSON
config and two auto-generated YAML manifests (train + eval), 288 total.
:class:`ExperimentGrid` is that expansion, architecture- and
domain-agnostic.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from typing import Any, Dict, Iterable, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    name: str
    params: Dict[str, Any]

    def config_json(self) -> str:
        """The per-experiment JSON config file (paper: 'a JSON configuration
        file where the specifics of each experiment are defined')."""
        return json.dumps({"experiment": self.name, **self.params},
                          indent=2, sort_keys=True, default=str)

    def short_hash(self) -> str:
        return hashlib.sha1(self.config_json().encode()).hexdigest()[:8]


class ExperimentGrid:
    """Cartesian product over named parameter axes, with optional filters.

    The expansion is computed once and cached (``__len__`` and repeated
    ``expand()`` calls used to redo the full product each time); treat
    ``axes``/``exclude`` as immutable after construction.
    """

    def __init__(self, prefix: str, axes: Dict[str, Sequence[Any]],
                 exclude=None):
        self.prefix = prefix
        self.axes = {k: list(v) for k, v in axes.items()}
        self.exclude = exclude or (lambda params: False)
        self._expanded: Optional[List[ExperimentSpec]] = None

    def __len__(self) -> int:
        return len(self.expand())

    def size_unfiltered(self) -> int:
        n = 1
        for v in self.axes.values():
            n *= len(v)
        return n

    def expand(self) -> List[ExperimentSpec]:
        """Returns a fresh list (safe to mutate); the expansion itself
        is computed once and cached."""
        if self._expanded is None:
            keys = list(self.axes)
            out = []
            for combo in itertools.product(*(self.axes[k] for k in keys)):
                params = dict(zip(keys, combo))
                if self.exclude(params):
                    continue
                tag = "-".join(f"{k}{_fmt(v)}" for k, v in params.items())
                out.append(ExperimentSpec(f"{self.prefix}-{tag}", params))
            self._expanded = out
        return list(self._expanded)

    def to_runs(self, kind: str = "train", **kwargs):
        """Expand straight into ``repro.api.RunSpec``s (params become
        overrides); kwargs: arch, resources, seed, duration_h, labels."""
        from repro.api.spec import grid_to_runs  # lazy: api imports core
        return grid_to_runs(self, kind=kind, **kwargs)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:g}".replace("-", "m").replace(".", "p")
    return str(v).replace("_", "").replace("/", "-").lower()


def paper_burned_area_grid() -> Dict[str, ExperimentGrid]:
    """The paper's exact hyperparameter search (Sect. III-B): 72 experiments
    per architecture x 2 architectures = 144 models."""
    axes = {
        "lr": [1e-3, 1e-4, 1e-5],
        "batch_size": [8, 16, 32],
        "init": ["imagenet", "random"],
        "optimizer": ["adam", "lamb"],
        "dataset": ["norm_rgb", "tci"],
    }
    return {
        arch: ExperimentGrid(f"ba-{arch}", axes)
        for arch in ("unet", "deeplabv3")
    }
