"""Dynamic batch sizing from accelerator memory.

Paper, Sect. III-A: "the batch size is dynamically set based on available
GPU memory, as the GPUs on Nautilus range from as little as the NVIDIA
GTX 1080 (11 GB) to as high as the NVIDIA A100 (80GB)".

On TPU the fleet is homogeneous (16 GB v5e) but the same mechanism picks
the per-replica batch given the model's analytic footprint: params +
optimizer state + gradients (sharded by the layout) are the fixed cost,
activations-per-sample (with the remat policy) the variable cost.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class MemoryBudget:
    device_gb: float = 16.0          # v5e HBM
    reserve_frac: float = 0.15       # runtime/fragmentation reserve


OPT_STATE_MULT = {"sgd": 0, "sgdm": 1, "adam": 2, "adamw": 2, "lamb": 2}


def fixed_bytes_per_device(cfg: ArchConfig, n_shards: int = 1,
                           opt_state_bytes: int = None) -> float:
    """params + grads + optimizer moments, sharded over `n_shards`."""
    pb = 2 if "16" in cfg.param_dtype else 4
    sb = opt_state_bytes if opt_state_bytes is not None else pb
    P = cfg.param_count()
    per = P * (pb            # params
               + pb          # grads
               + sb * OPT_STATE_MULT.get(cfg.optimizer, 2))
    return per / n_shards


def activation_bytes_per_sample(cfg: ArchConfig, seq: int,
                                act_shards: int = 1,
                                remat: bool = True) -> float:
    """Layer-boundary activations per sample with scan-over-layers remat:
    one (seq, d) tensor per layer saved, plus ~2 working layers."""
    pb = 2 if "16" in cfg.param_dtype else 4
    boundaries = cfg.n_layers if remat else 6 * cfg.n_layers
    working = 8  # live intermediates inside the current (re)computed layer
    per = (boundaries + working) * seq * cfg.d_model * pb
    return per / act_shards


def autobatch(cfg: ArchConfig, seq: int, *, budget: MemoryBudget = None,
              n_shards: int = 1, act_shards: int = 1,
              remat: bool = True, max_batch: int = 4096,
              min_batch: int = 1) -> int:
    """Largest power-of-two per-replica batch that fits the device budget.
    Returns 0 if even ``min_batch`` does not fit (the paper-faithful DP
    regime hits this for the 398B/400B architectures — the motivation for
    its multi-pod future work)."""
    budget = budget or MemoryBudget()
    avail = budget.device_gb * 1e9 * (1 - budget.reserve_frac)
    fixed = fixed_bytes_per_device(cfg, n_shards)
    per_sample = activation_bytes_per_sample(cfg, seq, act_shards, remat)
    room = avail - fixed
    if room < per_sample * min_batch:
        return 0
    b = int(room // per_sample)
    b = min(b, max_batch)
    # round down to a power of two (batch-size ladders in the paper's grids)
    p = 1
    while p * 2 <= b:
        p *= 2
    return p
