"""Survivable concurrent campaign execution — the multi-process
counterpart of :meth:`Orchestrator.run_local`'s sequential loop and the
execution-layer realization of what :class:`repro.core.scheduler.ClusterSim`
only models.

:class:`CampaignExecutor` launches every pending job as a

    python -m repro.launch run <kind> --arch ... --key value ...

subprocess (the container semantics of a Kubernetes Job: the child sees
only its spec, rebuilt from CLI flags, and prints a RunReport JSON), with

* **resource-aware admission** — a :class:`ResourcePool` over the same
  :class:`~repro.core.scheduler.NodeSpec` inventory the cluster sim
  schedules against, FIFO within priority (``JobSpec.priority``, higher
  first).  Admission requests are *learned*: a
  :class:`~repro.core.scheduler.LearnedRequests` model tightens each
  job's declared request to the observed p95 usage of completed attempts
  of the same kind (clamped to declared as a ceiling, so the pool can
  never admit past what the node really has);
* **backfill** (opt-in) — when the head of the queue does not fit, a
  smaller job may jump into capacity the head cannot use, under a
  starvation bound: a backfill candidate is admitted only if it provably
  cannot delay the head's earliest feasible start (its target node could
  never host the head, or its estimated runtime ends before the head's
  earliest feasible start computed from observed attempt walls);
* **speculative duplicates** (opt-in) — a running attempt whose progress
  (steps/s from its published checkpoint manifests) falls below
  ``slow_fraction`` of the campaign median gets a duplicate attempt in a
  sibling checkpoint dir, admitted under the same rules.  First finisher
  wins; the loser is SIGKILLed and logged as ``speculation_loss``, and
  the winner's checkpoint dir is promoted to the declared path — results
  stay bitwise-identical to non-speculative runs;
* **scheduler-crash recovery** — ``resume=True`` replays the durable
  event log, marks completed jobs done (never re-executing them),
  re-adopts still-alive orphan attempts by pid + kernel start-time
  identity, and re-queues dead orphans through the ``retry_env`` resume
  path.  SIGKILLing the *executor* mid-campaign loses no completed work;
* **per-attempt resource telemetry** — a sampler thread records CPU%,
  RSS and io counters per attempt into the event log; completed-attempt
  usage feeds the learned-request model and ``campaign status``;
* **real preemption** — an optional :class:`ChaosSpec` SIGKILLs running
  workers mid-step; a killed attempt is re-admitted with the job's
  ``retry_env`` overlay (``resume=true`` for train), so PR 3's
  CheckpointManager restores it from the last durable checkpoint.
  Failed (non-signal) attempts retry under exponential backoff with
  deterministic jitter; timed-out attempts get their own ``timeout``
  outcome and count into lost-work accounting;
* **a durable JSONL event log** (``campaign/events.jsonl``, fsynced per
  event) that powers ``python -m repro.launch campaign status`` and
  replays — incrementally, from any prefix — to a consistent state.

The subprocess spawn is injectable (``spawn=``), as are the clock
(``clock=``), the progress probe (``progress_fn=``) and the learned
request model (``learned=``), so scheduling, chaos, speculation and
backoff can all be exercised hermetically in tests without paying a jax
import per job.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import random
import signal as _signal
import statistics
import subprocess
import sys
import threading
import time
from pathlib import Path
from typing import (Any, Callable, Dict, IO, List, Mapping, Optional,
                    Sequence, Tuple, Union)

from repro.core.artifacts import PersistentVolume, S3Store
from repro.core.jobs import JobRecord, JobSpec, JobState, Resources
from repro.core.scheduler import LearnedRequests, NodeSpec

EVENTS_REL = "campaign/events.jsonl"
_CKPT_PREFIX = "step_"


# --------------------------------------------------------------------------
# Resource-aware admission
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _FreeNode:
    spec: NodeSpec
    name: str
    gpus_free: int = 0
    cpus_free: int = 0
    mem_free: float = 0.0
    # a draining node admits nothing and is removed from the pool once
    # its last resident attempt releases (Kubernetes cordon+drain)
    draining: bool = False

    def __post_init__(self):
        self.gpus_free = self.spec.gpus
        self.cpus_free = self.spec.cpus
        self.mem_free = self.spec.memory_gb


class ResourcePool:
    """Free-capacity accounting over a :class:`NodeSpec` inventory.

    The executor admits through :meth:`admit` and returns capacity
    through :meth:`release`; *which* fitting node an admission lands on
    is decided by a pluggable
    :class:`repro.core.placement.PlacementPolicy` (``best_fit`` by
    default — the cluster sim's historical rule), selected by the same
    name end-to-end from ``campaign run --placement``.  The pool is the
    single source of truth for the "never oversubscribe a node"
    invariant; both methods raise if it would be violated, whatever the
    policy ranks first.

    The inventory is **elastic**: :meth:`add_node` grows it mid-campaign
    and :meth:`drain` + :meth:`remove_node` shrink it.  Shrink never
    races capacity: a draining node stops admitting immediately but keeps
    its residents' accounting until they release, and :meth:`remove_node`
    refuses any node that is not both draining and fully free — so the
    never-oversubscribe invariant holds through any resize interleaving.
    """

    def __init__(self, inventory: Sequence[NodeSpec],
                 policy: Union[str, "PlacementPolicy", None] = None):
        from repro.core.placement import get_placement_policy
        self.policy = get_placement_policy(policy)
        self.nodes: List[_FreeNode] = []
        for spec in inventory:
            for i in range(spec.count):
                self.nodes.append(_FreeNode(spec, f"{spec.name}-{i:03d}"))
        if not self.nodes:
            raise ValueError("empty inventory")
        # monotonic name counter for add_node: never reused, so a
        # grow -> shrink -> grow interleaving cannot regenerate a live
        # name (len(self.nodes) could, once removals shifted it back)
        self._node_seq = len(self.nodes)

    def fits_when_empty(self, res: Resources) -> bool:
        """Could this request *ever* be placed?  Guards against queueing
        a job that would wait forever (the executor fails it instead).
        Draining nodes don't count — their capacity is leaving."""
        return any(res.fits(n.spec.gpus, n.spec.cpus, n.spec.memory_gb,
                            n.spec.gpu_memory_gb)
                   for n in self.nodes if not n.draining)

    def fits_when_empty_gang(self, res: Resources, n: int) -> bool:
        """Could ``n`` ranks of ``res`` *ever* be co-placed on an empty
        cluster?  Trial-places the whole gang on a pristine copy of the
        inventory (ranks may share a node when its capacity allows)."""
        if n <= 1:
            return self.fits_when_empty(res)
        keep = [dataclasses.replace(node.spec, count=1)
                for node in self.nodes if not node.draining]
        if not keep:
            return False
        trial = ResourcePool(keep, policy=self.policy)
        return trial.admit_gang(res, n) is not None

    # ------------------------------------------------------- elasticity
    def clone(self) -> "ResourcePool":
        """A deep copy of the current free-capacity state (the evictor
        simulates releases on a clone before killing anything)."""
        dup = ResourcePool.__new__(ResourcePool)
        dup.policy = self.policy
        dup._node_seq = self._node_seq
        dup.nodes = []
        for n in self.nodes:
            m = _FreeNode(n.spec, n.name)
            m.gpus_free, m.cpus_free, m.mem_free = \
                n.gpus_free, n.cpus_free, n.mem_free
            m.draining = n.draining
            dup.nodes.append(m)
        return dup

    def node(self, name: str) -> Optional[_FreeNode]:
        return next((n for n in self.nodes if n.name == name), None)

    def add_node(self, spec: NodeSpec, name: Optional[str] = None) -> str:
        """Grow the inventory by one node (empty, immediately
        admittable).  Returns its name.  Generated names come from a
        monotonic counter that never rewinds, so grow -> shrink -> grow
        cannot collide with a surviving node the way ``len(self.nodes)``
        once could."""
        if name is None:
            name = f"{spec.name}-{self._node_seq:03d}"
            while self.node(name) is not None:
                self._node_seq += 1
                name = f"{spec.name}-{self._node_seq:03d}"
            self._node_seq += 1
        node = _FreeNode(dataclasses.replace(spec, count=1), name)
        if self.node(node.name) is not None:
            raise ValueError(f"duplicate node name {node.name}")
        self.nodes.append(node)
        return node.name

    def drain(self, name: str) -> None:
        """Cordon ``name``: stop admitting to it.  Residents keep their
        capacity until they release; remove with :meth:`remove_node`
        once :meth:`drained_free` reports it empty."""
        node = self.node(name)
        if node is None:
            raise KeyError(f"unknown node {name}")
        node.draining = True

    def undrain(self, name: str) -> None:
        node = self.node(name)
        if node is None:
            raise KeyError(f"unknown node {name}")
        node.draining = False

    def drained_free(self) -> List[str]:
        """Draining nodes whose last resident has released — safe to
        remove without touching any live accounting."""
        return [n.name for n in self.nodes
                if n.draining and n.gpus_free == n.spec.gpus
                and n.cpus_free == n.spec.cpus
                and n.mem_free >= n.spec.memory_gb - 1e-9]

    def remove_node(self, name: str) -> None:
        node = self.node(name)
        if node is None:
            raise KeyError(f"unknown node {name}")
        if name not in self.drained_free():
            raise RuntimeError(
                f"refusing to remove node {name}: not draining or still "
                f"hosting attempts")
        self.nodes.remove(node)

    def snapshot(self) -> List[Dict[str, Any]]:
        """Per-node capacity + drain state, for events and status."""
        return [{"name": n.name, "gpus": n.spec.gpus,
                 "cpus": n.spec.cpus, "memory_gb": n.spec.memory_gb,
                 "gpu_memory_gb": n.spec.gpu_memory_gb,
                 "draining": n.draining}
                for n in self.nodes]

    def admit_gang(self, res: Resources, n: int) -> Optional[List[str]]:
        """All-or-nothing **co-located** placement of ``n`` ranks, each
        requesting ``res``: returns the per-rank node names, or None
        with nothing held (no hold-and-wait, so concurrent gangs can
        never deadlock on each other's partial grabs).

        Ranks land on the *fewest nodes possible* — intra-node ranks
        talk over NVLink/shared memory while cross-node ranks pay the
        network, so node count is the gang's topology cost.  Greedy
        largest-remaining-capacity selection is optimal for identical
        ranks; capacity ties fall back to the pool's placement policy
        (the candidate list is policy-ordered and the sort is stable).
        The full placement is computed against free capacity *before*
        anything is committed, so failure rolls back by construction
        and success can never oversubscribe (the per-rank commit still
        re-checks, like :meth:`admit`)."""
        from repro.core.placement import gang_rank_capacity
        n = max(1, n)
        cands = self._candidates(res)          # policy-ordered
        ranked = sorted(
            ((node, gang_rank_capacity(node, res, n)) for node in cands),
            key=lambda nc: -nc[1])             # stable: policy breaks ties
        chosen: List[Tuple[_FreeNode, int]] = []
        remaining = n
        for node, cap in ranked:
            if remaining <= 0:
                break
            take = min(cap, remaining)
            if take <= 0:
                continue
            chosen.append((node, take))
            remaining -= take
        if remaining > 0:
            return None                        # nothing was committed
        placed: List[str] = []
        for node, take in chosen:
            for _ in range(take):
                node.gpus_free -= res.gpus
                node.cpus_free -= res.cpus
                node.mem_free -= res.memory_gb
                if (node.gpus_free < 0 or node.cpus_free < 0
                        or node.mem_free < -1e-9):
                    raise RuntimeError(f"oversubscribed node {node.name}")
                placed.append(node.name)
        return placed

    def _candidates(self, res: Resources) -> List[_FreeNode]:
        cands = [n for n in self.nodes
                 if not n.draining
                 and res.fits(n.gpus_free, n.cpus_free, n.mem_free,
                              n.spec.gpu_memory_gb)]
        return self.policy.order(cands, res)

    def peek_node(self, res: Resources) -> Optional[_FreeNode]:
        """The node :meth:`admit` would pick right now, without
        admitting (backfill uses this to reason about placement)."""
        cands = self._candidates(res)
        return cands[0] if cands else None

    def admit(self, res: Resources,
              prefer: Optional[str] = None) -> Optional[str]:
        """Place one request; ``prefer`` pins it to that node when it
        fits (adoption re-charges an orphan where its process already
        runs — free re-placement would swap nodes between orphans and
        the event log would claim a placement that never happened)."""
        cands = self._candidates(res)
        if not cands:
            return None
        node = cands[0]
        if prefer is not None:
            pinned = next((n for n in cands if n.name == prefer), None)
            if pinned is not None:
                node = pinned
        node.gpus_free -= res.gpus
        node.cpus_free -= res.cpus
        node.mem_free -= res.memory_gb
        if node.gpus_free < 0 or node.cpus_free < 0 or node.mem_free < -1e-9:
            raise RuntimeError(f"oversubscribed node {node.name}")
        return node.name

    def release(self, node_name: str, res: Resources) -> None:
        node = next(n for n in self.nodes if n.name == node_name)
        node.gpus_free += res.gpus
        node.cpus_free += res.cpus
        node.mem_free += res.memory_gb
        if (node.gpus_free > node.spec.gpus
                or node.cpus_free > node.spec.cpus
                or node.mem_free > node.spec.memory_gb + 1e-9):
            raise RuntimeError(f"release overflow on node {node.name}")

    def in_use(self) -> Dict[str, Tuple[int, int, float]]:
        return {n.name: (n.spec.gpus - n.gpus_free,
                         n.spec.cpus - n.cpus_free,
                         n.spec.memory_gb - n.mem_free)
                for n in self.nodes}


def local_inventory(workers: int, jobs: Sequence[JobSpec]) -> List[NodeSpec]:
    """Default inventory for local execution: one node per worker, each
    sized to the largest single-job request — every worker slot fits
    exactly one job, so admission degenerates to the worker cap while
    still flowing through the resource accounting."""
    gpus = max([j.resources.gpus for j in jobs] or [1])
    cpus = max([j.resources.cpus for j in jobs] or [1])
    mem = max([j.resources.memory_gb for j in jobs] or [1.0])
    vram = max([j.resources.gpu_memory_gb_min for j in jobs] or [0.0])
    return [NodeSpec("worker", gpus=gpus, gpu_memory_gb=vram, cpus=cpus,
                     memory_gb=mem, count=max(1, int(workers)))]


# --------------------------------------------------------------------------
# Speculative duplicates
# --------------------------------------------------------------------------
@dataclasses.dataclass
class SpeculationSpec:
    """Straggler defense: first-finisher-wins duplicate launches.

    A running primary attempt becomes a speculation victim when

    * it has been alive at least ``min_runtime_s`` seconds, and — when
      ``grace`` is not None — longer than ``grace`` x the mean wall time
      of completed attempts of its kind (short jobs spend most of their
      wall in startup; a run that should already have finished is the
      honest straggler signal), and
    * its measured progress (steps/s from published checkpoint
      manifests by default) is below ``slow_fraction`` x the campaign
      median over at least ``min_peers`` peer measurements (live
      same-kind attempts, topped up with completed-attempt rates).

    The duplicate runs the *same spec* in a sibling checkpoint dir
    (``<dir>.specN``), admitted through the same pool under the same
    resource request, only into capacity the queue does not want.  The
    first attempt to finish wins; the loser is SIGKILLed and its wall
    time logged as ``speculation_loss``; the winner's checkpoint dir is
    promoted to the declared path, so downstream consumers see bitwise
    the same artifacts as a non-speculative run.
    """

    slow_fraction: float = 0.5
    min_runtime_s: float = 2.0
    grace: Optional[float] = 1.0
    min_peers: int = 2
    max_duplicates_per_job: int = 1


# --------------------------------------------------------------------------
# Fault injection
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ChaosSpec:
    """Inject real preemptions: SIGKILL selected jobs mid-run.

    ``kill_jobs`` names the victims; each is killed at most
    ``max_kills_per_job`` times.  A kill fires when the job's published
    checkpoint count reaches ``after_checkpoints`` (so the resume path is
    genuinely exercised) or — for jobs without a checkpoint dir, or when
    ``after_checkpoints == 0`` — after the attempt has been alive
    ``after_s`` seconds.  Speculative duplicate attempts are never chaos
    victims (chaos models node preemption of the *primary* placement).
    """

    kill_jobs: Sequence[str] = ()
    after_checkpoints: int = 1
    after_s: float = 0.0
    signal: int = int(_signal.SIGKILL)
    max_kills_per_job: int = 1

    @classmethod
    def sample(cls, names: Sequence[str], fraction: float = 0.5,
               seed: int = 0, **kw) -> "ChaosSpec":
        """Random-but-deterministic victim selection over ``names``."""
        rng = random.Random(seed)
        k = min(len(names), max(1, round(len(names) * fraction))) \
            if names else 0
        return cls(kill_jobs=sorted(rng.sample(list(names), k)), **kw)

    def wants_kill(self, job_name: str, kills_done: int, alive_s: float,
                   published_ckpts: Optional[int]) -> bool:
        if job_name not in self.kill_jobs:
            return False
        if kills_done >= self.max_kills_per_job:
            return False
        if self.after_checkpoints > 0 and published_ckpts is not None:
            return published_ckpts >= self.after_checkpoints
        return self.after_s > 0 and alive_s >= self.after_s


def _published_checkpoints(directory: Optional[str]) -> Optional[int]:
    """Count published ``step_N`` checkpoints without importing jax (the
    executor process never loads an ML stack)."""
    if not directory:
        return None
    d = Path(directory)
    if not d.is_dir():
        return 0
    n = 0
    for p in d.iterdir():
        if (p.is_dir() and p.name.startswith(_CKPT_PREFIX)
                and (p / "manifest.json").exists()):
            n += 1
    return n


def _latest_checkpoint_step(directory: Optional[str]) -> Optional[int]:
    """Newest published checkpoint step under ``directory`` (manifest
    presence required), again without any ML import."""
    if not directory:
        return None
    d = Path(directory)
    if not d.is_dir():
        return None
    best = None
    for p in d.iterdir():
        if (p.is_dir() and p.name.startswith(_CKPT_PREFIX)
                and (p / "manifest.json").exists()):
            try:
                step = int(p.name[len(_CKPT_PREFIX):])
            except ValueError:
                continue
            best = step if best is None else max(best, step)
    return best


def checkpoint_progress(run: "_Running", now: float) -> Optional[float]:
    """Default progress probe: steps/s inferred from the attempt's
    newest published checkpoint manifest.  None when the attempt has no
    checkpoint dir or nothing published yet (fresh attempts are never
    judged stragglers on zero evidence)."""
    step = _latest_checkpoint_step(run.ckpt_dir)
    if step is None or step <= 0:
        return None
    alive = now - run.started_t
    return step / alive if alive > 0 else None


# --------------------------------------------------------------------------
# PID identity + orphan adoption
# --------------------------------------------------------------------------
def _pid_start_time(pid: int) -> Optional[int]:
    """Kernel start time (clock ticks since boot) of ``pid`` from
    /proc/<pid>/stat — with the pid number, a unique process identity
    that survives pid reuse.  None off-Linux or when unreadable."""
    try:
        with open(f"/proc/{pid}/stat", "rb") as fh:
            data = fh.read().decode("ascii", "replace")
        # fields after the parenthesized comm (which may contain spaces):
        # state is overall field 3 == index 0 here; starttime is field 22
        return int(data.rsplit(") ", 1)[1].split()[19])
    except (OSError, IndexError, ValueError):
        return None


def _pid_alive(pid: Optional[int],
               pid_start: Optional[int] = None) -> bool:
    """Is ``pid`` alive *and the same process* we recorded?  A recycled
    pid (different kernel start time) counts as dead."""
    if not pid:
        return False
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        pass                         # exists, owned by someone else
    except OSError:
        return False
    try:
        with open(f"/proc/{pid}/stat", "rb") as fh:
            tail = fh.read().decode("ascii", "replace") \
                .rsplit(") ", 1)[1].split()
    except (OSError, IndexError):
        return True                  # off-Linux: os.kill was the answer
    # a zombie has exited — its outcome is final even if nobody reaped
    # it yet (an adopted orphan's original parent may never wait on it)
    if tail and tail[0] == "Z":
        return False
    if pid_start is not None:
        try:
            if int(tail[19]) != pid_start:
                return False         # recycled pid: a different process
        except (IndexError, ValueError):
            pass
    return True


class _AdoptedHandle:
    """Popen-shaped handle over an orphan attempt re-adopted after a
    scheduler crash.  The orphan is not our child, so there is no exit
    code to reap: liveness is pid + start-time identity, and the outcome
    is judged from the trailing RunReport in the attempt's stdout log
    (exactly the executor's success criterion for its own children)."""

    def __init__(self, pid: int, pid_start: Optional[int],
                 stdout_path: Path):
        self.pid = pid
        self.pid_start = pid_start
        self.stdout_path = Path(stdout_path)
        self.adopted = True

    def poll(self) -> Optional[int]:
        if _pid_alive(self.pid, self.pid_start):
            return None
        try:
            report = parse_trailing_report(
                self.stdout_path.read_text(errors="replace"))
        except OSError:
            report = None
        return 0 if report and report.get("status") != "failed" else 1

    def send_signal(self, sig: int) -> None:
        if _pid_alive(self.pid, self.pid_start):
            try:
                os.kill(self.pid, sig)
            except OSError:
                pass


class _GangHandle:
    """Popen-shaped handle over a gang of rank processes.

    ``poll`` returns None while any rank lives.  The first rank to die
    with a nonzero code (or signal) condemns the gang: every other live
    rank is killed — **gracefully** when ``grace_s`` is set (SIGTERM
    first, so survivors get the grace window to write a final
    checkpoint, then SIGKILL once the window expires), immediately
    otherwise — and once all are dead the condemning code is the gang's
    exit code, so the executor's existing preempted/failed branches
    apply unchanged to whole gangs.  All ranks exiting 0 is a gang
    success.  ``pid`` is rank 0's (the telemetry sampler and event
    identity follow the coordinator rank).
    """

    def __init__(self, procs: Sequence[Any],
                 on_rank_exit: Optional[Callable[[int, int], None]]
                 = None, grace_s: Optional[float] = None,
                 clock: Optional[Callable[[], float]] = None):
        self.procs = list(procs)
        self.pid = getattr(self.procs[0], "pid", None)
        self.on_rank_exit = on_rank_exit
        self.grace_s = grace_s
        self.clock = clock or time.time
        self.rcs: List[Optional[int]] = [None] * len(self.procs)
        self._condemned: Optional[int] = None
        self._condemned_t: Optional[float] = None
        self._escalated = False

    def poll(self) -> Optional[int]:
        for i, proc in enumerate(self.procs):
            if self.rcs[i] is not None:
                continue
            rc = proc.poll()
            if rc is None:
                continue
            self.rcs[i] = rc
            if self.on_rank_exit is not None:
                self.on_rank_exit(i, rc)
            if rc != 0 and self._condemned is None:
                self._condemned = rc
                self._condemned_t = self.clock()
                if self.grace_s is not None:
                    self._signal_live(int(_signal.SIGTERM))
                else:
                    self._kill_live()
        if (self._condemned_t is not None and not self._escalated
                and self.grace_s is not None
                and self.clock() - self._condemned_t >= self.grace_s):
            # survivors did not exit within the grace window (e.g. a
            # rank wedged in a collective on its dead peer): escalate
            self._escalated = True
            self._kill_live()
        if any(rc is None for rc in self.rcs):
            return None
        return self._condemned if self._condemned is not None else 0

    def _signal_live(self, sig: int) -> None:
        for i, proc in enumerate(self.procs):
            if self.rcs[i] is None:
                try:
                    proc.send_signal(sig)
                except OSError:      # pragma: no cover - exit race
                    pass

    def _kill_live(self) -> None:
        self._signal_live(int(_signal.SIGKILL))

    def send_signal(self, sig: int) -> None:
        for i, proc in enumerate(self.procs):
            if self.rcs[i] is None:
                try:
                    proc.send_signal(sig)
                except OSError:      # pragma: no cover - exit race
                    pass

    def signal_rank(self, rank: int, sig: int) -> None:
        """Deliver to ONE rank (chaos kills a single rank to prove the
        whole-gang requeue propagates from any member's death)."""
        if self.rcs[rank] is None:
            try:
                self.procs[rank].send_signal(sig)
            except OSError:          # pragma: no cover - exit race
                pass


# --------------------------------------------------------------------------
# Per-attempt resource telemetry (/proc sampling)
# --------------------------------------------------------------------------
def _read_cpu_ticks(pid: int) -> Optional[int]:
    try:
        with open(f"/proc/{pid}/stat", "rb") as fh:
            fields = fh.read().decode("ascii", "replace") \
                .rsplit(") ", 1)[1].split()
        return int(fields[11]) + int(fields[12])      # utime + stime
    except (OSError, IndexError, ValueError):
        return None


def _read_rss_mb(pid: int) -> Optional[float]:
    try:
        with open(f"/proc/{pid}/status", encoding="ascii",
                  errors="replace") as fh:
            for line in fh:
                if line.startswith("VmRSS:"):
                    return int(line.split()[1]) / 1024.0
    except (OSError, IndexError, ValueError):
        pass
    return None


def _read_io_mb(pid: int) -> Tuple[Optional[float], Optional[float]]:
    try:
        vals = {}
        with open(f"/proc/{pid}/io", encoding="ascii",
                  errors="replace") as fh:
            for line in fh:
                key, _, val = line.partition(":")
                vals[key.strip()] = val.strip()
        return (int(vals["read_bytes"]) / 1e6,
                int(vals["write_bytes"]) / 1e6)
    except (OSError, KeyError, ValueError):
        return None, None


# --------------------------------------------------------------------------
# Subprocess plumbing
# --------------------------------------------------------------------------
def job_run_argv(job: JobSpec, *, resume: bool = False,
                 env_overlay: Optional[Mapping[str, str]] = None
                 ) -> List[str]:
    """Rebuild the ``repro.launch run`` argv from the job's env encoding
    (the manifest is the source of truth, exactly as on a cluster).  With
    ``resume=True`` the job's ``retry_env`` overlay is applied first —
    the same semantics ``run_local`` gives in-process retries.
    ``env_overlay`` applies last (speculative duplicates redirect
    ``CHECKPOINT_DIR`` to their sibling workdir through it)."""
    from repro.api.spec import RunSpec, _encode_scalar  # lazy: api -> core
    env = dict(job.env)
    if resume and job.retry_env:
        env.update(job.retry_env)
    if env_overlay:
        env.update(env_overlay)
    spec = RunSpec.from_env(env)
    argv = ["run", spec.kind, "--arch", spec.arch,
            "--seed", str(spec.seed), "--name", job.name]
    for key, val in sorted(spec.overrides.items()):
        argv.append(f"--{key}={_encode_scalar(val)}")
    return argv


def _src_path() -> str:
    # .../src/repro/core/executor.py -> .../src
    return str(Path(__file__).resolve().parents[2])


def _default_spawn(job: JobSpec, attempt: int, argv: List[str],
                   env: Dict[str, str], stdout: IO, stderr: IO):
    return subprocess.Popen(argv, env=env, stdout=stdout, stderr=stderr)


def parse_trailing_report(text: str) -> Optional[Dict[str, Any]]:
    """Extract the final RunReport JSON from a run's stdout (step logs
    precede it; ``RunReport.to_json`` prints an indent-1 object whose
    first line is ``{``)."""
    lines = text.splitlines()
    for i in range(len(lines) - 1, -1, -1):
        if lines[i].lstrip().startswith("{"):
            try:
                obj = json.loads("\n".join(lines[i:]))
            except ValueError:
                continue
            if isinstance(obj, dict) and "status" in obj:
                return obj
    return None


# --------------------------------------------------------------------------
# Durable event log + replay
# --------------------------------------------------------------------------
class EventLog:
    """Append-only JSONL, fsynced per event — survives a SIGKILL of the
    orchestrating process itself.  Emission is thread-safe (the
    telemetry sampler thread writes concurrently with the main loop)."""

    def __init__(self, path: Path,
                 clock: Optional[Callable[[], float]] = None):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._fh = open(self.path, "a", encoding="utf-8")
        self._seq = 0
        self._clock = clock or time.time
        self._lock = threading.Lock()

    def emit(self, event: str, **fields) -> Dict[str, Any]:
        with self._lock:
            rec = {"event": event, "seq": self._seq,
                   "t": round(self._clock(), 4), **fields}
            self._seq += 1
            self._fh.write(json.dumps(rec, sort_keys=True, default=str)
                           + "\n")
            self._fh.flush()
            os.fsync(self._fh.fileno())
        return rec

    def close(self) -> None:
        with self._lock:
            self._fh.close()


TERMINAL_EVENTS = ("succeeded", "failed", "unschedulable")


def _new_job_state() -> Dict[str, Any]:
    return {"state": "Pending", "attempts": 0, "node": None,
            "preemptions": 0, "chaos_kills": 0, "timeouts": 0,
            "resumed_from_step": None, "error": None,
            "kind": None, "declared": None, "telemetry": None,
            "declared_vs_observed": None,
            "backfills": 0, "adoptions": 0,
            "speculative_launches": 0, "speculation_losses": 0,
            "speculation_loss_wall_s": 0.0,
            "winner_ckpt_dir": None, "promoted": False,
            "succeeded_wall_s": None,
            "evictions": 0, "gang_shrunk_from": None,
            "gang": 1, "gang_id": None, "ranks": {},
            "live": {}, "_last_exit_wall": None}


def _fresh_replay_state() -> Dict[str, Any]:
    return {"jobs": {}, "workers": None, "ended": False,
            "makespan_s": None, "resumes": 0, "violations": [],
            "nodes": {}, "_alloc": {},
            # utilization ledger accumulators (area under the per-node
            # allocation curve, integrated from event timestamps):
            # _util[name] holds raw busy/goodput/available second
            # integrals, _util_pending holds released-but-unclassified
            # attempt intervals (goodput is decided by the terminal
            # event), _t_hi is the newest event time seen (campaign_end
            # excluded, so the executor's own summary — written just
            # before campaign_end — derives the identical ledger)
            "_util": {}, "_util_pending": {}, "_t_hi": None}


def _node_entry(d: Mapping[str, Any]) -> Dict[str, Any]:
    return {"gpus": int(d.get("gpus") or 0),
            "cpus": int(d.get("cpus") or 0),
            "memory_gb": float(d.get("memory_gb") or 0.0),
            "draining": bool(d.get("draining")),
            "used": {"gpus": 0, "cpus": 0, "memory_gb": 0.0}}


def _replay_allocate(st8: Dict[str, Any], violations: List[str],
                     job: str, att, placements: Sequence[str],
                     res: Mapping[str, Any],
                     t: Optional[float] = None,
                     check: bool = True) -> None:
    """Charge one attempt's admission against the replayed node
    inventory; any oversubscription or admit-to-draining is a replay
    violation.  Logs from before inventory-carrying campaign_start
    events have no ``nodes`` — then this is a silent no-op.  ``t``
    opens the attempt's utilization interval (closed by
    :func:`_replay_release`).  ``check=False`` suppresses the
    violations for cross-generation handoffs (``adopted`` events): the
    dead scheduler's stale charges are still on the books until the
    resume path clears them, so transient double-occupancy there is
    bookkeeping lag, not a real oversubscription."""
    nodes = st8["nodes"]
    if not nodes or not res:
        return
    alloc = st8["_alloc"].setdefault(f"{job}:{att}", [])
    for nd in placements:
        info = nodes.get(nd)
        if info is None:
            continue
        if check and info["draining"]:
            violations.append(f"{job}: admitted to draining node {nd}")
        used = info["used"]
        used["gpus"] += int(res.get("gpus") or 0)
        used["cpus"] += int(res.get("cpus") or 0)
        used["memory_gb"] = round(
            used["memory_gb"] + float(res.get("memory_gb") or 0.0), 6)
        if check and (used["gpus"] > info["gpus"]
                      or used["cpus"] > info["cpus"]
                      or used["memory_gb"] > info["memory_gb"] + 1e-6):
            violations.append(f"oversubscribed node {nd} admitting {job}")
        alloc.append({"node": nd, "res": dict(res), "t": t})


def _replay_release(st8: Dict[str, Any], job: str, att,
                    t: Optional[float] = None) -> None:
    """Return one attempt's capacity and close its utilization
    intervals: the elapsed allocation becomes *busy* seconds
    immediately, and is parked in ``_util_pending`` until the job's
    terminal event decides whether it was *goodput* (the succeeding
    attempt) or lost work (everything else)."""
    pend = None
    for entry in st8["_alloc"].pop(f"{job}:{att}", []):
        res = entry["res"]
        info = st8["nodes"].get(entry["node"])
        if info is not None:
            used = info["used"]
            used["gpus"] = max(0, used["gpus"] - int(res.get("gpus") or 0))
            used["cpus"] = max(0, used["cpus"] - int(res.get("cpus") or 0))
            used["memory_gb"] = max(0.0, round(
                used["memory_gb"] - float(res.get("memory_gb") or 0.0), 6))
        u = st8["_util"].get(entry["node"])
        t0 = entry.get("t")
        if u is None or t0 is None or t is None:
            continue
        dt = max(0.0, float(t) - float(t0))
        gpu_s = dt * int(res.get("gpus") or 0)
        cpu_s = dt * int(res.get("cpus") or 0)
        u["busy_gpu_s"] += gpu_s
        u["busy_cpu_s"] += cpu_s
        if pend is None:
            pend = st8["_util_pending"].setdefault(job, [])
        pend.append({"attempt": str(att), "node": entry["node"],
                     "gpu_s": gpu_s, "cpu_s": cpu_s})


def _util_node_open(st8: Dict[str, Any], name: Optional[str],
                    d: Mapping[str, Any], t: Optional[float]) -> None:
    """A node entered (or re-entered) the inventory: start accruing its
    available capacity.  Draining nodes stay *available* — the hardware
    is still present and hosting residents — until removed."""
    if name is None:
        return
    u = st8["_util"].get(name)
    if u is None:
        u = st8["_util"][name] = {
            "gpus": 0, "cpus": 0, "open_t": None,
            "avail_gpu_s": 0.0, "avail_cpu_s": 0.0,
            "busy_gpu_s": 0.0, "busy_cpu_s": 0.0,
            "good_gpu_s": 0.0, "good_cpu_s": 0.0}
    u["gpus"] = int(d.get("gpus") or 0)
    u["cpus"] = int(d.get("cpus") or 0)
    if u["open_t"] is None and t is not None:
        u["open_t"] = float(t)


def _util_node_close(st8: Dict[str, Any], name: Optional[str],
                     t: Optional[float]) -> None:
    """A node left the inventory: bank its availability window.  The
    accumulated busy/goodput history is kept — removed nodes still
    appear in the ledger."""
    u = st8["_util"].get(name)
    if u is None or u["open_t"] is None or t is None:
        return
    dt = max(0.0, float(t) - u["open_t"])
    u["avail_gpu_s"] += dt * u["gpus"]
    u["avail_cpu_s"] += dt * u["cpus"]
    u["open_t"] = None


def _utilization_summary(st8: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    """Derive the per-node + cluster utilization ledger from the raw
    fold accumulators, virtually closing still-open availability and
    allocation intervals at the newest event time — WITHOUT mutating
    the fold state, so the incremental-fold property is preserved.

    ``busy`` counts every allocated second (useful or not); ``goodput``
    counts only seconds attributed to each job's succeeding attempt —
    busy minus goodput is work lost to preemption, eviction, timeouts,
    failures and speculation losses."""
    util = st8.get("_util") or {}
    if not util:
        return None
    t_end = st8.get("_t_hi")
    open_busy: Dict[str, Dict[str, float]] = {}
    if t_end is not None:
        for entries in (st8.get("_alloc") or {}).values():
            for e in entries:
                t0 = e.get("t")
                if t0 is None or e["node"] not in util:
                    continue
                dt = max(0.0, float(t_end) - float(t0))
                ob = open_busy.setdefault(
                    e["node"], {"gpu_s": 0.0, "cpu_s": 0.0})
                ob["gpu_s"] += dt * int(e["res"].get("gpus") or 0)
                ob["cpu_s"] += dt * int(e["res"].get("cpus") or 0)

    def frac(num: float, den: float) -> float:
        return round(num / den, 4) if den > 0 else 0.0

    nodes_out: Dict[str, Dict[str, float]] = {}
    tot = {k: 0.0 for k in ("avail_gpu", "busy_gpu", "good_gpu",
                            "avail_cpu", "busy_cpu", "good_cpu")}
    for name in sorted(util):
        u = util[name]
        avail_g, avail_c = u["avail_gpu_s"], u["avail_cpu_s"]
        if u["open_t"] is not None and t_end is not None:
            dt = max(0.0, float(t_end) - u["open_t"])
            avail_g += dt * u["gpus"]
            avail_c += dt * u["cpus"]
        ob = open_busy.get(name) or {}
        busy_g = u["busy_gpu_s"] + ob.get("gpu_s", 0.0)
        busy_c = u["busy_cpu_s"] + ob.get("cpu_s", 0.0)
        nodes_out[name] = {
            "available_gpu_s": round(avail_g, 4),
            "busy_gpu_s": round(busy_g, 4),
            "goodput_gpu_s": round(u["good_gpu_s"], 4),
            "busy_gpu_util": frac(busy_g, avail_g),
            "goodput_gpu_util": frac(u["good_gpu_s"], avail_g),
            "available_cpu_s": round(avail_c, 4),
            "busy_cpu_s": round(busy_c, 4),
            "goodput_cpu_s": round(u["good_cpu_s"], 4),
            "busy_cpu_util": frac(busy_c, avail_c),
            "goodput_cpu_util": frac(u["good_cpu_s"], avail_c),
        }
        tot["avail_gpu"] += avail_g
        tot["busy_gpu"] += busy_g
        tot["good_gpu"] += u["good_gpu_s"]
        tot["avail_cpu"] += avail_c
        tot["busy_cpu"] += busy_c
        tot["good_cpu"] += u["good_cpu_s"]
    cluster = {}
    for ax in ("gpu", "cpu"):
        cluster[f"available_{ax}_s"] = round(tot[f"avail_{ax}"], 4)
        cluster[f"busy_{ax}_s"] = round(tot[f"busy_{ax}"], 4)
        cluster[f"goodput_{ax}_s"] = round(tot[f"good_{ax}"], 4)
        cluster[f"busy_{ax}_util"] = frac(tot[f"busy_{ax}"],
                                          tot[f"avail_{ax}"])
        cluster[f"goodput_{ax}_util"] = frac(tot[f"good_{ax}"],
                                             tot[f"avail_{ax}"])
    return {"nodes": nodes_out, "cluster": cluster}


def _merge_telemetry(st: Dict[str, Any], summary: Dict[str, Any]) -> None:
    """Fold one attempt's telemetry summary into the job's aggregate:
    sample-weighted mean CPU%, max peak RSS/CPU, summed io."""
    prev = st.get("telemetry")
    if not prev:
        st["telemetry"] = dict(summary)
        return
    n0, n1 = prev.get("samples", 0), summary.get("samples", 0)
    tot = n0 + n1
    if tot:
        prev["cpu_pct_mean"] = round(
            (prev.get("cpu_pct_mean", 0.0) * n0
             + summary.get("cpu_pct_mean", 0.0) * n1) / tot, 2)
    prev["samples"] = tot
    for key in ("cpu_pct_peak", "rss_peak_mb"):
        prev[key] = max(prev.get(key) or 0.0, summary.get(key) or 0.0)
    for key in ("io_read_mb", "io_write_mb"):
        if summary.get(key) is not None:
            prev[key] = round((prev.get(key) or 0.0) + summary[key], 3)


def _observed_ratio(st: Dict[str, Any]) -> Optional[Dict[str, float]]:
    tel, dec = st.get("telemetry"), st.get("declared")
    if not tel or not dec:
        return None
    out = {}
    if dec.get("cpus") and tel.get("cpu_pct_peak") is not None:
        out["cpus"] = round(tel["cpu_pct_peak"] / 100.0 / dec["cpus"], 3)
    if dec.get("memory_gb") and tel.get("rss_peak_mb") is not None:
        out["memory"] = round(
            tel["rss_peak_mb"] / 1024.0 / dec["memory_gb"], 3)
    return out or None


def replay_events(lines, *, state: Optional[Dict[str, Any]] = None
                  ) -> Dict[str, Any]:
    """Replay an event log into campaign state.  Accepts an iterable of
    JSONL lines (or parsed dicts).  Half-written trailing lines (a crash
    mid-append) are skipped; when the log holds several campaigns
    (appended runs), a ``campaign_start`` resets state so the **last**
    campaign wins, while ``campaign_resume`` continues the current one.

    Replay is an incremental fold: pass a previously returned ``state``
    to continue it over new lines — ``replay_events(A + B)`` equals
    ``replay_events(B, state=replay_events(A))`` for any line-aligned
    split (the replay-idempotence property tests assert exactly this).
    The passed state is not mutated.

    Returns ``{"jobs": {name: {...}}, "counts": {...}, "workers",
    "ended", "makespan_s", "resumes", "utilization", "consistent",
    "violations"}`` — ``utilization`` is the per-node + cluster
    area-under-curve ledger (busy vs goodput GPU/CPU seconds over
    elastic availability windows), or ``None`` for inventory-less
    logs; ``consistent`` asserts the executor's bookkeeping invariants:
    monotonic per-job states, one terminal event per job, and (for ended
    campaigns) no non-terminal jobs left behind.  Per-job state includes
    orphan bookkeeping (``live`` pids), speculation and telemetry
    aggregates, and the declared-vs-observed request ratio.
    """
    if state is None:
        st8 = _fresh_replay_state()
    else:                            # continue without mutating caller's
        st8 = json.loads(json.dumps(
            {k: state[k] for k in _fresh_replay_state() if k in state},
            default=str))
        for miss, dflt in _fresh_replay_state().items():
            st8.setdefault(miss, dflt)
    jobs = st8["jobs"]
    violations = st8["violations"]

    for ln in lines:
        if isinstance(ln, (bytes, str)):
            ln = ln.strip()
            if not ln:
                continue
            try:
                ln = json.loads(ln)
            except ValueError:
                continue             # half-written trailing line
        if not isinstance(ln, dict):
            continue
        kind = ln.get("event")
        t_ev = ln.get("t")
        # newest event time drives the ledger's virtual horizon; the
        # campaign_end stamp is excluded so the executor's own summary
        # (written just before campaign_end) matches a later replay
        if (kind not in ("campaign_end", "campaign_start")
                and isinstance(t_ev, (int, float))):
            if st8["_t_hi"] is None or t_ev > st8["_t_hi"]:
                st8["_t_hi"] = float(t_ev)
        if kind == "campaign_start":     # newest campaign wins: reset
            st8["jobs"] = jobs = {}
            st8["violations"] = violations = []
            st8.update(workers=ln.get("workers"), ended=False,
                       makespan_s=None, resumes=0,
                       nodes={d["name"]: _node_entry(d)
                              for d in ln.get("inventory") or []},
                       _alloc={}, _util={}, _util_pending={},
                       _t_hi=float(t_ev)
                       if isinstance(t_ev, (int, float)) else None)
            for d in ln.get("inventory") or []:
                _util_node_open(st8, d.get("name"), d, st8["_t_hi"])
            continue
        if kind == "campaign_resume":
            st8["workers"] = ln.get("workers", st8["workers"])
            st8["ended"] = False
            st8["resumes"] += 1
            # the dead scheduler left allocation intervals open: close
            # them here (busy up to the resume stamp); adopted attempts
            # are re-charged below and keep accruing
            for key in list(st8["_alloc"]):
                jb, _, at = key.rpartition(":")
                _replay_release(st8, jb, at, t_ev)
            # the resuming scheduler built a fresh pool: restart the
            # node accounting (adopted events re-charge live orphans)
            # and reconcile node availability windows — nodes absent
            # from the new inventory stop accruing, new ones start
            new_names = {d.get("name") for d in ln.get("inventory") or []}
            for nm in list(st8["_util"]):
                if nm not in new_names:
                    _util_node_close(st8, nm, t_ev)
            st8["nodes"] = {d["name"]: _node_entry(d)
                            for d in ln.get("inventory") or []}
            st8["_alloc"] = {}
            for d in ln.get("inventory") or []:
                _util_node_open(st8, d.get("name"), d, t_ev)
            # re-charge attempts the resuming scheduler adopted (their
            # `adopted` events precede this line in the log)
            for la in ln.get("live_allocs") or []:
                _replay_allocate(st8, violations, la.get("job"),
                                 la.get("attempt"),
                                 la.get("placements") or [],
                                 la.get("resources") or {}, t_ev)
            continue
        if kind == "campaign_end":
            st8["ended"] = True
            st8["makespan_s"] = ln.get("makespan_s")
            continue
        if kind == "node_added":
            st8["nodes"][ln.get("node")] = _node_entry(ln)
            _util_node_open(st8, ln.get("node"), ln, t_ev)
            continue
        if kind == "node_draining":
            info = st8["nodes"].get(ln.get("node"))
            if info is not None:
                info["draining"] = True
            continue
        if kind == "node_undrained":
            info = st8["nodes"].get(ln.get("node"))
            if info is not None:
                info["draining"] = False
            continue
        if kind == "node_removed":
            info = st8["nodes"].pop(ln.get("node"), None)
            if info is not None and (info["used"]["gpus"]
                                     or info["used"]["cpus"]
                                     or info["used"]["memory_gb"] > 1e-6):
                violations.append(
                    f"node {ln.get('node')} removed with residents")
            _util_node_close(st8, ln.get("node"), t_ev)
            continue
        name = ln.get("job")
        if name is None:
            continue
        st = jobs.get(name)
        if st is None:
            st = jobs[name] = _new_job_state()
        for missing, dflt in _new_job_state().items():
            st.setdefault(missing, dflt)
        att = ln.get("attempt")
        if kind == "submitted":
            st["priority"] = ln.get("priority", 0)
            st["kind"] = ln.get("kind")
            if st["gang_shrunk_from"] is None:
                # an initial-pre-pass gang_shrunk precedes submitted;
                # the declared size must not clobber the shrunk one
                st["gang"] = int(ln.get("gang") or 1)
            if ln.get("resources"):
                st["declared"] = ln["resources"]
        elif kind == "admitted":
            if st["state"] in ("Succeeded", "Failed"):
                violations.append(f"{name}: admitted after terminal state")
            st["state"] = "Running"
            st["node"] = ln.get("node")
            if not ln.get("speculative"):
                st["attempts"] = max(st["attempts"], int(att or 0))
            if ln.get("backfill"):
                st["backfills"] += 1
            _replay_allocate(st8, violations, name, att,
                             ln.get("placements")
                             or ([ln.get("node")] if ln.get("node")
                                 else []),
                             ln.get("resources") or {}, t_ev)
        elif kind == "started":
            entry = {"pid": ln.get("pid"),
                     "pid_start": ln.get("pid_start"),
                     "t": ln.get("t"),
                     "speculative": bool(ln.get("speculative")),
                     "ckpt_dir": ln.get("ckpt_dir")}
            if ln.get("ranks"):
                # gang attempt: remember every rank's pid (resume must
                # kill them all) and reset per-rank exit bookkeeping
                entry["ranks"] = ln["ranks"]
                st["gang"] = int(ln.get("gang") or len(ln["ranks"]))
                st["gang_id"] = ln.get("gang_id")
                st["ranks"] = {
                    str(rk.get("rank")): {"pid": rk.get("pid"),
                                          "returncode": None}
                    for rk in ln["ranks"]}
            st["live"][str(att)] = entry
            if ln.get("speculative"):
                st["speculative_launches"] += 1
        elif kind == "rank_exited":
            rk = st["ranks"].setdefault(str(ln.get("rank")),
                                        {"pid": None, "returncode": None})
            rk["returncode"] = ln.get("returncode")
        elif kind == "adopted":
            st["state"] = "Running"
            st["adoptions"] += 1
            st["live"][str(att)] = {
                "pid": ln.get("pid"), "pid_start": ln.get("pid_start"),
                "t": ln.get("t"), "speculative": False,
                "ckpt_dir": ln.get("ckpt_dir")}
            # adoption MOVES the attempt's charge (the old campaign's
            # admitted line already holds one, possibly on another node)
            _replay_release(st8, name, att, t_ev)
            _replay_allocate(st8, violations, name, att,
                             [ln.get("node")] if ln.get("node") else [],
                             ln.get("resources") or {}, t_ev,
                             check=False)
        elif kind == "orphan_requeued":
            st["live"].pop(str(att), None)
            _replay_release(st8, name, att, t_ev)
            if st["state"] == "Running":
                st["state"] = "Pending"
        elif kind == "orphan_killed":
            st["live"].pop(str(att), None)
            _replay_release(st8, name, att, t_ev)
        elif kind == "exited":
            st["live"].pop(str(att), None)
            st["_last_exit_wall"] = ln.get("wall_s")
            _replay_release(st8, name, att, t_ev)
        elif kind == "evicted":
            st["evictions"] += 1
            if ln.get("requeued") and st["state"] == "Running":
                st["state"] = "Pending"
        elif kind == "gang_shrunk":
            if st["gang_shrunk_from"] is None:
                st["gang_shrunk_from"] = ln.get("gang_from")
            st["gang"] = int(ln.get("gang_to") or st["gang"])
        elif kind == "chaos_kill":
            st["chaos_kills"] += 1
        elif kind == "preempted":
            st["preemptions"] += 1
        elif kind == "attempt_timeout":
            st["timeouts"] += 1
        elif kind == "speculation_win":
            st["winner_ckpt_dir"] = ln.get("winner_ckpt_dir")
        elif kind == "speculation_promote":
            st["promoted"] = True
        elif kind == "speculation_loss":
            st["speculation_losses"] += 1
            st["speculation_loss_wall_s"] = round(
                st["speculation_loss_wall_s"] + (ln.get("wall_s") or 0.0),
                3)
            st["live"].pop(str(att), None)
        elif kind == "telemetry":
            if ln.get("summary"):
                _merge_telemetry(st, ln["summary"])
                st["declared_vs_observed"] = _observed_ratio(st)
        elif kind in TERMINAL_EVENTS:
            if st["state"] in ("Succeeded", "Failed"):
                violations.append(f"{name}: second terminal event {kind}")
            st["state"] = "Failed" if kind != "succeeded" else "Succeeded"
            # classify the job's parked busy intervals: only the
            # succeeding attempt's seconds count as goodput; every
            # other attempt (and a failed job entirely) was lost work
            pend = st8["_util_pending"].pop(name, [])
            if kind == "succeeded":
                st["resumed_from_step"] = ln.get("resumed_from_step")
                st["succeeded_wall_s"] = st.get("_last_exit_wall")
                att_s = None if att is None else str(att)
                for e in pend:
                    if att_s is not None and e["attempt"] != att_s:
                        continue
                    u = st8["_util"].get(e["node"])
                    if u is not None:
                        u["good_gpu_s"] += e["gpu_s"]
                        u["good_cpu_s"] += e["cpu_s"]
            else:
                st["error"] = ln.get("error")

    counts: Dict[str, int] = {}
    for st in jobs.values():
        counts[st["state"]] = counts.get(st["state"], 0) + 1
    all_viol = list(violations)
    if st8["ended"]:
        nonterminal = [n for n, st in jobs.items()
                       if st["state"] not in ("Succeeded", "Failed")]
        if nonterminal:
            all_viol.append(
                f"campaign ended with non-terminal jobs: {nonterminal}")
    return {**st8, "jobs": jobs, "counts": counts,
            "utilization": _utilization_summary(st8),
            "consistent": not all_viol, "violations": all_viol}


# --------------------------------------------------------------------------
# The executor
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _Running:
    rec: JobRecord
    attempt: int                     # per-job attempt seq (incl. duplicates)
    node: str
    handle: Any
    stdout_path: Path
    stderr_path: Path
    stdout_fh: Optional[IO]
    stderr_fh: Optional[IO]
    started_t: float
    resume: bool
    cores: List[int] = dataclasses.field(default_factory=list)
    eff: Optional[Resources] = None  # learned request admitted/released with
    speculative: bool = False
    spec_loser: bool = False         # a sibling won; kill was ours to eat
    timed_out: bool = False
    adopted: bool = False
    # graceful-kill escalation: SIGTERM sent at term_t, SIGKILL once the
    # grace window expires.  `evicted` marks evictions/drains — their
    # requeue consumes no retry budget and triggers no backoff.
    term_t: Optional[float] = None
    kill_reason: Optional[str] = None
    escalated: bool = False
    evicted: bool = False
    ckpt_dir: Optional[str] = None
    telem: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # gang attempts: one _Running covers all ranks (handle is a
    # _GangHandle); `placements` lists every rank's node (incl. `node`,
    # which is rank 0's) and `aux_fhs` the non-rank-0 log handles
    gang: int = 1
    gang_id: Optional[str] = None
    placements: List[str] = dataclasses.field(default_factory=list)
    aux_fhs: List[IO] = dataclasses.field(default_factory=list)


class CampaignExecutor:
    """Run a campaign's pending jobs as concurrent subprocesses.

    Parameters
    ----------
    records:    the orchestrator's ``{name: JobRecord}`` (mutated in
                place — states, attempts, results, telemetry).
    pvc:        :class:`PersistentVolume` for logs/results/events.
    s3:         optional :class:`S3Store`; succeeded results are exported.
    workers:    max concurrent subprocesses.
    inventory:  :class:`NodeSpec` sequence gating admission; default:
                :func:`local_inventory` (one max-request node per worker).
    chaos:      optional :class:`ChaosSpec` fault injection.
    worker_env: extra env vars for every subprocess (e.g. pinning each
                worker to one CPU thread for benchmark determinism).
    pin_cpus:   enforce the job's ``Resources.cpus`` request as a real
                CPU-affinity limit (the local analogue of a Kubernetes
                CPU limit): each worker slot gets a round-robin core set
                of that size, exported as ``REPRO_CPU_AFFINITY`` and
                applied by ``repro.launch`` before jax loads.  Linux
                only; silently off elsewhere.
    python:     interpreter for subprocesses (default ``sys.executable``).
    spawn:      injectable process factory for tests.
    attempt_timeout_s: kill attempts that exceed this wall time (its own
                ``timeout`` outcome, counted into preemptions and lost
                wall; retries still apply).
    resume:     replay an existing event log before scheduling: completed
                jobs are marked done (never re-executed), still-alive
                orphan attempts are re-adopted by pid + start-time
                identity, dead orphans re-queue through the retry_env
                resume path.
    speculate:  ``True`` (defaults) or a :class:`SpeculationSpec` —
                launch first-finisher-wins duplicates for stragglers.
    backfill:   allow jobs behind a blocked queue head to use capacity
                the head cannot, under the no-head-delay bound.  Off by
                default: admission is strict head-of-line within
                (-priority, submit order) among jobs not in backoff.
    telemetry:  sample per-attempt CPU%/RSS/io from /proc into the event
                log and feed completed usage to the learned-request
                model (``telemetry_every_s`` cadence; ``telemetry_log_-
                every_s`` rate-limits per-attempt sample events).
    retry_backoff_base_s / retry_backoff_cap_s / backoff_seed:
                exponential backoff with deterministic full jitter
                between *failure/timeout* retries (signal preemptions
                requeue immediately — a preempted pod is not the job's
                fault).  ``base * 2**(nfail-1)`` capped, scaled by
                ``0.5 + 0.5*rng()``.  ``base=0`` disables.
    clock:      injectable wall clock (``time.time``) — all event
                timestamps, backoff gates and timeout checks use it.
    straggler_env: ``{job_name: {env}}`` overlay applied only to the
                job's *primary* attempts (a degraded node in miniature:
                duplicates escape it — used by the straggler bench).
    learned:    injectable :class:`LearnedRequests` model.
    progress_fn: injectable ``(run, now) -> steps/s | None`` probe
                (default: newest published checkpoint manifest).
    """

    def __init__(self, records: Dict[str, JobRecord],
                 pvc: PersistentVolume, s3: Optional[S3Store] = None, *,
                 workers: int = 1,
                 inventory: Optional[Sequence[NodeSpec]] = None,
                 chaos: Optional[ChaosSpec] = None,
                 worker_env: Optional[Mapping[str, str]] = None,
                 pin_cpus: bool = False,
                 python: Optional[str] = None,
                 spawn: Optional[Callable] = None,
                 attempt_timeout_s: Optional[float] = None,
                 poll_s: float = 0.05,
                 grace_s: float = 5.0,
                 preempt: bool = False,
                 nodes_file: Optional[Union[str, Path]] = None,
                 resume: bool = False,
                 speculate: Union[bool, SpeculationSpec] = False,
                 backfill: bool = False,
                 telemetry: bool = True,
                 telemetry_every_s: float = 0.5,
                 telemetry_log_every_s: float = 2.0,
                 retry_backoff_base_s: float = 1.0,
                 retry_backoff_cap_s: float = 30.0,
                 backoff_seed: int = 0,
                 clock: Optional[Callable[[], float]] = None,
                 straggler_env: Optional[Mapping[str, Mapping[str, str]]]
                 = None,
                 learned: Optional[LearnedRequests] = None,
                 progress_fn: Optional[Callable] = None,
                 placement: Union[str, Any, None] = None):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.records = records
        self.pvc = pvc
        self.s3 = s3
        self.workers = int(workers)
        self.chaos = chaos
        self.worker_env = dict(worker_env or {})
        self.python = python or sys.executable
        self.spawn = spawn or _default_spawn
        self.attempt_timeout_s = attempt_timeout_s
        self.poll_s = poll_s
        self.grace_s = float(grace_s)
        self.preempt = preempt
        self.resume = resume
        if speculate is True:
            self.speculate: Optional[SpeculationSpec] = SpeculationSpec()
        else:
            self.speculate = speculate or None
        self.backfill = backfill
        self.telemetry = telemetry
        self.telemetry_every_s = telemetry_every_s
        self.telemetry_log_every_s = telemetry_log_every_s
        self.retry_backoff_base_s = retry_backoff_base_s
        self.retry_backoff_cap_s = retry_backoff_cap_s
        self._backoff_rng = random.Random(backoff_seed)
        self.clock = clock or time.time
        self.straggler_env = {k: dict(v)
                              for k, v in (straggler_env or {}).items()}
        self.learned = learned if learned is not None else LearnedRequests()
        self.progress_fn = progress_fn or checkpoint_progress
        pending = [r for r in records.values() if r.state == JobState.PENDING]
        self._order = {r.spec.name: i for i, r in enumerate(pending)}
        # elastic inventory: campaign/nodes.json (or an explicit
        # nodes_file) is watched every poll tick — rewrite it to grow or
        # drain+remove nodes mid-campaign.  When it exists up front and
        # no inventory was passed, it also *is* the initial inventory.
        self._nodes_file = (Path(nodes_file) if nodes_file
                            else pvc.path("campaign/nodes.json"))
        self._nodes_mtime: Optional[int] = None
        if inventory is None and self._nodes_file.exists():
            from repro.core.scheduler import node_specs_from_json
            try:
                inventory = node_specs_from_json(
                    json.loads(self._nodes_file.read_text()))
                self._nodes_mtime = self._nodes_file.stat().st_mtime_ns
            except (OSError, ValueError, TypeError, KeyError):
                inventory = None
        self.pool = ResourcePool(inventory if inventory is not None
                                 else local_inventory(workers,
                                                      [r.spec for r in pending]),
                                 policy=placement)
        self.pin_cpus = pin_cpus and hasattr(os, "sched_getaffinity")
        self._host_cpus = (sorted(os.sched_getaffinity(0))
                           if self.pin_cpus else [])
        # per-core count of running pinned attempts: new attempts take
        # the least-loaded cores, so concurrent jobs spread across the
        # host instead of stacking on one core
        self._core_load: Dict[int, int] = {c: 0 for c in self._host_cpus}
        self.log = EventLog(pvc.path(EVENTS_REL), clock=self.clock)
        # per-job bookkeeping
        self._queue: List[JobRecord] = list(pending)
        self._running: List[_Running] = []
        self._run_lock = threading.Lock()   # sampler thread reads _running
        self._attempt_history: Dict[str, List[dict]] = {}
        self._attempt_seq: Dict[str, int] = {}
        self._chaos_kills: Dict[str, int] = {}
        self._queued_t: Dict[str, float] = {}
        self._not_before: Dict[str, float] = {}
        self._nfail: Dict[str, int] = {}
        self._spec_count: Dict[str, int] = {}
        # effective gang size per job (elastic gangs shrink it, floor
        # JobSpec.gang_min) and requeues that consume no retry budget
        self._gang_now: Dict[str, int] = {}
        self._free_requeues: Dict[str, int] = {}
        self._evict_signals = 0
        self._nodes_added = 0
        self._nodes_drained = 0
        self._nodes_removed = 0
        self._kind_rates: Dict[str, List[float]] = {}
        self._kind_walls: Dict[str, List[float]] = {}
        self._pending_promote: Dict[str, Tuple[str, str]] = {}
        self._spec_launches = 0
        self._spec_wins = 0
        self._spec_wall_lost = 0.0
        self._backfills = 0
        self._adopted = 0
        self._orphans_requeued = 0
        self._resumed_done = 0
        self.queue_waits: List[float] = []
        self.summary: Dict[str, Any] = {}
        try:
            self._clk_tck = os.sysconf("SC_CLK_TCK")
        except (ValueError, OSError, AttributeError):
            self._clk_tck = 100
        self._stop = threading.Event()
        self._sampler: Optional[threading.Thread] = None

    # ------------------------------------------------------------ helpers
    def _sort_queue(self) -> None:
        self._queue.sort(key=lambda r: (-r.spec.priority,
                                        self._order[r.spec.name]))

    def _child_env(self) -> Dict[str, str]:
        env = {**os.environ, **self.worker_env}
        src = _src_path()
        existing = env.get("PYTHONPATH", "")
        if src not in existing.split(os.pathsep):
            env["PYTHONPATH"] = (src + os.pathsep + existing
                                 if existing else src)
        return env

    def _checkpoint_dir(self, job: JobSpec) -> Optional[str]:
        return job.env.get("CHECKPOINT_DIR")

    def _job_kind(self, job: JobSpec) -> str:
        return (f"{job.env.get('RUN_KIND', '?')}:"
                f"{job.env.get('ARCH', '')}")

    def _effective(self, job: JobSpec) -> Resources:
        return self.learned.effective(self._job_kind(job), job.resources)

    def _est_wall(self, kind: str) -> Optional[float]:
        walls = self._kind_walls.get(kind)
        return sum(walls) / len(walls) if walls else None

    def _procs_running(self) -> int:
        """Concurrent subprocess count — the worker cap's unit.  A gang
        attempt holds one _Running but `gang` processes."""
        with self._run_lock:
            return sum(max(1, r.gang) for r in self._running)

    def _gang(self, job: JobSpec) -> int:
        """The job's *effective* gang size: the declared world unless an
        elastic shrink picked a smaller admissible one."""
        return self._gang_now.get(job.name, max(1, job.gang))

    # ------------------------------------------------- graceful preemption
    def _graceful_kill(self, run: _Running, now: float, reason: str, *,
                       evict: bool = False) -> None:
        """The shared SIGTERM -> grace -> SIGKILL escalation (Kubernetes
        pod-preemption semantics).  SIGTERM goes out now; the child's
        handler writes a final checkpoint and exits; the poll loop
        escalates to SIGKILL if the attempt outlives ``grace_s``.  Used
        by the evictor, node drains, speculation-loser kills, and
        non-SIGKILL chaos."""
        if run.term_t is not None or run.timed_out:
            return
        run.term_t = now
        run.kill_reason = reason
        if evict:
            run.evicted = True
        run.handle.send_signal(int(_signal.SIGTERM))

    def _escalate_overdue(self, run: _Running, now: float) -> None:
        if (run.term_t is None or run.escalated
                or now - run.term_t < self.grace_s):
            return
        run.escalated = True
        self.log.emit("grace_expired", job=run.rec.spec.name,
                      attempt=run.attempt, reason=run.kill_reason,
                      grace_s=self.grace_s)
        run.handle.send_signal(int(_signal.SIGKILL))

    # ------------------------------------------------- elastic inventory
    def _check_nodes_file(self, now: float) -> None:
        """Apply a rewritten ``campaign/nodes.json``: grow with new
        nodes, drain+remove missing ones.  Torn/partial writes are
        retried on the next poll tick (writers should publish via
        tmp+rename)."""
        try:
            mtime = self._nodes_file.stat().st_mtime_ns
        except OSError:
            return
        if mtime == self._nodes_mtime:
            return
        from repro.core.scheduler import node_specs_from_json
        try:
            specs = node_specs_from_json(
                json.loads(self._nodes_file.read_text()))
        except (OSError, ValueError, TypeError, KeyError):
            return
        self._nodes_mtime = mtime
        self._apply_inventory(specs, now)

    def _apply_inventory(self, specs: Sequence[NodeSpec],
                         now: float) -> None:
        desired: Dict[str, NodeSpec] = {}
        for spec in specs:
            for i in range(max(1, spec.count)):
                desired[f"{spec.name}-{i:03d}"] = \
                    dataclasses.replace(spec, count=1)
        current = {n.name: n for n in self.pool.nodes}
        for name, spec in desired.items():
            node = current.get(name)
            if node is None:
                self.pool.add_node(spec, name)
                self._nodes_added += 1
                self.log.emit("node_added", node=name, gpus=spec.gpus,
                              cpus=spec.cpus, memory_gb=spec.memory_gb,
                              gpu_memory_gb=spec.gpu_memory_gb)
            elif node.draining:
                # re-added before the drain completed: cancel it
                node.draining = False
                self.log.emit("node_undrained", node=name)
        for name, node in current.items():
            if name in desired or node.draining:
                continue
            self.pool.drain(name)
            self._nodes_drained += 1
            with self._run_lock:
                residents = [r for r in self._running
                             if name in (r.placements or [r.node])]
            self.log.emit("node_draining", node=name,
                          residents=sorted({r.rec.spec.name
                                            for r in residents}))
            for r in residents:
                # the whole attempt leaves (a gang loses its rank here
                # and condemns itself): grace window to checkpoint,
                # then a free requeue
                self._graceful_kill(r, now, "drain", evict=True)
        self._reap_drained()
        self._recheck_schedulable(now)

    def _reap_drained(self) -> None:
        for name in self.pool.drained_free():
            self.pool.remove_node(name)
            self._nodes_removed += 1
            self.log.emit("node_removed", node=name)

    # ------------------------------------------ schedulability + shrink
    def _ensure_placeable(self, rec: JobRecord, now: float, *,
                          initial: bool = False) -> bool:
        """Could this queued job ever be admitted at the current
        inventory?  Elastic gangs (1 <= gang_min < gang) shrink to the
        largest admissible world instead of failing; rigid jobs that fit
        nothing are failed as unschedulable.  During a full drain (no
        admitting nodes) non-initial checks wait instead of failing —
        capacity may be about to grow back."""
        job = rec.spec
        gang = self._gang(job)
        admitting = any(not n.draining for n in self.pool.nodes)
        if gang > 1:
            if (gang <= self.workers
                    and self.pool.fits_when_empty_gang(job.resources,
                                                       gang)):
                return True
            gmin = int(getattr(job, "gang_min", 0) or 0)
            if 1 <= gmin < gang:
                for n in range(min(gang - 1, self.workers), gmin - 1, -1):
                    if self.pool.fits_when_empty_gang(job.resources, n):
                        self._gang_now[job.name] = n
                        self.log.emit("gang_shrunk", job=job.name,
                                      gang_from=gang, gang_to=n,
                                      gang_min=gmin)
                        return True
            if not admitting and not initial:
                return True              # wait out the resize
            self._queue.remove(rec)
            rec.state = JobState.FAILED
            rec.error = (
                f"unschedulable: gang of {gang} ranks x "
                f"{job.resources.cpus} cpus/"
                f"{job.resources.memory_gb:g}GB cannot be "
                f"placed atomically (workers={self.workers})"
                if gang <= self.workers else
                f"unschedulable: gang of {gang} ranks exceeds "
                f"worker cap {self.workers}")
            self.log.emit("unschedulable", job=job.name, gang=gang,
                          error=rec.error)
            self._stage_result(rec)
            return False
        if self.pool.fits_when_empty(job.resources):
            return True
        if not admitting and not initial:
            return True
        self._queue.remove(rec)
        rec.state = JobState.FAILED
        rec.error = ("unschedulable: resource request fits no "
                     "node in the inventory")
        self.log.emit("unschedulable", job=job.name, error=rec.error)
        self._stage_result(rec)
        return False

    def _recheck_schedulable(self, now: float) -> None:
        for rec in list(self._queue):
            self._ensure_placeable(rec, now)

    # ----------------------------------------------------------- evictor
    def _head_placeable_after(self, victims: Sequence[_Running],
                              head_eff: Resources, head_gang: int,
                              procs_free: int) -> bool:
        """Would releasing ``victims`` let the queue head start?  Pure
        simulation on a pool clone — nothing is killed here."""
        if procs_free + sum(max(1, v.gang) for v in victims) < head_gang:
            return False
        trial = self.pool.clone()
        for v in victims:
            for placement in (v.placements or [v.node]):
                trial.release(placement, v.eff or v.rec.spec.resources)
        if head_gang > 1:
            return trial.admit_gang(head_eff, head_gang) is not None
        return trial.admit(head_eff) is not None

    def _maybe_evict(self, now: float) -> None:
        """Preempting scheduler class: when the queue head outranks
        running work and cannot be placed, evict (checkpoint + requeue,
        no retry consumed) the cheapest set of strictly-lower-priority
        attempts whose release makes the head placeable."""
        if not self.preempt or not self._queue:
            return
        eligible = [r for r in self._queue
                    if self._not_before.get(r.spec.name, 0.0) <= now]
        if not eligible:
            return
        head = eligible[0]
        head_gang = self._gang(head.spec)
        head_eff = self._effective(head.spec)
        with self._run_lock:
            running = list(self._running)
        victims = [r for r in running
                   if r.rec.spec.priority < head.spec.priority
                   and r.term_t is None and not r.timed_out]
        if not victims:
            return
        procs_free = self.workers - self._procs_running()
        if self._head_placeable_after([], head_eff, head_gang,
                                      procs_free):
            return                       # head is placeable on its own
        # lowest priority first; speculative duplicates before primaries
        # (cheapest to lose); newest first within a class (least sunk
        # work thrown away)
        victims.sort(key=lambda r: (r.rec.spec.priority,
                                    0 if r.speculative else 1,
                                    -r.started_t))
        chosen: List[_Running] = []
        for v in victims:
            chosen.append(v)
            if self._head_placeable_after(chosen, head_eff, head_gang,
                                          procs_free):
                break
        else:
            return                       # even all victims don't free enough
        # back-trim: drop any victim whose release turned out unneeded
        if len(chosen) > 1:
            for v in list(chosen):
                rest = [r for r in chosen if r is not v]
                if self._head_placeable_after(rest, head_eff, head_gang,
                                              procs_free):
                    chosen = rest
        for v in chosen:
            self._evict_signals += 1
            self.log.emit("evict", job=v.rec.spec.name,
                          attempt=v.attempt,
                          victim_priority=v.rec.spec.priority,
                          head=head.spec.name,
                          head_priority=head.spec.priority,
                          speculative=v.speculative)
            self._graceful_kill(v, now, "evict", evict=True)

    # ---------------------------------------------------------- lifecycle
    def _start_attempt(self, rec: JobRecord, node: str, now: float, *,
                       eff: Resources, speculative: bool = False,
                       placements: Optional[List[str]] = None) -> None:
        job = rec.spec
        gang = 1 if speculative else self._gang(job)
        seq = self._attempt_seq.get(job.name, 0) + 1
        self._attempt_seq[job.name] = seq
        if not speculative:
            rec.attempts += 1
        resume = (not speculative and rec.attempts > 1
                  and bool(job.retry_env))
        ckpt = self._checkpoint_dir(job)
        overlay: Optional[Dict[str, str]] = None
        if speculative and ckpt:
            # the duplicate races in a sibling dir; the winner's dir is
            # promoted to the declared path on first finish
            ckpt = f"{ckpt}.spec{seq}"
            overlay = {"CHECKPOINT_DIR": ckpt}
        if not speculative and gang != max(1, job.gang):
            # elastic shrink: the child re-derives its world size from
            # the env overlay; the rank-agnostic checkpoint makes the
            # resume a pure re-placement
            overlay = dict(overlay or {})
            overlay["WORLD_SIZE"] = str(gang)
        argv = ([self.python, "-m", "repro.launch"]
                + job_run_argv(job, resume=resume, env_overlay=overlay))
        env = self._child_env()
        if not speculative and job.name in self.straggler_env:
            env.update(self.straggler_env[job.name])
        cores: List[int] = []
        if self.pin_cpus and self._host_cpus:
            # the Resources.cpus request becomes a real affinity limit:
            # take the currently least-loaded cores (released when the
            # attempt exits), so concurrent jobs spread across the host.
            # A gang's ranks share one core set sized to the gang total.
            need = max(1, min(job.resources.cpus * gang,
                              len(self._host_cpus)))
            cores = sorted(self._host_cpus,
                           key=lambda c: (self._core_load[c], c))[:need]
            for c in cores:
                self._core_load[c] += 1
            env["REPRO_CPU_AFFINITY"] = ",".join(str(c) for c in cores)
        gang_id: Optional[str] = None
        rank_meta: List[Dict[str, Any]] = []
        aux_fhs: List[IO] = []
        if gang > 1:
            # one subprocess per rank, all admitted already (placements);
            # rank 0 hosts the jax.distributed coordinator and its log
            # carries the gang's RunReport
            from repro.distributed.gang import free_port, rank_argv
            coordinator = f"127.0.0.1:{free_port()}"
            gang_id = f"{job.name}.g{seq}"
            procs: List[Any] = []
            out_p = err_p = None
            out_fh = err_fh = None
            for r in range(gang):
                o_p = self.pvc.path(
                    f"logs/{job.name}.attempt{seq}.rank{r}.out")
                e_p = self.pvc.path(
                    f"logs/{job.name}.attempt{seq}.rank{r}.err")
                o_p.parent.mkdir(parents=True, exist_ok=True)
                ofh, efh = open(o_p, "wb"), open(e_p, "wb")
                child = self.spawn(job, seq,
                                   rank_argv(argv, r, coordinator),
                                   env, ofh, efh)
                procs.append(child)
                cpid = getattr(child, "pid", None)
                rank_meta.append({
                    "rank": r, "pid": cpid,
                    "pid_start": _pid_start_time(cpid) if cpid else None})
                if r == 0:
                    out_p, err_p, out_fh, err_fh = o_p, e_p, ofh, efh
                else:
                    aux_fhs.extend((ofh, efh))

            def _rank_exited(rank: int, rc: int,
                             _name=job.name, _seq=seq, _gid=gang_id):
                self.log.emit("rank_exited", job=_name, attempt=_seq,
                              gang_id=_gid, rank=rank, returncode=rc)

            handle: Any = _GangHandle(procs, on_rank_exit=_rank_exited,
                                      grace_s=self.grace_s,
                                      clock=self.clock)
        else:
            out_p = self.pvc.path(f"logs/{job.name}.attempt{seq}.out")
            err_p = self.pvc.path(f"logs/{job.name}.attempt{seq}.err")
            out_p.parent.mkdir(parents=True, exist_ok=True)
            out_fh = open(out_p, "wb")
            err_fh = open(err_p, "wb")
            handle = self.spawn(job, seq, argv, env, out_fh, err_fh)
        run = _Running(
            rec=rec, attempt=seq, node=node, handle=handle,
            stdout_path=out_p, stderr_path=err_p,
            stdout_fh=out_fh, stderr_fh=err_fh,
            started_t=now, resume=resume, cores=cores, eff=eff,
            speculative=speculative, ckpt_dir=ckpt,
            gang=gang, gang_id=gang_id,
            placements=list(placements or [node]), aux_fhs=aux_fhs)
        with self._run_lock:
            self._running.append(run)
        pid = getattr(handle, "pid", None)
        self.log.emit("started", job=job.name, attempt=seq, pid=pid,
                      pid_start=_pid_start_time(pid) if pid else None,
                      resume=resume, node=node, speculative=speculative,
                      ckpt_dir=ckpt,
                      **({"gang": gang, "gang_id": gang_id,
                          "ranks": rank_meta} if gang > 1 else {}))

    def _admit(self, rec: JobRecord, node: str, now: float, *,
               eff: Resources, backfill: bool = False,
               head: Optional[str] = None,
               head_bound: Optional[float] = None,
               placements: Optional[List[str]] = None) -> None:
        self._queue.remove(rec)
        wait = now - self._queued_t.get(rec.spec.name, now)
        if rec.attempts == 0:            # PENDING -> RUNNING once
            rec.state = JobState.RUNNING
            self.queue_waits.append(wait)
        if rec.start_time is None:
            rec.start_time = now
        rec.state = JobState.RUNNING
        fields: Dict[str, Any] = dict(
            job=rec.spec.name, node=node,
            attempt=self._attempt_seq.get(rec.spec.name, 0) + 1,
            queue_wait_s=round(wait, 3),
            resources={"gpus": eff.gpus, "cpus": eff.cpus,
                       "memory_gb": eff.memory_gb})
        gang = self._gang(rec.spec)
        if gang > 1 or rec.spec.gang > 1:
            fields.update(gang=gang, placements=placements,
                          gang_nodes=len(set(placements or [node])))
        if eff is not rec.spec.resources:
            fields["learned_request"] = {"cpus": eff.cpus,
                                         "memory_gb": eff.memory_gb}
        if backfill:
            self._backfills += 1
            fields.update(backfill=True, blocked_head=head,
                          head_start_bound_s=(
                              round(head_bound - now, 3)
                              if head_bound is not None else None))
        self.log.emit("admitted", **fields)
        self._start_attempt(rec, node, now, eff=eff,
                            placements=placements)

    # ------------------------------------------------------- speculation
    def _live_siblings(self, run: _Running) -> List[_Running]:
        with self._run_lock:
            return [r for r in self._running
                    if r.rec is run.rec and r is not run]

    def _maybe_speculate(self, now: float) -> None:
        sp = self.speculate
        if sp is None:
            return
        for run in list(self._running):
            if (run.speculative or run.spec_loser
                    or self._procs_running() >= self.workers):
                continue
            job = run.rec.spec
            if not getattr(job, "speculation", True):
                continue
            if max(1, job.gang) > 1:
                # no speculative duplicate gangs: two coordinators would
                # race one checkpoint dir, and a duplicate's worth of
                # slots is a whole gang's worth of capacity
                continue
            if self._spec_count.get(job.name, 0) >= sp.max_duplicates_per_job:
                continue
            if any(r.speculative for r in self._live_siblings(run)):
                continue
            alive = now - run.started_t
            if alive < sp.min_runtime_s:
                continue
            kind = self._job_kind(job)
            walls = self._kind_walls.get(kind)
            if sp.grace is not None:
                # only attempts that have outlived grace x the mean
                # completed wall of their kind are straggler suspects
                if not walls:
                    continue
                if alive <= sp.grace * (sum(walls) / len(walls)):
                    continue
            prog = self.progress_fn(run, now)
            trigger = False
            median = None
            if prog is None:
                # overdue (grace gate passed) with zero published
                # progress: the degenerate straggler
                trigger = sp.grace is not None
            else:
                peers = []
                for other in list(self._running):
                    if other is run or other.spec_loser:
                        continue
                    if self._job_kind(other.rec.spec) != kind:
                        continue
                    p = self.progress_fn(other, now)
                    if p is not None:
                        peers.append(p)
                if len(peers) < sp.min_peers:
                    peers = peers + self._kind_rates.get(kind, [])
                if len(peers) >= sp.min_peers:
                    median = statistics.median(peers)
                    trigger = median > 0 and prog < sp.slow_fraction * median
            if not trigger:
                continue
            eff = self._effective(job)
            node = self.pool.admit(eff)
            if node is None:
                continue
            self._spec_count[job.name] = \
                self._spec_count.get(job.name, 0) + 1
            self._spec_launches += 1
            self.log.emit(
                "admitted", job=job.name, node=node,
                attempt=self._attempt_seq.get(job.name, 0) + 1,
                resources={"gpus": eff.gpus, "cpus": eff.cpus,
                           "memory_gb": eff.memory_gb},
                speculative=True,
                progress_steps_per_s=(round(prog, 4)
                                      if prog is not None else None),
                median_steps_per_s=(round(median, 4)
                                    if median is not None else None))
            self._start_attempt(run.rec, node, now, eff=eff,
                                speculative=True)

    def _promote_dir(self, name: str, winner: str, orig: str) -> None:
        """Move the winning duplicate's checkpoint dir onto the declared
        path (the loser's dir is parked, never deleted — post-mortems)."""
        self._pending_promote.pop(name, None)
        error = None
        try:
            if os.path.isdir(orig):
                park = orig + ".loser"
                n = 1
                while os.path.exists(park):
                    n += 1
                    park = f"{orig}.loser{n}"
                os.rename(orig, park)
            os.rename(winner, orig)
        except OSError as exc:            # pragma: no cover - race window
            error = str(exc)
        self.log.emit("speculation_promote", job=name,
                      winner_ckpt_dir=winner, promoted_to=orig,
                      error=error)

    def _finish_promotion_if_clear(self, name: str) -> None:
        pend = self._pending_promote.get(name)
        if pend is None:
            return
        with self._run_lock:
            live = any(r.rec.spec.name == name for r in self._running)
        if not live:
            self._promote_dir(name, pend[0], pend[1])

    # ----------------------------------------------------------- finish
    def _finish_attempt(self, run: _Running, rc: int, now: float) -> None:
        rec, job = run.rec, run.rec.spec
        for fh in (run.stdout_fh, run.stderr_fh, *run.aux_fhs):
            if fh is not None:
                try:
                    fh.close()
                except OSError:
                    pass
        wall = now - run.started_t
        # a gang attempt holds one admission per rank — release them all
        for placement in (run.placements or [run.node]):
            self.pool.release(placement, run.eff or job.resources)
        for c in run.cores:
            self._core_load[c] -= 1
        rec.node = run.node
        self._emit_telemetry(run, final=True)
        report = None
        try:
            report = parse_trailing_report(
                run.stdout_path.read_text(errors="replace"))
        except OSError:
            pass
        hist = self._attempt_history.setdefault(job.name, [])
        self.log.emit("exited", job=job.name, attempt=run.attempt,
                      returncode=rc, wall_s=round(wall, 3),
                      speculative=run.speculative, adopted=run.adopted)
        if run.spec_loser:
            # a sibling already won this job; this exit is the planned
            # kill of the loser — account the wall, touch nothing else
            hist.append({"attempt": run.attempt,
                         "outcome": "speculation_loss",
                         "wall_s": round(wall, 3), "returncode": rc,
                         "speculative": run.speculative})
            self._spec_wall_lost += wall
            self.log.emit("speculation_loss", job=job.name,
                          attempt=run.attempt, wall_s=round(wall, 3),
                          speculative=run.speculative)
            self._finish_promotion_if_clear(job.name)
            return
        ok = (rc == 0 and report is not None
              and report.get("status") != "failed")
        if ok:
            kind = self._job_kind(job)
            tel = self._telem_summary(run)
            if tel is not None:
                self.learned.observe(
                    kind,
                    cpus=tel["cpu_pct_peak"] / 100.0,
                    memory_gb=tel["rss_peak_mb"] / 1024.0)
                rec.telemetry = tel
            m = report.get("metrics") or {}
            steps = m.get("steps") or m.get("steps_run")
            if steps and wall > 0:
                self._kind_rates.setdefault(kind, []).append(steps / wall)
            if not run.speculative:
                self._kind_walls.setdefault(kind, []).append(wall)
            # first finisher wins: gracefully stop any racing sibling
            # attempts (SIGTERM -> grace -> SIGKILL; their exits are
            # accounted as speculation losses)
            siblings = self._live_siblings(run)
            for sib in siblings:
                sib.spec_loser = True
                self._graceful_kill(sib, now, "speculation")
            entry = {"attempt": run.attempt, "outcome": "succeeded",
                     "wall_s": round(wall, 3), "returncode": rc,
                     "speculative": run.speculative}
            resumed = m.get("resumed_from_step")
            if resumed is not None:
                entry["resumed_from_step"] = int(resumed)
            hist.append(entry)
            rec.end_time = now
            rec.error = None
            rec.result = report
            rec.state = JobState.SUCCEEDED
            orig = self._checkpoint_dir(job)
            if orig and run.ckpt_dir and run.ckpt_dir != orig:
                # the duplicate won: promote its dir to the declared
                # path (deferred until the losers are reaped)
                self._spec_wins += 1
                self.log.emit("speculation_win", job=job.name,
                              attempt=run.attempt,
                              winner_ckpt_dir=run.ckpt_dir)
                self._pending_promote[job.name] = (run.ckpt_dir, orig)
                if not siblings:
                    self._finish_promotion_if_clear(job.name)
            self.log.emit("succeeded", job=job.name, attempt=run.attempt,
                          resumed_from_step=entry.get("resumed_from_step"))
            self._stage_result(rec)
            return
        # ------------------------------------------------- failure path
        timed_out = run.timed_out
        evicted = run.evicted and not timed_out
        preempted = rc < 0 and not timed_out and not evicted
        outcome = ("timeout" if timed_out
                   else "evicted" if evicted
                   else "preempted" if preempted else "failed")
        error = (report or {}).get("error") or (
            f"attempt timeout after {round(wall, 1)}s" if timed_out
            else f"evicted ({run.kill_reason})" if evicted
            else f"killed by signal {-rc}" if rc < 0
            else f"exit code {rc}")
        if run.speculative:
            # a failed duplicate never harms its job: its crash is just a
            # speculation loss — the primary is still racing
            hist.append({"attempt": run.attempt,
                         "outcome": "speculation_loss",
                         "wall_s": round(wall, 3), "returncode": rc,
                         "error": error, "speculative": True})
            self._spec_wall_lost += wall
            self.log.emit("speculation_loss", job=job.name,
                          attempt=run.attempt, wall_s=round(wall, 3),
                          speculative=True, reason=outcome)
            return
        hist.append({"attempt": run.attempt, "outcome": outcome,
                     "wall_s": round(wall, 3), "returncode": rc,
                     "error": error, "speculative": False})
        siblings = self._live_siblings(run)
        if siblings:
            # the primary died but its duplicate is alive: the duplicate
            # is the job now (no requeue — the race already restarted it)
            for sib in siblings:
                sib.speculative = False
            event = ("attempt_timeout" if timed_out
                     else "preempted" if preempted else "attempt_failed")
            self.log.emit(event, job=job.name, attempt=run.attempt,
                          error=error, requeued=False,
                          duplicate_continues=True,
                          **({"signal": -rc} if rc < 0 else {}))
            return
        if evicted:
            # evictions/drains are the scheduler's fault, not the job's:
            # the attempt is free — it consumes no retry budget
            self._free_requeues[job.name] = \
                self._free_requeues.get(job.name, 0) + 1
        retryable = (rec.attempts
                     - self._free_requeues.get(job.name, 0)) <= job.retries
        backoff_s = 0.0
        if (retryable and not preempted and not evicted
                and self.retry_backoff_base_s > 0):
            # failures and timeouts back off exponentially with full
            # jitter; signal preemptions resume immediately (the cluster
            # killed the pod — the job did nothing wrong)
            nfail = self._nfail.get(job.name, 0) + 1
            self._nfail[job.name] = nfail
            backoff_s = (min(self.retry_backoff_cap_s,
                             self.retry_backoff_base_s * 2 ** (nfail - 1))
                         * (0.5 + 0.5 * self._backoff_rng.random()))
            self._not_before[job.name] = now + backoff_s
        if timed_out:
            self.log.emit("attempt_timeout", job=job.name,
                          attempt=run.attempt, error=error,
                          requeued=retryable,
                          backoff_s=round(backoff_s, 3))
        elif evicted:
            self.log.emit("evicted", job=job.name, attempt=run.attempt,
                          reason=run.kill_reason,
                          signal=(-rc if rc < 0 else None),
                          escalated=run.escalated, requeued=retryable)
        elif preempted:
            self.log.emit("preempted", job=job.name, attempt=run.attempt,
                          signal=-rc, requeued=retryable)
        else:
            self.log.emit("attempt_failed", job=job.name,
                          attempt=run.attempt, error=error,
                          requeued=retryable,
                          backoff_s=round(backoff_s, 3))
        if retryable:
            self._queue.append(rec)
            self._queued_t[job.name] = now
            self._sort_queue()
            if evicted:
                # capacity just changed under this job — a gang that no
                # longer fits shrinks here (gang_min floor) instead of
                # waiting forever
                self._ensure_placeable(rec, now)
        else:
            rec.end_time = now
            rec.error = error
            rec.result = report
            rec.state = JobState.FAILED
            self.log.emit("failed", job=job.name, error=error)
            self._stage_result(rec)

    def _stage_result(self, rec: JobRecord) -> None:
        job = rec.spec
        hist = self._attempt_history.get(job.name, [])
        payload = {
            "job": job.name, "state": rec.state.value,
            "attempts": rec.attempts, "attempt_history": hist,
            "wall_s": (rec.end_time - rec.start_time
                       if rec.end_time and rec.start_time else None),
            "node": rec.node,
            "chaos_kills": self._chaos_kills.get(job.name, 0),
            "evictions": self._free_requeues.get(job.name, 0),
            "telemetry": rec.telemetry,
            "error": rec.error, "result": rec.result,
        }
        self.pvc.stage_json(f"results/{job.name}.json", payload)
        if self.s3 is not None and rec.state == JobState.SUCCEEDED:
            self.s3.put_bytes(f"results/{job.name}.json",
                              json.dumps({"result": rec.result},
                                         default=str).encode())

    # --------------------------------------------------------- telemetry
    def _sample_once(self) -> None:
        with self._run_lock:
            runs = list(self._running)
        mono = time.monotonic()
        for run in runs:
            pid = getattr(run.handle, "pid", None)
            if not pid:
                continue
            ticks = _read_cpu_ticks(pid)
            rss = _read_rss_mb(pid)
            io_r, io_w = _read_io_mb(pid)
            t = run.telem
            if not t:
                t.update(samples=0, cpu_pct_mean=0.0, cpu_pct_peak=0.0,
                         rss_peak_mb=0.0, io_read_mb=None,
                         io_write_mb=None)
            cpu_pct = None
            if ticks is not None:
                last = t.get("_last")
                if last is not None and mono > last[0]:
                    cpu_pct = max(0.0, (ticks - last[1]) / self._clk_tck
                                  / (mono - last[0]) * 100.0)
                t["_last"] = (mono, ticks)
            if rss is not None:
                t["rss_peak_mb"] = max(t["rss_peak_mb"], rss)
            if io_r is not None:
                t["io_read_mb"], t["io_write_mb"] = io_r, io_w
            if cpu_pct is not None:
                n = t["samples"]
                t["cpu_pct_mean"] = (t["cpu_pct_mean"] * n + cpu_pct) \
                    / (n + 1)
                t["cpu_pct_peak"] = max(t["cpu_pct_peak"], cpu_pct)
                t["samples"] = n + 1
            last_log = t.get("_last_log")
            if (t.get("samples") and
                    (last_log is None
                     or mono - last_log >= self.telemetry_log_every_s)):
                t["_last_log"] = mono
                self.log.emit("telemetry_sample", job=run.rec.spec.name,
                              attempt=run.attempt,
                              cpu_pct=round(cpu_pct, 1)
                              if cpu_pct is not None else None,
                              rss_mb=round(rss, 1)
                              if rss is not None else None,
                              io_read_mb=io_r, io_write_mb=io_w)

    def _sampler_loop(self) -> None:
        while not self._stop.is_set():
            try:
                self._sample_once()
            except Exception:            # never let telemetry kill a run
                pass
            self._stop.wait(self.telemetry_every_s)

    def _telem_summary(self, run: _Running) -> Optional[Dict[str, Any]]:
        t = run.telem
        if not t or not t.get("samples"):
            return None
        return {"samples": t["samples"],
                "cpu_pct_mean": round(t["cpu_pct_mean"], 2),
                "cpu_pct_peak": round(t["cpu_pct_peak"], 2),
                "rss_peak_mb": round(t["rss_peak_mb"], 2),
                "io_read_mb": t["io_read_mb"],
                "io_write_mb": t["io_write_mb"]}

    def _emit_telemetry(self, run: _Running, final: bool = False) -> None:
        summary = self._telem_summary(run)
        if summary is not None:
            self.log.emit("telemetry", job=run.rec.spec.name,
                          attempt=run.attempt, final=final,
                          summary=summary)

    # ---------------------------------------------------------- backfill
    def _head_earliest_start(self, head_eff: Resources,
                             now: float) -> Optional[float]:
        """Earliest time the blocked queue head could start, simulating
        the release of every running attempt at its estimated finish
        (mean observed wall of its kind).  None when any running attempt
        has no estimate — conservative: no EASY backfill then."""
        free = {n.name: [n.gpus_free, n.cpus_free, n.mem_free,
                         n.spec.gpu_memory_gb]
                for n in self.pool.nodes}

        def fits_any() -> bool:
            return any(head_eff.fits(g, c, m, v)
                       for g, c, m, v in free.values())

        if fits_any():
            return now
        ends = []
        with self._run_lock:
            running = list(self._running)
        for run in running:
            est = self._est_wall(self._job_kind(run.rec.spec))
            if est is None:
                return None
            ends.append((max(now, run.started_t + est), run))
        for t_end, run in sorted(ends, key=lambda x: x[0]):
            res = run.eff or run.rec.spec.resources
            slot = free[run.node]
            slot[0] += res.gpus
            slot[1] += res.cpus
            slot[2] += res.memory_gb
            if fits_any():
                return t_end
        return None

    # ---------------------------------------------------------- resume
    def _apply_resume(self, now: float) -> bool:
        """Replay the existing event log and fold it into this run:
        completed jobs stay completed, live orphans are adopted, dead
        orphans re-queue on the resume path."""
        path = self.pvc.path(EVENTS_REL)
        try:
            lines = path.read_text(encoding="utf-8").splitlines()
        except OSError:
            return False
        state = replay_events(lines)
        if not state["jobs"]:
            return False
        for name, st in state["jobs"].items():
            rec = self.records.get(name)
            if rec is None:
                continue
            kind_key = self._job_kind(rec.spec)
            self._attempt_seq[name] = max(
                [st["attempts"]]
                + [int(a) for a in st["live"].keys() or [0]])
            if st["state"] in ("Succeeded", "Failed"):
                # any orphan attempt of a completed job (e.g. a
                # speculation loser the dead scheduler never reaped) is
                # stale by definition: kill it rather than adopt it
                for att, info in sorted(st["live"].items()):
                    pid = info.get("pid")
                    if pid and _pid_alive(pid, info.get("pid_start")):
                        try:
                            os.kill(pid, int(_signal.SIGKILL))
                        except OSError:
                            pass
                    self.log.emit("orphan_killed", job=name,
                                  attempt=int(att), pid=pid)
            if st["state"] == "Succeeded":
                self._resumed_done += 1
                if rec in self._queue:
                    self._queue.remove(rec)
                rec.state = JobState.SUCCEEDED
                rec.attempts = st["attempts"]
                rec.node = st["node"]
                rec.telemetry = st["telemetry"]
                res_p = self.pvc.path(f"results/{name}.json")
                if res_p.exists():
                    try:
                        payload = json.loads(res_p.read_text())
                        rec.result = payload.get("result")
                        if payload.get("attempt_history"):
                            self._attempt_history[name] = \
                                payload["attempt_history"]
                    except (OSError, ValueError):
                        pass
                if st["succeeded_wall_s"]:
                    self._kind_walls.setdefault(kind_key, []).append(
                        float(st["succeeded_wall_s"]))
                tel = st["telemetry"]
                if tel and tel.get("samples"):
                    self.learned.observe(
                        kind_key,
                        cpus=(tel.get("cpu_pct_peak") or 0.0) / 100.0,
                        memory_gb=(tel.get("rss_peak_mb") or 0.0)
                        / 1024.0)
                # a win recorded but no promote: the scheduler died
                # between the win and the rename — finish the promotion
                if st["winner_ckpt_dir"] and not st["promoted"]:
                    orig = self._checkpoint_dir(rec.spec)
                    if orig and os.path.isdir(st["winner_ckpt_dir"]):
                        self._promote_dir(name, st["winner_ckpt_dir"],
                                          orig)
                continue
            if st["state"] == "Failed":
                self._resumed_done += 1
                if rec in self._queue:
                    self._queue.remove(rec)
                rec.state = JobState.FAILED
                rec.attempts = st["attempts"]
                rec.error = st["error"]
                continue
            # pending or running at crash time
            rec.attempts = st["attempts"]
            adopted_any = False
            for att, info in sorted(st["live"].items()):
                pid = info.get("pid")
                pid_start = info.get("pid_start")
                ranks = info.get("ranks")
                if ranks:
                    # a dead scheduler's gang is never adopted: its
                    # coordinator address and rank membership can't be
                    # reconstructed safely — kill every surviving rank
                    # and requeue the whole gang on the resume path
                    for rk in ranks:
                        rpid = rk.get("pid")
                        if rpid and _pid_alive(rpid, rk.get("pid_start")):
                            try:
                                os.kill(rpid, int(_signal.SIGKILL))
                            except OSError:
                                pass
                    self._orphans_requeued += 1
                    self.log.emit("orphan_requeued", job=name,
                                  attempt=int(att), pid=pid,
                                  gang=len(ranks))
                    continue
                if pid and _pid_alive(pid, pid_start):
                    eff = rec.spec.resources     # declared: safe bound
                    # pin to the node the attempt already runs on; a
                    # free pick could swap two orphans' nodes and leave
                    # the log claiming placements that never happened
                    node = self.pool.admit(eff, prefer=st["node"])
                    if node is None:
                        # inventory shrank under us: kill, fall through
                        # to the requeue path
                        try:
                            os.kill(pid, int(_signal.SIGKILL))
                        except OSError:
                            pass
                    else:
                        out_p = self.pvc.path(
                            f"logs/{name}.attempt{att}.out")
                        err_p = self.pvc.path(
                            f"logs/{name}.attempt{att}.err")
                        handle = _AdoptedHandle(pid, pid_start, out_p)
                        run = _Running(
                            rec=rec, attempt=int(att), node=node,
                            handle=handle, stdout_path=out_p,
                            stderr_path=err_p, stdout_fh=None,
                            stderr_fh=None,
                            started_t=float(info.get("t") or now),
                            resume=False, eff=eff,
                            speculative=bool(info.get("speculative")),
                            adopted=True,
                            ckpt_dir=info.get("ckpt_dir"))
                        with self._run_lock:
                            self._running.append(run)
                        rec.state = JobState.RUNNING
                        if rec.start_time is None:
                            rec.start_time = float(info.get("t") or now)
                        self._adopted += 1
                        adopted_any = True
                        self.log.emit("adopted", job=name,
                                      attempt=int(att), pid=pid,
                                      pid_start=pid_start, node=node,
                                      resources={
                                          "gpus": eff.gpus,
                                          "cpus": eff.cpus,
                                          "memory_gb": eff.memory_gb},
                                      ckpt_dir=info.get("ckpt_dir"))
                        continue
                self._orphans_requeued += 1
                self.log.emit("orphan_requeued", job=name,
                              attempt=int(att), pid=pid)
            if adopted_any and rec in self._queue:
                self._queue.remove(rec)
        return True

    # ---------------------------------------------------------------- run
    def run(self) -> Dict[str, JobRecord]:
        t0 = self.clock()
        self._sort_queue()
        resumed = self.resume and self._apply_resume(t0)
        if resumed:
            # campaign_resume continues the replayed campaign — a fresh
            # campaign_start would make replay discard its own history
            with self._run_lock:
                live_allocs = [
                    {"job": r.rec.spec.name, "attempt": r.attempt,
                     "placements": list(r.placements or [r.node]),
                     "resources": {
                         "gpus": (r.eff or r.rec.spec.resources).gpus,
                         "cpus": (r.eff or r.rec.spec.resources).cpus,
                         "memory_gb":
                             (r.eff or r.rec.spec.resources).memory_gb}}
                    for r in self._running]
            self.log.emit("campaign_resume", workers=self.workers,
                          jobs=len(self._queue) + len(self._running),
                          done=self._resumed_done,
                          adopted=self._adopted,
                          requeued=self._orphans_requeued,
                          nodes=len(self.pool.nodes),
                          inventory=self.pool.snapshot(),
                          live_allocs=live_allocs)
        else:
            self.log.emit("campaign_start", workers=self.workers,
                          jobs=len(self._queue),
                          nodes=len(self.pool.nodes),
                          placement=self.pool.policy.name,
                          inventory=self.pool.snapshot())
        # fail jobs that could never be placed, before anything runs
        # (a gang needs `gang` process slots at once: more ranks than
        # workers would block the queue head forever even on an
        # infinite inventory — unless gang_min lets it shrink)
        for rec in list(self._queue):
            self._ensure_placeable(rec, t0, initial=True)
        for rec in self._queue:
            self._queued_t[rec.spec.name] = t0
            self.log.emit("submitted", job=rec.spec.name,
                          priority=rec.spec.priority,
                          kind=rec.spec.env.get("RUN_KIND"),
                          gang=max(1, rec.spec.gang),
                          resources={
                              "gpus": rec.spec.resources.gpus,
                              "cpus": rec.spec.resources.cpus,
                              "memory_gb": rec.spec.resources.memory_gb})
        if self.telemetry:
            self._sampler = threading.Thread(target=self._sampler_loop,
                                             name="telemetry-sampler",
                                             daemon=True)
            self._sampler.start()
        try:
            self._loop()
        finally:
            self._stop.set()
            if self._sampler is not None:
                self._sampler.join(timeout=5.0)
        makespan = self.clock() - t0
        self._write_summary(makespan)
        self.log.emit("campaign_end", makespan_s=round(makespan, 3),
                      **{k: self.summary[k]
                         for k in ("jobs", "states", "preemptions",
                                   "wall_goodput")})
        self.log.close()
        return self.records

    def _loop(self) -> None:
        while self._queue or self._running:
            now = self.clock()
            # ---- elastic inventory: apply nodes.json rewrites, reap
            # drained-empty nodes, and let high-priority heads evict
            self._check_nodes_file(now)
            self._reap_drained()
            self._maybe_evict(now)
            # ---- admission: strict head-of-line within (-priority,
            # order) among backoff-eligible jobs; optional backfill past
            # a blocked head under the no-head-delay bound.  The worker
            # cap counts *processes*: a gang of N consumes N slots.
            progressed = True
            while progressed and self._procs_running() < self.workers:
                progressed = False
                eligible = [r for r in self._queue
                            if self._not_before.get(r.spec.name, 0.0)
                            <= now]
                if not eligible:
                    break
                head = eligible[0]
                head_gang = self._gang(head.spec)
                head_eff = self._effective(head.spec)
                if self._procs_running() + head_gang > self.workers:
                    # head blocked on process slots, not nodes: no
                    # backfill (a backfiller would hold the very slot
                    # the head is waiting for)
                    break
                if head_gang > 1:
                    placements = self.pool.admit_gang(head_eff, head_gang)
                    if placements is not None:
                        self._admit(head, placements[0], now,
                                    eff=head_eff, placements=placements)
                        progressed = True
                        continue
                else:
                    node = self.pool.admit(head_eff)
                    if node is not None:
                        self._admit(head, node, now, eff=head_eff)
                        progressed = True
                        continue
                if not self.backfill:
                    break
                # EASY reasoning models single-node release order; for a
                # gang head only the provably-disjoint rule is sound
                t_head = (None if head_gang > 1
                          else self._head_earliest_start(head_eff, now))
                for cand in eligible[1:]:
                    if cand.spec.gang > 1:
                        # gangs never backfill: an N-slot jump past a
                        # blocked head is exactly the starvation the
                        # bound exists to prevent
                        continue
                    if self._procs_running() >= self.workers:
                        break
                    eff_c = self._effective(cand.spec)
                    target = self.pool.peek_node(eff_c)
                    if target is None:
                        continue
                    # sound rule: the head could never use the
                    # candidate's target node, even empty
                    disjoint = not head_eff.fits(
                        target.spec.gpus, target.spec.cpus,
                        target.spec.memory_gb, target.spec.gpu_memory_gb)
                    est_c = self._est_wall(self._job_kind(cand.spec))
                    # EASY rule: the candidate's estimated finish lands
                    # before the head's earliest feasible start
                    easy_ok = (t_head is not None and est_c is not None
                               and now + est_c <= t_head)
                    if not (disjoint or easy_ok):
                        continue
                    node = self.pool.admit(eff_c)
                    if node is None:
                        continue
                    self._admit(cand, node, now, eff=eff_c,
                                backfill=True, head=head.spec.name,
                                head_bound=t_head)
                    progressed = True
                    break
            # ---- speculative duplicates into leftover capacity
            self._maybe_speculate(now)
            # ---- poll running attempts
            for run in list(self._running):
                rc = run.handle.poll()
                if rc is None:
                    # SIGTERM'd attempts that outlive the grace window
                    # are escalated to SIGKILL (pod-preemption contract)
                    self._escalate_overdue(run, now)
                    alive = now - run.started_t
                    name = run.rec.spec.name
                    kills = self._chaos_kills.get(name, 0)
                    # cheap membership/budget checks first; the
                    # checkpoint-dir scan (disk) only runs for live
                    # victims that still have kills left.  Speculative
                    # duplicates are not chaos victims.
                    victim = (self.chaos is not None
                              and not run.speculative
                              and not run.spec_loser
                              and name in self.chaos.kill_jobs
                              and kills < self.chaos.max_kills_per_job)
                    if victim and self.chaos.wants_kill(
                            name, kills, alive,
                            _published_checkpoints(
                                self._checkpoint_dir(run.rec.spec))):
                        self._chaos_kills[name] = kills + 1
                        if run.gang > 1:
                            # kill ONE rank (the last, not the
                            # coordinator) — the point of gang chaos is
                            # proving any member's death condemns and
                            # requeues the whole gang
                            victim_rank = run.gang - 1
                            self.log.emit("chaos_kill", job=name,
                                          attempt=run.attempt,
                                          signal=self.chaos.signal,
                                          rank=victim_rank)
                            run.handle.signal_rank(victim_rank,
                                                   self.chaos.signal)
                        else:
                            self.log.emit("chaos_kill", job=name,
                                          attempt=run.attempt,
                                          signal=self.chaos.signal)
                            run.handle.send_signal(self.chaos.signal)
                        if self.chaos.signal == int(_signal.SIGTERM):
                            # graceful chaos rides the same escalation
                            # clock as evictions
                            run.term_t = run.term_t or now
                            run.kill_reason = run.kill_reason or "chaos"
                    elif (self.attempt_timeout_s is not None
                            and alive > self.attempt_timeout_s
                            and not run.timed_out and not run.spec_loser):
                        run.timed_out = True
                        self.log.emit("timeout_kill", job=name,
                                      attempt=run.attempt,
                                      after_s=round(alive, 1))
                        run.handle.send_signal(int(_signal.SIGKILL))
                    continue
                with self._run_lock:
                    self._running.remove(run)
                self._finish_attempt(run, rc, now)
            if self._running:
                time.sleep(self.poll_s)
            elif self._queue:
                # nothing running and the whole queue is backing off:
                # idle-wait instead of hot-spinning on the clock
                time.sleep(self.poll_s)

    # ------------------------------------------------------------ summary
    def _write_summary(self, makespan: float) -> None:
        hists = self._attempt_history
        all_attempts = [a for h in hists.values() for a in h]
        useful = sum(a["wall_s"] for a in all_attempts
                     if a["outcome"] == "succeeded")
        lost = sum(a["wall_s"] for a in all_attempts
                   if a["outcome"] != "succeeded")
        salvaged = sum(a.get("resumed_from_step") or 0
                       for a in all_attempts if a["outcome"] == "succeeded")
        states: Dict[str, int] = {}
        for r in self.records.values():
            states[r.state.value] = states.get(r.state.value, 0) + 1
        waits = sorted(self.queue_waits)

        def pct(p: float) -> float:
            if not waits:
                return 0.0
            i = min(len(waits) - 1, int(round(p / 100 * (len(waits) - 1))))
            return round(waits[i], 4)

        n_preempted = sum(1 for a in all_attempts
                          if a["outcome"] == "preempted")
        n_timeout = sum(1 for a in all_attempts
                        if a["outcome"] == "timeout")
        n_evicted = sum(1 for a in all_attempts
                        if a["outcome"] == "evicted")
        n_spec_loss = sum(1 for a in all_attempts
                          if a["outcome"] == "speculation_loss")
        self.summary = {
            "workers": self.workers,
            "jobs": len(self.records),
            "states": states,
            "makespan_s": round(makespan, 3),
            "serial_attempt_wall_s": round(useful + lost, 3),
            "queue_wait_s": {"p50": pct(50), "p95": pct(95),
                             "max": pct(100),
                             "mean": round(sum(waits) / len(waits), 4)
                             if waits else 0.0},
            "attempts_total": len(all_attempts),
            # a timed-out or evicted attempt is lost work exactly like a
            # preempted one; all count here (each also reported alone)
            "preemptions": n_preempted + n_timeout + n_evicted,
            "timeouts": n_timeout,
            "evictions": n_evicted,
            "evict_signals": self._evict_signals,
            "chaos_kills": sum(self._chaos_kills.values()),
            "useful_attempt_wall_s": round(useful, 3),
            "lost_attempt_wall_s": round(lost, 3),
            "wall_goodput": round(useful / (useful + lost), 4)
            if useful + lost > 0 else 1.0,
            "steps_salvaged_by_resume": int(salvaged),
            "speedup_vs_serial": round((useful + lost) / makespan, 3)
            if makespan > 0 else 0.0,
            "speculation": {"launches": self._spec_launches,
                            "wins": self._spec_wins,
                            "losses": n_spec_loss,
                            "loss_wall_s": round(self._spec_wall_lost,
                                                 3)},
            "backfills": self._backfills,
            "resumed": bool(self._resumed_done or self._adopted
                            or self._orphans_requeued),
            "resumed_done": self._resumed_done,
            "orphans_adopted": self._adopted,
            "orphans_requeued": self._orphans_requeued,
            "learned_requests": self.learned.snapshot(),
            "nodes": {"added": self._nodes_added,
                      "drained": self._nodes_drained,
                      "removed": self._nodes_removed,
                      "final": self.pool.snapshot()},
            "placement": self.pool.policy.name,
            # the utilization ledger is derived SOLELY from event-log
            # replay (not from in-memory counters), so `campaign status
            # --json` over the same log reproduces it bit-for-bit
            "utilization": self._replay_utilization(),
        }
        self.pvc.stage_json("results/_campaign_summary.json", self.summary)

    def _replay_utilization(self) -> Optional[Dict[str, Any]]:
        try:
            with open(self.log.path, "r", encoding="utf-8") as fh:
                return replay_events(fh).get("utilization")
        except OSError:
            return None


# --------------------------------------------------------------------------
# Status view
# --------------------------------------------------------------------------
def find_events_file(path) -> Optional[Path]:
    """Resolve a ``campaign status`` target: an events file, or a
    directory searched (newest-first) for ``events.jsonl``."""
    p = Path(path)
    if p.is_file():
        return p
    if p.is_dir():
        cands = sorted(p.rglob("events.jsonl"),
                       key=lambda q: q.stat().st_mtime, reverse=True)
        if cands:
            return cands[0]
    return None


def format_status(state: Dict[str, Any]) -> str:
    """Human-readable table for ``python -m repro.launch campaign
    status`` from a :func:`replay_events` result."""
    lines = []
    jobs = state["jobs"]
    width = max([len(n) for n in jobs] + [4])

    def gang_cell(st: Dict[str, Any]) -> str:
        # a gang job is ONE row; this cell carries the per-rank view of
        # its newest attempt: "run" while alive, the exit code once dead
        if int(st.get("gang") or 1) <= 1:
            return "-"
        ranks = st.get("ranks") or {}
        parts = []
        for rk in sorted(ranks, key=int):
            rc = ranks[rk].get("returncode")
            parts.append(f"{rk}:{'run' if rc is None else rc}")
        return f"{st['gang']}[{' '.join(parts)}]" if parts \
            else str(st["gang"])

    lines.append(f"{'job':<{width}}  {'state':<10} {'attempts':>8} "
                 f"{'preempt':>7} {'evict':>5} {'resumed@':>8} "
                 f"{'rss_mb':>7} "
                 f"{'cpu%':>6} {'obs/req':>7}  {'gang':<14} node")
    for name in sorted(jobs):
        st = jobs[name]
        resumed = st["resumed_from_step"]
        tel = st.get("telemetry") or {}
        ratio = st.get("declared_vs_observed") or {}
        rss = tel.get("rss_peak_mb")
        cpu = tel.get("cpu_pct_mean")
        obs = ratio.get("cpus")
        gcell = gang_cell(st)
        if st.get("gang_shrunk_from"):
            inner = gcell if gcell != "-" else str(st.get("gang") or 1)
            gcell = f"{st['gang_shrunk_from']}->{inner}"
        lines.append(
            f"{name:<{width}}  {st['state']:<10} {st['attempts']:>8} "
            f"{st['preemptions']:>7} "
            f"{st.get('evictions') or 0:>5} "
            f"{('-' if resumed is None else resumed):>8} "
            f"{('-' if rss is None else round(rss)):>7} "
            f"{('-' if cpu is None else round(cpu)):>6} "
            f"{('-' if obs is None else obs):>7}  "
            f"{gcell:<14} "
            f"{st['node'] or '-'}")
    tail = (f"{len(jobs)} jobs {state['counts']} workers={state['workers']} "
            f"ended={state['ended']}")
    nodes = state.get("nodes") or {}
    if nodes:
        draining = sum(1 for n in nodes.values() if n.get("draining"))
        tail += f" nodes={len(nodes)}"
        if draining:
            tail += f"({draining} draining)"
    if state["makespan_s"] is not None:
        tail += f" makespan_s={state['makespan_s']}"
    if state.get("resumes"):
        tail += f" resumes={state['resumes']}"
    util = (state.get("utilization") or {}).get("cluster")
    if util:
        tail += (f" gpu_util={util['busy_gpu_util']}"
                 f"(goodput {util['goodput_gpu_util']})")
    if not state["consistent"]:
        tail += f"  INCONSISTENT: {state['violations']}"
    lines.append(tail)
    return "\n".join(lines)
