"""Real concurrent campaign execution — the multi-process counterpart of
:meth:`Orchestrator.run_local`'s sequential loop and the execution-layer
realization of what :class:`repro.core.scheduler.ClusterSim` only models.

:class:`CampaignExecutor` launches every pending job as a

    python -m repro.launch run <kind> --arch ... --key value ...

subprocess (the container semantics of a Kubernetes Job: the child sees
only its spec, rebuilt from CLI flags, and prints a RunReport JSON), with

* **resource-aware admission** — a :class:`ResourcePool` over the same
  :class:`~repro.core.scheduler.NodeSpec` inventory the cluster sim
  schedules against: a job is admitted only when a worker slot is free
  *and* some node has the CPUs / memory / devices its
  :class:`~repro.core.jobs.Resources` request, FIFO within priority
  (``JobSpec.priority``, higher first);
* **real preemption** — an optional :class:`ChaosSpec` SIGKILLs running
  workers mid-step; a killed attempt is re-admitted with the job's
  ``retry_env`` overlay (``resume=true`` for train), so PR 3's
  CheckpointManager restores it from the last durable checkpoint;
* **per-run capture** — stdout/stderr per attempt under ``logs/``, the
  final RunReport plus full attempt history (incl. ``resumed_from_step``
  and goodput/lost-work accounting) under ``results/``;
* **a durable JSONL event log** (``campaign/events.jsonl``, fsynced per
  event) that powers ``python -m repro.launch campaign status`` and
  replays to a consistent terminal state after any crash.

The subprocess spawn is injectable (``spawn=``) so schedulers and chaos
can be exercised hermetically in tests without paying a jax import per
job.
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import signal as _signal
import subprocess
import sys
import time
from pathlib import Path
from typing import (Any, Callable, Dict, IO, List, Mapping, Optional,
                    Sequence, Tuple)

from repro.core.artifacts import PersistentVolume, S3Store
from repro.core.jobs import JobRecord, JobSpec, JobState, Resources
from repro.core.scheduler import NodeSpec

EVENTS_REL = "campaign/events.jsonl"
_CKPT_PREFIX = "step_"


# --------------------------------------------------------------------------
# Resource-aware admission
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _FreeNode:
    spec: NodeSpec
    name: str
    gpus_free: int = 0
    cpus_free: int = 0
    mem_free: float = 0.0

    def __post_init__(self):
        self.gpus_free = self.spec.gpus
        self.cpus_free = self.spec.cpus
        self.mem_free = self.spec.memory_gb


class ResourcePool:
    """Free-capacity accounting over a :class:`NodeSpec` inventory.

    The executor admits through :meth:`admit` (best-fit: smallest
    sufficient GPU memory, then fewest free devices — the cluster sim's
    placement rule) and returns capacity through :meth:`release`.  The
    pool is the single source of truth for the "never oversubscribe a
    node" invariant; both methods raise if it would be violated.
    """

    def __init__(self, inventory: Sequence[NodeSpec]):
        self.nodes: List[_FreeNode] = []
        for spec in inventory:
            for i in range(spec.count):
                self.nodes.append(_FreeNode(spec, f"{spec.name}-{i:03d}"))
        if not self.nodes:
            raise ValueError("empty inventory")

    def fits_when_empty(self, res: Resources) -> bool:
        """Could this request *ever* be placed?  Guards against queueing
        a job that would wait forever (the executor fails it instead)."""
        return any(res.fits(n.spec.gpus, n.spec.cpus, n.spec.memory_gb,
                            n.spec.gpu_memory_gb) for n in self.nodes)

    def admit(self, res: Resources) -> Optional[str]:
        cands = [n for n in self.nodes
                 if res.fits(n.gpus_free, n.cpus_free, n.mem_free,
                             n.spec.gpu_memory_gb)]
        if not cands:
            return None
        cands.sort(key=lambda n: (n.spec.gpu_memory_gb, n.gpus_free))
        node = cands[0]
        node.gpus_free -= res.gpus
        node.cpus_free -= res.cpus
        node.mem_free -= res.memory_gb
        if node.gpus_free < 0 or node.cpus_free < 0 or node.mem_free < -1e-9:
            raise RuntimeError(f"oversubscribed node {node.name}")
        return node.name

    def release(self, node_name: str, res: Resources) -> None:
        node = next(n for n in self.nodes if n.name == node_name)
        node.gpus_free += res.gpus
        node.cpus_free += res.cpus
        node.mem_free += res.memory_gb
        if (node.gpus_free > node.spec.gpus
                or node.cpus_free > node.spec.cpus
                or node.mem_free > node.spec.memory_gb + 1e-9):
            raise RuntimeError(f"release overflow on node {node.name}")

    def in_use(self) -> Dict[str, Tuple[int, int, float]]:
        return {n.name: (n.spec.gpus - n.gpus_free,
                         n.spec.cpus - n.cpus_free,
                         n.spec.memory_gb - n.mem_free)
                for n in self.nodes}


def local_inventory(workers: int, jobs: Sequence[JobSpec]) -> List[NodeSpec]:
    """Default inventory for local execution: one node per worker, each
    sized to the largest single-job request — every worker slot fits
    exactly one job, so admission degenerates to the worker cap while
    still flowing through the resource accounting."""
    gpus = max([j.resources.gpus for j in jobs] or [1])
    cpus = max([j.resources.cpus for j in jobs] or [1])
    mem = max([j.resources.memory_gb for j in jobs] or [1.0])
    vram = max([j.resources.gpu_memory_gb_min for j in jobs] or [0.0])
    return [NodeSpec("worker", gpus=gpus, gpu_memory_gb=vram, cpus=cpus,
                     memory_gb=mem, count=max(1, int(workers)))]


# --------------------------------------------------------------------------
# Fault injection
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ChaosSpec:
    """Inject real preemptions: SIGKILL selected jobs mid-run.

    ``kill_jobs`` names the victims; each is killed at most
    ``max_kills_per_job`` times.  A kill fires when the job's published
    checkpoint count reaches ``after_checkpoints`` (so the resume path is
    genuinely exercised) or — for jobs without a checkpoint dir, or when
    ``after_checkpoints == 0`` — after the attempt has been alive
    ``after_s`` seconds.
    """

    kill_jobs: Sequence[str] = ()
    after_checkpoints: int = 1
    after_s: float = 0.0
    signal: int = int(_signal.SIGKILL)
    max_kills_per_job: int = 1

    @classmethod
    def sample(cls, names: Sequence[str], fraction: float = 0.5,
               seed: int = 0, **kw) -> "ChaosSpec":
        """Random-but-deterministic victim selection over ``names``."""
        rng = random.Random(seed)
        k = min(len(names), max(1, round(len(names) * fraction))) \
            if names else 0
        return cls(kill_jobs=sorted(rng.sample(list(names), k)), **kw)

    def wants_kill(self, job_name: str, kills_done: int, alive_s: float,
                   published_ckpts: Optional[int]) -> bool:
        if job_name not in self.kill_jobs:
            return False
        if kills_done >= self.max_kills_per_job:
            return False
        if self.after_checkpoints > 0 and published_ckpts is not None:
            return published_ckpts >= self.after_checkpoints
        return self.after_s > 0 and alive_s >= self.after_s


def _published_checkpoints(directory: Optional[str]) -> Optional[int]:
    """Count published ``step_N`` checkpoints without importing jax (the
    executor process never loads an ML stack)."""
    if not directory:
        return None
    d = Path(directory)
    if not d.is_dir():
        return 0
    n = 0
    for p in d.iterdir():
        if (p.is_dir() and p.name.startswith(_CKPT_PREFIX)
                and (p / "manifest.json").exists()):
            n += 1
    return n


# --------------------------------------------------------------------------
# Subprocess plumbing
# --------------------------------------------------------------------------
def job_run_argv(job: JobSpec, *, resume: bool = False) -> List[str]:
    """Rebuild the ``repro.launch run`` argv from the job's env encoding
    (the manifest is the source of truth, exactly as on a cluster).  With
    ``resume=True`` the job's ``retry_env`` overlay is applied first —
    the same semantics ``run_local`` gives in-process retries."""
    from repro.api.spec import RunSpec, _encode_scalar  # lazy: api -> core
    env = dict(job.env)
    if resume and job.retry_env:
        env.update(job.retry_env)
    spec = RunSpec.from_env(env)
    argv = ["run", spec.kind, "--arch", spec.arch,
            "--seed", str(spec.seed), "--name", job.name]
    for key, val in sorted(spec.overrides.items()):
        argv.append(f"--{key}={_encode_scalar(val)}")
    return argv


def _src_path() -> str:
    # .../src/repro/core/executor.py -> .../src
    return str(Path(__file__).resolve().parents[2])


def _default_spawn(job: JobSpec, attempt: int, argv: List[str],
                   env: Dict[str, str], stdout: IO, stderr: IO):
    return subprocess.Popen(argv, env=env, stdout=stdout, stderr=stderr)


def parse_trailing_report(text: str) -> Optional[Dict[str, Any]]:
    """Extract the final RunReport JSON from a run's stdout (step logs
    precede it; ``RunReport.to_json`` prints an indent-1 object whose
    first line is ``{``)."""
    lines = text.splitlines()
    for i in range(len(lines) - 1, -1, -1):
        if lines[i].lstrip().startswith("{"):
            try:
                obj = json.loads("\n".join(lines[i:]))
            except ValueError:
                continue
            if isinstance(obj, dict) and "status" in obj:
                return obj
    return None


# --------------------------------------------------------------------------
# Durable event log + replay
# --------------------------------------------------------------------------
class EventLog:
    """Append-only JSONL, fsynced per event — survives a SIGKILL of the
    orchestrating process itself."""

    def __init__(self, path: Path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._fh = open(self.path, "a", encoding="utf-8")
        self._seq = 0

    def emit(self, event: str, **fields) -> Dict[str, Any]:
        rec = {"event": event, "seq": self._seq,
               "t": round(time.time(), 4), **fields}
        self._seq += 1
        self._fh.write(json.dumps(rec, sort_keys=True, default=str) + "\n")
        self._fh.flush()
        os.fsync(self._fh.fileno())
        return rec

    def close(self) -> None:
        self._fh.close()


TERMINAL_EVENTS = ("succeeded", "failed", "unschedulable")


def replay_events(lines) -> Dict[str, Any]:
    """Replay an event log into campaign state.  Accepts an iterable of
    JSONL lines (or parsed dicts); when the log holds several campaigns
    (appended runs), the **last** ``campaign_start`` wins.

    Returns ``{"jobs": {name: {...}}, "counts": {...}, "workers", "ended",
    "makespan_s", "consistent", "violations": [...]}`` — ``consistent``
    asserts the executor's bookkeeping invariants: monotonic per-job
    states, one terminal event per job, and (for ended campaigns)
    conservation: submitted == succeeded + failed + unschedulable.
    """
    events: List[Dict[str, Any]] = []
    for ln in lines:
        if isinstance(ln, (bytes, str)):
            ln = ln.strip()
            if not ln:
                continue
            try:
                ln = json.loads(ln)
            except ValueError:
                continue   # half-written trailing line after a crash
        events.append(ln)
    # keep only the newest campaign
    starts = [i for i, e in enumerate(events)
              if e.get("event") == "campaign_start"]
    if starts:
        events = events[starts[-1]:]

    jobs: Dict[str, Dict[str, Any]] = {}
    violations: List[str] = []
    meta: Dict[str, Any] = {"workers": None, "ended": False,
                            "makespan_s": None}
    for e in events:
        kind = e.get("event")
        if kind == "campaign_start":
            meta["workers"] = e.get("workers")
            continue
        if kind == "campaign_end":
            meta["ended"] = True
            meta["makespan_s"] = e.get("makespan_s")
            continue
        name = e.get("job")
        if name is None:
            continue
        st = jobs.setdefault(name, {
            "state": "Pending", "attempts": 0, "node": None,
            "preemptions": 0, "chaos_kills": 0,
            "resumed_from_step": None, "error": None})
        if kind == "submitted":
            st["priority"] = e.get("priority", 0)
        elif kind == "admitted":
            if st["state"] in ("Succeeded", "Failed"):
                violations.append(f"{name}: admitted after terminal state")
            st["state"] = "Running"
            st["node"] = e.get("node")
            st["attempts"] = max(st["attempts"], int(e.get("attempt", 0)))
        elif kind == "chaos_kill":
            st["chaos_kills"] += 1
        elif kind == "preempted":
            st["preemptions"] += 1
        elif kind in TERMINAL_EVENTS:
            if st["state"] in ("Succeeded", "Failed"):
                violations.append(f"{name}: second terminal event {kind}")
            st["state"] = "Failed" if kind != "succeeded" else "Succeeded"
            if kind == "succeeded":
                st["resumed_from_step"] = e.get("resumed_from_step")
            else:
                st["error"] = e.get("error")
    counts: Dict[str, int] = {}
    for st in jobs.values():
        counts[st["state"]] = counts.get(st["state"], 0) + 1
    if meta["ended"]:
        nonterminal = [n for n, st in jobs.items()
                       if st["state"] not in ("Succeeded", "Failed")]
        if nonterminal:
            violations.append(
                f"campaign ended with non-terminal jobs: {nonterminal}")
    return {"jobs": jobs, "counts": counts, **meta,
            "consistent": not violations, "violations": violations}


# --------------------------------------------------------------------------
# The executor
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _Running:
    rec: JobRecord
    attempt: int
    node: str
    handle: Any
    stdout_path: Path
    stderr_path: Path
    stdout_fh: Optional[IO]
    stderr_fh: Optional[IO]
    started_t: float
    resume: bool
    cores: List[int] = dataclasses.field(default_factory=list)


class CampaignExecutor:
    """Run a campaign's pending jobs as concurrent subprocesses.

    Parameters
    ----------
    records:    the orchestrator's ``{name: JobRecord}`` (mutated in
                place — states, attempts, results).
    pvc:        :class:`PersistentVolume` for logs/results/events.
    s3:         optional :class:`S3Store`; succeeded results are exported.
    workers:    max concurrent subprocesses.
    inventory:  :class:`NodeSpec` sequence gating admission; default:
                :func:`local_inventory` (one max-request node per worker).
    chaos:      optional :class:`ChaosSpec` fault injection.
    worker_env: extra env vars for every subprocess (e.g. pinning each
                worker to one CPU thread for benchmark determinism).
    pin_cpus:   enforce the job's ``Resources.cpus`` request as a real
                CPU-affinity limit (the local analogue of a Kubernetes
                CPU limit): each worker slot gets a round-robin core set
                of that size, exported as ``REPRO_CPU_AFFINITY`` and
                applied by ``repro.launch`` before jax loads.  Linux
                only; silently off elsewhere.
    python:     interpreter for subprocesses (default ``sys.executable``).
    spawn:      injectable process factory for tests.
    attempt_timeout_s: kill attempts that exceed this wall time (counts
                as a failed attempt; retries still apply).
    """

    def __init__(self, records: Dict[str, JobRecord],
                 pvc: PersistentVolume, s3: Optional[S3Store] = None, *,
                 workers: int = 1,
                 inventory: Optional[Sequence[NodeSpec]] = None,
                 chaos: Optional[ChaosSpec] = None,
                 worker_env: Optional[Mapping[str, str]] = None,
                 pin_cpus: bool = False,
                 python: Optional[str] = None,
                 spawn: Optional[Callable] = None,
                 attempt_timeout_s: Optional[float] = None,
                 poll_s: float = 0.05):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.records = records
        self.pvc = pvc
        self.s3 = s3
        self.workers = int(workers)
        self.chaos = chaos
        self.worker_env = dict(worker_env or {})
        self.python = python or sys.executable
        self.spawn = spawn or _default_spawn
        self.attempt_timeout_s = attempt_timeout_s
        self.poll_s = poll_s
        pending = [r for r in records.values() if r.state == JobState.PENDING]
        self._order = {r.spec.name: i for i, r in enumerate(pending)}
        self.pool = ResourcePool(inventory if inventory is not None
                                 else local_inventory(workers,
                                                      [r.spec for r in pending]))
        self.pin_cpus = pin_cpus and hasattr(os, "sched_getaffinity")
        self._host_cpus = (sorted(os.sched_getaffinity(0))
                           if self.pin_cpus else [])
        # per-core count of running pinned attempts: new attempts take
        # the least-loaded cores, so concurrent jobs spread across the
        # host instead of stacking on one core
        self._core_load: Dict[int, int] = {c: 0 for c in self._host_cpus}
        self.log = EventLog(pvc.path(EVENTS_REL))
        # per-job bookkeeping
        self._queue: List[JobRecord] = list(pending)
        self._running: List[_Running] = []
        self._attempt_history: Dict[str, List[dict]] = {}
        self._chaos_kills: Dict[str, int] = {}
        self._queued_t: Dict[str, float] = {}
        self.queue_waits: List[float] = []
        self.summary: Dict[str, Any] = {}

    # ------------------------------------------------------------ helpers
    def _sort_queue(self) -> None:
        self._queue.sort(key=lambda r: (-r.spec.priority,
                                        self._order[r.spec.name]))

    def _child_env(self) -> Dict[str, str]:
        env = {**os.environ, **self.worker_env}
        src = _src_path()
        existing = env.get("PYTHONPATH", "")
        if src not in existing.split(os.pathsep):
            env["PYTHONPATH"] = (src + os.pathsep + existing
                                 if existing else src)
        return env

    def _checkpoint_dir(self, job: JobSpec) -> Optional[str]:
        return job.env.get("CHECKPOINT_DIR")

    # ---------------------------------------------------------- lifecycle
    def _start_attempt(self, rec: JobRecord, node: str, now: float) -> None:
        job = rec.spec
        rec.attempts += 1
        attempt = rec.attempts
        resume = attempt > 1 and bool(job.retry_env)
        argv = ([self.python, "-m", "repro.launch"]
                + job_run_argv(job, resume=resume))
        out_p = self.pvc.path(f"logs/{job.name}.attempt{attempt}.out")
        err_p = self.pvc.path(f"logs/{job.name}.attempt{attempt}.err")
        out_p.parent.mkdir(parents=True, exist_ok=True)
        out_fh = open(out_p, "wb")
        err_fh = open(err_p, "wb")
        env = self._child_env()
        cores: List[int] = []
        if self.pin_cpus and self._host_cpus:
            # the Resources.cpus request becomes a real affinity limit:
            # take the currently least-loaded cores (released when the
            # attempt exits), so concurrent jobs spread across the host
            need = max(1, min(job.resources.cpus, len(self._host_cpus)))
            cores = sorted(self._host_cpus,
                           key=lambda c: (self._core_load[c], c))[:need]
            for c in cores:
                self._core_load[c] += 1
            env["REPRO_CPU_AFFINITY"] = ",".join(str(c) for c in cores)
        handle = self.spawn(job, attempt, argv, env, out_fh, err_fh)
        self._running.append(_Running(
            rec=rec, attempt=attempt, node=node, handle=handle,
            stdout_path=out_p, stderr_path=err_p,
            stdout_fh=out_fh, stderr_fh=err_fh,
            started_t=now, resume=resume, cores=cores))
        self.log.emit("started", job=job.name, attempt=attempt,
                      pid=getattr(handle, "pid", None), resume=resume,
                      node=node)

    def _finish_attempt(self, run: _Running, rc: int, now: float) -> None:
        rec, job = run.rec, run.rec.spec
        for fh in (run.stdout_fh, run.stderr_fh):
            if fh is not None:
                try:
                    fh.close()
                except OSError:
                    pass
        wall = now - run.started_t
        self.pool.release(run.node, job.resources)
        for c in run.cores:
            self._core_load[c] -= 1
        rec.node = run.node
        report = None
        try:
            report = parse_trailing_report(
                run.stdout_path.read_text(errors="replace"))
        except OSError:
            pass
        hist = self._attempt_history.setdefault(job.name, [])
        self.log.emit("exited", job=job.name, attempt=run.attempt,
                      returncode=rc, wall_s=round(wall, 3))
        ok = rc == 0 and report is not None and report.get("status") != "failed"
        if ok:
            entry = {"attempt": run.attempt, "outcome": "succeeded",
                     "wall_s": round(wall, 3), "returncode": rc}
            resumed = (report.get("metrics") or {}).get("resumed_from_step")
            if resumed is not None:
                entry["resumed_from_step"] = int(resumed)
            hist.append(entry)
            rec.end_time = now
            rec.error = None
            rec.result = report
            rec.state = JobState.SUCCEEDED
            self.log.emit("succeeded", job=job.name, attempt=run.attempt,
                          resumed_from_step=entry.get("resumed_from_step"))
            self._stage_result(rec)
            return
        preempted = rc < 0
        error = (report or {}).get("error") or (
            f"killed by signal {-rc}" if preempted
            else f"exit code {rc}")
        hist.append({"attempt": run.attempt,
                     "outcome": "preempted" if preempted else "failed",
                     "wall_s": round(wall, 3), "returncode": rc,
                     "error": error})
        retryable = rec.attempts <= job.retries
        if preempted:
            self.log.emit("preempted", job=job.name, attempt=run.attempt,
                          signal=-rc, requeued=retryable)
        else:
            self.log.emit("attempt_failed", job=job.name,
                          attempt=run.attempt, error=error,
                          requeued=retryable)
        if retryable:
            self._queue.append(rec)
            self._queued_t[job.name] = now
            self._sort_queue()
        else:
            rec.end_time = now
            rec.error = error
            rec.result = report
            rec.state = JobState.FAILED
            self.log.emit("failed", job=job.name, error=error)
            self._stage_result(rec)

    def _stage_result(self, rec: JobRecord) -> None:
        job = rec.spec
        hist = self._attempt_history.get(job.name, [])
        payload = {
            "job": job.name, "state": rec.state.value,
            "attempts": rec.attempts, "attempt_history": hist,
            "wall_s": (rec.end_time - rec.start_time
                       if rec.end_time and rec.start_time else None),
            "node": rec.node,
            "chaos_kills": self._chaos_kills.get(job.name, 0),
            "error": rec.error, "result": rec.result,
        }
        self.pvc.stage_json(f"results/{job.name}.json", payload)
        if self.s3 is not None and rec.state == JobState.SUCCEEDED:
            self.s3.put_bytes(f"results/{job.name}.json",
                              json.dumps({"result": rec.result},
                                         default=str).encode())

    # ---------------------------------------------------------------- run
    def run(self) -> Dict[str, JobRecord]:
        t0 = time.time()
        self._sort_queue()
        self.log.emit("campaign_start", workers=self.workers,
                      jobs=len(self._queue),
                      nodes=len(self.pool.nodes))
        # fail jobs that could never be placed, before anything runs
        for rec in list(self._queue):
            if not self.pool.fits_when_empty(rec.spec.resources):
                self._queue.remove(rec)
                rec.state = JobState.FAILED
                rec.error = ("unschedulable: resource request fits no "
                             "node in the inventory")
                self.log.emit("unschedulable", job=rec.spec.name,
                              error=rec.error)
                self._stage_result(rec)
        for rec in self._queue:
            self._queued_t[rec.spec.name] = t0
            self.log.emit("submitted", job=rec.spec.name,
                          priority=rec.spec.priority,
                          kind=rec.spec.env.get("RUN_KIND"))

        while self._queue or self._running:
            now = time.time()
            # ---- admission: highest priority first, backfill what fits
            admitted_any = True
            while admitted_any and len(self._running) < self.workers:
                admitted_any = False
                for rec in list(self._queue):
                    node = self.pool.admit(rec.spec.resources)
                    if node is None:
                        continue
                    self._queue.remove(rec)
                    wait = now - self._queued_t[rec.spec.name]
                    if rec.attempts == 0:     # PENDING -> RUNNING once
                        rec.state = JobState.RUNNING
                        rec.start_time = now
                        self.queue_waits.append(wait)
                    self.log.emit("admitted", job=rec.spec.name, node=node,
                                  attempt=rec.attempts + 1,
                                  queue_wait_s=round(wait, 3))
                    self._start_attempt(rec, node, now)
                    admitted_any = True
                    break
            # ---- poll running attempts
            for run in list(self._running):
                rc = run.handle.poll()
                if rc is None:
                    alive = now - run.started_t
                    name = run.rec.spec.name
                    kills = self._chaos_kills.get(name, 0)
                    # cheap membership/budget checks first; the
                    # checkpoint-dir scan (disk) only runs for live
                    # victims that still have kills left
                    victim = (self.chaos is not None
                              and name in self.chaos.kill_jobs
                              and kills < self.chaos.max_kills_per_job)
                    if victim and self.chaos.wants_kill(
                            name, kills, alive,
                            _published_checkpoints(
                                self._checkpoint_dir(run.rec.spec))):
                        self._chaos_kills[name] = kills + 1
                        self.log.emit("chaos_kill", job=run.rec.spec.name,
                                      attempt=run.attempt,
                                      signal=self.chaos.signal)
                        run.handle.send_signal(self.chaos.signal)
                    elif (self.attempt_timeout_s is not None
                            and alive > self.attempt_timeout_s):
                        self.log.emit("timeout_kill", job=run.rec.spec.name,
                                      attempt=run.attempt,
                                      after_s=round(alive, 1))
                        run.handle.send_signal(int(_signal.SIGKILL))
                    continue
                self._running.remove(run)
                self._finish_attempt(run, rc, now)
            if self._running:
                time.sleep(self.poll_s)
        makespan = time.time() - t0
        self._write_summary(makespan)
        self.log.emit("campaign_end", makespan_s=round(makespan, 3),
                      **{k: self.summary[k]
                         for k in ("jobs", "states", "preemptions",
                                   "wall_goodput")})
        self.log.close()
        return self.records

    # ------------------------------------------------------------ summary
    def _write_summary(self, makespan: float) -> None:
        hists = self._attempt_history
        all_attempts = [a for h in hists.values() for a in h]
        useful = sum(a["wall_s"] for a in all_attempts
                     if a["outcome"] == "succeeded")
        lost = sum(a["wall_s"] for a in all_attempts
                   if a["outcome"] != "succeeded")
        salvaged = sum(a.get("resumed_from_step") or 0
                       for a in all_attempts if a["outcome"] == "succeeded")
        states: Dict[str, int] = {}
        for r in self.records.values():
            states[r.state.value] = states.get(r.state.value, 0) + 1
        waits = sorted(self.queue_waits)

        def pct(p: float) -> float:
            if not waits:
                return 0.0
            i = min(len(waits) - 1, int(round(p / 100 * (len(waits) - 1))))
            return round(waits[i], 4)

        self.summary = {
            "workers": self.workers,
            "jobs": len(self.records),
            "states": states,
            "makespan_s": round(makespan, 3),
            "serial_attempt_wall_s": round(useful + lost, 3),
            "queue_wait_s": {"p50": pct(50), "p95": pct(95),
                             "max": pct(100),
                             "mean": round(sum(waits) / len(waits), 4)
                             if waits else 0.0},
            "attempts_total": len(all_attempts),
            "preemptions": sum(1 for a in all_attempts
                               if a["outcome"] == "preempted"),
            "chaos_kills": sum(self._chaos_kills.values()),
            "useful_attempt_wall_s": round(useful, 3),
            "lost_attempt_wall_s": round(lost, 3),
            "wall_goodput": round(useful / (useful + lost), 4)
            if useful + lost > 0 else 1.0,
            "steps_salvaged_by_resume": int(salvaged),
            "speedup_vs_serial": round((useful + lost) / makespan, 3)
            if makespan > 0 else 0.0,
        }
        self.pvc.stage_json("results/_campaign_summary.json", self.summary)


# --------------------------------------------------------------------------
# Status view
# --------------------------------------------------------------------------
def find_events_file(path) -> Optional[Path]:
    """Resolve a ``campaign status`` target: an events file, or a
    directory searched (newest-first) for ``events.jsonl``."""
    p = Path(path)
    if p.is_file():
        return p
    if p.is_dir():
        cands = sorted(p.rglob("events.jsonl"),
                       key=lambda q: q.stat().st_mtime, reverse=True)
        if cands:
            return cands[0]
    return None


def format_status(state: Dict[str, Any]) -> str:
    """Human-readable table for ``python -m repro.launch campaign
    status`` from a :func:`replay_events` result."""
    lines = []
    jobs = state["jobs"]
    width = max([len(n) for n in jobs] + [4])
    lines.append(f"{'job':<{width}}  {'state':<10} {'attempts':>8} "
                 f"{'preempt':>7} {'resumed@':>8}  node")
    for name in sorted(jobs):
        st = jobs[name]
        resumed = st["resumed_from_step"]
        lines.append(
            f"{name:<{width}}  {st['state']:<10} {st['attempts']:>8} "
            f"{st['preemptions']:>7} "
            f"{('-' if resumed is None else resumed):>8}  "
            f"{st['node'] or '-'}")
    tail = (f"{len(jobs)} jobs {state['counts']} workers={state['workers']} "
            f"ended={state['ended']}")
    if state["makespan_s"] is not None:
        tail += f" makespan_s={state['makespan_s']}"
    if not state["consistent"]:
        tail += f"  INCONSISTENT: {state['violations']}"
    lines.append(tail)
    return "\n".join(lines)
