"""Artifact stores: directory-backed PersistentVolume (the paper stages
datasets in PVCs) and S3Store (the paper copies every trained model to S3
after training "to ensure their later availability for evaluation")."""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, List, Optional


class PersistentVolume:
    """A named mount with quota accounting, like a Nautilus PVC."""

    def __init__(self, root: str, name: str = "repro-data",
                 quota_gb: Optional[float] = None):
        self.name = name
        self.root = (Path(root) / name).resolve()
        self.root.mkdir(parents=True, exist_ok=True)
        self.quota_gb = quota_gb

    def path(self, rel: str) -> Path:
        p = (self.root / rel).resolve()
        if not str(p).startswith(str(self.root.resolve())):
            raise ValueError(f"path escapes volume: {rel}")
        return p

    def stage_bytes(self, rel: str, data: bytes) -> Path:
        self._check_quota(len(data))
        p = self.path(rel)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_bytes(data)
        return p

    def stage_json(self, rel: str, obj: Any) -> Path:
        return self.stage_bytes(rel, json.dumps(obj, indent=1,
                                                default=str).encode())

    def read_bytes(self, rel: str) -> bytes:
        return self.path(rel).read_bytes()

    def exists(self, rel: str) -> bool:
        return self.path(rel).exists()

    def usage_bytes(self) -> int:
        return sum(f.stat().st_size for f in self.root.rglob("*")
                   if f.is_file())

    def _check_quota(self, incoming: int):
        if self.quota_gb is not None:
            if (self.usage_bytes() + incoming) > self.quota_gb * 1e9:
                raise IOError(f"PVC {self.name} quota exceeded "
                              f"({self.quota_gb} GB)")

    def listdir(self, rel: str = ".") -> List[str]:
        base = self.path(rel)
        return sorted(str(p.relative_to(self.root))
                      for p in base.rglob("*") if p.is_file())


class S3Store:
    """S3-shaped object store backed by a directory: put/get/list with
    ETag-style content hashes."""

    def __init__(self, root: str, bucket: str = "repro-models"):
        self.bucket = bucket
        self.root = (Path(root) / bucket).resolve()
        self.root.mkdir(parents=True, exist_ok=True)

    def _key_path(self, key: str) -> Path:
        p = (self.root / key.lstrip("/")).resolve()
        if not str(p).startswith(str(self.root.resolve())):
            raise ValueError(f"bad key {key}")
        return p

    def put_bytes(self, key: str, data: bytes) -> str:
        p = self._key_path(key)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_bytes(data)
        return hashlib.md5(data).hexdigest()

    def put_file(self, key: str, local: os.PathLike) -> str:
        p = self._key_path(key)
        p.parent.mkdir(parents=True, exist_ok=True)
        shutil.copyfile(local, p)
        return hashlib.md5(Path(local).read_bytes()).hexdigest()

    def get_bytes(self, key: str) -> bytes:
        return self._key_path(key).read_bytes()

    def exists(self, key: str) -> bool:
        return self._key_path(key).exists()

    def list(self, prefix: str = "") -> List[str]:
        out = []
        for p in self.root.rglob("*"):
            if p.is_file():
                k = str(p.relative_to(self.root))
                if k.startswith(prefix):
                    out.append(k)
        return sorted(out)
