"""Heterogeneous-cluster discrete-event scheduler simulation.

The paper's value proposition is cluster-level: 234 models / 4,040 hours of
compute run *in parallel* on Nautilus ("over five and a half months if this
compute were to be performed on a single server").  :class:`ClusterSim`
reproduces that accounting: given a node inventory (modeled on Nautilus's
heterogeneous GPU fleet, GTX-1080 11 GB through A100 80 GB) and a set of
jobs with resource requests and durations, it simulates placement,
queueing, optional preemption, and reports makespan and utilization —
deterministically.

This is also the planning tool the TPU port uses: the same JobSpecs can be
scheduled against a v5e-pod inventory to size an experiment campaign
before submitting.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.jobs import JobRecord, JobSpec, JobState, Resources


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    name: str
    gpus: int
    gpu_memory_gb: float
    cpus: int
    memory_gb: float
    count: int = 1

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def node_spec_from_dict(d: Dict[str, object]) -> NodeSpec:
    """A single inventory entry from its JSON form (``to_dict`` inverse;
    missing optionals default)."""
    return NodeSpec(
        name=str(d["name"]),
        gpus=int(d.get("gpus", 0)),
        gpu_memory_gb=float(d.get("gpu_memory_gb", 0.0)),
        cpus=int(d.get("cpus", 1)),
        memory_gb=float(d.get("memory_gb", 1.0)),
        count=int(d.get("count", 1)))


def node_specs_from_json(obj: object) -> List[NodeSpec]:
    """Parse the ``campaign/nodes.json`` control-file payload: either a
    bare list of node dicts or ``{"nodes": [...]}``.  Raises on any
    malformed entry so a torn write is rejected whole."""
    if isinstance(obj, dict):
        obj = obj.get("nodes")
    if not isinstance(obj, list):
        raise ValueError("nodes.json must be a list or {'nodes': [...]}")
    specs = [node_spec_from_dict(d) for d in obj]
    if len({s.name for s in specs}) != len(specs):
        raise ValueError("duplicate node names in nodes.json")
    return specs


# Modeled on the paper's description of Nautilus: "over 1300 NVIDIA GPUs and
# 19,000 CPU Cores", "GPUs on Nautilus range from as little as the NVIDIA
# GTX 1080 (11 GB) to as high as the NVIDIA A100 (80GB)".
NAUTILUS_INVENTORY: List[NodeSpec] = [
    NodeSpec("gtx1080-8g", gpus=8, gpu_memory_gb=11, cpus=64, memory_gb=256, count=45),
    NodeSpec("rtx2080ti-8g", gpus=8, gpu_memory_gb=11, cpus=64, memory_gb=256, count=30),
    NodeSpec("rtx3090-8g", gpus=8, gpu_memory_gb=24, cpus=96, memory_gb=384, count=45),
    NodeSpec("a40-4g", gpus=4, gpu_memory_gb=48, cpus=96, memory_gb=512, count=30),
    NodeSpec("v100-8g", gpus=8, gpu_memory_gb=32, cpus=96, memory_gb=384, count=15),
    NodeSpec("a100-8g", gpus=8, gpu_memory_gb=80, cpus=128, memory_gb=1024, count=12),
    NodeSpec("cpu-pool", gpus=0, gpu_memory_gb=0, cpus=96, memory_gb=512, count=40),
]
# totals: 1,296 GPUs and ~18.8k CPU cores — matching the paper's "over
# 1300 NVIDIA GPUs and 19,000 CPU Cores" era within rounding.

TPU_V5E_POD_INVENTORY: List[NodeSpec] = [
    NodeSpec("v5e-host", gpus=4, gpu_memory_gb=16, cpus=112, memory_gb=192,
             count=64),  # 64 hosts x 4 chips = one 256-chip pod
]


class LearnedRequests:
    """Observed-usage admission model: declared resource requests are
    habitually padded (the gap "Benchmarking Resource Usage" measures on
    real clusters), so the executor records each completed attempt's
    peak CPU cores and RSS per job *kind* and, once ``min_samples``
    attempts of a kind have completed, admits later jobs of that kind at
    the p95 of observed peaks instead of the declared number.

    The declared request stays a hard **ceiling** (a job never gets
    admitted with more than it asked for) and there are floors of one
    core / ``mem_floor_gb``, so the effective request always satisfies
    ``floor <= effective <= declared`` — tightening requests can only
    *increase* packing, never oversubscribe a node.  GPUs are never
    learned: a device is held exclusively whether busy or not.
    """

    def __init__(self, min_samples: int = 3, percentile: float = 95.0,
                 mem_floor_gb: float = 0.25):
        self.min_samples = int(min_samples)
        self.percentile = float(percentile)
        self.mem_floor_gb = float(mem_floor_gb)
        self._cpu: Dict[str, List[float]] = {}
        self._mem: Dict[str, List[float]] = {}

    def observe(self, kind: str, *, cpus: Optional[float] = None,
                memory_gb: Optional[float] = None) -> None:
        """Record one completed attempt's peak usage (cores, GB)."""
        if cpus is not None:
            self._cpu.setdefault(kind, []).append(float(cpus))
        if memory_gb is not None:
            self._mem.setdefault(kind, []).append(float(memory_gb))

    def _pct(self, vals: List[float]) -> float:
        vs = sorted(vals)
        i = min(len(vs) - 1,
                max(0, math.ceil(self.percentile / 100.0 * len(vs)) - 1))
        return vs[i]

    def effective(self, kind: str, declared: Resources) -> Resources:
        """The request to admit with: observed p95 clamped into
        ``[floor, declared]``; the declared request verbatim until
        ``min_samples`` observations of this kind exist."""
        cpu_s = self._cpu.get(kind, ())
        mem_s = self._mem.get(kind, ())
        cpus = declared.cpus
        mem = declared.memory_gb
        if len(cpu_s) >= self.min_samples:
            cpus = min(declared.cpus,
                       max(1, math.ceil(self._pct(list(cpu_s)))))
        if len(mem_s) >= self.min_samples:
            mem = min(declared.memory_gb,
                      max(self.mem_floor_gb,
                          round(self._pct(list(mem_s)), 3)))
        if cpus == declared.cpus and mem == declared.memory_gb:
            return declared
        return dataclasses.replace(declared, cpus=cpus, memory_gb=mem)

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """Per-kind learned state for summaries / ``campaign status``."""
        out: Dict[str, Dict[str, float]] = {}
        for kind in sorted(set(self._cpu) | set(self._mem)):
            entry: Dict[str, float] = {}
            cpu_s, mem_s = self._cpu.get(kind), self._mem.get(kind)
            if cpu_s:
                entry["cpu_samples"] = len(cpu_s)
                entry["cpu_p95_cores"] = round(self._pct(cpu_s), 3)
            if mem_s:
                entry["mem_samples"] = len(mem_s)
                entry["mem_p95_gb"] = round(self._pct(mem_s), 3)
            out[kind] = entry
        return out


@dataclasses.dataclass
class _Node:
    spec: NodeSpec
    name: str
    gpus_free: int = 0
    cpus_free: int = 0
    mem_free: float = 0.0

    def __post_init__(self):
        self.gpus_free = self.spec.gpus
        self.cpus_free = self.spec.cpus
        self.mem_free = self.spec.memory_gb


@dataclasses.dataclass
class SimResult:
    makespan_h: float
    total_gpu_hours: float
    total_wall_hours: float          # sum of per-job wall time
    records: List[JobRecord]
    gpu_utilization: float
    queue_wait_h_mean: float
    per_node_busy_h: Dict[str, float]
    # preemption accounting (checkpoint-aware): work redone because it
    # wasn't checkpointed, and the fraction of occupancy that was useful
    preemptions: int = 0
    lost_gpu_hours: float = 0.0
    goodput: float = 1.0
    # busy vs goodput, aligned with the executor's utilization ledger:
    # busy counts every occupied GPU-hour (useful or lost), goodput only
    # the hours that survived preemption — per node they reconcile as
    # sum(busy) == total_gpu_hours + lost_gpu_hours and
    # sum(goodput) == total_gpu_hours; ``gpu_utilization`` stays the
    # goodput flavor for backwards compatibility.
    per_node_goodput_h: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    busy_utilization: float = 0.0
    goodput_utilization: float = 0.0

    def speedup_vs_serial(self) -> float:
        return self.total_wall_hours / self.makespan_h if self.makespan_h else 0.0


class ClusterSim:
    """Deterministic discrete-event job scheduler.

    ``checkpoint_every_h > 0`` models jobs that checkpoint durably on
    that cadence: a preemption then loses only the work since the last
    checkpoint (the resubmitted job runs ``duration - retained`` hours)
    instead of the whole attempt — the difference between the paper's
    restart-from-scratch regime and this PR's resume subsystem.
    """

    def __init__(self, inventory: Sequence[NodeSpec] = None, seed: int = 0,
                 preemption_rate: float = 0.0,
                 checkpoint_every_h: float = 0.0,
                 placement=None):
        from repro.core.placement import get_placement_policy
        inventory = inventory if inventory is not None else NAUTILUS_INVENTORY
        self.nodes: List[_Node] = []
        for spec in inventory:
            for i in range(spec.count):
                self.nodes.append(_Node(spec, f"{spec.name}-{i:03d}"))
        self.rng = random.Random(seed)
        self.preemption_rate = preemption_rate
        self.checkpoint_every_h = checkpoint_every_h
        # same PlacementPolicy names as the real executor pool, so a
        # policy evaluated here is the policy `campaign run --placement`
        # executes (default best_fit = the historical hard-coded sort)
        self.placement = get_placement_policy(placement)

    def _find_node(self, spec: JobSpec) -> Optional[_Node]:
        cands = [n for n in self.nodes
                 if spec.resources.fits(n.gpus_free, n.cpus_free, n.mem_free,
                                        n.spec.gpu_memory_gb)]
        if not cands:
            return None
        return self.placement.order(cands, spec.resources)[0]

    def run(self, jobs: Sequence[JobSpec]) -> SimResult:
        records = [JobRecord(spec=j) for j in jobs]
        pending: List[Tuple[float, int]] = [(0.0, i) for i in range(len(records))]
        # event heap: (time, seq, kind, payload)
        events: List[Tuple[float, int, str, tuple]] = []
        seq = 0
        now = 0.0
        busy: Dict[str, float] = {n.name: 0.0 for n in self.nodes}
        good: Dict[str, float] = {n.name: 0.0 for n in self.nodes}
        queue_waits: List[float] = []
        ckpt = self.checkpoint_every_h
        # per-job retained progress (always a multiple of ckpt; stays 0
        # without checkpointing -> every retry recomputes from scratch)
        done = [0.0] * len(records)
        preemptions = 0
        lost_h = 0.0

        def try_schedule():
            nonlocal seq, preemptions, lost_h
            still = []
            # FIFO within priority, mirroring the real executor's
            # admission order (highest priority first, then submit
            # time, then submission index as the deterministic tie)
            for submit_t, idx in sorted(
                    pending,
                    key=lambda p: (-records[p[1]].spec.priority, p[0], p[1])):
                rec = records[idx]
                node = self._find_node(rec.spec)
                if node is None:
                    still.append((submit_t, idx))
                    continue
                node.gpus_free -= rec.spec.resources.gpus
                node.cpus_free -= rec.spec.resources.cpus
                node.mem_free -= rec.spec.resources.memory_gb
                rec.state = JobState.RUNNING
                rec.node = node.name
                rec.start_time = now
                rec.attempts += 1
                queue_waits.append(now - submit_t)
                gpus = rec.spec.resources.gpus
                work = rec.spec.duration_h - done[idx]   # remaining work
                preempt = (self.preemption_rate > 0
                           and rec.attempts <= rec.spec.retries
                           and self.rng.random() < self.preemption_rate)
                if preempt:
                    dur = work * self.rng.uniform(0.1, 0.9)
                    preemptions += 1
                    if ckpt > 0:      # resume keeps whole checkpoints
                        total = done[idx] + dur
                        retained = (total // ckpt) * ckpt
                        lost_h += (total - retained) * gpus
                        # checkpoints newly banked this attempt survive
                        good[node.name] += (retained - done[idx]) * gpus
                        done[idx] = retained
                    else:             # restart-from-scratch regime
                        lost_h += dur * gpus
                    heapq.heappush(events, (now + dur, seq, "preempt", (idx,)))
                else:
                    dur = work
                    good[node.name] += dur * gpus
                    heapq.heappush(events, (now + dur, seq, "finish", (idx,)))
                seq += 1
                busy[node.name] += dur * gpus
            pending[:] = still

        try_schedule()
        while events:
            now, _, kind, (idx,) = heapq.heappop(events)
            rec = records[idx]
            node = next(n for n in self.nodes if n.name == rec.node)
            node.gpus_free += rec.spec.resources.gpus
            node.cpus_free += rec.spec.resources.cpus
            node.mem_free += rec.spec.resources.memory_gb
            if kind == "finish":
                rec.state = JobState.SUCCEEDED
                rec.end_time = now
            else:  # preempted: resubmit (Nautilus opportunistic semantics)
                rec.state = JobState.PREEMPTED
                pending.append((now, idx))
            try_schedule()

        total_gpu_h = sum(r.spec.duration_h * r.spec.resources.gpus
                          for r in records)
        total_wall = sum(r.spec.duration_h for r in records)
        # availability denominator; guard CPU-only inventories too
        avail = now * sum(n.spec.gpus for n in self.nodes)
        util_good = total_gpu_h / avail if avail > 0 else 0.0
        util_busy = sum(busy.values()) / avail if avail > 0 else 0.0
        return SimResult(
            makespan_h=now,
            total_gpu_hours=total_gpu_h,
            total_wall_hours=total_wall,
            records=records,
            gpu_utilization=util_good,
            queue_wait_h_mean=(sum(queue_waits) / len(queue_waits)
                               if queue_waits else 0.0),
            per_node_busy_h=busy,
            preemptions=preemptions,
            lost_gpu_hours=lost_h,
            goodput=(total_gpu_h / (total_gpu_h + lost_h)
                     if total_gpu_h + lost_h > 0 else 1.0),
            per_node_goodput_h=good,
            busy_utilization=util_busy,
            goodput_utilization=util_good,
        )
