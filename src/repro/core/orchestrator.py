"""The automation layer that ties grids, templates, scheduling and
execution together — the paper's bash scripts + kubectl, as a library
(and exactly the "Kubernetes Python API … Python library or application
that can more easily and reliably manage jobs" the paper names as future
work).

Three execution modes:

* ``run_local``  — actually executes each job's Python payload (real JAX
  training at reduced scale), with retries and simulated preemption;
  manifests, per-experiment configs, logs and results land in the
  PersistentVolume, final artifacts in the S3Store — mirroring the paper's
  data flow (PVC staging -> train -> S3 export).
* ``run_cluster`` — *real* concurrent execution: every job runs as a
  ``python -m repro.launch run <kind>`` subprocess under resource-aware
  admission (see :class:`repro.core.executor.CampaignExecutor`), with
  durable event logging, real SIGKILL preemption, and checkpoint resume.
* ``simulate``   — schedules the same jobs on a ClusterSim inventory and
  returns makespan/utilization (used to validate the paper's Tables III/V
  accounting).
"""
from __future__ import annotations

import json
import time
import traceback
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.artifacts import PersistentVolume, S3Store
from repro.core.jobs import JobRecord, JobSpec, JobState
from repro.core.scheduler import ClusterSim, NodeSpec, SimResult
from repro.core.templating import render_job_manifest, to_yaml


def _registry_payload() -> Callable[..., Any]:
    """Container semantics for locally executed RunSpec jobs: the payload
    sees only its env, rebuilds the spec, and runs it through the
    ``repro.api`` registry; a failed RunReport raises so the
    orchestrator's retry/fault accounting still applies."""
    def payload(**env):
        from repro.api import RunSpec
        from repro.api import run as api_run
        report = api_run(RunSpec.from_env(env))
        if not report.ok:
            raise RuntimeError(report.error or f"{report.name} failed")
        return report
    return payload


def _resumed_from_step(result: Any) -> Optional[int]:
    """Pull ``resumed_from_step`` out of a payload result (RunReport or
    plain dict) without importing repro.api: the attempt history records
    where a resumed attempt picked up."""
    metrics = getattr(result, "metrics", None)
    if metrics is None and isinstance(result, dict):
        metrics = result.get("metrics", result)
    if isinstance(metrics, dict):
        val = metrics.get("resumed_from_step")
        if val is not None:
            return int(val)
    return None


def _jsonable(result: Any) -> Any:
    """Uniform serialization: RunReports (and anything exposing
    ``to_dict``) become plain dicts before landing in PVC/S3."""
    to_dict = getattr(result, "to_dict", None)
    return to_dict() if callable(to_dict) else result


class Orchestrator:
    def __init__(self, pvc: PersistentVolume, s3: Optional[S3Store] = None,
                 inventory: Optional[Sequence[NodeSpec]] = None,
                 seed: int = 0):
        self.pvc = pvc
        self.s3 = s3
        self.inventory = inventory
        self.seed = seed
        self.records: Dict[str, JobRecord] = {}

    # ------------------------------------------------------------------
    def submit(self, job: JobSpec) -> JobRecord:
        """Register a job: write its manifest + config to the PVC (the
        paper auto-generates all manifests before any submission)."""
        if job.name in self.records:
            raise ValueError(f"duplicate job name {job.name}")
        rec = JobRecord(spec=job, submit_time=time.time())
        self.records[job.name] = rec
        manifest = render_job_manifest(
            job.name, experiment=job.labels.get("experiment", "default"),
            env=job.env, gpus=job.resources.gpus, cpus=job.resources.cpus,
            memory_gb=job.resources.memory_gb, retries=job.retries)
        self.pvc.stage_bytes(f"manifests/{job.name}.yaml",
                             to_yaml(manifest).encode())
        return rec

    def submit_many(self, jobs: Sequence[JobSpec]) -> List[JobRecord]:
        return [self.submit(j) for j in jobs]

    def submit_runs(self, runs: Sequence[Any],
                    attach_payload: bool = False) -> List[JobRecord]:
        """Submit ``repro.api.RunSpec``s directly: each becomes a JobSpec
        whose manifest env is the spec's bash-style encoding.  With
        ``attach_payload`` the job executes through the runner registry
        (container semantics: the payload rebuilds the spec from env and
        returns a RunReport dict)."""
        jobs = []
        for run in runs:
            payload = _registry_payload() if attach_payload else None
            jobs.append(run.to_job(payload=payload))
        return self.submit_many(jobs)

    # ------------------------------------------------------------------
    def run_local(self, parallelism: int = 1,
                  fail_fast: bool = False) -> Dict[str, JobRecord]:
        """Execute payloads (in submission order; payloads run
        sequentially on this host, but `parallelism` drives simulated
        lane accounting — each job is placed on the earliest-free of
        `parallelism` lanes, and the resulting **simulated** makespan is
        recorded as ``simulated_makespan_s`` in
        ``results/_local_run_summary.json`` — never as ``makespan_s``,
        which is reserved for the *real* wall-clock campaign makespan
        :meth:`run_cluster` measures).

        State transitions are monotonic per job: PENDING -> RUNNING once,
        then exactly one final state after all attempts.  Every attempt
        is recorded — failures as ``logs/<job>.attempt<N>.log``, and the
        full per-attempt history in the job's result JSON.
        """
        if parallelism < 1:
            raise ValueError(f"parallelism must be >= 1, got {parallelism}")
        lanes = [0.0] * parallelism          # simulated busy-time per lane
        pending = [r for r in self.records.values()
                   if r.state == JobState.PENDING]
        for rec in pending:
            job = rec.spec
            rec.state = JobState.RUNNING     # PENDING -> RUNNING, once
            rec.start_time = time.time()
            attempt_history = []
            result, error = None, None
            for attempt in range(1 + job.retries):
                rec.attempts = attempt + 1
                t_attempt = time.time()
                # retries run with the resume overlay (when the job has
                # one): the payload restarts from its last checkpoint
                env = (job.env if attempt == 0 or not job.retry_env
                       else {**job.env, **job.retry_env})
                try:
                    result = job.payload(**env) if job.payload else None
                    error = None
                    entry = {"attempt": rec.attempts, "outcome": "succeeded",
                             "wall_s": time.time() - t_attempt}
                    resumed = _resumed_from_step(result)
                    if resumed is not None:
                        entry["resumed_from_step"] = resumed
                    attempt_history.append(entry)
                    break
                except Exception as e:  # noqa: BLE001 — job-level fault barrier
                    error = f"{type(e).__name__}: {e}"
                    attempt_history.append(
                        {"attempt": rec.attempts, "outcome": "failed",
                         "wall_s": time.time() - t_attempt, "error": error})
                    self.pvc.stage_bytes(
                        f"logs/{job.name}.attempt{rec.attempts}.log",
                        traceback.format_exc().encode())
                    if fail_fast:
                        rec.end_time = time.time()
                        rec.error = error
                        rec.state = JobState.FAILED
                        raise
            # RUNNING -> final, once, after the retry loop
            rec.end_time = time.time()
            rec.error = error
            rec.result = result
            rec.state = (JobState.SUCCEEDED if error is None
                         else JobState.FAILED)
            lane = min(range(parallelism), key=lanes.__getitem__)
            lanes[lane] += rec.end_time - rec.start_time
            rec.node = f"lane{lane}"
            payload_json = _jsonable(result)
            self.pvc.stage_json(
                f"results/{job.name}.json",
                {"job": job.name, "state": rec.state.value,
                 "attempts": rec.attempts,
                 "attempt_history": attempt_history,
                 "wall_s": rec.end_time - rec.start_time,
                 "lane": lane, "error": error, "result": payload_json})
            if self.s3 is not None and rec.state == JobState.SUCCEEDED:
                self.s3.put_bytes(
                    f"results/{job.name}.json",
                    json.dumps({"result": payload_json},
                               default=str).encode())
        if pending:
            self.pvc.stage_json("results/_local_run_summary.json", {
                "parallelism": parallelism,
                "jobs": len(pending),
                "serial_s": sum(lanes),
                # deliberately NOT named ``makespan_s``: that key means
                # real wall-clock in _campaign_summary.json /
                # BENCH_campaign.json, while this one is simulated lane
                # accounting — the names must never collide
                "simulated_makespan_s": max(lanes),
                "lane_busy_s": lanes,
            })
        return self.records

    # ------------------------------------------------------------------
    def run_cluster(self, workers: int = 1, *, inventory=None,
                    **executor_kw) -> Dict[str, JobRecord]:
        """Execute the pending jobs as **real concurrent subprocesses**
        (``python -m repro.launch run <kind>``), up to ``workers`` at a
        time, gated by resource-aware admission over ``inventory`` (the
        orchestrator's own inventory by default, else one
        max-request-sized node per worker).

        Preemption is real: a :class:`repro.core.executor.ChaosSpec`
        SIGKILLs selected runs mid-step and the executor re-admits them
        with the ``resume=true`` retry overlay so the CheckpointManager
        restores them.  Every attempt lands in the durable event log
        (``campaign/events.jsonl``) and per-job ``results/*.json``; the
        campaign summary (real wall-clock ``makespan_s``, queue-wait
        p50/p95, goodput/lost-work) in
        ``results/_campaign_summary.json``.

        Every other :class:`repro.core.executor.CampaignExecutor` knob
        forwards verbatim through ``executor_kw``: ``chaos=``,
        ``resume=True`` (scheduler-crash recovery: replay the event log,
        adopt live orphans, re-queue dead ones), ``speculate=``
        (straggler duplicates), ``backfill=True``, ``telemetry=``,
        retry-backoff tuning, ``attempt_timeout_s=``, injectable
        ``spawn``/``clock``/``learned``/``progress_fn``, etc.
        """
        from repro.core.executor import CampaignExecutor
        ex = CampaignExecutor(
            self.records, self.pvc, self.s3, workers=workers,
            inventory=inventory if inventory is not None else self.inventory,
            **executor_kw)
        ex.run()
        self.last_campaign_summary = ex.summary
        return self.records

    # ------------------------------------------------------------------
    def simulate(self, preemption_rate: float = 0.0,
                 checkpoint_every_h: float = 0.0,
                 placement=None) -> SimResult:
        """Schedule the submitted jobs on the cluster sim.  With
        ``checkpoint_every_h`` the jobs are modeled as durable-checkpoint
        trainers: preemption loses only the work since the last
        checkpoint, not the attempt (see :class:`ClusterSim`).
        ``placement`` selects a :class:`repro.core.placement
        .PlacementPolicy` by the same names ``run_cluster`` accepts."""
        sim = ClusterSim(self.inventory, seed=self.seed,
                         preemption_rate=preemption_rate,
                         checkpoint_every_h=checkpoint_every_h,
                         placement=placement)
        return sim.run([r.spec for r in self.records.values()])

    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        states = {}
        for r in self.records.values():
            states[r.state.value] = states.get(r.state.value, 0) + 1
        return {
            "jobs": len(self.records),
            "states": states,
            "manifests": len(self.pvc.listdir("manifests")),
        }
