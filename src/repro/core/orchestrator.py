"""The automation layer that ties grids, templates, scheduling and
execution together — the paper's bash scripts + kubectl, as a library
(and exactly the "Kubernetes Python API … Python library or application
that can more easily and reliably manage jobs" the paper names as future
work).

Two execution modes:

* ``run_local``  — actually executes each job's Python payload (real JAX
  training at reduced scale), with retries and simulated preemption;
  manifests, per-experiment configs, logs and results land in the
  PersistentVolume, final artifacts in the S3Store — mirroring the paper's
  data flow (PVC staging -> train -> S3 export).
* ``simulate``   — schedules the same jobs on a ClusterSim inventory and
  returns makespan/utilization (used to validate the paper's Tables III/V
  accounting).
"""
from __future__ import annotations

import json
import time
import traceback
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.artifacts import PersistentVolume, S3Store
from repro.core.jobs import JobRecord, JobSpec, JobState
from repro.core.scheduler import ClusterSim, NodeSpec, SimResult
from repro.core.templating import render_job_manifest, to_yaml


class Orchestrator:
    def __init__(self, pvc: PersistentVolume, s3: Optional[S3Store] = None,
                 inventory: Optional[Sequence[NodeSpec]] = None,
                 seed: int = 0):
        self.pvc = pvc
        self.s3 = s3
        self.inventory = inventory
        self.seed = seed
        self.records: Dict[str, JobRecord] = {}

    # ------------------------------------------------------------------
    def submit(self, job: JobSpec) -> JobRecord:
        """Register a job: write its manifest + config to the PVC (the
        paper auto-generates all manifests before any submission)."""
        if job.name in self.records:
            raise ValueError(f"duplicate job name {job.name}")
        rec = JobRecord(spec=job, submit_time=time.time())
        self.records[job.name] = rec
        manifest = render_job_manifest(
            job.name, experiment=job.labels.get("experiment", "default"),
            env=job.env, gpus=job.resources.gpus, cpus=job.resources.cpus,
            memory_gb=job.resources.memory_gb, retries=job.retries)
        self.pvc.stage_bytes(f"manifests/{job.name}.yaml",
                             to_yaml(manifest).encode())
        return rec

    def submit_many(self, jobs: Sequence[JobSpec]) -> List[JobRecord]:
        return [self.submit(j) for j in jobs]

    # ------------------------------------------------------------------
    def run_local(self, parallelism: int = 1,
                  fail_fast: bool = False) -> Dict[str, JobRecord]:
        """Execute payloads (in submission order; parallelism is simulated
        — payloads run sequentially on this host but scheduling/accounting
        treats `parallelism` lanes)."""
        pending = [r for r in self.records.values()
                   if r.state == JobState.PENDING]
        for rec in pending:
            job = rec.spec
            for attempt in range(1 + job.retries):
                rec.attempts = attempt + 1
                rec.state = JobState.RUNNING
                rec.start_time = time.time()
                try:
                    result = job.payload(**job.env) if job.payload else None
                    rec.result = result
                    rec.state = JobState.SUCCEEDED
                    rec.end_time = time.time()
                    self.pvc.stage_json(
                        f"results/{job.name}.json",
                        {"job": job.name, "attempts": rec.attempts,
                         "wall_s": rec.end_time - rec.start_time,
                         "result": result})
                    if self.s3 is not None:
                        self.s3.put_bytes(
                            f"results/{job.name}.json",
                            json.dumps({"result": result},
                                       default=str).encode())
                    break
                except Exception as e:  # noqa: BLE001 — job-level fault barrier
                    rec.error = f"{type(e).__name__}: {e}"
                    rec.state = JobState.FAILED
                    rec.end_time = time.time()
                    self.pvc.stage_bytes(
                        f"logs/{job.name}.attempt{attempt}.log",
                        traceback.format_exc().encode())
                    if fail_fast:
                        raise
        return self.records

    # ------------------------------------------------------------------
    def simulate(self, preemption_rate: float = 0.0) -> SimResult:
        sim = ClusterSim(self.inventory, seed=self.seed,
                         preemption_rate=preemption_rate)
        return sim.run([r.spec for r in self.records.values()])

    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        states = {}
        for r in self.records.values():
            states[r.state.value] = states.get(r.state.value, 0) + 1
        return {
            "jobs": len(self.records),
            "states": states,
            "manifests": len(self.pvc.listdir("manifests")),
        }
