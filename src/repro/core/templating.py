"""Manifest templating — the paper auto-generates its 288 Kubernetes YAML
files and per-experiment JSON configs with Jinja2; this is a dependency-free
equivalent: ``{{ var }}`` substitution (with dotted lookups) over strings
and nested structures, plus a minimal YAML emitter so manifests land on
disk in the same form the paper's automation submits."""
from __future__ import annotations

import re
from typing import Any, Dict, Mapping

_VAR = re.compile(r"\{\{\s*([\w.\[\]]+)\s*\}\}")


def _lookup(ctx: Mapping, dotted: str):
    cur: Any = ctx
    for part in dotted.split("."):
        m = re.match(r"(\w+)\[(\d+)\]$", part)
        if m:
            cur = cur[m.group(1)][int(m.group(2))]
        elif isinstance(cur, Mapping):
            cur = cur[part]
        else:
            cur = getattr(cur, part)
    return cur


def render_template(template, ctx: Mapping):
    """Recursively render {{ var }} placeholders in strings / dict / list
    structures.  A string that is exactly one placeholder keeps the looked-up
    value's type (so resource numbers stay numbers)."""
    if isinstance(template, str):
        whole = _VAR.fullmatch(template.strip())
        if whole:
            return _lookup(ctx, whole.group(1))
        return _VAR.sub(lambda m: str(_lookup(ctx, m.group(1))), template)
    if isinstance(template, Mapping):
        return {k: render_template(v, ctx) for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        return [render_template(v, ctx) for v in template]
    return template


JOB_TEMPLATE = {
    "apiVersion": "batch/v1",
    "kind": "Job",
    "metadata": {
        "name": "{{ name }}",
        "labels": {"experiment": "{{ experiment }}", "app": "repro"},
    },
    "spec": {
        "backoffLimit": "{{ retries }}",
        "template": {"spec": {
            "containers": [{
                "name": "{{ name }}",
                "image": "{{ image }}",
                "command": ["python", "-m", "{{ module }}"],
                "env": "{{ env_list }}",
                "resources": {"limits": {
                    "nvidia.com/gpu": "{{ gpus }}",
                    "cpu": "{{ cpus }}",
                    "memory": "{{ memory }}",
                }},
                "volumeMounts": [{"name": "data", "mountPath": "/data"}],
            }],
            "volumes": [{"name": "data",
                         "persistentVolumeClaim": {"claimName": "{{ pvc }}"}}],
            "restartPolicy": "Never",
        }},
    },
}


def render_job_manifest(name: str, *, experiment: str = "default",
                        module: str = "repro.launch.train",
                        image: str = "repro/trainer:latest",
                        env: Dict[str, str] = None,
                        gpus: int = 1, cpus: int = 4, memory_gb: float = 24,
                        retries: int = 3, pvc: str = "repro-data") -> dict:
    env = env or {}
    ctx = {
        "name": name, "experiment": experiment, "module": module,
        "image": image, "retries": retries, "gpus": gpus, "cpus": cpus,
        "memory": f"{memory_gb:g}Gi", "pvc": pvc,
        "env_list": [{"name": k, "value": str(v)}
                     for k, v in sorted(env.items())],
    }
    return render_template(JOB_TEMPLATE, ctx)


def to_yaml(obj, indent: int = 0) -> str:
    """Tiny YAML emitter (subset: dicts, lists, scalars)."""
    pad = "  " * indent
    if isinstance(obj, Mapping):
        if not obj:
            return pad + "{}"
        lines = []
        for k, v in obj.items():
            if isinstance(v, (Mapping, list)) and v:
                lines.append(f"{pad}{k}:")
                lines.append(to_yaml(v, indent + 1))
            else:
                lines.append(f"{pad}{k}: {_scalar(v)}")
        return "\n".join(lines)
    if isinstance(obj, list):
        if not obj:
            return pad + "[]"
        lines = []
        for v in obj:
            if isinstance(v, (Mapping, list)) and v:
                body = to_yaml(v, indent + 1)
                first, _, rest = body.partition("\n")
                lines.append(f"{pad}- {first.strip()}" + ("\n" + rest if rest else ""))
            else:
                lines.append(f"{pad}- {_scalar(v)}")
        return "\n".join(lines)
    return pad + _scalar(obj)


def _scalar(v) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if v is None:
        return "null"
    if isinstance(v, (int, float)):
        return f"{v:g}" if isinstance(v, float) else str(v)
    s = str(v)
    if re.search(r"[:#{}\[\],&*?|>'\"%@`]", s) or s != s.strip():
        return '"' + s.replace('"', '\\"') + '"'
    return s
