# The paper's primary contribution: cluster-scale experiment orchestration
# (grid expansion, templated job manifests, heterogeneous-resource
# scheduling, staged artifacts, dynamic batch sizing) — JAX/TPU-native.
from repro.core.jobs import JobSpec, JobState, Resources
from repro.core.placement import (PlacementPolicy, PLACEMENT_POLICIES,
                                  get_placement_policy)
from repro.core.experiment import ExperimentGrid, ExperimentSpec
from repro.core.templating import render_template, render_job_manifest
from repro.core.scheduler import (ClusterSim, LearnedRequests, NodeSpec,
                                  NAUTILUS_INVENTORY, node_spec_from_dict,
                                  node_specs_from_json)
from repro.core.orchestrator import Orchestrator
from repro.core.executor import (CampaignExecutor, ChaosSpec, ResourcePool,
                                 SpeculationSpec, replay_events)
from repro.core.artifacts import PersistentVolume, S3Store
from repro.core.autobatch import autobatch

__all__ = [
    "JobSpec", "JobState", "Resources",
    "PlacementPolicy", "PLACEMENT_POLICIES", "get_placement_policy",
    "ExperimentGrid", "ExperimentSpec",
    "render_template", "render_job_manifest",
    "ClusterSim", "LearnedRequests", "NodeSpec", "NAUTILUS_INVENTORY",
    "node_spec_from_dict", "node_specs_from_json",
    "Orchestrator", "CampaignExecutor", "ChaosSpec", "ResourcePool",
    "SpeculationSpec", "replay_events",
    "PersistentVolume", "S3Store", "autobatch",
]
