from repro.train.loop import Preemption, TrainLoop
from repro.train.precision import (POLICIES, Precision, cast_floating,
                                   get_precision)
from repro.train.step import (TrainState, init_train_state, make_eval_step,
                              make_train_step)

__all__ = ["TrainState", "make_train_step", "make_eval_step",
           "init_train_state", "TrainLoop", "Preemption",
           "Precision", "POLICIES", "get_precision", "cast_floating"]
