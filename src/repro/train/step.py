"""Training step: value_and_grad over the model loss + optimizer update,
with optional microbatch gradient accumulation (``lax.scan`` over
microbatches so peak activation memory is one microbatch).

The step factory owns the compilation of the hot path:

* ``make_train_step`` returns a **jitted** step with the ``TrainState``
  donated (``donate_argnums=(0,)``) — params and optimizer state are
  updated in place, like the serve engine's donated decode state, so a
  step allocates no second copy of the model.  The input state is dead
  after the call; callers must rebind (``state, m = step(state, batch)``).
* grad-norm and clipping share one global reduction: the squared-norm
  tree sum feeds both the ``grad_norm`` metric and the clip scale, so
  enabling clipping adds no extra pass over the gradients.
* a :class:`repro.train.precision.Precision` policy selects compute
  dtype (bf16 activations under ``"bf16"``) while master params,
  optimizer state, microbatch grad accumulation and the loss stay f32.

Pass ``jit_compile=False`` to get the bare python step (the sharded
launchers wrap it in their own ``jax.jit`` with explicit shardings).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import init_params, train_loss
from repro.optim import Optimizer, get_optimizer, constant
from repro.train.precision import Precision, get_precision


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def init_train_state(key, cfg: ArchConfig, optimizer: Optional[Optimizer] = None,
                     state_dtype=None) -> TrainState:
    optimizer = optimizer or get_optimizer(cfg.optimizer, state_dtype=state_dtype)
    params = init_params(key, cfg)
    return TrainState(params, optimizer.init(params),
                      jnp.zeros((), jnp.int32))


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int):
    def r(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape(n, B // n, *x.shape[1:])
    return jax.tree.map(r, batch)


def _global_sq_norm(grads) -> jnp.ndarray:
    """Single global reduction: sum of squared gradient entries (f32)."""
    return sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
               for g in jax.tree.leaves(grads))


def make_train_step(cfg: ArchConfig, optimizer: Optional[Optimizer] = None,
                    lr_schedule: Optional[Callable] = None,
                    remat: bool = True, microbatches: int = 1,
                    loss_chunk: int = 512,
                    precision: Union[str, Precision, None] = "f32",
                    grad_clip: Optional[float] = None,
                    donate: bool = True, jit_compile: bool = True):
    """Returns train_step(state, batch) -> (new_state, metrics).

    With ``jit_compile=True`` (default) the returned function is jitted
    with the state donated (when ``donate``): the caller's input state
    buffers are consumed by the step.  ``grad_clip`` clips the global
    gradient norm to the given value using the same reduction that
    produces the ``grad_norm`` metric.
    """
    optimizer = optimizer or get_optimizer(cfg.optimizer)
    lr_schedule = lr_schedule or constant(1e-4)
    prec = get_precision(precision)
    grad_dtype = jnp.dtype(prec.grad_dtype)

    def loss_fn(params, mb):
        return train_loss(params, cfg, mb, remat=remat, loss_chunk=loss_chunk,
                          compute_dtype=(prec.compute_dtype
                                         if prec.casts_compute else None))

    def train_step(state: TrainState, batch):
        params = state.params
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = _split_microbatches(batch, microbatches)

            def acc_step(carry, mb):
                tot_loss, acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(grad_dtype), acc, g)
                return (tot_loss + l, acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, grad_dtype), params)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), zeros), mbs)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        # one global reduction feeds both the metric and the clip scale
        gnorm = jnp.sqrt(_global_sq_norm(grads))
        if grad_clip is not None and grad_clip > 0:
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                grads)

        lr = lr_schedule(state.step)
        new_params, new_opt = optimizer.update(
            grads, state.opt_state, params, state.step, lr)
        metrics = {"loss": loss, "lr": lr, "grad_norm": gnorm}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    if jit_compile:
        train_step = jax.jit(train_step,
                             donate_argnums=(0,) if donate else ())
    return train_step


def make_eval_step(cfg: ArchConfig, loss_chunk: int = 512,
                   precision: Union[str, Precision, None] = "f32",
                   jit_compile: bool = True):
    """Returns eval_step(params, batch) -> scalar loss, jitted by default
    (the seed version never compiled the eval path)."""
    prec = get_precision(precision)

    def eval_step(params, batch):
        return train_loss(params, cfg, batch, remat=False,
                          loss_chunk=loss_chunk,
                          compute_dtype=(prec.compute_dtype
                                         if prec.casts_compute else None))

    return jax.jit(eval_step) if jit_compile else eval_step
