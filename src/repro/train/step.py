"""Training step: value_and_grad over the model loss + optimizer update,
with optional microbatch gradient accumulation (``lax.scan`` over
microbatches so peak activation memory is one microbatch)."""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import init_params, train_loss
from repro.optim import Optimizer, get_optimizer, constant


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def init_train_state(key, cfg: ArchConfig, optimizer: Optional[Optimizer] = None,
                     state_dtype=None) -> TrainState:
    optimizer = optimizer or get_optimizer(cfg.optimizer, state_dtype=state_dtype)
    params = init_params(key, cfg)
    return TrainState(params, optimizer.init(params),
                      jnp.zeros((), jnp.int32))


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int):
    def r(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape(n, B // n, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(cfg: ArchConfig, optimizer: Optional[Optimizer] = None,
                    lr_schedule: Optional[Callable] = None,
                    remat: bool = True, microbatches: int = 1,
                    loss_chunk: int = 512):
    """Returns train_step(state, batch) -> (new_state, metrics)."""
    optimizer = optimizer or get_optimizer(cfg.optimizer)
    lr_schedule = lr_schedule or constant(1e-4)

    def loss_fn(params, mb):
        return train_loss(params, cfg, mb, remat=remat, loss_chunk=loss_chunk)

    def train_step(state: TrainState, batch):
        params = state.params
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = _split_microbatches(batch, microbatches)

            def acc_step(carry, mb):
                tot_loss, acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (tot_loss + l, acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), zeros), mbs)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        lr = lr_schedule(state.step)
        new_params, new_opt = optimizer.update(
            grads, state.opt_state, params, state.step, lr)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "lr": lr, "grad_norm": gnorm}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_eval_step(cfg: ArchConfig, loss_chunk: int = 512):
    def eval_step(params, batch):
        return train_loss(params, cfg, batch, remat=False,
                          loss_chunk=loss_chunk)
    return eval_step
