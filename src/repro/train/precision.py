"""Mixed-precision policy for the training hot path.

One :class:`Precision` names the dtype of every role in a train step:

* ``param_dtype``   — master parameters and optimizer state (what the
  checkpoint holds).  Stays float32 under every shipped policy, so a
  bf16 run checkpoints/restores bitwise-identically to an f32 run's
  durability contract (see ``tests/test_resume.py``).
* ``compute_dtype`` — forward/backward activation dtype.  Parameters
  are cast leaf-wise to this dtype *inside* the step (the cast's VJP
  returns the cotangent to the master dtype, so gradients land f32).
* ``grad_dtype``    — microbatch gradient-accumulation dtype.  Kept
  f32: bf16 accumulation loses low-order bits exactly where the sum
  of many small microbatch grads lives.
* Loss is always computed and reduced in f32 (the cross-entropy path
  in :func:`repro.models.train_loss` upcasts before logsumexp).

Policies are named so they thread through RunSpec overrides / CLI flags
(``--precision bf16``) without dtype plumbing at every call site.
"""
from __future__ import annotations

import dataclasses
from typing import Union

from repro.models.model import cast_floating  # noqa: F401  (re-export)


@dataclasses.dataclass(frozen=True)
class Precision:
    name: str = "f32"
    param_dtype: str = "float32"     # master params + optimizer state
    compute_dtype: str = "float32"   # forward/backward activations
    grad_dtype: str = "float32"      # microbatch grad accumulation

    @property
    def casts_compute(self) -> bool:
        return self.compute_dtype != self.param_dtype


POLICIES = {
    "f32": Precision(),
    "bf16": Precision(name="bf16", compute_dtype="bfloat16"),
}


def get_precision(policy: Union[str, Precision, None]) -> Precision:
    """Resolve a policy name (``"f32"``/``"bf16"``), a :class:`Precision`,
    or ``None`` (-> f32) to a :class:`Precision`."""
    if policy is None:
        return POLICIES["f32"]
    if isinstance(policy, Precision):
        return policy
    if policy not in POLICIES:
        raise ValueError(f"unknown precision policy {policy!r}; "
                         f"known: {sorted(POLICIES)}")
    return POLICIES[policy]
