"""Durable training loop: step execution + metrics/throughput accounting
+ checkpoint/resume, extracted from the inline loop ``train_main`` used
to carry.

The paper's campaigns only complete because Nautilus jobs survive
preemption; :class:`TrainLoop` is the library form of that property.  It
owns

* step execution over a *seekable* data source (anything exposing
  ``next_batch()/cursor()/seek(cursor)`` — see
  :class:`repro.data.tokens.SeekableTokenBatches`),
* metrics and throughput accounting (pure step rate vs. checkpoint
  overhead, reported separately),
* a :class:`repro.checkpoint.CheckpointManager` for atomic cadence
  checkpoints of the **full** :class:`TrainState` plus the data cursor,
* resume (``resume()`` restores state + step + data position from the
  newest valid checkpoint, falling back past torn ones),
* an injectable fault hook (``preempt_at_step=k`` raises
  :class:`Preemption` before executing step ``k``) so tests and CI can
  kill a real run mid-flight and resume it.

The fault hook only fires on runs that did not resume — a resumed
attempt re-crossing the same step must not re-preempt, mirroring a
cluster preemption hitting one attempt, not every attempt.
"""
from __future__ import annotations

import os
import signal
import time
from typing import Any, Callable, Dict, Optional

from repro.checkpoint.manager import CheckpointManager


class Preemption(RuntimeError):
    """An injected mid-run kill (the SIGTERM a Nautilus preemption
    delivers).  Pending checkpoint writes are flushed first — the grace
    period a real preemption grants."""


class TrainLoop:
    """Reusable step loop with durable checkpoint/resume.

    Parameters
    ----------
    step_fn:    jitted ``(state, batch) -> (state, metrics)``.
    state:      initial :class:`repro.train.TrainState` (or any pytree
                whose ``step`` leaf is the completed-step count).
    data:       seekable batch source (``next_batch/cursor/seek``).
    checkpointer: optional :class:`CheckpointManager`; cadence comes from
                the manager (``every_steps``/``every_s``).
    preempt_at_step: fault hook — raise :class:`Preemption` when about to
                execute this (0-based) step, unless the run resumed.
    fault_hook: generalization of ``preempt_at_step``: called with the
                step index before each step; raise to inject any fault.
    log_every:  print a metrics line every N steps (0 disables).
    sigterm_save: install a SIGTERM handler for the duration of ``run``
                that finishes the in-flight step, writes a final atomic
                checkpoint (state + data cursor), then re-raises SIGTERM
                with the default handler so the process still dies with
                the preemption signal (rc = -SIGTERM).  This is the
                Kubernetes pod-preemption contract: an evicted run loses
                at most the step it was executing.  Only effective with
                a checkpointer, from the main thread.
    """

    def __init__(self, step_fn: Callable, state, data, *,
                 checkpointer: Optional[CheckpointManager] = None,
                 preempt_at_step: Optional[int] = None,
                 fault_hook: Optional[Callable[[int], None]] = None,
                 log_every: int = 10,
                 sigterm_save: bool = True):
        self.step_fn = step_fn
        self.state = state
        self.data = data
        self.checkpointer = checkpointer
        self.preempt_at_step = preempt_at_step
        self.fault_hook = fault_hook
        self.log_every = log_every
        self.sigterm_save = sigterm_save
        self.start_step = int(state.step)
        self.resumed_from_step: Optional[int] = None
        self.losses: list = []
        self._sigterm_flag = False

    # ------------------------------------------------------------- resume
    def resume(self) -> bool:
        """Restore the newest valid checkpoint into the loop: state, step
        and data cursor.  Returns True when something was restored."""
        if self.checkpointer is None:
            return False
        restored = self.checkpointer.restore_latest(like=self.state)
        if restored is None:
            return False
        state, step, extra = restored
        self.state = state
        self.start_step = int(step)
        self.resumed_from_step = int(step)
        cursor = extra.get("data_cursor")
        if cursor is not None and hasattr(self.data, "seek"):
            self.data.seek(cursor)
        return True

    # ---------------------------------------------------------------- run
    def run(self, total_steps: int) -> Dict[str, Any]:
        """Execute steps ``start_step .. total_steps-1``; returns the run
        summary dict (losses, throughput, checkpoint accounting)."""
        ck = self.checkpointer
        old_term = None
        if ck is not None and self.sigterm_save:
            # flag-only handler: the checkpoint is written *between*
            # steps by the main loop, never from async-signal context
            def _on_term(signum, frame):
                self._sigterm_flag = True

            try:
                old_term = signal.signal(signal.SIGTERM, _on_term)
            except ValueError:          # not the main thread
                old_term = None
        t0 = time.time()
        step_s = 0.0                    # pure step time, ex-checkpointing
        # environmental straggler injection (a degraded/oversubscribed
        # node in miniature): stall wall-clock per step without touching
        # any math, so a slowed run stays bitwise-identical.  The
        # campaign executor's straggler bench sets this on one victim.
        try:
            stall_s = float(os.environ.get("REPRO_STEP_DELAY_S", "") or 0)
        except ValueError:
            stall_s = 0.0
        try:
            for i in range(self.start_step, total_steps):
                if self._sigterm_flag:
                    self._checkpoint_and_die()
                if stall_s > 0:
                    time.sleep(stall_s)
                if self.fault_hook is not None:
                    self.fault_hook(i)
                if (self.preempt_at_step is not None
                        and i == self.preempt_at_step
                        and self.resumed_from_step is None):
                    if ck is not None:
                        ck.wait()       # the preemption grace period
                    raise Preemption(
                        f"injected preemption before step {i} "
                        f"(completed {i} of {total_steps})")
                ts = time.time()
                batch = self.data.next_batch()
                self.state, metrics = self.step_fn(self.state, batch)
                self.losses.append(float(metrics["loss"]))
                step_s += time.time() - ts
                if self.log_every and (i % self.log_every == 0
                                       or i == total_steps - 1):
                    print(f"step {i:5d} loss {self.losses[-1]:.4f} "
                          f"lr {float(metrics['lr']):.2e} "
                          f"gnorm {float(metrics['grad_norm']):.3f}",
                          flush=True)
                if ck is not None and ck.should_save(i + 1):
                    extra = {}          # cursor captured only when saving
                    if hasattr(self.data, "cursor"):
                        extra["data_cursor"] = self.data.cursor()
                    ck.save(self.state, i + 1, extra=extra)
            # a SIGTERM that lands during the final step (or after the
            # loop) still checkpoints before the process dies
            if self._sigterm_flag:
                self._checkpoint_and_die()
        finally:
            if old_term is not None:
                signal.signal(signal.SIGTERM, old_term)
        if ck is not None:
            ck.wait()
        wall = time.time() - t0
        steps_run = max(0, total_steps - self.start_step)
        result: Dict[str, Any] = {
            "steps": total_steps,
            "steps_run": steps_run,
            "resumed_from_step": self.resumed_from_step,
            "wall_s": round(wall, 2),
            "steps_per_s": round(steps_run / wall, 3) if wall else 0.0,
            "pure_step_s": round(step_s, 3),
        }
        if self.losses:
            result.update(first_loss=self.losses[0],
                          final_loss=self.losses[-1],
                          loss_drop=self.losses[0] - self.losses[-1])
        if ck is not None:
            st = ck.stats()
            overhead = (st["blocked_s"] / wall) if wall else 0.0
            result["checkpoint"] = {**st,
                                    "overhead_frac": round(overhead, 4)}
        return result

    # ------------------------------------------------- SIGTERM final save
    def _checkpoint_and_die(self) -> None:
        """A SIGTERM landed between steps: drain in-flight cadence
        writes, publish a final atomic checkpoint at the completed step
        (state + data cursor), then die with the default SIGTERM
        disposition — the scheduler must still see rc = -SIGTERM and
        classify the exit as a preemption, never a success."""
        ck = self.checkpointer
        if ck is not None:
            ck.wait()
            extra: Dict[str, Any] = {"sigterm": True}
            if hasattr(self.data, "cursor"):
                extra["data_cursor"] = self.data.cursor()
            ck.save(self.state, int(self.state.step), extra=extra)
            ck.wait()
        signal.signal(signal.SIGTERM, signal.SIG_DFL)
        os.kill(os.getpid(), signal.SIGTERM)

    # ---------------------------------------------------- final checkpoint
    def save_final(self, extra: Optional[dict] = None) -> Optional[int]:
        """Force a checkpoint of the current state (e.g. at run end, even
        with no cadence configured).  Returns the checkpointed step."""
        if self.checkpointer is None:
            return None
        step = int(self.state.step)
        payload = dict(extra or {})
        if hasattr(self.data, "cursor"):
            payload.setdefault("data_cursor", self.data.cursor())
        self.checkpointer.save(self.state, step, extra=payload)
        self.checkpointer.wait()
        return step
