"""Process-mesh context for data-parallel training.

``init_distributed(world_size, rank, coordinator)`` brings up
``jax.distributed`` (gloo collectives on CPU — the container has no
NCCL) and returns a :class:`DistContext` over a 1-D ``data`` mesh of
every device in the job.  ``world_size=1`` degenerates to a local
single-device mesh with no distributed runtime, so the same trainer
code path serves both cases (and the world=1 oracle test runs
in-process).

The synchronization model is GSPMD, not hand-written ``psum``: the
train step is jitted with batch inputs sharded ``P("data")`` and state
in/out replicated ``P()`` — XLA inserts the gradient all-reduce (and
overlaps it with backward compute where the schedule allows), which is
exactly the FireCaffe reduction this package's bench meters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DATA_AXIS = "data"


def init_distributed(world_size: int = 1, rank: int = 0,
                     coordinator: Optional[str] = None) -> "DistContext":
    """Initialize the distributed runtime (when ``world_size > 1``) and
    build the process-mesh context.  Must run before any other jax call
    in the process — ``jax.distributed.initialize`` cannot attach to an
    already-initialized backend."""
    world_size = int(world_size)
    if world_size > 1:
        if coordinator is None:
            raise ValueError("world_size > 1 requires coordinator "
                             "('host:port' of rank 0)")
        if not 0 <= int(rank) < world_size:
            raise ValueError(f"rank {rank} outside world of {world_size}")
        # CPU collectives need an explicit cross-process backend.  Must
        # not query the backend here (jax.default_backend() would
        # initialize it, which forbids distributed init) — the setting
        # is inert on GPU/TPU.
        try:
            jax.config.update("jax_cpu_collectives_implementation",
                              "gloo")
        except AttributeError:       # pragma: no cover - older jaxlib
            pass
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=world_size,
                                   process_id=int(rank))
        devices = np.array(jax.devices())
    else:
        devices = np.array(jax.devices()[:1])
    mesh = Mesh(devices, (DATA_AXIS,))
    return DistContext(world_size=world_size, rank=int(rank),
                       coordinator=coordinator, mesh=mesh)


@dataclasses.dataclass
class DistContext:
    """One rank's view of the data-parallel job."""

    world_size: int
    rank: int
    coordinator: Optional[str]
    mesh: Mesh

    @property
    def is_coordinator(self) -> bool:
        return self.rank == 0

    @property
    def devices(self) -> int:
        return self.mesh.devices.size

    # ------------------------------------------------------- shardings
    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def batch_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(DATA_AXIS))

    def row_range(self, global_rows: int) -> tuple:
        """The contiguous ``[lo, hi)`` slice of the global batch this
        process's devices own (``jax.devices()`` orders process-major,
        so shards are contiguous per process)."""
        if global_rows % self.devices:
            raise ValueError(f"global batch {global_rows} not divisible "
                             f"by {self.devices} devices")
        local = jax.local_device_count() if self.world_size > 1 else 1
        per_dev = global_rows // self.devices
        lo = self.rank * local * per_dev
        return lo, lo + local * per_dev

    # ----------------------------------------------------- global arrays
    def global_batch(self, local_tree: Any, global_rows: int) -> Any:
        """Per-rank host shards -> one global jax.Array tree sharded
        ``P("data")`` on dim 0."""
        sh = self.batch_sharding()

        def lift(x):
            x = np.asarray(x)
            return jax.make_array_from_process_local_data(
                sh, x, (global_rows,) + x.shape[1:])
        return jax.tree.map(lift, local_tree)

    def replicate(self, tree: Any) -> Any:
        """Host (or local-device) tree -> fully replicated global arrays
        (every rank must pass identical values)."""
        sh = self.replicated()
        return jax.tree.map(
            lambda x: jax.make_array_from_process_local_data(
                sh, np.asarray(x)), tree)

    # ------------------------------------------------------------- jit
    def jit_step(self, step_fn, *, donate_state: bool = True):
        """Wrap a bare ``(state, batch) -> (state, metrics)`` step (from
        ``make_train_step(jit_compile=False)``) in the data-parallel
        jit: batch sharded over ``data``, state/metrics replicated, the
        input state donated exactly as the single-process path does."""
        repl, bsh = self.replicated(), self.batch_sharding()
        return jax.jit(step_fn, in_shardings=(repl, bsh),
                       out_shardings=(repl, repl),
                       donate_argnums=(0,) if donate_state else ())

    # ------------------------------------------------------- agreement
    def allgather(self, value) -> np.ndarray:
        """Gather a small per-rank value to every rank (shape
        ``(world, ...)``).  Identity-stack at world=1."""
        arr = np.asarray(value)
        if self.world_size <= 1:
            return arr[None]
        from jax.experimental import multihost_utils
        return np.asarray(multihost_utils.process_allgather(arr))

    def agree(self, value, what: str = "value"):
        """Assert all ranks hold the same scalar/array; returns it.
        Catches divergent resume (one rank restored a different
        checkpoint step) before it poisons a collective."""
        gathered = self.allgather(value)
        if not all(np.array_equal(gathered[0], g) for g in gathered[1:]):
            raise RuntimeError(
                f"ranks disagree on {what}: "
                f"{[np.asarray(g).tolist() for g in gathered]}")
        return value
