"""Multi-process data-parallel training.

The campaign executor scales *across* runs; this package scales a
*single* run across processes: ``jax.distributed``-initialized ranks
each hold a shard of the global batch, compute local grads through the
existing donated/bf16/Pallas train step, and synchronize via mesh
all-reduce (GSPMD inserts the ``psum`` from the replicated-output
sharding over the process ``data`` mesh).  FireCaffe (PAPERS.md) is the
blueprint: reduction bandwidth is the scaling contract, measured in
``benchmarks/dist_train_bench.py``.

Exports resolve lazily: the executor imports :mod:`.gang` helpers from
its jax-free scheduler process, so importing this package must not pull
in jax (only :mod:`.context`, :mod:`.data` and :mod:`.trainer` do).
"""
from __future__ import annotations

_EXPORTS = {
    "DistContext": "repro.distributed.context",
    "init_distributed": "repro.distributed.context",
    "ShardedBatches": "repro.distributed.data",
    "shard_rows": "repro.distributed.data",
    "DistributedTrainLoop": "repro.distributed.trainer",
    "dist_train_main": "repro.distributed.trainer",
    "allreduce_bytes_per_step": "repro.distributed.trainer",
    "free_port": "repro.distributed.gang",
    "rank_argv": "repro.distributed.gang",
    "run_gang_local": "repro.distributed.gang",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
