"""Local gang launcher: run a world-N training job as N rank processes.

This is the path ``repro.launch run train --world_size N`` takes when
invoked *without* ``--dist_rank`` (a user at a shell, or CI): the
parent process stays jax-free, spawns one ``run train`` subprocess per
rank with ``--dist_rank i --coordinator 127.0.0.1:<port>`` appended,
and adopts rank 0's RunReport as the job's result.  The campaign
executor does the same spawn itself (gang admission needs per-rank
process handles) — see ``core/executor.py``.
"""
from __future__ import annotations

import os
import signal
import socket
import subprocess
import sys
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, List, Optional


def free_port(host: str = "127.0.0.1") -> int:
    """An OS-assigned free TCP port (bind-to-0).  Racy by nature, but
    the coordinator binds immediately after."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind((host, 0))
        return s.getsockname()[1]


def rank_argv(base_argv: List[str], rank: int, coordinator: str
              ) -> List[str]:
    """Append the per-rank distributed flags to a ``run train`` argv."""
    return list(base_argv) + [f"--dist_rank={rank}",
                              f"--coordinator={coordinator}"]


def _src_path() -> str:
    # .../src/repro/distributed/gang.py -> .../src
    return str(Path(__file__).resolve().parents[2])


def run_gang_local(spec, world: int, *,
                   log_dir: Optional[str] = None,
                   timeout_s: Optional[float] = None,
                   grace_s: float = 5.0) -> Dict[str, Any]:
    """Spawn ``world`` rank subprocesses for ``spec`` (a train RunSpec
    whose overrides carry ``world_size``), wait for the gang, and
    return rank 0's report metrics plus a ``gang`` section.  Any rank
    failing kills the rest — gang semantics, not straggler tolerance.
    """
    from repro.api.spec import _encode_scalar
    from repro.core.executor import parse_trailing_report

    coordinator = f"127.0.0.1:{free_port()}"
    base = [sys.executable, "-m", "repro.launch", "run", spec.kind,
            "--arch", spec.arch, "--seed", str(spec.seed),
            "--name", spec.run_name]
    for key, val in sorted(spec.overrides.items()):
        if key in ("dist_rank", "coordinator"):
            continue
        base.append(f"--{key}={_encode_scalar(val)}")

    env = dict(os.environ)
    src = _src_path()
    existing = env.get("PYTHONPATH", "")
    if src not in existing.split(os.pathsep):
        env["PYTHONPATH"] = src + os.pathsep + existing if existing else src

    logs = Path(log_dir) if log_dir else Path(tempfile.mkdtemp(
        prefix=f"gang-{spec.run_name}-"))
    logs.mkdir(parents=True, exist_ok=True)
    procs, outs = [], []
    for r in range(world):
        out_p = logs / f"rank{r}.out"
        err_p = logs / f"rank{r}.err"
        outs.append(out_p)
        procs.append(subprocess.Popen(
            rank_argv(base, r, coordinator), env=env,
            stdout=open(out_p, "wb"), stderr=open(err_p, "wb")))
    rcs: List[Optional[int]] = [None] * world
    try:
        # rank 0 finishes last in the happy path (it writes the final
        # checkpoint); wait for it first, then reap the rest
        for r in range(world):
            rcs[r] = procs[r].wait(timeout=timeout_s)
    except subprocess.TimeoutExpired:
        pass
    finally:
        # graceful teardown of stragglers: SIGTERM (the coordinator's
        # handler flushes a final checkpoint), a shared grace deadline,
        # then SIGKILL — the same escalation the executor applies
        live = [r for r, p in enumerate(procs) if p.poll() is None]
        for r in live:
            procs[r].send_signal(signal.SIGTERM)
        deadline = time.monotonic() + max(0.0, grace_s)
        for r in live:
            try:
                rcs[r] = procs[r].wait(
                    timeout=max(0.0, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                procs[r].send_signal(signal.SIGKILL)
                rcs[r] = procs[r].wait()
        for r, p in enumerate(procs):
            if rcs[r] is None:
                rcs[r] = p.returncode
    if any(rc != 0 for rc in rcs):
        bad = next(r for r, rc in enumerate(rcs) if rc != 0)
        err_tail = ""
        try:
            err_tail = (logs / f"rank{bad}.err").read_text(
                errors="replace")[-2000:]
        except OSError:
            pass
        raise RuntimeError(
            f"gang rank {bad}/{world} exited rc={rcs[bad]} "
            f"(all rcs={rcs}); stderr tail:\n{err_tail}")
    report = parse_trailing_report(outs[0].read_text(errors="replace"))
    if report is None or report.get("status") == "failed":
        raise RuntimeError(f"gang rank 0 produced no usable RunReport "
                           f"(see {outs[0]})")
    metrics = dict(report.get("metrics") or {})
    metrics["gang"] = {"world_size": world, "coordinator": coordinator,
                       "returncodes": rcs, "log_dir": str(logs)}
    return metrics
