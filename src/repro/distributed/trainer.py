"""The data-parallel trainer: ``train_main``'s multi-process twin.

One ``dist_train_main`` call is ONE rank of a gang.  Rank 0 hosts the
``jax.distributed`` coordinator and owns checkpoint *writes*; every
rank restores from the same checkpoint dir on resume (writes are
atomic ``tmp -> rename`` publishes, so readers never see torn state)
and the loop asserts cross-rank agreement on the restored step before
any collective runs.  Loss/step trajectories at world=N are equal to a
single-process run at the same global batch — every rank draws the
identical global stream and keeps its rows, and the grad all-reduce is
the same mean the single process computes (the oracle test in
``tests/test_distributed.py`` pins this down to numerical identity on
one host).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np


class DistributedTrainLoop:
    """A :class:`repro.train.TrainLoop` whose resume re-replicates the
    restored host state onto the process mesh and cross-checks rank
    agreement.  (Constructed via :func:`make_loop` — the import of
    TrainLoop stays inside jax-using code paths.)"""

    def __new__(cls, *a, **kw):                 # pragma: no cover - guard
        raise TypeError("use DistributedTrainLoop.create(...)")

    @classmethod
    def create(cls, step_fn, state, data, *, ctx,
               checkpointer=None, preempt_at_step=None, log_every=10,
               sigterm_save=False):
        from repro.train import TrainLoop

        class _Loop(TrainLoop):
            def resume(self) -> bool:
                restored = super().resume()
                if restored:
                    # restore_latest yields host arrays; lift them back
                    # to fully-replicated global arrays on the mesh
                    self.state = ctx.replicate(self.state)
                ctx.agree(np.asarray(self.start_step, dtype=np.int64),
                          "resumed step")
                return restored

        return _Loop(step_fn, state, data, checkpointer=checkpointer,
                     preempt_at_step=preempt_at_step, log_every=log_every,
                     sigterm_save=sigterm_save)


def allreduce_bytes_per_step(param_bytes: int, world: int) -> int:
    """Analytic ring all-reduce traffic per step and per rank:
    ``2 * (N-1)/N * grad_bytes`` (reduce-scatter + all-gather), the
    FireCaffe reduction-bandwidth model this repo treats as the scaling
    contract.  Zero at world=1."""
    if world <= 1:
        return 0
    return int(2 * (world - 1) / world * param_bytes)


def dist_train_main(arch: str, *, world_size: int, dist_rank: int = 0,
                    coordinator: Optional[str] = None,
                    reduced: bool = True, steps: int = 100,
                    batch: int = 8, seq: int = 128, lr: float = 3e-4,
                    optimizer: str = None, seed: int = 0,
                    checkpoint_dir: str = None, s3_root: str = None,
                    log_every: int = 10, checkpoint_every: int = 0,
                    checkpoint_keep: int = 3, checkpoint_async: bool = True,
                    resume: bool = False, preempt_at_step: int = None,
                    precision: str = "f32", grad_clip: float = None,
                    microbatches: int = 1,
                    attention_backend: str = None,
                    mixer_backend: str = None) -> Dict[str, Any]:
    """Run one rank of a data-parallel training job.  ``batch`` is the
    GLOBAL batch; each rank computes ``batch / world_size`` rows.  The
    return dict is ``train_main``'s result plus a ``dist`` section
    (rank 0's report is the one the executor and gang launcher parse).
    """
    # distributed init must precede every other jax interaction
    from repro.distributed.context import init_distributed
    ctx = init_distributed(world_size, dist_rank, coordinator)

    import jax
    from repro.checkpoint import CheckpointManager, export_to_s3
    from repro.configs import get_config, get_reduced
    from repro.core.artifacts import S3Store
    from repro.data.inputs import SeekableSyntheticBatches
    from repro.data.tokens import SeekableTokenBatches
    from repro.distributed.data import ShardedBatches
    from repro.optim import get_optimizer, warmup_cosine
    from repro.sharding import ShardCtx, rules
    from repro.sharding.ctx import use_ctx
    from repro.train import init_train_state, make_train_step

    if batch % max(1, ctx.devices):
        raise ValueError(f"global batch {batch} must divide over "
                         f"{ctx.devices} devices")
    cfg = get_reduced(arch) if reduced else get_config(arch)
    backends = {}
    if attention_backend:
        backends["attention_backend"] = attention_backend
    if mixer_backend:
        backends["mixer_backend"] = mixer_backend
    if backends:
        cfg = dataclasses.replace(cfg, **backends)
    opt = get_optimizer(optimizer or cfg.optimizer)
    state = init_train_state(jax.random.PRNGKey(seed), cfg, opt)
    state = ctx.replicate(jax.tree.map(np.asarray, state))

    # the existing donated/bf16/Pallas step, bare (jit_compile=False is
    # documented for exactly this: sharded launchers add their own jit)
    bare_step = make_train_step(
        cfg, opt, lr_schedule=warmup_cosine(lr, steps,
                                            warmup_steps=max(steps // 10, 1)),
        precision=precision, grad_clip=grad_clip,
        microbatches=max(1, int(microbatches)), jit_compile=False)
    sctx = ShardCtx(ctx.mesh, rules.logical_axes(ctx.mesh, "dp"))

    def step_with_ctx(st, b):
        # trace-time activation constraints resolve batch -> "data"
        with use_ctx(sctx):
            return bare_step(st, b)

    step_fn = ctx.jit_step(step_with_ctx)

    text_lm = cfg.family in ("dense", "moe", "ssm", "hybrid")
    if text_lm:
        inner = SeekableTokenBatches(cfg.vocab, batch, seq, seed)
        to_named = lambda raw: {"tokens": raw[0], "labels": raw[1]}  # noqa: E731
    else:
        inner = SeekableSyntheticBatches(cfg, batch, seq, seed)
        to_named = None
    data = ShardedBatches(inner, ctx, to_named=to_named, global_rows=batch)

    ckpt = None
    if checkpoint_dir:
        # one shared dir: rank 0 writes on cadence, every rank restores.
        # Non-coordinators get a zero-cadence manager (restore-only).
        ckpt = CheckpointManager(
            checkpoint_dir, keep_last=max(int(checkpoint_keep), 1),
            every_steps=(int(checkpoint_every)
                         if ctx.is_coordinator else 0),
            async_saves=bool(checkpoint_async) and ctx.is_coordinator)
    # only the coordinator saves on SIGTERM (it owns checkpoint writes);
    # other ranks die with the signal and the gang requeues as one
    loop = DistributedTrainLoop.create(
        step_fn, state, data, ctx=ctx, checkpointer=ckpt,
        preempt_at_step=preempt_at_step,
        log_every=log_every if ctx.is_coordinator else 0,
        sigterm_save=ctx.is_coordinator)
    if resume:
        loop.resume()
    try:
        run = loop.run(steps)
    finally:
        if ckpt is not None:
            ckpt.wait()

    param_bytes = sum(
        int(np.prod(p.shape)) * 4
        for p in jax.tree.leaves(loop.state.params))
    result: Dict[str, Any] = {
        "arch": cfg.name, "params": cfg.param_count(),
        **run,
        "dist": {
            "world_size": ctx.world_size,
            "rank": ctx.rank,
            "devices": ctx.devices,
            "global_batch": batch,
            "local_batch": batch // max(1, ctx.world_size),
            "microbatches": max(1, int(microbatches)),
            "grad_bytes": param_bytes,
            # per-rank ring traffic for the one grad reduction per step
            # (grads reduce in f32; microbatch accumulation is local)
            "allreduce_bytes_per_step": allreduce_bytes_per_step(
                param_bytes, ctx.world_size),
        },
    }
    if steps <= 512:
        # the oracle tests compare full trajectories; bounded so long
        # runs don't bloat their reports
        result["losses"] = list(loop.losses)
    if ckpt is not None:
        if ctx.is_coordinator:
            loop.save_final(extra={"arch": cfg.name,
                                   "final_loss": run.get("final_loss")})
        overhead = result.get("checkpoint", {}).get("overhead_frac", 0.0)
        result["checkpoint"] = {**ckpt.stats(), "overhead_frac": overhead}
        ckpt.close()
        if s3_root and ctx.is_coordinator:
            s3 = S3Store(s3_root)
            n = export_to_s3(checkpoint_dir, s3, f"models/{cfg.name}")
            result["s3_objects"] = n
    return result
