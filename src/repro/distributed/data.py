"""Rank-sharded views over the seekable batch streams (PR 3).

Every rank advances an *identical* global stream — same seed, same rng
trajectory, same cursor — so the union of rank shards is exactly the
batch a single-process run at the same global batch size would draw
(the correctness oracle depends on this).  Cursors are therefore global
and rank-agnostic: any rank's cursor resumes every rank.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import numpy as np


def shard_rows(batch: Dict[str, np.ndarray], rank: int,
               world: int) -> Dict[str, np.ndarray]:
    """Rank's contiguous row shard of a global host batch dict."""
    out = {}
    for key, arr in batch.items():
        arr = np.asarray(arr)
        if arr.shape[0] % world:
            raise ValueError(f"batch dim {arr.shape[0]} of {key!r} not "
                             f"divisible by world size {world}")
        per = arr.shape[0] // world
        out[key] = arr[rank * per:(rank + 1) * per]
    return out


class ShardedBatches:
    """Wrap a seekable *global* stream for one rank.

    ``inner`` yields full global batches (``next_batch/cursor/seek``);
    ``to_named`` maps its raw output to a ``{name: host array}`` dict
    (e.g. the LM streams yield ``(tokens, labels)`` tuples).  Each
    ``next_batch`` advances the global stream, keeps this rank's rows,
    and assembles the global device array tree through the context —
    ready for the data-parallel jitted step.
    """

    def __init__(self, inner: Any, ctx, *,
                 to_named: Optional[Callable[[Any], Dict[str, Any]]] = None,
                 global_rows: Optional[int] = None):
        self.inner = inner
        self.ctx = ctx
        self.to_named = to_named or (lambda raw: dict(raw))
        self.global_rows = int(global_rows
                               if global_rows is not None
                               else inner.batch)

    def next_batch(self):
        named = {k: np.asarray(v)
                 for k, v in self.to_named(self.inner.next_batch()).items()}
        lo, hi = self.ctx.row_range(self.global_rows)
        local = {k: v[lo:hi] for k, v in named.items()}
        return self.ctx.global_batch(local, self.global_rows)

    def cursor(self) -> dict:
        return self.inner.cursor()

    def seek(self, cursor: dict) -> None:
        self.inner.seek(cursor)

    def __iter__(self):
        while True:
            yield self.next_batch()
