# Pallas TPU kernels for the compute hot-spots (flash attention for the
# 32k-prefill path, the Mamba2 SSD chunk scan, and the data pipeline's
# percentile-stretch normalization).  Each subpackage ships:
#   kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling
#   ops.py    — jit'd public wrapper (padding, head-grouping, chunking)
#   ref.py    — pure-jnp oracle used by the allclose sweep tests
