"""Public wrapper: computes per-band percentiles (jnp sort) then applies
the fused stretch kernel.

Differentiable like the other two Pallas kernels: the stretch carries a
``jax.custom_vjp`` (Pallas has no reverse-mode rule) whose backward is
the analytic elementwise gradient of ``clip((x-lo)/(hi-lo), 0, 1)`` in
plain jnp — the ``lo``/``hi`` percentile bounds stay ordinary jnp ops
outside the custom-VJP boundary, so their (interpolation-weight)
gradients flow through jax autodiff and ``jax.grad`` of the kernel path
matches ``jax.grad`` of the pure-jnp oracle (tested per dtype in
``tests/test_kernels.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.percentile_norm.kernel import percentile_norm_kernel

_EPS = 1e-12   # matches the kernel's / ref's max(hi - lo, 1e-12) guard


def percentile_normalize(img, *, p_lo: float = 1.0, p_hi: float = 99.0,
                         block_rows: int = 1024,
                         interpret: bool | None = None):
    """img: (..., C) raster -> float32 [0,1]; per-band [p_lo, p_hi] stretch
    (the paper's Sentinel-2 normalization).

    ``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = default_interpret()
    return _percentile_normalize(img, p_lo=p_lo, p_hi=p_hi,
                                 block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("p_lo", "p_hi", "block_rows",
                                             "interpret"))
def _percentile_normalize(img, *, p_lo, p_hi, block_rows, interpret):
    shape = img.shape
    flat = img.reshape(-1, shape[-1]).astype(jnp.float32)
    lo = jnp.percentile(flat, p_lo, axis=0)[None, :]
    hi = jnp.percentile(flat, p_hi, axis=0)[None, :]
    out = _stretch(flat, lo, hi, block_rows, interpret)
    return out.reshape(shape)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _stretch(flat, lo, hi, block_rows, interpret):
    return percentile_norm_kernel(flat, lo, hi, block_rows=block_rows,
                                  interpret=interpret)


def _stretch_fwd(flat, lo, hi, block_rows, interpret):
    out = percentile_norm_kernel(flat, lo, hi, block_rows=block_rows,
                                 interpret=interpret)
    return out, (flat, lo, hi)


def _stretch_bwd(block_rows, interpret, residuals, ct):
    flat, lo, hi = residuals
    x = flat.astype(jnp.float32)
    s = 1.0 / jnp.maximum(hi - lo, _EPS)       # (1, C)
    u = (x - lo) * s
    # clip subgradient: 1 inside, 0 outside, 0.5 at exact ties — jax's
    # min/max convention, which the percentile-neighbor pixels hit
    # exactly (x == lo or x == hi)
    w = jnp.where((u > 0.0) & (u < 1.0), 1.0,
                  jnp.where((u == 0.0) | (u == 1.0), 0.5, 0.0))
    g = ct.astype(jnp.float32) * w
    dx = (g * s).astype(flat.dtype)
    # y = (x - lo) * s, s = 1/(hi - lo):  dy/dlo = s*(u - 1), dy/dhi = -s*u
    dlo = jnp.sum(g * s * (u - 1.0), axis=0, keepdims=True).astype(lo.dtype)
    dhi = jnp.sum(g * (-s) * u, axis=0, keepdims=True).astype(hi.dtype)
    return dx, dlo, dhi


_stretch.defvjp(_stretch_fwd, _stretch_bwd)
