"""Public wrapper: computes per-band percentiles (jnp sort) then applies
the fused stretch kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.percentile_norm.kernel import percentile_norm_kernel


def percentile_normalize(img, *, p_lo: float = 1.0, p_hi: float = 99.0,
                         block_rows: int = 1024,
                         interpret: bool | None = None):
    """img: (..., C) raster -> float32 [0,1]; per-band [p_lo, p_hi] stretch
    (the paper's Sentinel-2 normalization).

    ``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = default_interpret()
    return _percentile_normalize(img, p_lo=p_lo, p_hi=p_hi,
                                 block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("p_lo", "p_hi", "block_rows",
                                             "interpret"))
def _percentile_normalize(img, *, p_lo, p_hi, block_rows, interpret):
    shape = img.shape
    flat = img.reshape(-1, shape[-1]).astype(jnp.float32)
    lo = jnp.percentile(flat, p_lo, axis=0)[None, :]
    hi = jnp.percentile(flat, p_hi, axis=0)[None, :]
    out = percentile_norm_kernel(flat, lo, hi, block_rows=block_rows,
                                 interpret=interpret)
    return out.reshape(shape)
