"""Pure-jnp oracle: the paper's 1st/99th-percentile stretch."""
from __future__ import annotations

import jax.numpy as jnp


def percentile_normalize_ref(img, p_lo: float = 1.0, p_hi: float = 99.0):
    """img: (..., C) -> float32 in [0,1], per-band percentile stretch."""
    flat = img.reshape(-1, img.shape[-1]).astype(jnp.float32)
    lo = jnp.percentile(flat, p_lo, axis=0)
    hi = jnp.percentile(flat, p_hi, axis=0)
    out = (flat - lo) / jnp.maximum(hi - lo, 1e-12)
    return jnp.clip(out, 0.0, 1.0).reshape(img.shape).astype(jnp.float32)
