from repro.kernels.percentile_norm.ops import percentile_normalize

__all__ = ["percentile_normalize"]
