"""Percentile-stretch normalization as a tiled Pallas kernel.

The paper's pipeline normalizes 808 GB of Sentinel-2 rasters by clamping
each band to its [1st, 99th] percentile and stretching to [0,1]
(Sect. II-B1).  On TPU this is a pure HBM-bandwidth-bound elementwise
pass; the kernel tiles (rows x bands) blocks through VMEM with the
per-band (lo, hi) bounds resident, fusing subtract/scale/clip into one
read-once-write-once sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import default_interpret


def _norm_kernel(x_ref, lo_ref, hi_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (block_rows, C)
    lo = lo_ref[...].astype(jnp.float32)        # (1, C)
    hi = hi_ref[...].astype(jnp.float32)
    scale = 1.0 / jnp.maximum(hi - lo, 1e-12)
    o_ref[...] = jnp.clip((x - lo) * scale, 0.0, 1.0).astype(o_ref.dtype)


def percentile_norm_kernel(x, lo, hi, *, block_rows: int = 1024,
                           interpret: bool | None = None):
    """x: (R, C) pixels-by-bands; lo/hi: (1, C) percentile bounds.
    ``interpret=None`` auto-detects the backend (compiled on TPU,
    interpret elsewhere)."""
    if interpret is None:
        interpret = default_interpret()
    R, C = x.shape
    block_rows = min(block_rows, R)
    pad = (-R) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    nrb = (R + pad) // block_rows

    out = pl.pallas_call(
        _norm_kernel,
        grid=(nrb,),
        in_specs=[
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((1, C), lambda i: (0, 0)),
            pl.BlockSpec((1, C), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=interpret,
    )(x, lo, hi)
    return out[:R]
