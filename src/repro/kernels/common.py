"""Shared Pallas runtime helpers for the kernel subpackages."""
from __future__ import annotations

import jax


def default_interpret(backend: str | None = None) -> bool:
    """Pallas interpret-mode default: compiled on TPU, interpreter
    everywhere else (CPU CI, tests, dry-runs)."""
    return (backend or jax.default_backend()) != "tpu"


BACKENDS = ("jnp", "pallas", "auto")


def resolve_backend(backend: str) -> str:
    """Resolve a kernel-backend knob to a concrete backend.

    ``"jnp"`` and ``"pallas"`` are explicit.  ``"auto"`` picks the Pallas
    kernels where they compile natively (TPU, via
    :func:`default_interpret`) and the pure-jnp lowering everywhere else
    — interpret-mode Pallas is a validation tool, not a runtime path.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown kernel backend {backend!r}; "
                         f"known: {BACKENDS}")
    if backend == "auto":
        return "jnp" if default_interpret() else "pallas"
    return backend
