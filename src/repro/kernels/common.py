"""Shared Pallas runtime helpers for the kernel subpackages."""
from __future__ import annotations

import jax


def default_interpret(backend: str | None = None) -> bool:
    """Pallas interpret-mode default: compiled on TPU, interpreter
    everywhere else (CPU CI, tests, dry-runs)."""
    return (backend or jax.default_backend()) != "tpu"
