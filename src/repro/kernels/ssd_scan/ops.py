"""Public wrapper: chunking, group->head expansion, padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.ssd_scan.kernel import ssd_scan_kernel


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, head_block: int = 8,
             interpret: bool | None = None):
    """SSD selective scan.  x: (Bs,S,nh,hp); dt: (Bs,S,nh); A: (nh,);
    B/C: (Bs,S,g,N) group-shared.  Returns y: (Bs,S,nh,hp).

    ``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = default_interpret()
    return _ssd_scan(x, dt, A, B, C, chunk=chunk, head_block=head_block,
                     interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "head_block",
                                             "interpret"))
def _ssd_scan(x, dt, A, B, C, *, chunk, head_block, interpret):
    Bs, S, nh, hp = x.shape
    g = B.shape[2]
    rep = nh // g
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # pad dt with zeros => exp(0*A)=1 decay, zero input: harmless
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    hb = head_block
    while nh % hb:
        hb //= 2
    hb = max(hb, 1)

    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    xq = x.reshape(Bs, nc, Q, nh, hp)
    dtq = dt.reshape(Bs, nc, Q, nh)
    Bq = Bh.reshape(Bs, nc, Q, nh, -1)
    Cq = Ch.reshape(Bs, nc, Q, nh, -1)

    y = ssd_scan_kernel(xq, dtq, A, Bq, Cq, chunk=Q, head_block=hb,
                        interpret=interpret)
    return y.reshape(Bs, Sp, nh, hp)[:, :S]
