"""Public wrapper: chunking, group->head expansion, padding — and the
``jax.custom_vjp`` that makes the Pallas path trainable.

The backward pass differentiates a mathematically-equivalent pure-jnp
chunked formulation (recompute-from-inputs, the FlashAttention residual
strategy): the kernel's intra/inter-chunk decomposition is re-expressed
as a ``lax.scan`` whose autodiff *is* the SSD backward recurrence.  This
keeps one source of truth for the backward math on every backend; a
hand-fused Pallas backward kernel can later swap in behind the same
``defvjp`` without touching callers."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.ssd_scan.kernel import ssd_scan_kernel


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, head_block: int = 8,
             interpret: bool | None = None, return_state: bool = False):
    """SSD selective scan.  x: (Bs,S,nh,hp); dt: (Bs,S,nh); A: (nh,);
    B/C: (Bs,S,g,N) group-shared.  Returns y: (Bs,S,nh,hp), or
    ``(y, h_final (Bs,nh,hp,N) f32)`` with ``return_state=True``.

    Differentiable (``jax.grad`` through either output form).
    ``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = default_interpret()
    y, h = _ssd_scan(x, dt, A, B, C, chunk, head_block, interpret)
    return (y, h) if return_state else y


def _chunk_geometry(S: int, chunk: int):
    Q = min(chunk, S)
    pad = (-S) % Q
    return Q, pad


def _pad_chunk(x, dt, B, C, Q, pad):
    if pad:
        # pad dt with zeros => exp(0*A)=1 decay, zero input: harmless
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return x, dt, B, C


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _ssd_scan_vjp(x, dt, A, B, C, chunk, head_block, interpret):
    return _ssd_fwd_impl(x, dt, A, B, C, chunk, head_block, interpret)


def _ssd_fwd_impl(x, dt, A, B, C, chunk, head_block, interpret):
    Bs, S, nh, hp = x.shape
    g = B.shape[2]
    rep = nh // g
    Q, pad = _chunk_geometry(S, chunk)
    x, dt, B, C = _pad_chunk(x, dt, B, C, Q, pad)
    Sp = S + pad
    nc = Sp // Q

    hb = head_block
    while nh % hb:
        hb //= 2
    hb = max(hb, 1)

    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    xq = x.reshape(Bs, nc, Q, nh, hp)
    dtq = dt.reshape(Bs, nc, Q, nh)
    Bq = Bh.reshape(Bs, nc, Q, nh, -1)
    Cq = Ch.reshape(Bs, nc, Q, nh, -1)

    y, h = ssd_scan_kernel(xq, dtq, A, Bq, Cq, chunk=Q, head_block=hb,
                           interpret=interpret)
    return y.reshape(Bs, Sp, nh, hp)[:, :S], h


def _ssd_jnp_equiv(x, dt, A, B, C, chunk):
    """Pure-jnp chunked SSD, matching the kernel math term for term
    (f32 compute, masked-exponent intra-chunk matmuls, carried state).
    Autodiff of this function is the backward pass of the Pallas op."""
    Bs, S, nh, hp = x.shape
    g, N = B.shape[2], B.shape[3]
    rep = nh // g
    in_dtype = x.dtype
    Q, pad = _chunk_geometry(S, chunk)
    x, dt, B, C = _pad_chunk(x, dt, B, C, Q, pad)
    Sp = S + pad
    nc = Sp // Q

    xf = x.astype(jnp.float32).reshape(Bs, nc, Q, nh, hp)
    dtc = dt.astype(jnp.float32).reshape(Bs, nc, Q, nh)
    Bc = jnp.repeat(B, rep, axis=2).astype(jnp.float32).reshape(
        Bs, nc, Q, nh, N)
    Cc = jnp.repeat(C, rep, axis=2).astype(jnp.float32).reshape(
        Bs, nc, Q, nh, N)
    xf, dtc, Bc, Cc = (jnp.moveaxis(a, 1, 0) for a in (xf, dtc, Bc, Cc))
    Af = A.astype(jnp.float32)

    def chunk_step(h, inp):
        xq, dtq, Bq, Cq = inp                      # (Bs,Q,nh,hp) etc.
        la = jnp.cumsum(dtq * Af, axis=1)          # (Bs,Q,nh)
        la_last = la[:, -1, :]                     # (Bs,nh)
        G = jnp.einsum("bihn,bjhn->bijh", Cq, Bq)  # (Bs,Q,Q,nh)
        # mask the EXPONENT, not the product (upper triangle overflows)
        diff = la[:, :, None, :] - la[:, None, :, :]
        tri = jnp.tril(jnp.ones((xq.shape[1], xq.shape[1]), bool))
        diff = jnp.where(tri[None, :, :, None], diff, -jnp.inf)
        M = G * jnp.exp(diff)
        y = jnp.einsum("bijh,bjh,bjhp->bihp", M, dtq, xq)
        y += jnp.einsum("bihn,bhpn->bihp", Cq * jnp.exp(la)[..., None], h)
        decay_out = jnp.exp(la_last[:, None, :] - la) * dtq
        h_new = jnp.exp(la_last)[:, :, None, None] * h + jnp.einsum(
            "bjhp,bjhn->bhpn", xq * decay_out[..., None], Bq)
        return h_new, y

    h0 = jnp.zeros((Bs, nh, hp, N), jnp.float32)
    h_final, yc = jax.lax.scan(chunk_step, h0, (xf, dtc, Bc, Cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(Bs, Sp, nh, hp)[:, :S]
    return y.astype(in_dtype), h_final


def _ssd_fwd(x, dt, A, B, C, chunk, head_block, interpret):
    y, h = _ssd_fwd_impl(x, dt, A, B, C, chunk, head_block, interpret)
    return (y, h), (x, dt, A, B, C)


def _ssd_bwd(chunk, head_block, interpret, res, cts):
    x, dt, A, B, C = res
    _, vjp_fn = jax.vjp(
        lambda x, dt, A, B, C: _ssd_jnp_equiv(x, dt, A, B, C, chunk),
        x, dt, A, B, C)
    return vjp_fn(cts)


_ssd_scan_vjp.defvjp(_ssd_fwd, _ssd_bwd)
_ssd_scan = jax.jit(_ssd_scan_vjp, static_argnums=(5, 6, 7))
