"""Mamba2 SSD chunk scan as a Pallas TPU kernel.

TPU adaptation of the CUDA SSD kernels (arXiv:2405.21060): the sequential
chunk recurrence maps onto the innermost grid axis — grid =
``(batch, head_blocks, n_chunks)`` — with the inter-chunk SSM state
``(hblk, hp, N)`` carried in VMEM scratch across grid steps (TPU grids are
sequential; no inter-block synchronization is needed, unlike the
stream-K-style CUDA decomposition).  Intra-chunk work is two dense
(Q x Q) MXU matmuls under a causal decay mask; Q = 128/256 keeps every
matmul dimension MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import default_interpret


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref,
                *, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)    # (Q, hblk, hp)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (Q, hblk)
    A = a_ref[...].astype(jnp.float32)     # (hblk,)
    Bm = b_ref[0, 0].astype(jnp.float32)   # (Q, hblk, N)
    Cm = c_ref[0, 0].astype(jnp.float32)   # (Q, hblk, N)
    h = h_ref[...]                         # (hblk, hp, N) fp32

    la = jnp.cumsum(dt * A, axis=0)        # (Q, hblk) cumulative log decay
    la_last = la[-1]                       # (hblk,)

    # intra-chunk: masked (Q x Q) per head block — mask the exponent so
    # the unused upper triangle never overflows
    G = jnp.einsum("qhn,khn->qkh", Cm, Bm)
    Q = x.shape[0]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    diff = jnp.where(tri[:, :, None], la[:, None, :] - la[None, :, :],
                     -jnp.inf)
    M = G * jnp.exp(diff) * dt[None, :, :]
    y = jnp.einsum("qkh,khp->qhp", M, x)

    # inter-chunk contribution from carried state
    y += jnp.einsum("qhn,hpn->qhp", Cm * jnp.exp(la)[..., None], h)

    # state update
    decay_out = jnp.exp(la_last[None, :] - la) * dt       # (Q, hblk)
    h_ref[...] = (jnp.exp(la_last)[:, None, None] * h +
                  jnp.einsum("qhp,qhn->hpn", x * decay_out[..., None], Bm))

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...]


def ssd_scan_kernel(x, dt, A, B, C, *, chunk: int, head_block: int,
                    interpret: bool | None = None):
    """x: (Bs, nc, Q, nh, hp); dt: (Bs, nc, Q, nh); A: (nh,);
    B/C: (Bs, nc, Q, nh, N) (pre-expanded to per-head groups).
    Returns (y with x's shape, h_final (Bs, nh, hp, N) f32).
    ``interpret=None`` auto-detects the backend (compiled on TPU,
    interpret elsewhere)."""
    if interpret is None:
        interpret = default_interpret()
    Bs, nc, Q, nh, hp = x.shape
    N = B.shape[-1]
    assert nh % head_block == 0, (nh, head_block)
    nhb = nh // head_block

    kernel = functools.partial(_ssd_kernel, n_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=(Bs, nhb, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, head_block, hp),
                         lambda b, hb, c: (b, c, 0, hb, 0)),
            pl.BlockSpec((1, 1, Q, head_block),
                         lambda b, hb, c: (b, c, 0, hb)),
            pl.BlockSpec((head_block,), lambda b, hb, c: (hb,)),
            pl.BlockSpec((1, 1, Q, head_block, N),
                         lambda b, hb, c: (b, c, 0, hb, 0)),
            pl.BlockSpec((1, 1, Q, head_block, N),
                         lambda b, hb, c: (b, c, 0, hb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, head_block, hp),
                         lambda b, hb, c: (b, c, 0, hb, 0)),
            pl.BlockSpec((1, head_block, hp, N),
                         lambda b, hb, c: (b, hb, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((Bs, nh, hp, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((head_block, hp, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
