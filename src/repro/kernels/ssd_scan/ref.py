"""Naive sequential-recurrence oracle for the SSD scan:
    h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T ;  y_t = C_t . h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C):
    """x: (Bs, S, nh, hp); dt: (Bs, S, nh); A: (nh,) negative;
    B/C: (Bs, S, g, N).  Returns (y, h_final)."""
    Bs, S, nh, hp = x.shape
    g, N = B.shape[2], B.shape[3]
    rep = nh // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)   # (Bs,S,nh,N)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp                  # (Bs,nh,hp) (Bs,nh) (Bs,nh,N)
        a = jnp.exp(dtt * A)                   # (Bs,nh)
        h = a[..., None, None] * h + jnp.einsum(
            "bhp,bhn->bhpn", xt * dtt[..., None], Bt)
        y = jnp.einsum("bhpn,bhn->bhp", h, Ct)
        return h, y

    h0 = jnp.zeros((Bs, nh, hp, N), jnp.float32)
    hT, ys = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
         jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), hT
