"""Public jit'd wrapper: layout handling (B,S,H,hd) -> (B*H,S,hd), padding
to block multiples, GQA head grouping, block-size selection."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (default_interpret,
                                                  flash_attention_kernel)


def _pick_block(s: int, preferred: int = 256) -> int:
    for b in (preferred, 128, 64, 32, 16, 8):
        if s % b == 0 or s > b:
            return b
    return s


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool | None = None):
    """q: (B, Sq, H, hd); k/v: (B, Sk, Kh, hd) -> (B, Sq, H, hd).

    ``interpret`` selects the Pallas execution mode: ``None`` (default)
    auto-detects the backend — compiled on TPU, interpret mode (kernel
    body on CPU, for validation) everywhere else.  Pass an explicit bool
    to override.
    """
    if interpret is None:
        interpret = default_interpret()
    return _flash_attention(q, k, v, causal=causal, window=window,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def _flash_attention(q, k, v, *, causal, window, block_q, block_k,
                     interpret):
    B, Sq, H, hd = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    sq_pad = -(-Sq // block_q) * block_q
    sk_pad = -(-Sk // block_k) * block_k

    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * Kh, Sk, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * Kh, Sk, hd)
    qf = jnp.pad(qf, ((0, 0), (0, sq_pad - Sq), (0, 0)))
    kf = jnp.pad(kf, ((0, 0), (0, sk_pad - Sk), (0, 0)))
    vf = jnp.pad(vf, ((0, 0), (0, sk_pad - Sk), (0, 0)))

    out = flash_attention_kernel(
        qf, kf, vf, causal=causal, window=window, sq=Sq, sk=Sk,
        block_q=block_q, block_k=block_k, interpret=interpret)
    out = out[:, :Sq].reshape(B, H, Sq, hd)
    return jnp.moveaxis(out, 1, 2)
