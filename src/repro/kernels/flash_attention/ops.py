"""Public jit'd wrapper: layout handling (B,S,H,hd) -> (B*H,S,hd), padding
to block multiples, GQA head grouping, block-size selection — and the
``jax.custom_vjp`` that makes the Pallas path trainable: forward runs the
Pallas forward kernel (keeping the per-row logsumexp as the only
residual), backward runs the FlashAttention-2 backward kernels and
reduces dK/dV over the GQA group."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.flash_attention.kernel import (flash_attention_bwd_kernel,
                                                  flash_attention_fwd_kernel)


def _pick_block(s: int, preferred: int = 256) -> int:
    for b in (preferred, 128, 64, 32, 16, 8):
        if s % b == 0 or s > b:
            return b
    return s


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool | None = None):
    """q: (B, Sq, H, hd); k/v: (B, Sk, Kh, hd) -> (B, Sq, H, hd).

    Differentiable: ``jax.grad`` through this op runs the Pallas backward
    kernels (see ``kernel.py``), so the Pallas path serves training as
    well as prefill.

    ``interpret`` selects the Pallas execution mode: ``None`` (default)
    auto-detects the backend — compiled on TPU, interpret mode (kernel
    body on CPU, for validation) everywhere else.  Pass an explicit bool
    to override.
    """
    if interpret is None:
        interpret = default_interpret()
    return _flash_attention(q, k, v, causal, window, block_q, block_k,
                            interpret)


def _layout(q, k, v, block_q, block_k):
    """(B,S,H,hd) -> padded (B*H, S_pad, hd) layout + geometry."""
    B, Sq, H, hd = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    sq_pad = -(-Sq // block_q) * block_q
    sk_pad = -(-Sk // block_k) * block_k

    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * Kh, Sk, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * Kh, Sk, hd)
    qf = jnp.pad(qf, ((0, 0), (0, sq_pad - Sq), (0, 0)))
    kf = jnp.pad(kf, ((0, 0), (0, sk_pad - Sk), (0, 0)))
    vf = jnp.pad(vf, ((0, 0), (0, sk_pad - Sk), (0, 0)))
    return qf, kf, vf, (B, Sq, Sk, H, Kh, hd, block_q, block_k)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_attention_vjp(q, k, v, causal, window, block_q, block_k,
                         interpret):
    out, _ = _flash_fwd(q, k, v, causal, window, block_q, block_k, interpret)
    return out


def _flash_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    qf, kf, vf, geom = _layout(q, k, v, block_q, block_k)
    B, Sq, Sk, H, Kh, hd, bq, bk = geom
    outf, lse = flash_attention_fwd_kernel(
        qf, kf, vf, causal=causal, window=window, sq=Sq, sk=Sk,
        block_q=bq, block_k=bk, interpret=interpret)
    out = jnp.moveaxis(outf[:, :Sq].reshape(B, H, Sq, hd), 1, 2)
    # residual is `out`, not `outf`: downstream autodiff keeps `out`
    # alive anyway (it feeds the wo matmul), so no duplicate
    # activation-sized buffer survives to the backward pass
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, block_q, block_k, interpret, res, g):
    q, k, v, out, lse = res
    qf, kf, vf, geom = _layout(q, k, v, block_q, block_k)
    B, Sq, Sk, H, Kh, hd, bq, bk = geom
    sq_pad = lse.shape[1]

    def to_padded(x):
        xf = jnp.moveaxis(x, 2, 1).reshape(B * H, Sq, hd)
        return jnp.pad(xf, ((0, 0), (0, sq_pad - Sq), (0, 0)))

    gf = to_padded(g)
    # D = rowsum(dO * O): padded rows have dO = 0, so D = 0 there
    delta = jnp.sum(gf.astype(jnp.float32)
                    * to_padded(out).astype(jnp.float32), axis=-1)
    dqf, dkf, dvf = flash_attention_bwd_kernel(
        qf, kf, vf, gf, lse, delta, causal=causal, window=window, sk=Sk,
        block_q=bq, block_k=bk, interpret=interpret)

    dq = jnp.moveaxis(dqf[:, :Sq].reshape(B, H, Sq, hd), 1, 2)
    # dk/dv come back per query head: reduce over the GQA group
    n_rep = H // Kh
    dk = dkf[:, :Sk].reshape(B, Kh, n_rep, Sk, hd).sum(axis=2)
    dv = dvf[:, :Sk].reshape(B, Kh, n_rep, Sk, hd).sum(axis=2)
    dk = jnp.moveaxis(dk, 1, 2)
    dv = jnp.moveaxis(dv, 1, 2)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_flash_attention_vjp.defvjp(_flash_fwd, _flash_bwd)
_flash_attention = jax.jit(_flash_attention_vjp,
                           static_argnums=(3, 4, 5, 6, 7))
