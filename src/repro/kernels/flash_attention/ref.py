"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q: (B, Sq, H, hd); k/v: (B, Sk, Kh, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    n_rep = H // Kh
    k = jnp.repeat(k, n_rep, axis=2)
    v = jnp.repeat(v, n_rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(hd).astype(jnp.float32)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
