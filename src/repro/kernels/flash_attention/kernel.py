"""Blockwise online-softmax attention (FlashAttention) as Pallas TPU
kernels — forward *and* backward.

TPU adaptation (vs the CUDA original): the (q-block x kv-block) tile walk
is expressed as a 3-D sequential grid — the innermost axis revisits the
same output block, so running statistics / accumulators live in VMEM
scratch that persists across grid steps (TPU grids are sequential, unlike
CUDA thread blocks).  Block shapes are multiples of (128, 128) at
production sizes so the score/value products map directly onto the
128x128 MXU; GQA is handled by an index-map that maps each query-head
block onto its kv-head group, so no repeated-KV materialization happens
in HBM.

Backward follows the FlashAttention-2 decomposition: the forward keeps
only the per-row logsumexp ``L = m + log l`` as a residual, the backward
recomputes the score tiles and uses

    P   = exp(S - L)
    dV  = P^T dO
    dP  = dO V^T
    dS  = P * (dP - D),   D = rowsum(dO * O)
    dQ  = scale * dS K        (accumulated over kv blocks)
    dK  = scale * dS^T Q      (accumulated over q blocks)

split into two kernels so each output block is owned by exactly one
innermost accumulation loop: ``dq`` iterates kv blocks innermost,
``dk/dv`` iterates q blocks innermost.  dK/dV are produced per *query*
head; the wrapper sums over the GQA group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret

NEG_INF = -1e30


def _tile_mask(iq, ik, *, block_q, block_k, causal, window, sk, shape):
    """(bq, bk) bool mask for score tile (iq, ik): kv padding + causal +
    sliding window, from absolute positions."""
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    mask = k_pos < sk                                  # kv padding
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    return mask


# --------------------------------------------------------------- forward
def _attn_fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref,
                     l_ref, *, scale: float, causal: bool, window, sq: int,
                     sk: int, block_q: int, block_k: int, n_kv: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    mask = _tile_mask(iq, ik, block_q=block_q, block_k=block_k,
                      causal=causal, window=window, sk=sk, shape=s.shape)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                             # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0],
                             (((1,), (0,)), ((), ()))).astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)
        # logsumexp residual for the backward pass
        lse_ref[0] = (m_ref[...] + jnp.log(l))[:, 0]


def flash_attention_fwd_kernel(q, k, v, *, causal: bool, window, sq: int,
                               sk: int, block_q: int, block_k: int,
                               interpret: bool | None = None):
    """q: (BH, Sq_pad, hd); k/v: (BKH, Sk_pad, hd).  Sq_pad % block_q == 0,
    Sk_pad % block_k == 0.  BH % BKH == 0 (GQA).  Returns (out, lse) with
    lse: (BH, Sq_pad) f32.

    ``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = default_interpret()
    BH, sq_pad, hd = q.shape
    BKH, sk_pad, _ = k.shape
    n_rep = BH // BKH
    nq = sq_pad // block_q
    nk = sk_pad // block_k
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _attn_fwd_kernel, scale=scale, causal=causal, window=window,
        sq=sq, sk=sk, block_q=block_q, block_k=block_k, n_kv=nk)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // n_rep, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // n_rep, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, sq_pad, hd), q.dtype),
            jax.ShapeDtypeStruct((BH, sq_pad), jnp.float32),
        ],
        scratch_shapes=_scratch(block_q, hd),
        interpret=interpret,
    )(q, k, v)


def _scratch(block_q, hd):
    from jax.experimental.pallas import tpu as pltpu
    return [
        pltpu.VMEM((block_q, hd), jnp.float32),   # acc
        pltpu.VMEM((block_q, 1), jnp.float32),    # running max
        pltpu.VMEM((block_q, 1), jnp.float32),    # normalizer
    ]


# -------------------------------------------------------------- backward
def _attn_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                        dq_ref, dq_acc, *, scale: float, causal: bool,
                        window, sk: int, block_q: int, block_k: int,
                        n_kv: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
    mask = _tile_mask(iq, ik, block_q=block_q, block_k=block_k,
                      causal=causal, window=window, sk=sk, shape=s.shape)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse_ref[0][:, None])               # (bq, bk)

    do = do_ref[0].astype(jnp.float32)                 # (bq, hd)
    dp = jax.lax.dot_general(                          # dO V^T: (bq, bk)
        do, v_ref[0].astype(jnp.float32), (((1,), (1,)), ((), ())))
    ds = p * (dp - delta_ref[0][:, None])              # (bq, bk)
    dq_acc[...] += jax.lax.dot_general(                # dS K: (bq, hd)
        ds, k, (((1,), (0,)), ((), ()))) * scale

    @pl.when(ik == n_kv - 1)
    def _finalize():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)


def _attn_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dk_ref, dv_ref, dk_acc, dv_acc, *, scale: float,
                         causal: bool, window, sk: int, block_q: int,
                         block_k: int, n_q: int):
    ik = pl.program_id(1)          # kv block owns the output
    iq = pl.program_id(2)          # innermost: accumulate over q blocks

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
    mask = _tile_mask(iq, ik, block_q=block_q, block_k=block_k,
                      causal=causal, window=window, sk=sk, shape=s.shape)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse_ref[0][:, None])               # (bq, bk)

    do = do_ref[0].astype(jnp.float32)                 # (bq, hd)
    dv_acc[...] += jax.lax.dot_general(                # P^T dO: (bk, hd)
        p, do, (((0,), (0,)), ((), ())))
    dp = jax.lax.dot_general(do, v_ref[0].astype(jnp.float32),
                             (((1,), (1,)), ((), ())))
    ds = p * (dp - delta_ref[0][:, None])              # (bq, bk)
    dk_acc[...] += jax.lax.dot_general(                # dS^T Q: (bk, hd)
        ds, q_ref[0].astype(jnp.float32), (((0,), (0,)), ((), ()))) * scale

    @pl.when(iq == n_q - 1)
    def _finalize():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd_kernel(q, k, v, do, lse, delta, *, causal: bool,
                               window, sk: int, block_q: int, block_k: int,
                               interpret: bool | None = None):
    """Backward pass.  q/do: (BH, Sq_pad, hd); k/v: (BKH, Sk_pad, hd);
    lse/delta: (BH, Sq_pad) f32 (delta = rowsum(dO * O)).

    Returns (dq (BH, Sq_pad, hd), dk, dv (BH, Sk_pad, hd)) — dk/dv at
    *query*-head granularity; the caller reduces over the GQA group.
    All three are f32 (they are gradient accumulators).
    """
    if interpret is None:
        interpret = default_interpret()
    BH, sq_pad, hd = q.shape
    BKH, sk_pad, _ = k.shape
    n_rep = BH // BKH
    nq = sq_pad // block_q
    nk = sk_pad // block_k
    scale = 1.0 / (hd ** 0.5)
    from jax.experimental.pallas import tpu as pltpu

    dq_kernel = functools.partial(
        _attn_bwd_dq_kernel, scale=scale, causal=causal, window=window,
        sk=sk, block_q=block_q, block_k=block_k, n_kv=nk)
    dq = pl.pallas_call(
        dq_kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // n_rep, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // n_rep, j, 0)),
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, sq_pad, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dkv_kernel = functools.partial(
        _attn_bwd_dkv_kernel, scale=scale, causal=causal, window=window,
        sk=sk, block_q=block_q, block_k=block_k, n_q=nq)
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(BH, nk, nq),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j, i: (b // n_rep, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j, i: (b // n_rep, j, 0)),
            pl.BlockSpec((1, block_q, hd), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, block_q), lambda b, j, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, sk_pad, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, sk_pad, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
