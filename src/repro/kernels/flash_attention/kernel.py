"""Blockwise online-softmax attention (FlashAttention) as a Pallas TPU
kernel.

TPU adaptation (vs the CUDA original): the (q-block x kv-block) tile walk
is expressed as a 3-D sequential grid ``(batch*heads, n_q_blocks,
n_kv_blocks)`` — the innermost axis revisits the same output block, so the
running max / normalizer / accumulator live in VMEM scratch that persists
across grid steps (TPU grids are sequential, unlike CUDA thread blocks).
Block shapes are multiples of (128, 128) at production sizes so the
score/value products map directly onto the 128x128 MXU; GQA is handled by
an index-map that maps each query-head block onto its kv-head group, so
no repeated-KV materialization happens in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window, sq: int, sk: int,
                 block_q: int, block_k: int, n_kv: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < sk                                  # kv padding
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                             # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0],
                             (((1,), (0,)), ((), ()))).astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(ik == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool, window, sq: int,
                           sk: int, block_q: int, block_k: int,
                           interpret: bool | None = None):
    """q: (BH, Sq_pad, hd); k/v: (BKH, Sk_pad, hd).  Sq_pad % block_q == 0,
    Sk_pad % block_k == 0.  BH % BKH == 0 (GQA).

    ``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = default_interpret()
    BH, sq_pad, hd = q.shape
    BKH, sk_pad, _ = k.shape
    n_rep = BH // BKH
    nq = sq_pad // block_q
    nk = sk_pad // block_k
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        sq=sq, sk=sk, block_q=block_q, block_k=block_k, n_kv=nk)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // n_rep, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // n_rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, sq_pad, hd), q.dtype),
        scratch_shapes=_scratch(block_q, hd),
        interpret=interpret,
    )(q, k, v)


def _scratch(block_q, hd):
    from jax.experimental.pallas import tpu as pltpu
    return [
        pltpu.VMEM((block_q, hd), jnp.float32),   # acc
        pltpu.VMEM((block_q, 1), jnp.float32),    # running max
        pltpu.VMEM((block_q, 1), jnp.float32),    # normalizer
    ]
