"""Checkpointing: parameter/optimizer pytrees -> sharded .npz files with a
JSON manifest, plus S3 export (the paper copies all trained models to S3
after training).  Leaves are flattened by path; files are split so no
single shard exceeds ``shard_bytes``."""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core.artifacts import S3Store


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str, tree, step: int = 0,
                    shard_bytes: int = 1 << 30,
                    metadata: Optional[dict] = None) -> str:
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    shards, cur, cur_bytes = [], {}, 0
    for k in sorted(flat):
        arr = flat[k]
        if cur and cur_bytes + arr.nbytes > shard_bytes:
            shards.append(cur)
            cur, cur_bytes = {}, 0
        cur[k] = arr
        cur_bytes += arr.nbytes
    if cur:
        shards.append(cur)

    manifest = {"step": step, "n_shards": len(shards),
                "keys": {}, "metadata": metadata or {}}
    for i, shard in enumerate(shards):
        fname = f"shard_{i:04d}.npz"
        np.savez(d / fname, **{k.replace("/", "|"): v
                               for k, v in shard.items()})
        for k, v in shard.items():
            manifest["keys"][k] = {"shard": fname, "shape": list(v.shape),
                                   "dtype": str(v.dtype)}
    (d / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return str(d)


def load_checkpoint(directory: str, like=None):
    """Returns (tree_or_flat_dict, step).  With ``like`` provided, leaves
    are restored into that pytree structure (shape-checked)."""
    d = Path(directory)
    manifest = json.loads((d / "manifest.json").read_text())
    flat: Dict[str, np.ndarray] = {}
    by_shard: Dict[str, list] = {}
    for k, info in manifest["keys"].items():
        by_shard.setdefault(info["shard"], []).append(k)
    for fname, keys in by_shard.items():
        with np.load(d / fname) as z:
            for k in keys:
                flat[k] = z[k.replace("/", "|")]
    if like is None:
        return flat, manifest["step"]

    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path, leaf in leaves_like:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), new_leaves)
    return tree, manifest["step"]


def export_to_s3(directory: str, s3: S3Store, prefix: str) -> int:
    """Paper: 'all models are copied to S3 cloud storage following
    training'.  Returns number of objects uploaded."""
    n = 0
    for f in sorted(Path(directory).glob("*")):
        if f.is_file():
            s3.put_file(f"{prefix}/{f.name}", f)
            n += 1
    return n
