"""Checkpointing: parameter/optimizer pytrees -> sharded .npz files with a
JSON manifest, plus S3 export (the paper copies all trained models to S3
after training).  Leaves are flattened by path; files are split so no
single shard exceeds ``shard_bytes``.

A checkpoint directory is *valid* iff ``manifest.json`` parses and every
shard it references loads with every declared key.  Anything else — a
missing or truncated manifest, a torn final shard from a preemption
mid-write — raises :class:`CheckpointError` so callers (in particular
:class:`repro.checkpoint.CheckpointManager`) can fall back to an older
checkpoint instead of crashing with a bare ``KeyError``/``BadZipFile``.
"""
from __future__ import annotations

import json
import os
import zipfile
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core.artifacts import S3Store

MANIFEST = "manifest.json"


class CheckpointError(RuntimeError):
    """A checkpoint directory is unreadable (missing/truncated manifest,
    torn shard).  Distinct from shape/key mismatches against ``like=``,
    which stay ``ValueError``/``KeyError`` — those mean the checkpoint is
    intact but *wrong* for the requested restore."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str, tree, step: int = 0,
                    shard_bytes: int = 1 << 30,
                    metadata: Optional[dict] = None,
                    fsync: bool = False) -> str:
    """Write ``tree`` into ``directory``.  Shards first, manifest last, so
    a torn write is detectable (manifest missing => invalid).  With
    ``fsync=True`` the manifest (and its directory entry) are fsynced —
    used by the atomic manager path before the rename that publishes the
    checkpoint."""
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    shards, cur, cur_bytes = [], {}, 0
    for k in sorted(flat):
        arr = flat[k]
        if cur and cur_bytes + arr.nbytes > shard_bytes:
            shards.append(cur)
            cur, cur_bytes = {}, 0
        cur[k] = arr
        cur_bytes += arr.nbytes
    if cur:
        shards.append(cur)

    manifest = {"step": step, "n_shards": len(shards),
                "keys": {}, "metadata": metadata or {}}
    for i, shard in enumerate(shards):
        fname = f"shard_{i:04d}.npz"
        np.savez(d / fname, **{k.replace("/", "|"): v
                               for k, v in shard.items()})
        if fsync:                       # shards durable *before* manifest
            fd = os.open(d / fname, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
        for k, v in shard.items():
            manifest["keys"][k] = {"shard": fname, "shape": list(v.shape),
                                   "dtype": str(v.dtype)}
    mpath = d / MANIFEST
    with open(mpath, "w") as f:
        f.write(json.dumps(manifest, indent=1))
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    if fsync:
        dirfd = os.open(d, os.O_RDONLY)
        try:
            os.fsync(dirfd)
        finally:
            os.close(dirfd)
    return str(d)


def read_manifest(directory: str) -> dict:
    """Parse ``manifest.json`` or raise :class:`CheckpointError` with an
    actionable message (missing vs truncated/corrupt)."""
    mpath = Path(directory) / MANIFEST
    if not mpath.exists():
        raise CheckpointError(
            f"no {MANIFEST} in {directory} — checkpoint incomplete "
            f"(torn write or wrong directory)")
    try:
        manifest = json.loads(mpath.read_text())
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointError(
            f"{mpath} is truncated or corrupt: {e}") from e
    if not isinstance(manifest, dict) or "keys" not in manifest:
        raise CheckpointError(f"{mpath} has no 'keys' table — not a "
                              f"checkpoint manifest")
    return manifest


def load_checkpoint(directory: str, like=None):
    """Returns (tree_or_flat_dict, step).  With ``like`` provided, leaves
    are restored into that pytree structure (shape-checked; dtype-only
    mismatches are cast to the ``like`` leaf's dtype, so e.g. a float32
    checkpoint restores into a bf16 state and vice versa)."""
    d = Path(directory)
    manifest = read_manifest(d)
    flat: Dict[str, np.ndarray] = {}
    by_shard: Dict[str, list] = {}
    for k, info in manifest["keys"].items():
        by_shard.setdefault(info["shard"], []).append(k)
    for fname, keys in by_shard.items():
        try:
            with np.load(d / fname) as z:
                for k in keys:
                    flat[k] = z[k.replace("/", "|")]
        except (FileNotFoundError, zipfile.BadZipFile, OSError, EOFError,
                KeyError, ValueError) as e:
            raise CheckpointError(
                f"shard {fname} in {directory} is missing or torn "
                f"({type(e).__name__}: {e}); manifest declares "
                f"{len(keys)} keys in it") from e
    if like is None:
        return flat, manifest["step"]

    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path, leaf in leaves_like:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        want = getattr(leaf, "dtype", None) or np.asarray(leaf).dtype
        if arr.dtype != want:          # dtype-only mismatch: cast, don't crash
            arr = arr.astype(want)
        new_leaves.append(jax.numpy.asarray(arr, dtype=want))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), new_leaves)
    return tree, manifest["step"]


def export_to_s3(directory: str, s3: S3Store, prefix: str) -> int:
    """Paper: 'all models are copied to S3 cloud storage following
    training'.  Recurses so the manager's ``step_*/`` layout exports with
    its structure intact; hidden entries (``.tmp-*`` in-flight writes,
    ``.old-*`` aside copies) are never uploaded.  Returns number of
    objects uploaded."""
    root = Path(directory)
    n = 0
    for f in sorted(root.rglob("*")):
        rel = f.relative_to(root)
        if f.is_file() and not any(part.startswith(".")
                                   for part in rel.parts):
            s3.put_file(f"{prefix}/{rel.as_posix()}", f)
            n += 1
    return n
