from repro.checkpoint.io import (CheckpointError, export_to_s3,
                                 load_checkpoint, read_manifest,
                                 save_checkpoint)
from repro.checkpoint.manager import CheckpointManager, list_checkpoints

__all__ = ["save_checkpoint", "load_checkpoint", "export_to_s3",
           "read_manifest", "CheckpointError", "CheckpointManager",
           "list_checkpoints"]
