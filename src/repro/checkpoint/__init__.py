from repro.checkpoint.io import save_checkpoint, load_checkpoint, export_to_s3

__all__ = ["save_checkpoint", "load_checkpoint", "export_to_s3"]
