"""Durable checkpoint manager: atomic, rotated, optionally-async saves of
a *full* training state (params + optimizer state + step + data cursor),
and a fallback-aware ``restore_latest``.

Layout under the manager's root directory::

    <root>/step_00000015/shard_0000.npz
    <root>/step_00000015/manifest.json      # written last, fsynced
    <root>/step_00000030/...

Durability protocol (what survives a preemption mid-write):

* a save writes into ``<root>/.tmp-...`` — shards first, manifest last
  with fsync — then publishes with an atomic ``os.replace`` to
  ``step_N``; readers never observe a half-written ``step_N``;
* rotation deletes oldest published checkpoints beyond ``keep_last``
  only after the new one is published;
* ``restore_latest`` walks published checkpoints newest-first and skips
  (with a note) any that fail validation — a torn checkpoint costs the
  work since the previous one, never the run.

Async mode snapshots the state to host memory on the caller's thread
(the only part that must see a consistent state) and performs the disk
write on a single background worker, so the training hot path only ever
pays the snapshot + any wait for a previous in-flight save.
"""
from __future__ import annotations

import os
import queue
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.io import (CheckpointError, load_checkpoint,
                                 read_manifest, save_checkpoint)

_STEP_PREFIX = "step_"


def _step_dir(step: int) -> str:
    return f"{_STEP_PREFIX}{step:08d}"


def list_checkpoints(directory) -> List[Tuple[int, Path]]:
    """Published (step, path) pairs under ``directory``, oldest first.
    Only well-formed ``step_N`` names count — tmp dirs are invisible."""
    d = Path(directory)
    if not d.exists():
        return []
    out = []
    for p in d.iterdir():
        if p.is_dir() and p.name.startswith(_STEP_PREFIX):
            try:
                out.append((int(p.name[len(_STEP_PREFIX):]), p))
            except ValueError:
                continue
    return sorted(out)


class CheckpointManager:
    """Owns one checkpoint directory: cadence, atomicity, rotation,
    restore-with-fallback, and save-time accounting.

    Parameters
    ----------
    directory:        checkpoint root (created on first save).
    keep_last:        retain at most this many published checkpoints.
    every_steps:      ``maybe_save`` cadence in completed steps (0/None
                      disables step-cadence saves).
    every_s:          additional wallclock cadence — save when this many
                      seconds elapsed since the last save, even between
                      step boundaries.
    async_saves:      write on a background thread (default); the hot
                      path pays only the host snapshot.
    """

    def __init__(self, directory, *, keep_last: int = 3,
                 every_steps: Optional[int] = None,
                 every_s: Optional[float] = None,
                 async_saves: bool = True):
        if keep_last < 1:
            raise ValueError(f"keep_last must be >= 1, got {keep_last}")
        self.directory = Path(directory)
        self.keep_last = keep_last
        self.every_steps = int(every_steps or 0)
        self.every_s = float(every_s or 0.0)
        self.async_saves = async_saves
        self._last_save_t = time.time()
        # accounting (read via .stats())
        self.saves = 0
        self.save_s = 0.0           # background/disk write time
        self.blocked_s = 0.0        # hot-path time: snapshot + queue wait
        self.restore_skipped: List[str] = []
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._worker: Optional[threading.Thread] = None
        self._worker_err: Optional[BaseException] = None

    # ------------------------------------------------------------ cadence
    def should_save(self, completed_steps: int) -> bool:
        if self.every_steps and completed_steps % self.every_steps == 0:
            return True
        if self.every_s and (time.time() - self._last_save_t) >= self.every_s:
            return True
        return False

    def maybe_save(self, state, completed_steps: int,
                   extra: Optional[dict] = None) -> bool:
        if completed_steps > 0 and self.should_save(completed_steps):
            self.save(state, completed_steps, extra=extra)
            return True
        return False

    # -------------------------------------------------------------- save
    def save(self, state, step: int, extra: Optional[dict] = None) -> None:
        """Durably checkpoint ``state`` as ``step_<step>``.  Returns once
        the save is (async mode) enqueued with a consistent host snapshot,
        or (sync mode) published.  A step that is already durably
        published is not re-written — touching it would risk the one
        invariant that matters (the newest published checkpoint survives
        any kill)."""
        if self.latest_step() == int(step):
            try:                        # only trust an intact manifest
                read_manifest(self.directory / _step_dir(int(step)))
                return
            except CheckpointError:
                pass                    # torn: fall through and re-write
        t0 = time.time()
        # host snapshot must be a real COPY: np.asarray on CPU returns a
        # zero-copy view of the jax buffer, which pins the state for the
        # whole background write and silently blocks the train step's
        # buffer donation (every step during a write pays a full state
        # copy instead).  One explicit memcpy here is the cost the
        # "hot path pays only the snapshot" contract budgets for.
        snapshot = jax.tree.map(lambda x: np.array(x, copy=True), state)
        metadata = dict(extra or {})
        if self.async_saves:
            self._ensure_worker()
            self._raise_worker_error()
            self._q.put((snapshot, int(step), metadata))  # waits if in flight
            self.blocked_s += time.time() - t0
        else:
            self._write(snapshot, int(step), metadata)
            self.blocked_s += time.time() - t0
        self._last_save_t = time.time()

    def _write(self, snapshot, step: int, metadata: dict) -> None:
        t0 = time.time()
        self.directory.mkdir(parents=True, exist_ok=True)
        tmp = self.directory / f".tmp-{_step_dir(step)}-{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        save_checkpoint(tmp, snapshot, step=step, metadata=metadata,
                        fsync=True)
        final = self.directory / _step_dir(step)
        old = None
        if final.exists():              # re-save of the same step: move the
            old = self.directory / f".old-{final.name}-{os.getpid()}"
            if old.exists():
                shutil.rmtree(old)
            os.replace(final, old)      # published copy aside first, so a
        os.replace(tmp, final)          # kill here still leaves one intact
        if old is not None:
            shutil.rmtree(old, ignore_errors=True)
        self._rotate()
        self.saves += 1
        self.save_s += time.time() - t0

    def _rotate(self) -> None:
        ckpts = list_checkpoints(self.directory)
        for _, path in ckpts[:max(0, len(ckpts) - self.keep_last)]:
            shutil.rmtree(path, ignore_errors=True)

    # ------------------------------------------------------ async worker
    def _ensure_worker(self) -> None:
        if self._worker is not None and self._worker.is_alive():
            return

        def loop():
            while True:
                item = self._q.get()
                if item is None:
                    return
                try:
                    self._write(*item)
                except BaseException as e:  # surfaced on next save/wait
                    self._worker_err = e
                finally:
                    self._q.task_done()

        self._worker = threading.Thread(target=loop, daemon=True,
                                        name="checkpoint-writer")
        self._worker.start()

    def _raise_worker_error(self) -> None:
        if self._worker_err is not None:
            err, self._worker_err = self._worker_err, None
            raise CheckpointError(
                f"background checkpoint write failed: {err}") from err

    def wait(self) -> None:
        """Block until all enqueued saves are published (and re-raise any
        background write failure)."""
        if self._worker is not None:
            self._q.join()
        self._raise_worker_error()

    def close(self) -> None:
        self.wait()
        if self._worker is not None and self._worker.is_alive():
            self._q.put(None)
            self._worker.join(timeout=5.0)
        self._worker = None

    # ------------------------------------------------------------ restore
    def restore_latest(self, like=None
                       ) -> Optional[Tuple[Any, int, Dict[str, Any]]]:
        """Restore the newest valid checkpoint: ``(state, step, extra)``,
        or ``None`` when no usable checkpoint exists.  Torn/corrupt
        checkpoints are skipped (recorded in ``restore_skipped``) and the
        walk falls back to the previous one."""
        self.wait()
        for step, path in reversed(list_checkpoints(self.directory)):
            try:
                manifest = read_manifest(path)
                tree, mstep = load_checkpoint(path, like=like)
            except CheckpointError as e:
                self.restore_skipped.append(f"{path.name}: {e}")
                continue
            return tree, int(mstep), dict(manifest.get("metadata", {}))
        return None

    def latest_step(self) -> Optional[int]:
        ckpts = list_checkpoints(self.directory)
        return ckpts[-1][0] if ckpts else None

    # ---------------------------------------------------------- accounting
    def stats(self) -> Dict[str, Any]:
        return {
            "saves": self.saves,
            "save_s": round(self.save_s, 4),
            "blocked_s": round(self.blocked_s, 4),
            "async": self.async_saves,
            "keep_last": self.keep_last,
            "restore_skipped": list(self.restore_skipped),
        }
