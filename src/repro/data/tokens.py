"""Deterministic synthetic token stream for LM training examples/smokes:
a Zipf-distributed 'corpus' with Markov bigram structure so losses fall
measurably during the few-hundred-step example runs."""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, seed: int = 0, zipf_a: float = 1.2,
                 markov_states: int = 64):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        base = ranks ** (-zipf_a)
        self.base = base / base.sum()
        # a few per-state distributions (permuted base) => learnable bigrams
        self.n_states = markov_states
        self.perms = [self.rng.permutation(vocab)
                      for _ in range(markov_states)]

    def sample(self, n: int) -> np.ndarray:
        out = np.empty(n, np.int64)
        state = 0
        # vectorized in blocks for speed; state changes per block
        i = 0
        while i < n:
            blk = min(512, n - i)
            p = self.base[np.argsort(self.perms[state])]
            out[i:i + blk] = self.rng.choice(self.vocab, size=blk, p=p)
            state = int(out[i + blk - 1]) % self.n_states
            i += blk
        return out

    def batches(self, batch: int, seq: int) -> Iterator[np.ndarray]:
        while True:
            yield self.sample(batch * (seq + 1)).reshape(batch, seq + 1)


def lm_batch_iterator(vocab: int, batch: int, seq: int, seed: int = 0
                      ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yields (tokens (B,S), labels (B,S)) int32 pairs."""
    stream = TokenStream(vocab, seed)
    for arr in stream.batches(batch, seq):
        yield (arr[:, :-1].astype(np.int32), arr[:, 1:].astype(np.int32))
