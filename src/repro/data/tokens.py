"""Deterministic synthetic token stream for LM training examples/smokes:
a Zipf-distributed 'corpus' with Markov bigram structure so losses fall
measurably during the few-hundred-step example runs."""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, seed: int = 0, zipf_a: float = 1.2,
                 markov_states: int = 64):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        base = ranks ** (-zipf_a)
        self.base = base / base.sum()
        # a few per-state distributions (permuted base) => learnable bigrams
        self.n_states = markov_states
        self.perms = [self.rng.permutation(vocab)
                      for _ in range(markov_states)]

    def sample(self, n: int) -> np.ndarray:
        out = np.empty(n, np.int64)
        state = 0
        # vectorized in blocks for speed; state changes per block
        i = 0
        while i < n:
            blk = min(512, n - i)
            p = self.base[np.argsort(self.perms[state])]
            out[i:i + blk] = self.rng.choice(self.vocab, size=blk, p=p)
            state = int(out[i + blk - 1]) % self.n_states
            i += blk
        return out

    def batches(self, batch: int, seq: int) -> Iterator[np.ndarray]:
        while True:
            yield self.sample(batch * (seq + 1)).reshape(batch, seq + 1)


class SeekableTokenBatches:
    """The LM batch stream with a JSON-able cursor, so a resumed run
    consumes *exactly* the token sequence it would have seen without the
    interruption.

    The cursor captures the generator's bit-generator state plus the
    batch index; ``seek`` restores it in O(1) (no replay).  ``seek`` with
    a bare ``{"step": n}`` cursor (no rng state) falls back to
    fast-forwarding ``n`` batches from the seeded start — equivalent,
    O(n), and what ``lm_batch_iterator(start_step=...)`` uses."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.stream = TokenStream(vocab, seed)
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        self.step = 0

    def next_batch(self) -> Tuple[np.ndarray, np.ndarray]:
        arr = self.stream.sample(
            self.batch * (self.seq + 1)).reshape(self.batch, self.seq + 1)
        self.step += 1
        return arr[:, :-1].astype(np.int32), arr[:, 1:].astype(np.int32)

    def cursor(self) -> dict:
        state = self.stream.rng.bit_generator.state
        # numpy state dicts hold plain ints/strs at depth <= 2: JSON-able
        return {"step": self.step, "rng_state": state}

    def seek(self, cursor: dict) -> None:
        step = int(cursor["step"])
        if "rng_state" in cursor and cursor["rng_state"] is not None:
            self.stream = TokenStream(self.vocab, self.seed)
            self.stream.rng.bit_generator.state = cursor["rng_state"]
            self.step = step
        else:                       # replay from the seeded start
            self.stream = TokenStream(self.vocab, self.seed)
            self.step = 0
            for _ in range(step):
                self.next_batch()

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            yield self.next_batch()


def lm_batch_iterator(vocab: int, batch: int, seq: int, seed: int = 0,
                      start_step: int = 0
                      ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yields (tokens (B,S), labels (B,S)) int32 pairs.  ``start_step``
    seeks past the first N batches, yielding the same sequence a fresh
    iterator would from batch N on."""
    it = SeekableTokenBatches(vocab, batch, seq, seed)
    if start_step:
        it.seek({"step": int(start_step)})
    return iter(it)
