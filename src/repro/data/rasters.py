"""Synthetic Sentinel-2-style rasters + ground-truth polygon rasterization.

The paper's pipeline: download L2A rasters (13 bands, we synthesize the
RGB+NIR subset), rasterize CWFIS/PRODES ground-truth polygons into masks,
then normalize and chip.  Real imagery cannot ship in this repo, so
``synth_raster`` generates spatially-correlated multi-band scenes with
burn-scar/deforestation-shaped regions, and ``rasterize_polygons`` is a
real even-odd point-in-polygon rasterizer (the rasterio.rasterize
equivalent).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Scene:
    """One raster + its ground-truth mask + provenance id."""
    raster: np.ndarray        # (H, W, C) float32, reflectance-like
    mask: np.ndarray          # (H, W) uint8 {0,1}
    scene_id: str


def _stable_seed(name: str, seed: int) -> int:
    """Process-independent seed (python hash() is randomized per process)."""
    digest = hashlib.sha256(f"{name}:{seed}".encode()).digest()
    return int.from_bytes(digest[:4], "little")


def _smooth_noise(rng, h, w, octaves=4, base=16):
    """Cheap multi-octave value noise via bilinear-upsampled grids."""
    out = np.zeros((h, w), np.float32)
    amp = 1.0
    for o in range(octaves):
        gh, gw = base * (2 ** o) + 1, base * (2 ** o) + 1
        grid = rng.standard_normal((gh, gw)).astype(np.float32)
        ys = np.linspace(0, gh - 1, h)
        xs = np.linspace(0, gw - 1, w)
        y0 = np.clip(ys.astype(int), 0, gh - 2)
        x0 = np.clip(xs.astype(int), 0, gw - 2)
        fy = (ys - y0)[:, None]
        fx = (xs - x0)[None, :]
        g = (grid[y0][:, x0] * (1 - fy) * (1 - fx)
             + grid[y0 + 1][:, x0] * fy * (1 - fx)
             + grid[y0][:, x0 + 1] * (1 - fy) * fx
             + grid[y0 + 1][:, x0 + 1] * fy * fx)
        out += amp * g
        amp *= 0.5
    return out


def _band_effect(h, w, bands, *, red, rest, nir):
    """Per-band spectral shift: band 0 = red, last band = NIR (if >= 4
    bands), everything else = `rest`."""
    eff = np.full((h, w, bands), rest, np.float32)
    eff[:, :, 0] = red
    if bands >= 4:
        eff[:, :, -1] = nir
    return eff


def random_polygon(rng, center, mean_radius, n_vertices=12) -> np.ndarray:
    """Star-convex polygon around `center` (burn scars are blobby)."""
    angles = np.sort(rng.uniform(0, 2 * np.pi, n_vertices))
    radii = mean_radius * rng.uniform(0.5, 1.5, n_vertices)
    xs = center[0] + radii * np.cos(angles)
    ys = center[1] + radii * np.sin(angles)
    return np.stack([xs, ys], axis=1)


def rasterize_polygons(polygons: Sequence[np.ndarray], h: int, w: int
                       ) -> np.ndarray:
    """Even-odd point-in-polygon rasterization -> (h, w) uint8 mask.
    Vectorized per scanline over polygon edges."""
    mask = np.zeros((h, w), bool)
    xs = np.arange(w) + 0.5
    for poly in polygons:
        px, py = poly[:, 0], poly[:, 1]
        qx, qy = np.roll(px, -1), np.roll(py, -1)
        y0 = max(int(np.floor(py.min())), 0)
        y1 = min(int(np.ceil(py.max())) + 1, h)
        for row in range(y0, y1):
            yc = row + 0.5
            cond = (py <= yc) != (qy <= yc)
            if not cond.any():
                continue
            t = (yc - py[cond]) / (qy[cond] - py[cond])
            x_int = px[cond] + t * (qx[cond] - px[cond])
            # even-odd: count crossings left of each pixel center
            crossings = (x_int[None, :] > xs[:, None]).sum(axis=1)
            mask[row] |= (crossings % 2).astype(bool)
    return mask.astype(np.uint8)


def synth_raster(scene_id: str, h: int = 512, w: int = 512, bands: int = 4,
                 n_burns: Tuple[int, int] = (1, 4), seed: int = 0) -> Scene:
    """Synthesize one scene: correlated background + burn polygons that
    darken NIR / redden visible inside the mask (spectrally plausible)."""
    rng = np.random.default_rng(_stable_seed(scene_id, seed))
    base = np.stack([_smooth_noise(rng, h, w) for _ in range(bands)], -1)
    base = (base - base.min()) / (np.ptp(base) + 1e-9)
    raster = 800 + 2500 * base + rng.normal(0, 60, (h, w, bands))

    n = rng.integers(n_burns[0], n_burns[1] + 1)
    polys = [random_polygon(
        rng, center=(rng.uniform(0.15, 0.85) * w, rng.uniform(0.15, 0.85) * h),
        mean_radius=rng.uniform(0.08, 0.25) * min(h, w))
        for _ in range(n)]
    mask = rasterize_polygons(polys, h, w)

    m = mask.astype(np.float32)[..., None]
    burn_effect = _band_effect(h, w, bands, red=+400.0, rest=-300.0,
                               nir=-900.0)
    raster = raster + m * burn_effect + m * rng.normal(0, 80, (h, w, bands))
    return Scene(raster.astype(np.float32), mask, scene_id)


def synth_change_pair(scene_id: str, h: int = 256, w: int = 256,
                      bands: int = 4, seed: int = 0):
    """Deforestation pair: (before, after, change_mask) — 'after' applies
    clearing polygons to the shared background (PRODES-style)."""
    before = synth_raster(scene_id + "-t0", h, w, bands, (0, 0), seed)
    rng = np.random.default_rng(_stable_seed(scene_id + "-chg", seed))
    n = rng.integers(1, 4)
    polys = [random_polygon(
        rng, center=(rng.uniform(0.2, 0.8) * w, rng.uniform(0.2, 0.8) * h),
        mean_radius=rng.uniform(0.06, 0.2) * min(h, w)) for _ in range(n)]
    change = rasterize_polygons(polys, h, w)
    m = change.astype(np.float32)[..., None]
    effect = _band_effect(h, w, bands, red=+600.0, rest=+200.0, nir=-1200.0)
    after = before.raster + m * effect + rng.normal(0, 60, (h, w, bands))
    return before.raster, after.astype(np.float32), change
