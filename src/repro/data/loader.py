"""Chip dataset loader: shuffled, epoch-based batching over chip lists —
the asynchronous-CPU-dataloading role the paper assigns to its CPU
allocations, single-process here.  ``prefetch`` overlaps host batch
assembly with device compute via a background thread and early
``jax.device_put``."""
from __future__ import annotations

import queue
import threading
from typing import Iterator, List, Sequence, Tuple

import numpy as np

from repro.data.chipping import Chip


class ChipLoader:
    def __init__(self, chips: Sequence[Chip], batch_size: int,
                 seed: int = 0, drop_last: bool = True):
        if not chips:
            raise ValueError("empty chip set")
        self.chips = list(chips)
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self.drop_last = drop_last

    def __len__(self):
        n = len(self.chips) // self.batch_size
        if not self.drop_last and len(self.chips) % self.batch_size:
            n += 1
        return n

    def epoch(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        idx = self.rng.permutation(len(self.chips))
        bs = self.batch_size
        stop = len(idx) - (len(idx) % bs if self.drop_last else 0)
        for i in range(0, stop, bs):
            sel = idx[i:i + bs]
            imgs = np.stack([self.chips[j].image for j in sel])
            masks = np.stack([self.chips[j].mask for j in sel])
            yield imgs.astype(np.float32), masks.astype(np.int32)


def prefetch(loader, n: int = 2, device=None) -> Iterator:
    """Double-buffered prefetch: a background thread assembles the next
    ``n`` batches and stages them onto the device with an early
    ``jax.device_put``, so host batch assembly overlaps device compute —
    the async-CPU-dataloading role the paper assigns to its CPU
    allocations.

    ``loader`` is a :class:`ChipLoader` (its ``epoch()`` is consumed) or
    any iterable of pytrees of host arrays.  Yields device-resident
    batches in order; producer exceptions re-raise at the consumer.
    Closing the generator early (break / GeneratorExit) unblocks and
    stops the producer thread so queued device batches are released.
    """
    import jax

    it = loader.epoch() if hasattr(loader, "epoch") else iter(loader)
    q: "queue.Queue" = queue.Queue(maxsize=max(1, n))
    stop = threading.Event()
    END, ERR = object(), object()

    def put(item) -> bool:
        while not stop.is_set():
            try:
                q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def producer():
        try:
            for batch in it:
                staged = jax.tree.map(
                    lambda a: jax.device_put(a, device), batch)
                if not put(staged):
                    return
            put(END)
        except BaseException as e:  # surfaced on the consumer side
            put((ERR, e))

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            item = q.get()
            if item is END:
                return
            if isinstance(item, tuple) and len(item) == 2 and item[0] is ERR:
                raise item[1]
            yield item
    finally:
        stop.set()
        while not q.empty():   # drop staged batches so buffers free
            try:
                q.get_nowait()
            except queue.Empty:
                break
