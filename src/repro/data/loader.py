"""Chip dataset loader: shuffled, epoch-based batching over chip lists —
the asynchronous-CPU-dataloading role the paper assigns to its CPU
allocations, single-process here."""
from __future__ import annotations

from typing import Iterator, List, Sequence, Tuple

import numpy as np

from repro.data.chipping import Chip


class ChipLoader:
    def __init__(self, chips: Sequence[Chip], batch_size: int,
                 seed: int = 0, drop_last: bool = True):
        if not chips:
            raise ValueError("empty chip set")
        self.chips = list(chips)
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self.drop_last = drop_last

    def __len__(self):
        n = len(self.chips) // self.batch_size
        if not self.drop_last and len(self.chips) % self.batch_size:
            n += 1
        return n

    def epoch(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        idx = self.rng.permutation(len(self.chips))
        bs = self.batch_size
        stop = len(idx) - (len(idx) % bs if self.drop_last else 0)
        for i in range(0, stop, bs):
            sel = idx[i:i + bs]
            imgs = np.stack([self.chips[j].image for j in sel])
            masks = np.stack([self.chips[j].mask for j in sel])
            yield imgs.astype(np.float32), masks.astype(np.int32)
