"""Sliding-window chipping — the paper's exact recipe (Sect. II-B2):
256x256 windows with 25% overlap; keep only chips with >= `min_frac` of
BOTH classes; de-duplicate redundant chips; split train/val/test *by
raster* ("Instead of blindly splitting our dataset … we chose to split our
dataset by rasters").
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Chip:
    image: np.ndarray     # (chip, chip, C) float32
    mask: np.ndarray      # (chip, chip) uint8
    scene_id: str
    y: int
    x: int

    def content_hash(self) -> str:
        h = hashlib.sha1()
        h.update(np.ascontiguousarray(self.image).tobytes())
        h.update(np.ascontiguousarray(self.mask).tobytes())
        return h.hexdigest()


def chip_positions(h: int, w: int, chip: int, overlap: float) -> List[Tuple[int, int]]:
    stride = max(int(chip * (1 - overlap)), 1)
    ys = list(range(0, max(h - chip, 0) + 1, stride))
    xs = list(range(0, max(w - chip, 0) + 1, stride))
    if ys and ys[-1] != h - chip and h >= chip:
        ys.append(h - chip)
    if xs and xs[-1] != w - chip and w >= chip:
        xs.append(w - chip)
    return [(y, x) for y in ys for x in xs]


def make_chips(raster: np.ndarray, mask: np.ndarray, scene_id: str,
               chip: int = 256, overlap: float = 0.25,
               min_frac: float = 0.10) -> List[Chip]:
    """Both-class threshold: paper keeps chips with at least 10% burned AND
    10% unburned pixels."""
    h, w = mask.shape
    out = []
    for y, x in chip_positions(h, w, chip, overlap):
        m = mask[y:y + chip, x:x + chip]
        frac = float(m.mean())
        if frac < min_frac or frac > 1 - min_frac:
            continue
        out.append(Chip(raster[y:y + chip, x:x + chip].copy(), m.copy(),
                        scene_id, y, x))
    return out


def dedup_chips(chips: Sequence[Chip]) -> List[Chip]:
    """Paper: 'There were some redundant rasters that generated redundant
    chips. So we removed the redundant data.'"""
    seen = set()
    out = []
    for c in chips:
        hh = c.content_hash()
        if hh in seen:
            continue
        seen.add(hh)
        out.append(c)
    return out


def split_by_raster(chips: Sequence[Chip],
                    fractions=(0.68, 0.20, 0.12), seed: int = 0
                    ) -> Dict[str, List[Chip]]:
    """Raster-level split; rasters with many chips go to train/val, rasters
    with few chips to test (paper: 'use rasters with a few chips in our
    test set as this will make our test set more diverse')."""
    by_scene: Dict[str, List[Chip]] = {}
    for c in chips:
        by_scene.setdefault(c.scene_id, []).append(c)
    scenes = sorted(by_scene, key=lambda s: -len(by_scene[s]))
    total = sum(len(v) for v in by_scene.values())
    out = {"train": [], "val": [], "test": []}
    budget = {"train": fractions[0] * total, "val": fractions[1] * total}
    for s in scenes:
        cs = by_scene[s]
        if len(out["train"]) < budget["train"]:
            out["train"].extend(cs)
        elif len(out["val"]) < budget["val"]:
            out["val"].extend(cs)
        else:
            out["test"].extend(cs)
    return out


def augment_rotations(chips: Sequence[Chip],
                      angles=(90, 180)) -> List[Chip]:
    """Paper (deforestation): 'rotation augmentation at 90 and 180 degrees
    to increase dataset size'."""
    out = list(chips)
    for c in chips:
        for a in angles:
            k = a // 90
            out.append(Chip(np.rot90(c.image, k).copy(),
                            np.rot90(c.mask, k).copy(),
                            c.scene_id + f"-rot{a}", c.y, c.x))
    return out
