from repro.data.inputs import input_specs, make_batch, decode_specs

__all__ = ["input_specs", "make_batch", "decode_specs"]
