from repro.data.inputs import (SeekableSyntheticBatches, decode_specs,
                               input_specs, make_batch)

__all__ = ["input_specs", "make_batch", "decode_specs",
           "SeekableSyntheticBatches"]
