"""Model-input builders.

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins (weak-type
correct, shardable, no allocation) for the dry-run; ``make_batch`` returns
concrete random arrays for smoke tests and examples.  For the ``vlm`` and
``audio`` families the modality frontend is a stub per instructions: the
specs provide precomputed patch/frame embeddings of the right shape.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def _act_dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


def input_specs(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training/prefill input ShapeDtypeStructs for one global batch."""
    if cfg.family == "audio":
        return {
            "features": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                             _act_dtype(cfg)),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "mask": jax.ShapeDtypeStruct((batch, seq), jnp.bool_),
        }
    if cfg.family == "vlm":
        P = min(cfg.frontend_tokens, max(seq // 4, 1))
        return {
            "patches": jax.ShapeDtypeStruct((batch, P, cfg.d_model),
                                            _act_dtype(cfg)),
            "tokens": jax.ShapeDtypeStruct((batch, seq - P), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}


def decode_specs(cfg: ArchConfig, batch: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """serve_step inputs: ONE new token + absolute positions."""
    return {
        "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        "position": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def make_batch(cfg: ArchConfig, batch: int, seq: int, seed: int = 0):
    """Concrete random batch matching ``input_specs``."""
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, batch, seq)
    out = {}
    for name, s in specs.items():
        if s.dtype == jnp.int32:
            hi = cfg.vocab if name in ("tokens", "labels") else 2
            out[name] = jnp.asarray(
                rng.integers(0, hi, size=s.shape, dtype=np.int64),
                dtype=jnp.int32)
        elif s.dtype == jnp.bool_:
            out[name] = jnp.asarray(rng.random(s.shape) < 0.3)
        else:
            out[name] = jnp.asarray(
                rng.standard_normal(s.shape, dtype=np.float32),
                dtype=s.dtype)
    return out


class SeekableSyntheticBatches:
    """Step-indexed ``make_batch`` stream with a trivial cursor: batch i
    is a pure function of ``(cfg, seed + i)``, so seeking is O(1) and a
    resumed run sees the identical sequence (the multimodal counterpart
    of :class:`repro.data.tokens.SeekableTokenBatches`)."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, seed: int = 0):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        self.step = 0

    def next_batch(self):
        b = make_batch(self.cfg, self.batch, self.seq,
                       seed=self.seed + self.step)
        self.step += 1
        return b

    def cursor(self) -> dict:
        return {"step": self.step}

    def seek(self, cursor: dict) -> None:
        self.step = int(cursor["step"])

    def __iter__(self):
        while True:
            yield self.next_batch()


def make_decode_batch(cfg: ArchConfig, batch: int, position: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, size=(batch, 1)), dtype=jnp.int32),
        "position": jnp.full((batch,), position, jnp.int32),
    }
